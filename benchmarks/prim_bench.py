"""Paper Table I + Fig. 4: the PrIM suite.

Part 1 — Table I: run every workload (bank-parallel vs host oracle) at a
CPU-sized input, report correctness + host wall-clock per call.

Part 2 — Fig. 4: the calibrated cross-system comparison at paper-scale
reference inputs, with the paper's four KT4 anchors printed against the
model's geomeans (validated in tests/test_perf_model.py within tolerance).
"""

from __future__ import annotations

import time

import jax

from repro import prim
from repro.core.bank_parallel import BankGrid, make_bank_mesh
from repro.core.perf_model import Figure4, compare

SIZES = {"NW": 128, "MLP": 128, "BFS": 256, "GEMV": 512}


def run(report):
    grid = BankGrid(make_bank_mesh())
    key = jax.random.PRNGKey(0)

    report.section("Table I — PrIM workloads: bank-parallel run vs oracle")
    rows = []
    for name, mod in prim.WORKLOADS.items():
        n = SIZES.get(name, 4096)
        k = jax.random.fold_in(key, abs(hash(name)) % 997)
        inputs = (mod.make_inputs(n, k, bins=mod.BINS_L) if name == "HST-L"
                  else mod.make_inputs(n, k))
        t0 = time.perf_counter()
        got = mod.run_pim(grid, **inputs)
        jax.block_until_ready(got)
        dt_pim = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        want = mod.ref(**inputs)
        jax.block_until_ready(want)
        dt_ref = (time.perf_counter() - t0) * 1e6
        import numpy as np
        ok = all(np.array_equal(np.asarray(g), np.asarray(w))
                 for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
        rows.append({"benchmark": name, "n": n, "correct": ok,
                     "suitable(fig4)": mod.SUITABLE,
                     "us_per_call_pim": round(dt_pim, 0),
                     "us_per_call_ref": round(dt_ref, 0)})
        assert ok, name
    report.table(rows)
    report.note("wall-clock here is host-CPU (includes first-call trace); "
                "relative structure only — the cross-system numbers below "
                "are the calibrated model.")

    report.section("Fig. 4 — cross-system comparison (calibrated model, "
                   "paper-scale inputs)")
    fig = Figure4([compare(c) for c in prim.all_ref_counts()])
    report.raw(fig.render())
    report.note(f"anchors: 2556/CPU {fig.avg_speedup_2556_vs_cpu:.1f}x "
                "(paper 23.2x), 640/CPU "
                f"{fig.avg_speedup_640_vs_cpu:.1f}x (paper 10.1x), "
                f"2556/GPU suitable {fig.avg_speedup_2556_vs_gpu_suitable:.2f}x "
                "(paper 2.54x), energy-eff 640 "
                f"{fig.avg_energy_eff_640_vs_cpu:.2f}x (paper 1.64x).")
