"""Paper Fig. 2 + Fig. 3: microbenchmark characterization.

Fig. 2 — arithmetic throughput vs operational intensity:
  * UPMEM DPU curve from the calibrated instruction model (the paper's
    measured shape: compute-saturated from 0.25 op/byte, ~70 MOPS at
    1 add/int32, rising to the ~350 MOPS pipeline roof),
  * TPU v5e curve from the machine model (balance at ~240 FLOP/byte) —
    the Takeaway-1 INVERSION this framework is built around,
  * the TPU streaming kernel (kernels/microbench.py) validated against
    its oracle at every sweep point (wall-clock on this CPU container is
    not meaningful; on a v5e the same sweep measures the real curve).

Fig. 3 — per-op/dtype arithmetic throughput on one DPU (model), with the
paper's orderings asserted.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pim_model import TPU_V5E, UPMEM_2556
from repro.kernels import ops, ref


def fig2_rows():
    dpu = UPMEM_2556
    rows = []
    for k in (1, 2, 4, 8, 16, 32, 64, 128):
        oi = k / 4.0                               # int32: k adds / 4 bytes
        # DPU: pipeline model (4 bookkeeping instr + k adds per element)
        els = dpu.freq_hz / (4 + k)
        mops_dpu = k * els / 1e6
        # memory roof for reference
        roof_dpu = oi * dpu.mram_bw / 1e6
        # TPU v5e: same sweep against the machine model (VPU int roof
        # approximated at peak_flops/4 for 32-bit lanes)
        tpu_compute = TPU_V5E.peak_flops / 4
        tpu_mem = oi * TPU_V5E.hbm_bw
        gops_tpu = min(tpu_compute, tpu_mem) / 1e9
        rows.append({"oi_op_per_byte": oi, "dpu_mops": mops_dpu,
                     "dpu_mem_roof_mops": roof_dpu,
                     "dpu_bound": "compute" if mops_dpu < roof_dpu else "memory",
                     "tpu_gops": gops_tpu,
                     "tpu_bound": "compute" if tpu_compute < tpu_mem else "memory"})
    return rows


def fig3_rows():
    dpu = UPMEM_2556
    rows = []
    for dtype in ("int32", "int64", "float", "double"):
        for op in ("add", "sub", "mul", "div"):
            rows.append({"op": op, "dtype": dtype,
                         "mops_per_dpu": dpu.op_throughput(op, dtype) / 1e6})
    return rows


def run(report):
    report.section("Fig. 2 — throughput vs operational intensity "
                   "(DPU model + TPU machine model)")
    rows = fig2_rows()
    report.table(rows)
    # paper's claims, checked live
    knee = rows[0]
    assert knee["dpu_bound"] == "compute", "KT1: DPU compute-bound at OI=0.25"
    report.note("DPU is compute-bound from OI=0.25 op/B (paper KT1); "
                f"TPU stays memory-bound until ~{TPU_V5E.balance:.0f} "
                "FLOP/B — the inversion DESIGN.md §2 documents.")

    # kernel validation sweep (the TPU-side artifact)
    x = jax.random.randint(jax.random.PRNGKey(0), (1 << 16,), 0, 127,
                           jnp.int32)
    t_rows = []
    for k in (1, 4, 16):
        t0 = time.perf_counter()
        got = ops.stream_ops(x, k)
        got.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e6
        ok = bool(jnp.array_equal(got, ref.microbench_stream(x, k)))
        t_rows.append({"ops_per_elem": k, "kernel_ok": ok,
                       "us_per_call_host": round(dt, 1)})
        assert ok
    report.section("Fig. 2 kernel validation (interpret mode)")
    report.table(t_rows)

    report.section("Fig. 3 — arithmetic throughput per op/dtype "
                   "(one DPU, calibrated model)")
    rows3 = fig3_rows()
    report.table(rows3)
    by = {(r["op"], r["dtype"]): r["mops_per_dpu"] for r in rows3}
    assert by[("add", "int32")] > 5 * by[("mul", "int32")]
    assert by[("add", "int32")] > by[("add", "float")] > by[("add", "double")]
    report.note("orderings match paper Fig. 3: add/sub ~10x mul/div; "
                "int >> float >> double (KT2).")
