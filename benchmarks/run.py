"""Benchmark orchestrator — one module per paper table/figure:

  microbench        Fig. 2 (throughput vs OI), Fig. 3 (op/dtype throughput)
  prim_bench        Table I (the 16 workloads) + Fig. 4 (cross-system)
  suitability_bench §II Key Takeaways 1-3 scoring (PrIM + LM steps)
  scaling_bench     strong scaling vs #DPUs (full-paper §5.2)
  dispatch_bench    pure-CPU vs pure-PIM vs hybrid offload plans
                    (decode + chunked prefill, serial vs overlapped)
  gateway_bench     serving gateway under seeded Poisson traffic:
                    sustained req/s + tail latency, plan-cache hit
                    rate, overload goodput, paper-scale projection
  roofline_bench    §Roofline 40-cell dry-run table (from runs/*.json)

Run: PYTHONPATH=src python -m benchmarks.run [module ...] [--quick]
                                             [--trace OUT_JSON]

`--quick` runs a module's reduced smoke sweep when it offers one
(dispatch_bench: the prefill-DAG planning sweep only — the CI coverage
job's smoke). `--trace OUT_JSON` is forwarded to modules that accept a
`trace_out` parameter (dispatch_bench: records a measured execution
trace of the dispatch-backed serving run and writes it as JSON plus a
Chrome trace_event twin, DESIGN.md §13).
"""

from __future__ import annotations

import inspect
import sys
import time


class Report:
    """Plain-text table/section sink (markdown-ish, CSV-friendly)."""

    def section(self, title: str):
        print(f"\n## {title}\n")

    def note(self, text: str):
        print(f"  NOTE: {text}")

    def raw(self, text: str):
        print(text)

    def table(self, rows: list[dict]):
        if not rows:
            print("  (empty)")
            return
        cols = list(rows[0].keys())
        print("| " + " | ".join(cols) + " |")
        print("|" + "|".join("---" for _ in cols) + "|")
        for r in rows:
            print("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")


def main(argv=None) -> int:
    from . import (dispatch_bench, gateway_bench, microbench, prim_bench,
                   roofline_bench, scaling_bench, suitability_bench)
    modules = {
        "microbench": microbench,
        "prim_bench": prim_bench,
        "suitability_bench": suitability_bench,
        "scaling_bench": scaling_bench,
        "dispatch_bench": dispatch_bench,
        "gateway_bench": gateway_bench,
        "roofline_bench": roofline_bench,
    }
    args = list(argv or sys.argv[1:])
    quick = "--quick" in args
    trace_out = None
    if "--trace" in args:
        i = args.index("--trace")
        if i + 1 >= len(args) or args[i + 1].startswith("--"):
            print("error: --trace needs an output path", file=sys.stderr)
            return 2
        trace_out = args[i + 1]
        del args[i:i + 2]
    names = [a for a in args if not a.startswith("--")] or list(modules)
    report = Report()
    t0 = time.perf_counter()
    failed = []
    for name in names:
        print(f"\n{'=' * 72}\n= benchmarks.{name}\n{'=' * 72}")
        try:
            run_fn = modules[name].run
            params = inspect.signature(run_fn).parameters
            kw = {}
            if "quick" in params:
                kw["quick"] = quick
            if "trace_out" in params:
                kw["trace_out"] = trace_out
            run_fn(report, **kw)
        except Exception:  # keep the harness going, report at end
            import traceback
            traceback.print_exc()
            failed.append(name)
    print(f"\n{'=' * 72}")
    print(f"done in {time.perf_counter() - t0:.1f}s; "
          f"{len(names) - len(failed)}/{len(names)} benchmark modules ok"
          + (f"; FAILED: {failed}" if failed else ""))
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
