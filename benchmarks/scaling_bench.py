"""Strong scaling with DPU count (full paper §5.2): fixed paper-scale
inputs, system size swept 64 -> 2556 DPUs through the calibrated model.
Reproduces the paper's scaling observations: streaming workloads scale
near-linearly until the launch overhead floor; inter-DPU-bound workloads
(BFS, NW, MLP) saturate early because the host channel does not scale
(Takeaway 3)."""

from __future__ import annotations

import dataclasses

from repro import prim
from repro.core.perf_model import time_on_pim
from repro.core.pim_model import UPMEM_2556

DPUS = (64, 160, 320, 640, 1280, 2556)


def run(report):
    report.section("Strong scaling vs #DPUs (calibrated model, "
                   "time normalized to 64 DPUs)")
    rows = []
    for name, mod in prim.WORKLOADS.items():
        c = mod.counts_l(mod.REF_N) if name == "HST-L" \
            else mod.counts(mod.REF_N)
        t64 = None
        row = {"benchmark": name}
        for n in DPUS:
            dpu = dataclasses.replace(UPMEM_2556, n_dpus=n)
            t = time_on_pim(c, dpu).total_s
            t64 = t64 or t
            row[f"{n}"] = round(t64 / t, 2)
        row["ideal_2556"] = round(2556 / 64, 1)
        rows.append(row)
    report.table(rows)
    # the paper's qualitative split, asserted
    by = {r["benchmark"]: r["2556"] for r in rows}
    assert by["VA"] > 10.0, by["VA"]           # streaming: scales
    assert by["BFS"] < 3.0, by["BFS"]          # host-channel bound (KT3)
    assert by["NW"] < by["RED"]                # wavefront < local reduce
    report.note("streaming workloads scale with DPUs until the launch "
                "overhead floor; BFS/NW/MLP saturate early — their "
                "inter-DPU traffic rides the fixed host channel (KT3).")
