"""Key Takeaways 1-3 as a benchmark: score compiled workloads on both
machines (paper §II; core/suitability.py).

Scores (a) the PrIM reference kernels against the UPMEM machine — the
paper's own suitability verdicts — and (b) the LM serving/training steps of
a reduced arch against the TPU machine, showing the framework's thesis:
decode is the PIM-suitable stage (memory-bound GEMV), train/prefill are
compute-bound (DESIGN.md §4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import prim
from repro.configs import REDUCED
from repro.configs.shapes import ShapeConfig
from repro.core.hlo_analysis import analyze_hlo
from repro.core.suitability import score
from repro.models import Shardings, forward, init_cache, init_params
from repro.train import DataConfig, HParams, adamw_init, make_batch, \
    make_train_step


def _score_fn(fn, args, name, machine):
    compiled = jax.jit(fn).lower(*args).compile()
    an = analyze_hlo(compiled.as_text(), trip_count_fallback=4)
    return score(an, name=name, machine=machine)


def run(report):
    key = jax.random.PRNGKey(0)

    report.section("PrIM kernels scored on the UPMEM machine (KT1-3)")
    rows = []
    for name in ("VA", "GEMV", "SpMV", "BS", "RED", "SCAN-SSA", "TRNS",
                 "TS", "HST-S"):
        mod = prim.WORKLOADS[name]
        inputs = mod.make_inputs(4096, key)
        # non-array params (e.g. HST's bin count) are static, not traced
        import functools
        static = {k: v for k, v in inputs.items() if isinstance(v, int)}
        arrays = [v for v in inputs.values() if not isinstance(v, int)]
        fn = functools.partial(mod.ref, **static) if static else mod.ref
        rep = _score_fn(lambda *a: fn(*a), arrays, name, "upmem_2556")
        rows.append({"workload": name,
                     "OI(F/B)": round(rep.operational_intensity, 3),
                     "KT1 mem-bound": rep.memory_bound,
                     "KT2 simple-ops": rep.simple_ops,
                     "KT3 low-comm": rep.low_comm,
                     "PIM-suitable": rep.pim_suitable})
    report.table(rows)

    report.section("LM steps scored on the TPU machine (the decode thesis)")
    cfg = REDUCED["granite-3-8b"]
    shd = Shardings(None)
    params = init_params(key, cfg, shd)
    rows = []

    # train step
    shape = ShapeConfig("b", 64, 4, "train")
    batch = make_batch(cfg, shape, 0, DataConfig())
    opt = adamw_init(params, cfg)
    step = make_train_step(cfg, shd, HParams())
    rep = _score_fn(step, (params, opt, batch), "train_step", "tpu_v5e")
    rows.append({"step": "train", "OI(F/B)": round(rep.operational_intensity, 1),
                 "mem-bound": rep.memory_bound,
                 "balance": round(rep.machine_balance, 1)})

    # prefill
    cache = init_cache(cfg, 4, 128, shd)
    toks = jnp.ones((4, 64), jnp.int32)
    rep = _score_fn(
        lambda p, c, t: forward(p, cfg, shd, tokens=t, cache=c)[0],
        (params, cache, toks), "prefill", "tpu_v5e")
    rows.append({"step": "prefill", "OI(F/B)": round(rep.operational_intensity, 1),
                 "mem-bound": rep.memory_bound,
                 "balance": round(rep.machine_balance, 1)})

    # decode
    tok1 = jnp.ones((4, 1), jnp.int32)
    rep = _score_fn(
        lambda p, c, t: forward(p, cfg, shd, tokens=t, cache=c)[0],
        (params, cache, tok1), "decode", "tpu_v5e")
    rows.append({"step": "decode", "OI(F/B)": round(rep.operational_intensity, 1),
                 "mem-bound": rep.memory_bound,
                 "balance": round(rep.machine_balance, 1)})
    report.table(rows)
    assert rows[-1]["mem-bound"], "decode must be memory-bound (the thesis)"
    report.note("decode sits far below the TPU balance point (a batched "
                "GEMV — PrIM's GEMV pattern), which is why the serving path "
                "uses the bank-parallel weight-stationary layout.")
