"""§Roofline summary: the 40-cell dry-run roofline table.

Reads the dry-run artifacts (runs/dryrun_single*.json, written by
``python -m repro.launch.dryrun``) and renders the per-cell three-term
roofline. If the artifacts are missing it says how to produce them instead
of spending ~10 minutes compiling here (the dry-run needs the 512-device
env var that must not leak into this process)."""

from __future__ import annotations

import glob
import json
import os


def run(report):
    paths = sorted(glob.glob("runs/dryrun_single*.json"))
    if not paths:
        report.note("no dry-run artifacts under runs/; generate with:\n"
                    "  PYTHONPATH=src python -m repro.launch.dryrun "
                    "--arch all --shape all --mesh both --out "
                    "runs/dryrun_single.json")
        return
    path = paths[-1]
    with open(path) as f:
        records = json.load(f)
    report.section(f"Roofline (single-pod 16x16), from {path}")
    rows = []
    for r in records:
        if r.get("status") == "skip":
            rows.append({"cell": f'{r["arch"]}/{r["shape"]}',
                         "dominant": "SKIP", "compute_s": "-",
                         "memory_s": "-", "collective_s": "-",
                         "roofline_frac": r.get("reason", "")[:40]})
            continue
        if r.get("status") != "ok":
            rows.append({"cell": f'{r["arch"]}/{r["shape"]}',
                         "dominant": "FAIL", "compute_s": "-",
                         "memory_s": "-", "collective_s": "-",
                         "roofline_frac": r.get("error", "")[:40]})
            continue
        rf = r["roofline"]
        rows.append({"cell": rf["name"], "dominant": rf["dominant"],
                     "compute_s": f'{rf["compute_s"]:.3f}',
                     "memory_s": f'{rf["memory_s"]:.3f}',
                     "collective_s": f'{rf["collective_s"]:.4f}',
                     "roofline_frac": f'{rf["roofline_fraction"]:.3f}',
                     "mem_roof_frac": f'{rf.get("memory_roof_fraction", 0):.3f}'})
    report.table(rows)
    ok = [r for r in records if r.get("status") == "ok"]
    report.note(f"{len(ok)} compiled cells, "
                f"{sum(1 for r in records if r.get('status') == 'skip')} "
                "documented skips. Full records (memory_analysis, "
                "collective schedule, guidance) in the JSON.")
