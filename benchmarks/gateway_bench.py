"""Serving-gateway benchmark: continuous batching under Poisson traffic.

The ROADMAP's "millions of users" number, measured and modeled through
`repro.serve.gateway` (DESIGN.md §14). Six sections:

  1. Steady-state throughput (reduced scale, MEASURED wall clock): a
     seeded Poisson arrival stream through `Gateway` over the fused-jit
     engine at a sustainable rate — sustained requests/s, p50/p99 TTFT
     and inter-token latency, goodput.
  2. Plan-cache amortization under batch-signature churn: a long
     deterministic run whose admissions/evictions churn the live-slot
     count and position buckets; ASSERTS >80% plan-cache hit rate at
     steady state (the ISSUE-7 acceptance gate) and reports the planner
     solves amortized away.
  3. Overload and goodput: offered load far above capacity against a
     bounded queue with the shed policy — per-priority-class completion
     and rejection, goodput vs offered, interactive-vs-batch tail
     latency (the reject/shed policy at work).
  4. Budget/EOS admission gate: a budget-1 request produces EXACTLY one
     token on the fused AND dispatch engines (the ISSUE-7 bugfix
     acceptance; before the fix admit() always entered decode and
     over-generated).
  5. Paper-scale projection (MODELED): decode/prefill DAGs priced at
     paper dims (4k d_model / 32 layers / 2556-DPU grid) through the
     same `PlanCache` keying, swept over batch sizes — modeled tokens/s,
     sustained requests/s, and requests/day (the "millions of users"
     statement, stated honestly as a cost-model projection).
  6. Dispatch-engine gateway + measured trace: the gateway drives the
     planner-routed engine with a tracer attached; the planner-fidelity
     gate replays the gateway-driven decode timeline (predicted
     `pipelined_s` within 10% of the replayed trace) and `--trace
     OUT_JSON` exports the trace plus its Chrome trace_event twin.

`run(report, quick=True)` (CI's `benchmarks.run gateway_bench --quick`)
keeps sections 2-4 and 6 at reduced request counts — the acceptance
asserts all still run.
"""

from __future__ import annotations

import dataclasses
import importlib
import time

from repro.dispatch import PlanCache, batch_signature, workloads
from repro.dispatch import trace as dtrace
from repro.dispatch.placement import plan as plan_placement
from repro.dispatch.schedule import make_schedule


def _setup(cfg_name="granite-3-8b"):
    import jax
    from repro.configs import REDUCED
    from repro.models import Shardings, init_params
    cfg = REDUCED[cfg_name]
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    return cfg, shd, params


def _class_rows(gw):
    """Per-priority-class outcome rows for one finished gateway."""
    from repro.serve import PRIORITIES, percentile
    rows = []
    for p, name in enumerate(PRIORITIES):
        done = [g for g in gw.finished if g.priority == p]
        rej = [g for g in gw.rejected if g.priority == p]
        ttfts = sorted(g.ttft_s for g in done if g.ttft_s is not None)
        rows.append({"class": name, "completed": len(done),
                     "rejected": len(rej),
                     "shed": sum(1 for g in rej
                                 if g.reject_reason == "shed"),
                     "TTFT p50 ms":
                         round(percentile(ttfts, 50) * 1e3, 2),
                     "TTFT p99 ms":
                         round(percentile(ttfts, 99) * 1e3, 2)})
    return rows


def _steady_state(report, cfg, shd, params, n_requests):
    """Section 1: measured wall-clock serving under seeded Poisson."""
    from repro.serve import Gateway, ServeEngine, poisson_requests
    report.section("Steady-state serving under seeded Poisson "
                   "(reduced scale, measured wall clock)")
    import jax.numpy as jnp
    from repro.serve import Request
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=64, shd=shd)
    # warm the jit caches — the decode step plus ONE prefill trace per
    # distinct prompt length in the sweep — so the measured run prices
    # steady-state serving, not XLA compiles
    for i, plen in enumerate(range(4, 9)):
        eng.serve([Request(-1 - i, jnp.ones((plen,), jnp.int32), 2)])
    gw = Gateway(eng, queue_capacity=n_requests + 1, pos_bucket=16,
                 slo_ttft_s=0.5, slo_itl_s=0.25)
    # prewarm the plan cache out of band (cold DAG builds are ~100s of
    # ms each — in-band misses would stall every live slot's next token)
    t0 = time.perf_counter()
    warm = gw.prewarm(range(4, 9))
    report.note(f"plan-cache prewarm: {warm['misses']} signature solves "
                f"in {time.perf_counter() - t0:.1f}s before traffic")
    reqs = poisson_requests(n_requests, 8.0, seed=7,
                            vocab=cfg.vocab_size, prompt_lens=(4, 8),
                            max_new=(4, 10))
    stats = gw.run(reqs)
    report.table([dict((k, v) for k, v in stats.rows())])
    assert stats.completed == n_requests, "steady-state run dropped work"
    report.note(f"fused-jit engine, 4 slots: {stats.sustained_rps:.1f} "
                f"sustained req/s at p99 TTFT "
                f"{stats.ttft_p99_s * 1e3:.1f}ms / p99 ITL "
                f"{stats.itl_p99_s * 1e3:.1f}ms (CPU-JAX wall clock; "
                "paper-scale projection in the modeled section)")
    return stats


def _churn_sweep(report, cfg, shd, params, n_requests):
    """Section 2: the plan-cache hit-rate gate under signature churn."""
    from repro.serve import Gateway, ManualClock, ServeEngine, \
        poisson_requests
    report.section("Plan-cache amortization under batch-signature churn")
    eng = ServeEngine(cfg, params, batch_slots=4, max_len=64, shd=shd)
    gw = Gateway(eng, queue_capacity=n_requests + 1, pos_bucket=8,
                 clock=ManualClock(tick=1e-4))
    reqs = poisson_requests(n_requests, 100.0, seed=11,
                            vocab=cfg.vocab_size, prompt_lens=(3, 10),
                            max_new=(2, 12))
    stats = gw.run(reqs)
    pc = stats.plan_cache
    report.table([{"requests": stats.completed, "steps": stats.steps,
                   "planner calls": pc["calls"], "hits": pc["hits"],
                   "solves (misses)": pc["misses"],
                   "hit rate": f"{pc['hit_rate']:.1%}"}])
    # ISSUE-7 acceptance: >80% of planner consults served from cache at
    # steady state even though every admission/eviction and every
    # position-bucket crossing changes the batch signature
    assert pc["hit_rate"] > 0.80, \
        f"plan-cache hit rate {pc['hit_rate']:.1%} <= 80% on churn sweep"
    report.note(f"pos_bucket=8 over a 4-slot engine: {pc['misses']} "
                f"planner solves serve {pc['calls']} consults — "
                "replanning amortizes exactly like FaceCache compiles")
    return stats


def _overload(report, cfg, shd, params, n_requests):
    """Section 3: bounded queue + shed policy under 5x overload."""
    from repro.serve import Gateway, ManualClock, ServeEngine, \
        poisson_requests
    report.section("Overload: bounded queue, shed policy, goodput")
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, shd=shd)
    gw = Gateway(eng, queue_capacity=3, shed_policy="shed",
                 pos_bucket=16, clock=ManualClock(tick=2e-3),
                 slo_ttft_s=0.15)
    reqs = poisson_requests(n_requests, 2000.0, seed=13,
                            vocab=cfg.vocab_size, prompt_lens=(4, 8),
                            max_new=(4, 8))
    stats = gw.run(reqs)
    report.table([dict((k, v) for k, v in stats.rows())])
    report.table(_class_rows(gw))
    assert stats.rejected > 0, "overload run never rejected"
    assert stats.completed + stats.rejected == stats.offered
    report.note("a near-simultaneous burst against one slot and a "
                "3-deep queue: the bounded queue sheds lowest-priority "
                "work, goodput counts only requests that met the 150ms "
                "TTFT SLO")
    return stats


def _budget_gate(report, cfg, shd, params, dis_eng):
    """Section 4: budget-1 yields exactly 1 token on both engines."""
    import jax.numpy as jnp
    from repro.serve import Request, ServeEngine
    report.section("Budget/EOS admission gate (budget-1 == 1 token)")
    rows = []
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,
                          shd=shd)
    for engine, eng in (("jit", jit_eng), ("dispatch", dis_eng)):
        req = Request(0, jnp.asarray([3, 1, 4, 1, 5], jnp.int32),
                      max_new_tokens=1)
        assert eng.admit(req), "free engine refused admission"
        # ISSUE-7 acceptance: exactly one token, finished at admit, the
        # slot never entered decode
        assert req.done and len(req.out_tokens) == 1, \
            f"{engine}: budget-1 produced {len(req.out_tokens)} tokens"
        assert eng.n_free == 2, f"{engine}: budget-1 held a slot"
        rows.append({"engine": engine, "tokens": len(req.out_tokens),
                     "done at admit": req.done,
                     "slot freed": eng.n_free == 2})
    report.table(rows)


def _paper_projection(report):
    """Section 5: the modeled 'millions of users' statement."""
    report.section("Paper-scale projection (modeled, 2556-DPU grid)")
    cache = PlanCache()
    base = workloads.DecodeDims()          # 4k d_model / 32 layers
    avg_new, prompt_len, chunk = 256, 2048, 512

    def price_decode(nb):
        key = batch_signature(nb, (base.seq - 1,), pos_bucket=256)
        def build():
            dims = dataclasses.replace(base, batch=nb)
            dag = workloads.decode_dag(dims)
            p = plan_placement(dag)
            return make_schedule(dag, p, pipelined=True).pipelined_s
        return cache.get_or_plan(key, build)

    splits = workloads.prefill_chunk_splits(prompt_len, chunk)
    pkey = batch_signature(1, splits=splits, phase="prefill")
    def build_prefill():
        dag = workloads.prefill_dag(base, prefill_len=prompt_len,
                                    chunk=chunk, batch=1)
        p = plan_placement(dag, objective="overlapped")
        return make_schedule(dag, p, pipelined=True).pipelined_s
    prefill_s = cache.get_or_plan(pkey, build_prefill)

    fleet_ranks = 256
    rows = []
    best_daily = 0.0
    for nb in (1, 8, 32, 64):
        step_s = price_decode(nb)
        tok_s = nb / step_s
        # depth-first admission: a request costs its prefill plus
        # avg_new decode-step shares of the batch
        req_s = nb / (avg_new * step_s + prefill_s)
        daily = req_s * 86_400
        best_daily = max(best_daily, daily)
        rows.append({"batch slots": nb,
                     "decode step ms": round(step_s * 1e3, 1),
                     "tokens/s": round(tok_s, 1),
                     "req/s (256 new, 2k prompt)": round(req_s, 3),
                     "req/day/rank": f"{daily:,.0f}",
                     f"req/day x{fleet_ranks} ranks":
                         f"{daily * fleet_ranks:,.0f}"})
    report.table(rows)
    # the "millions of users" statement, stated honestly: one
    # host+2556-DPU rank serves thousands of long-form requests/day
    # (the un-quantized host GEMVs dominate the modeled step — the KT2
    # quantization item on the ROADMAP is what lifts this); a
    # 256-rank fleet clears a million requests/day
    assert best_daily * fleet_ranks > 1e6, \
        "paper-scale fleet projection under 1M req/day"

    # ISSUE-9: the x256 column above is the NAIVE multiplier — 256
    # independent ranks, each with a dedicated full-bandwidth host
    # channel. The honest fleet packs ranks 4-per-host (the Topology
    # model's rank-parallel channels): each rank keeps its own transfer
    # channel, but the pod's concurrent streams divide the host's DRAM
    # fabric, so each rank's decode/prefill timeline is REPLAYED under a
    # what-if system with 1/ranks_per_host of the channel bandwidth
    # (`trace.replay.what_if`) and the fleet is re-priced from that
    # modeled multi-rank throughput. Both numbers are reported.
    rp = importlib.import_module("repro.dispatch.trace.replay")
    ranks_per_host = 4
    wi = rp.what_if(channel_scale=1.0 / ranks_per_host)
    nb = 64
    dims = dataclasses.replace(base, batch=nb)
    dag = workloads.decode_dag(dims)
    p = plan_placement(dag)
    dstep_s = rp.replay(rp.modeled_trace(dag, p), dag, p.assignment,
                        dpu=wi).total_s
    pdag = workloads.prefill_dag(base, prefill_len=prompt_len,
                                 chunk=chunk, batch=1)
    pp = plan_placement(pdag, objective="overlapped")
    pstep_s = rp.replay(rp.modeled_trace(pdag, pp), pdag, pp.assignment,
                        dpu=wi).total_s
    rank_req_s = nb / (avg_new * dstep_s + pstep_s)
    fleet_daily = rank_req_s * fleet_ranks * 86_400
    naive_daily = best_daily * fleet_ranks
    # stress row: all 256 ranks on ONE host fabric — where the dedicated-
    # channel assumption finally breaks and transfers surface past compute
    stress_s = rp.replay(rp.modeled_trace(dag, p), dag, p.assignment,
                         dpu=rp.what_if(
                             channel_scale=1.0 / fleet_ranks)).total_s
    stress_daily = (nb / (avg_new * stress_s + pstep_s)) \
        * fleet_ranks * 86_400
    report.table([
        {"fleet model": f"naive x{fleet_ranks} (dedicated channels)",
         "decode step ms": round(price_decode(nb) * 1e3, 1),
         "req/day fleet": f"{naive_daily:,.0f}",
         "vs naive": "1.00x"},
        {"fleet model": (f"{fleet_ranks // ranks_per_host} hosts x "
                         f"{ranks_per_host} ranks (what-if replay, "
                         f"channels /{ranks_per_host})"),
         "decode step ms": round(dstep_s * 1e3, 1),
         "req/day fleet": f"{fleet_daily:,.0f}",
         "vs naive": f"{fleet_daily / naive_daily:.2f}x"},
        {"fleet model": (f"stress: {fleet_ranks} ranks, one fabric "
                         f"(channels /{fleet_ranks})"),
         "decode step ms": round(stress_s * 1e3, 1),
         "req/day fleet": f"{stress_daily:,.0f}",
         "vs naive": f"{stress_daily / naive_daily:.2f}x"},
    ])
    assert fleet_daily > 1e6, \
        "modeled multi-rank fleet projection under 1M req/day"
    assert fleet_daily <= naive_daily * (1 + 1e-9) and \
        stress_daily <= fleet_daily * (1 + 1e-9), \
        "channel contention cannot beat dedicated channels"
    report.note(f"modeled hybrid plans (planner ladder, seconds): one "
                f"2556-DPU rank sustains ~{best_daily:,.0f} long-form "
                f"requests/day at 64 slots; the re-priced "
                f"{fleet_ranks}-rank fleet (pods of {ranks_per_host} "
                "ranks sharing a host fabric, per-rank timelines "
                "replayed under the contended what-if channels) "
                f"clears ~{fleet_daily / 1e6:.1f}M requests/day — "
                "millions of daily users at ~1 request each. The "
                "pod-contended replay matches the dedicated-channel "
                "step: the dense decode timeline is host-GEMV-bound "
                "(KT2) and its transfers stay hidden under compute even "
                f"at 1/{ranks_per_host} bandwidth — the stress row "
                "shows channel contention only surfaces when the whole "
                "fleet shares one fabric. Projection only (no UPMEM "
                "hardware here); the same cost model the fidelity gate "
                "pins within 10% of replayed traces at reduced scale. "
                "The quantized MoE projection below is the int8 "
                "expert/KV lever that shrinks the host-bound step")

    # the KT2 flip through the same PlanCache keying: the quantized MoE
    # serving step (int8 expert GEMMs on the DPU 8x8-multiplier band,
    # int8 KV) vs its f32 twin at mixtral-8x7b dims — the sustained-req/s
    # delta the ISSUE-8 flip buys a serving rank
    report.section("Quantized MoE projection (int8 experts + int8 KV "
                   "vs f32, mixtral-8x7b dims)")
    moe32 = workloads.MOE_PAPER_DIMS
    moe8 = workloads.MOE_PAPER_DIMS_INT8

    def price_moe_decode(dims, nb, tag):
        key = batch_signature(nb, (dims.seq - 1,), pos_bucket=256,
                              phase=f"moe-decode-{tag}")
        def build():
            dd = dataclasses.replace(dims, batch=nb)
            dag = workloads.moe_decode_dag(dd)
            p = plan_placement(dag)
            return make_schedule(dag, p, pipelined=True).pipelined_s
        return cache.get_or_plan(key, build)

    def price_moe_prefill(dims, tag):
        key = batch_signature(1, splits=splits, phase=f"moe-prefill-{tag}")
        def build():
            dag = workloads.prefill_dag(dims, prefill_len=prompt_len,
                                        chunk=chunk, batch=1)
            p = plan_placement(dag, objective="overlapped")
            return make_schedule(dag, p, pipelined=True).pipelined_s
        return cache.get_or_plan(key, build)

    pf32 = price_moe_prefill(moe32, "f32")
    pf8 = price_moe_prefill(moe8, "int8")
    rows = []
    for nb in (8, 32):
        s32 = price_moe_decode(moe32, nb, "f32")
        s8 = price_moe_decode(moe8, nb, "int8")
        r32 = nb / (avg_new * s32 + pf32)
        r8 = nb / (avg_new * s8 + pf8)
        # ISSUE-8 acceptance: the quantized configuration sustains
        # strictly more requests/s at every projected batch size
        assert r8 > r32, \
            f"int8 MoE projection no faster than f32 at batch {nb}"
        rows.append({"batch slots": nb,
                     "f32 step ms": round(s32 * 1e3, 1),
                     "int8 step ms": round(s8 * 1e3, 1),
                     "f32 req/s": round(r32, 3),
                     "int8 req/s": round(r8, 3),
                     "sustained req/s delta":
                         f"+{(r8 / r32 - 1) * 100:.0f}%"})
    report.table(rows)
    report.note("the KT2 flip in serving terms: int8 expert FFNs plan "
                "onto the DPU grid (2-cycle native 8x8 muls) and the "
                "int8 KV cache quarters the bank-resident attention "
                "stream, so each decode step shrinks and the same rank "
                "sustains the req/s delta above at identical batch "
                "shapes")


def _dispatch_trace(report, cfg, eng, n_requests, trace_out):
    """Section 6: gateway-driven dispatch engine, fidelity-gated trace."""
    from repro.serve import Gateway, ManualClock, poisson_requests
    report.section("Dispatch-engine gateway, measured trace + "
                   "fidelity gate")
    tracer = dtrace.Trace("gateway-dispatch",
                          meta={"engine": "dispatch", "slots": 2})
    gw = Gateway(eng, queue_capacity=n_requests + 1, pos_bucket=16,
                 clock=ManualClock(tick=1e-3))
    gw.attach_tracer(tracer)
    reqs = poisson_requests(n_requests, 100.0, seed=17,
                            vocab=cfg.vocab_size, prompt_lens=(3, 8),
                            max_new=(3, 6))
    stats = gw.run(reqs)
    rep = dtrace.fidelity(eng._decode.dag, eng._decode.plan,
                          trace=tracer)
    report.table([{"requests": stats.completed, "steps": stats.steps,
                   "decode spans": len(tracer.by_kind("decode_step")),
                   "prefill spans": len(tracer.by_kind("prefill_step")),
                   "executor-cache hit rate":
                       f"{eng._prefill_step.executor_cache.stats['hit_rate']:.1%}",
                   "fidelity err %": round(rep.rel_err * 100.0, 2)}])
    # the planner-fidelity gate on a GATEWAY-driven timeline: predicted
    # pipelined_s within 10% of the replayed measured trace
    assert rep.ok, rep.render()
    if trace_out:
        tracer.save(trace_out)
        chrome = trace_out.replace(".json", "") + ".chrome.json"
        tracer.save_chrome(chrome)
        report.note(f"gateway trace -> {trace_out} (+ Chrome twin "
                    f"{chrome})")
    report.note(rep.render())


def run(report, quick: bool = False, trace_out: str | None = None):
    """Drive the gateway sweeps; `quick` keeps sections 2-4 and 6 at
    reduced request counts (CI smoke), full mode adds the measured
    steady-state section and the paper-scale projection."""
    from repro.serve import ServeEngine
    cfg, shd, params = _setup()
    if not quick:
        _steady_state(report, cfg, shd, params, n_requests=24)
    _churn_sweep(report, cfg, shd, params,
                 n_requests=10 if quick else 40)
    _overload(report, cfg, shd, params, n_requests=8 if quick else 30)
    # one dispatch engine shared by the budget gate and the traced run
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,
                          shd=shd, engine="dispatch",
                          dispatch_kwargs={"prefill_chunk": 4})
    _budget_gate(report, cfg, shd, params, dis_eng)
    if not quick:
        _paper_projection(report)
    _dispatch_trace(report, cfg, dis_eng,
                    n_requests=3 if quick else 6, trace_out=trace_out)
