"""Offload planning benchmark: pure-CPU vs pure-PIM vs hybrid plans.

Three sweeps over `repro.dispatch`:

  1. The 16 PrIM workloads at Fig.-4 granularity (one operator each):
     the planner's per-workload device pick vs the paper's suitability
     grouping — the hybrid (CPU+GPU+PIM) device choice recovers the
     group-2 workloads that pure PIM loses.
  2. The mixed PrIM pipeline (streaming -> transpose/rotate -> streaming):
     the DP plan beats BOTH pure placements by running the streams
     bank-parallel and handing the reorganization to the host.
  3. The LM decode step (serve.engine's workload) at paper scale: weight
     GEMVs on the host (float mul is a software routine on DPUs, KT2),
     quantized KV-cache attention bank-parallel (streaming int dots, KT1).
  4. The decode DAG (residual branches kept, KV-residency charged): the
     exact frontier-DP plan must beat both steelmanned pure baselines
     (pure CPU gets KV homed on the host) — the ISSUE-2 acceptance gate.
  5. The chunked prefill DAG (4 chunks at paper scale): serial- vs
     overlapped-objective plans, and the cross-phase residency trade —
     keeping the cache bank-resident for decode costs prefill only the
     KV write-back traffic (ISSUE-3). The same sweep prices the OLD
     serial chunk loop (chunk-major order, groups strictly serialized)
     against the unified executor's pipelined timeline
     (`Schedule.pipelined_s`) and asserts the pipelined discipline
     strictly beats the loop's throughput at paper scale (ISSUE-4).
  6. The MoE decode DAG at paper scale (mixtral-8x7b dims: 8 experts
     top-2, routed ladder per layer with token/combine EXCHANGE edges):
     the hybrid plan must strictly beat steelmanned pure CPU (KV
     re-homed to the host) and pure PIM (KV at home, but float expert
     GEMMs + two host-relayed all-to-alls per layer — the shape the
     architecture is worst at, KT3) — the ISSUE-5 acceptance gate.
  7. The QUANTIZED MoE decode DAG (int8 experts + int8 KV): the KT2
     flip — every expert FFN plans onto the DPU grid and the quantized
     hybrid strictly beats the f32 hybrid (ISSUE-8).
  8. Multi-rank scale-out (ISSUE-9): the 4-rank expert-parallel plan of
     the quantized mixtral DAG (expert shards rotated over
     rank-qualified devices, one transfer channel per rank) must
     strictly beat the SAME sharded plan behind a single channel on the
     pipelined wall-clock AND survive the per-rank replay fidelity
     gate; plus cross-step pipelining — the 2-step scoring DAG beats 2x
     the single-step wall-clock by overlapping across the step boundary.
  9. Long-context sliding-window attention (ISSUE-10): the SAME model
     priced windowed (32k prompt, 4k ring-buffer window) vs as its
     full-attention twin — the windowed decode plan (ring-sized KV
     protos/migration) and the BANDED prefill DAG (dead cross-chunk KV
     edges dropped) must each STRICTLY beat the full-attention plan,
     with replay error through the fidelity gate.

Every sweep row also reports the planner-fidelity round trip
(`replay err %`): the plan's predicted `pipelined_s` against the
re-priced replay of its own modeled execution trace
(`repro.dispatch.trace`, DESIGN.md §13).

Finally the reduced-scale pipelines are actually executed through
`dispatch.runtime` — and dispatch-backed `ServeEngine` runs (dense
decode at the default dtype, MoE decode on the f32 mixtral-reduced
model) are checked token-identical against the fused-jit engine. A
closing section records a MEASURED trace of the dispatch serving
decode step, reports the tracing overhead against the ISSUE-6 <5%
budget, gates the planner's prediction against the replayed trace,
and (with `--trace OUT_JSON`) exports the trace plus its Chrome
trace_event twin.

`run(report, quick=True)` (the CI coverage job's
`python -m benchmarks.run dispatch_bench --quick`) runs only a reduced
prefill-DAG sweep plus a reduced MoE sweep: DAG build, both planner
objectives, the overlapped<=serial gate, the pure-baseline comparison,
the serial-chunk-loop vs pipelined-executor timeline comparison, the
MoE exchange bookkeeping asserts, and the reduced-dims sliding-window
sweep (the sweep-9 inequalities at window 8).
"""

from __future__ import annotations

from repro import prim
from repro.dispatch import trace as dtrace
from repro.dispatch import workloads
from repro.dispatch.placement import (compare_plans, node_time, plan,
                                      pure_plan)
from repro.dispatch.schedule import make_schedule


def _replay_err(graph, p):
    """Predicted-vs-replayed relative error (%) for one plan row: the
    plan's predicted `pipelined_s` against the re-priced replay of its
    own modeled trace (the record->replay round trip, DESIGN.md §13)."""
    return round(dtrace.fidelity(graph, p).rel_err * 100.0, 2)


def _prefill_sweep(report, dims, prefill_len, chunk, bnb_budget=20_000):
    """Plan one chunked prefill DAG under both objectives; assert the
    acceptance inequalities and report the residency trade."""
    dag = workloads.prefill_dag(dims, prefill_len=prefill_len, chunk=chunk)
    serial = plan(dag, bnb_budget=bnb_budget)
    over = plan(dag, bnb_budget=bnb_budget, objective="overlapped")
    serial_sched = make_schedule(dag, serial)
    pim = pure_plan(dag, "upmem_2556")
    cpu_kv_pim = pure_plan(dag, "xeon")
    rehomed_dag = workloads.prefill_dag(dims, prefill_len=prefill_len,
                                        chunk=chunk, kv_home="xeon")
    cpu_rehomed = pure_plan(rehomed_dag, "xeon")
    report.table([
        {"plan": "pure_pim (KV@pim)",
         "serial ms": round(pim.total_s * 1e3, 1),
         "overlapped ms": round(make_schedule(dag, pim).overlapped_s
                                * 1e3, 1),
         "replay err %": _replay_err(dag, pim)},
        {"plan": "pure_cpu (KV@pim: migrate+writeback)",
         "serial ms": round(cpu_kv_pim.total_s * 1e3, 1),
         "overlapped ms": round(make_schedule(dag, cpu_kv_pim).overlapped_s
                                * 1e3, 1),
         "replay err %": _replay_err(dag, cpu_kv_pim)},
        {"plan": "pure_cpu (KV re-homed to host)",
         "serial ms": round(cpu_rehomed.total_s * 1e3, 1),
         "overlapped ms": "-",
         "replay err %": _replay_err(rehomed_dag, cpu_rehomed)},
        {"plan": f"planned, objective=serial [{serial.method}]",
         "serial ms": round(serial.total_s * 1e3, 1),
         "overlapped ms": round(serial_sched.overlapped_s * 1e3, 1),
         "replay err %": _replay_err(dag, serial)},
        {"plan": f"planned, objective=overlapped [{over.method}]",
         "serial ms": round(over.total_s * 1e3, 1),
         "overlapped ms": round(over.overlapped_s * 1e3, 1),
         "replay err %": _replay_err(dag, over)},
    ])
    # ISSUE-3 acceptance: the planner never loses to a pure placement of
    # the same graph, and the overlapped objective never schedules worse
    # than the serial plan
    assert serial.total_s <= pim.total_s and \
        serial.total_s <= cpu_kv_pim.total_s, "planned prefill >= a pure"
    assert over.overlapped_s <= serial_sched.overlapped_s + 1e-15, \
        "overlapped objective scheduled worse than the serial plan"
    writeback = sum(g.writeback_s for g in serial_sched.groups)
    report.note(
        f"{len(dag.nodes)}-node DAG (frontier {dag.max_frontier()}, "
        f"method {serial.method}): prefill "
        "is compute-dense (KT1) so the planner keeps it host-side and "
        f"pays {serial.migrate_s * 1e3:.1f}ms of KV traffic "
        f"({writeback * 1e3:.1f}ms write-back in the timeline) to keep "
        "the cache bank-resident for decode; re-homing the cache to the "
        f"host would save {(serial.total_s - cpu_rehomed.total_s) * 1e3:.1f}"
        "ms of prefill but forfeit decode's at-home attention (sweep 4)")

    # serial chunk loop vs pipelined executor timeline (ISSUE-4): the same
    # overlapped-objective plan, priced under the pre-executor discipline
    # (chunk-major linearization, launch groups strictly serialized) and
    # under the executor's pipelined discipline (interleaved timeline,
    # write-backs hidden under later chunks' compute)
    loop_order = workloads.prefill_serial_order(dag)
    loop_s = make_schedule(dag, over, order=loop_order).overlapped_s
    pipe_s = make_schedule(dag, over, pipelined=True).pipelined_s
    report.table([
        {"prefill execution": "serial chunk loop (pre-executor)",
         "wall-clock ms": round(loop_s * 1e3, 2),
         "tokens/s": round(prefill_len / loop_s)},
        {"prefill execution": "pipelined executor timeline",
         "wall-clock ms": round(pipe_s * 1e3, 2),
         "tokens/s": round(prefill_len / pipe_s)},
    ])
    assert pipe_s <= loop_s + 1e-15, \
        "pipelined executor slower than the serial chunk loop"
    report.note(f"pipelined cross-chunk prefill is "
                f"{(loop_s / pipe_s - 1) * 100:.1f}% faster than the "
                "serial chunk loop (chunk i+1's qkv ladder runs under "
                "chunk i's KV write-back; launch groups overlap across "
                "devices)")
    return dag, serial, over, loop_s, pipe_s


def _moe_sweep(report, dims):
    """Plan one MoE decode DAG (router -> token exchange -> expert FFNs
    -> combine exchange per layer); assert the ISSUE-5 acceptance
    inequalities and report what the exchange edges cost each plan."""
    dag = workloads.moe_decode_dag(dims)
    hybrid = plan(dag)
    rehomed_dag = workloads.moe_decode_dag(dims, kv_home="xeon")
    cpu = pure_plan(rehomed_dag, "xeon")
    pim = pure_plan(dag, "upmem_2556")
    sched = make_schedule(dag, hybrid, pipelined=True)
    report.table([
        {"plan": "pure_cpu (KV re-homed to host)",
         "modeled ms": round(cpu.total_s * 1e3, 3),
         "exchange ms": round(cpu.exchange_s * 1e3, 3),
         "replay err %": _replay_err(rehomed_dag, cpu)},
        {"plan": "pure_pim (KV@pim)",
         "modeled ms": round(pim.total_s * 1e3, 3),
         "exchange ms": round(pim.exchange_s * 1e3, 3),
         "replay err %": _replay_err(dag, pim)},
        {"plan": f"hybrid [{hybrid.method}]",
         "modeled ms": round(hybrid.total_s * 1e3, 3),
         "exchange ms": round(hybrid.exchange_s * 1e3, 3),
         "replay err %": _replay_err(dag, hybrid)},
    ])
    # ISSUE-5 acceptance: the hybrid strictly beats both steelmanned
    # pures, and only the all-PIM plan pays the host-relayed exchanges
    assert hybrid.total_s < cpu.total_s, "MoE hybrid >= pure CPU"
    assert hybrid.total_s < pim.total_s, "MoE hybrid >= pure PIM"
    assert pim.exchange_s > 0, "pure PIM paid no exchange"
    n_exchanges = sum(g.n_exchanges for g in sched.groups)
    assert sched.pipelined_s <= sched.overlapped_s + 1e-15
    report.note(
        f"{len(dag.nodes)}-node MoE DAG (frontier {dag.max_frontier()}, "
        f"method {hybrid.method}): attention stays at the bank-resident "
        "KV; router/experts plan onto the host, so the hybrid pays "
        f"{hybrid.exchange_s * 1e3:.3f}ms of exchange vs pure PIM's "
        f"{pim.exchange_s * 1e3:.3f}ms (2 host-relayed all-to-alls per "
        f"layer; {n_exchanges} booked in the hybrid timeline) — "
        "all-to-all volume scales with tokens x capacity, not experts")
    return dag, hybrid, cpu, pim


def _moe_quant_gate(report, f32_hybrid):
    """KT2-flip headline gate (ISSUE-8): plan the int8-quantized MoE
    decode DAG (int8 expert weights with int32 accumulation, int8 KV) at
    the same mixtral-8x7b dims and assert the flip — the dtype-aware
    planner now puts EVERY expert FFN on the DPU grid (the 8x8-multiplier
    band prices int8 muls at 2 cycles vs float's 32-cycle software
    ladder) and the quantized hybrid strictly beats the f32 hybrid's
    host-heavy MoE plan."""
    dag = workloads.moe_decode_dag(workloads.MOE_PAPER_DIMS_INT8)
    hybrid = plan(dag)
    over = plan(dag, objective="overlapped")
    experts = [n for n, node in dag.nodes.items()
               if node.kind == "moe_expert"]
    on_pim = sum(1 for n in experts
                 if hybrid.assignment[n].startswith("upmem"))
    report.table([
        {"plan": "f32 hybrid (sweep above)",
         "modeled ms": round(f32_hybrid.total_s * 1e3, 3),
         "experts on PIM": sum(
             1 for n in experts
             if f32_hybrid.assignment[n].startswith("upmem"))},
        {"plan": f"int8 hybrid [{hybrid.method}]",
         "modeled ms": round(hybrid.total_s * 1e3, 3),
         "experts on PIM": on_pim,
         "replay err %": _replay_err(dag, hybrid)},
    ])
    # ISSUE-8 acceptance: the quantized experts land bank-parallel under
    # BOTH objectives and the quantized hybrid strictly wins end to end
    assert experts and on_pim == len(experts), \
        f"only {on_pim}/{len(experts)} quantized experts on PIM"
    assert all(over.assignment[n].startswith("upmem") for n in experts), \
        "overlapped objective hosted a quantized expert"
    assert hybrid.total_s < f32_hybrid.total_s, \
        "quantized MoE hybrid did not beat the f32 hybrid (KT2 not flipped)"
    report.note(
        f"KT2 flipped: all {len(experts)} expert FFNs plan onto the DPU "
        "grid once their GEMMs hit the native 8x8-multiplier band "
        f"(int8 mul = 2 cycles); the quantized hybrid models "
        f"{f32_hybrid.total_s / hybrid.total_s:.2f}x faster than the f32 "
        "hybrid whose float experts were host-bound")
    return hybrid


def _multi_rank_sweep(report, quant_hybrid):
    """Sweep 8 (ISSUE-9): multi-rank scale-out. Shard the quantized
    mixtral MoE decode DAG's expert FFNs over 4 PIM ranks
    (`expert_parallel_plan`) and price the SAME sharded graph under a
    1-rank topology (every shard behind the one host channel) vs the
    4-rank topology (one transfer channel per rank) — isolating what
    rank-parallel CPU<->DPU transfers and per-rank exchange relays buy
    with compute held fixed. The second half prices cross-step
    pipelining: the 2-step scoring DAG (no sampled-token dependence, so
    step i+1's embed overlaps under step i's head) against 2x the
    single-step wall-clock."""
    from repro.dispatch.placement import Topology
    dims = workloads.MOE_PAPER_DIMS_INT8
    g = workloads.moe_decode_dag(dims, expert_shards=4)
    p1 = workloads.expert_parallel_plan(g, Topology(n_ranks=1))
    p4 = workloads.expert_parallel_plan(g, Topology(n_ranks=4))
    s1 = make_schedule(g, p1, pipelined=True)
    s4 = make_schedule(g, p4, pipelined=True)
    report.table([
        {"plan": "expert-parallel x4, 1 rank (single channel)",
         "pipelined ms": round(s1.pipelined_s * 1e3, 3),
         "overlapped ms": round(s1.overlapped_s * 1e3, 3),
         "replay err %": _replay_err(g, p1)},
        {"plan": "expert-parallel x4, 4 ranks (per-rank channels)",
         "pipelined ms": round(s4.pipelined_s * 1e3, 3),
         "overlapped ms": round(s4.overlapped_s * 1e3, 3),
         "replay err %": _replay_err(g, p4)},
        {"plan": "unsharded int8 hybrid (sweep 7)",
         "pipelined ms": round(
             make_schedule(workloads.moe_decode_dag(dims), quant_hybrid,
                           pipelined=True).pipelined_s * 1e3, 3),
         "overlapped ms": "-", "replay err %": "-"},
    ])
    # ISSUE-9 acceptance: the 4-rank plan strictly beats the single
    # channel on the modeled pipelined wall-clock, and its prediction
    # survives the per-rank replay round trip inside the fidelity band
    assert s4.pipelined_s < s1.pipelined_s, \
        "4-rank expert-parallel plan did not beat the single channel"
    fid = dtrace.fidelity(g, p4)
    assert fid.ok, f"multi-rank fidelity {fid.rel_err:.1%} out of band"
    report.note(
        f"4 ranks model {s1.pipelined_s / s4.pipelined_s:.2f}x faster "
        "than the same sharded plan behind one channel: each rank's "
        "expert slice stages in/exchanges over its own host channel, so "
        "the router scatter and combine gather parallelize across ranks "
        f"(per-rank replay err {fid.rel_err * 100:.2f}%)")

    # cross-step pipelining: scoring/speculative-verification steps chain
    # attn{i}/s{k} -> attn{i}/s{k+1} (KV order) but NOT head -> embed
    g2 = workloads.decode_steps_dag(dims, n_steps=2)
    p_2 = plan(g2, objective="overlapped")
    s_2 = make_schedule(g2, p_2, pipelined=True)
    one = make_schedule(workloads.moe_decode_dag(dims),
                        plan(workloads.moe_decode_dag(dims),
                             objective="overlapped"),
                        pipelined=True).pipelined_s
    report.table([
        {"steps": "1 (x2, serialized)",
         "pipelined ms": round(2 * one * 1e3, 3), "replay err %": "-"},
        {"steps": "2 (cross-step DAG, scoring)",
         "pipelined ms": round(s_2.pipelined_s * 1e3, 3),
         "replay err %": _replay_err(g2, p_2)},
    ])
    assert s_2.pipelined_s < 2 * one, \
        "cross-step DAG failed to overlap across the step boundary"
    report.note(
        f"2 pipelined steps model {(2 * one - s_2.pipelined_s) * 1e3:.1f} "
        "ms under 2x one step: step 2's embed/QKV start while step 1's "
        "head is still in flight (sampled decode would re-serialize via "
        "head -> embed; `decode_steps_dag(sampled=True)` prices that)")


def _swa_sweep(report, dims, prefill_len, chunk, bnb_budget=20_000):
    """Sweep 9 (ISSUE-10): long-context sliding-window attention. Price
    the SAME model twice — once with a ring-buffer KV window
    (`DecodeDims.window`, attention protos/migration sized at
    min(kv_len, window) rows) and once as its full-attention twin
    (window=0) — for both phases: the windowed decode DAG vs the
    full-cache decode DAG, and the BANDED prefill DAG (cross-chunk KV
    edges outside the window dropped, `prefill_live_from`) vs the full
    lower-triangular prefill DAG at the same prompt/chunking. The
    windowed hybrid must STRICTLY beat the full-attention plan in both
    phases, and its predictions must survive the replay fidelity gate."""
    import dataclasses
    full = dataclasses.replace(dims, window=0)

    # decode: ring-sized KV vs the full cache
    dag_w = workloads.decode_dag(dims)
    dag_f = workloads.decode_dag(full)
    p_w, p_f = plan(dag_w), plan(dag_f)
    report.table([
        {"decode plan": f"full attention ({full.kv_len}-row KV)",
         "modeled ms": round(p_f.total_s * 1e3, 3),
         "kv-migrate ms": round(p_f.migrate_s * 1e3, 3),
         "replay err %": _replay_err(dag_f, p_f)},
        {"decode plan": f"windowed ({dims.kv_len}-slot ring) "
                        f"[{p_w.method}]",
         "modeled ms": round(p_w.total_s * 1e3, 3),
         "kv-migrate ms": round(p_w.migrate_s * 1e3, 3),
         "replay err %": _replay_err(dag_w, p_w)},
    ])
    # ISSUE-10 acceptance (decode): at the same model dims the windowed
    # plan strictly beats full attention — the ring cache is the only
    # difference, so every win is attention rows not priced
    assert p_w.total_s < p_f.total_s, \
        "windowed decode hybrid did not beat the full-attention plan"
    fid_d = dtrace.fidelity(dag_w, p_w)
    assert fid_d.ok, \
        f"windowed decode fidelity {fid_d.rel_err:.1%} out of band"

    # prefill: banded DAG (dead cross-chunk KV edges dropped) vs full
    pre_w = workloads.prefill_dag(dims, prefill_len=prefill_len,
                                  chunk=chunk)
    pre_f = workloads.prefill_dag(full, prefill_len=prefill_len,
                                  chunk=chunk)
    q_w = plan(pre_w, bnb_budget=bnb_budget)
    q_f = plan(pre_f, bnb_budget=bnb_budget)
    s_w = make_schedule(pre_w, q_w, pipelined=True)
    s_f = make_schedule(pre_f, q_f, pipelined=True)
    edges_w = sum(len(p) for p in pre_w.preds.values())
    edges_f = sum(len(p) for p in pre_f.preds.values())
    report.table([
        {"prefill plan": f"full causal ({edges_f} edges)",
         "serial ms": round(q_f.total_s * 1e3, 1),
         "pipelined ms": round(s_f.pipelined_s * 1e3, 1),
         "replay err %": _replay_err(pre_f, q_f)},
        {"prefill plan": f"banded, window {dims.window} "
                         f"({edges_w} edges) [{q_w.method}]",
         "serial ms": round(q_w.total_s * 1e3, 1),
         "pipelined ms": round(s_w.pipelined_s * 1e3, 1),
         "replay err %": _replay_err(pre_w, q_w)},
    ])
    # ISSUE-10 acceptance (prefill): the banded DAG strictly beats the
    # full plan — dropped KV edges are flops, residency, AND write-back
    # the planner never has to schedule
    assert edges_w < edges_f, "banded prefill DAG dropped no edges"
    assert q_w.total_s < q_f.total_s, \
        "banded prefill plan did not beat the full-attention plan"
    assert s_w.pipelined_s <= s_f.pipelined_s + 1e-15, \
        "banded prefill pipelines worse than full attention"
    fid_p = dtrace.fidelity(pre_w, q_w)
    assert fid_p.ok, \
        f"banded prefill fidelity {fid_p.rel_err:.1%} out of band"
    report.note(
        f"window {dims.window} of {full.seq}: windowed decode models "
        f"{p_f.total_s / p_w.total_s:.2f}x faster than full attention "
        f"(ring holds {dims.kv_len} of {full.kv_len} KV rows); banded "
        f"prefill drops {edges_f - edges_w} dead cross-chunk edges and "
        f"models {q_f.total_s / q_w.total_s:.2f}x faster serial, "
        f"{s_f.pipelined_s / s_w.pipelined_s:.2f}x pipelined (decode "
        f"replay err {fid_d.rel_err * 100:.2f}%, prefill "
        f"{fid_p.rel_err * 100:.2f}%)")


def _three_way(report, graph, devices=("xeon", "upmem_2556")):
    plans = compare_plans(graph, devices=devices)
    rows = [{"plan": k, "modeled ms": round(p.total_s * 1e3, 3),
             "compute ms": round(p.compute_s * 1e3, 3),
             "transfer ms": round(p.transfer_s * 1e3, 3),
             "launches": round(p.launch_s * 1e3, 3),
             "devices": "+".join(p.used_devices),
             "replay err %": _replay_err(graph, p)}
            for k, p in plans.items()]
    report.table(rows)
    sched = make_schedule(graph, plans["hybrid"])
    report.raw(sched.render())
    return plans, sched


def _trace_section(report, trace_out=None, steps: int = 20):
    """Record a measured execution trace of the dispatch-backed serving
    decode step, measure the tracing overhead (traced vs untraced
    wall-clock over the same step loop — the ISSUE-6 <5% budget), gate
    the planner's prediction against the replayed trace, and optionally
    export the trace (JSON + Chrome trace_event twin)."""
    import time

    import jax
    import jax.numpy as jnp
    from repro.configs import REDUCED
    from repro.models import Shardings, init_params
    from repro.serve import Request, ServeEngine

    cfg = REDUCED["granite-3-8b"]
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=512, shd=shd,
                      engine="dispatch",
                      dispatch_kwargs={"prefill_engine": "jit"})
    for i in range(2):   # fill both slots; budget outlasts every loop below
        eng.admit(Request(i, jnp.arange(4, dtype=jnp.int32) + 3, 10_000))

    def step_loop(n):
        t0 = time.perf_counter()
        for _ in range(n):
            eng.step()
        return time.perf_counter() - t0

    step_loop(5)                         # warm-up: compile every stage once
    untraced = min(step_loop(steps) for _ in range(3))
    tracer = dtrace.Trace(name=f"bench:{cfg.name}:dispatch")
    tracer.meta.update(arch=cfg.name, engine="dispatch",
                       assignment=dict(eng._decode.executor.assignment))
    eng.attach_tracer(tracer)
    traced = min(step_loop(steps) for _ in range(3))
    eng.attach_tracer(None)
    overhead = traced / untraced - 1.0
    report.table([
        {"decode loop": "untraced",
         f"best-of-3 wall s ({steps} steps)": round(untraced, 4),
         "ms/step": round(untraced / steps * 1e3, 3)},
        {"decode loop": "traced",
         f"best-of-3 wall s ({steps} steps)": round(traced, 4),
         "ms/step": round(traced / steps * 1e3, 3)},
    ])
    report.note(f"tracing overhead: {overhead * 100.0:+.2f}% of untraced "
                "executor wall-clock (budget <5%: a trace event is two "
                "perf_counter reads and a dict append per span)")
    rep = dtrace.fidelity(eng._decode.dag, eng._decode.plan, trace=tracer)
    report.raw("  " + rep.render())
    assert rep.ok, "measured serving trace replays outside the gate band"
    if trace_out:
        chrome = (trace_out[:-5] if trace_out.endswith(".json")
                  else trace_out) + ".chrome.json"
        tracer.save(trace_out)
        tracer.save_chrome(chrome)
        n_steps = len(tracer.by_kind("decode_step"))
        report.note(f"trace: {len(tracer.events)} events ({n_steps} decode "
                    f"steps) -> {trace_out} (+ {chrome})")
    return overhead


def run(report, quick: bool = False, trace_out: str | None = None):
    if quick:
        # CI smoke: the chunked prefill DAG at reduced scale, both
        # objectives, acceptance gates asserted
        report.section("QUICK: chunked prefill DAG (reduced dims, "
                       "2 chunks), serial vs overlapped objective")
        _prefill_sweep(report, workloads.REDUCED_DIMS, prefill_len=8,
                       chunk=4)
        # MoE smoke (ISSUE-5): the routed-expert decode DAG at reduced
        # dims — exchange bookkeeping + pure-baseline asserts only (the
        # strict hybrid win is a paper-scale property, sweep 6)
        report.section("QUICK: MoE decode DAG (reduced dims, 4 experts "
                       "top-2), exchange-phase bookkeeping")
        dag = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
        hybrid = plan(dag)
        pim = pure_plan(dag, "upmem_2556")
        sched = make_schedule(dag, pim, pipelined=True)
        report.table([
            {"plan": "pure_pim", "modeled ms": round(pim.total_s * 1e3, 3),
             "exchange ms": round(pim.exchange_s * 1e3, 3),
             "replay err %": _replay_err(dag, pim)},
            {"plan": f"planned [{hybrid.method}]",
             "modeled ms": round(hybrid.total_s * 1e3, 3),
             "exchange ms": round(hybrid.exchange_s * 1e3, 3),
             "replay err %": _replay_err(dag, hybrid)},
        ])
        assert hybrid.total_s <= pim.total_s, "MoE planned >= pure PIM"
        assert hybrid.total_s <= pure_plan(dag, "xeon").total_s
        assert pim.exchange_s > 0, "pure PIM paid no MoE exchange"
        assert sum(g.n_exchanges for g in sched.groups) == \
            2 * workloads.MOE_REDUCED_DIMS.n_layers
        assert sched.pipelined_s <= sched.overlapped_s + 1e-15
        report.note("MoE routing planned as an exchange phase: all-PIM "
                    "pays 2 host-relayed all-to-alls per layer "
                    "(transfer-channel-only occupancy in the timeline)")
        # quantized smoke (ISSUE-8): the int8 MoE DAG builds with the
        # int8 mul band on its expert nodes and the DPU prices a
        # quantized expert strictly below its f32 twin (the paper-scale
        # PIM flip itself is sweep 7's gate — at reduced dims everything
        # is host-cheap and the flip is not expected)
        report.section("QUICK: quantized MoE decode DAG (int8 experts + "
                       "int8 KV, reduced dims)")
        dag8 = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS_INT8)
        h8 = plan(dag8)
        assert dag8.name.endswith("-int8"), dag8.name
        experts = [n for n, node in dag8.nodes.items()
                   if node.kind == "moe_expert"]
        assert experts and all(
            dag8.nodes[n].ops.get(("mul", "int8"), 0) > 0 for n in experts
        ), "quantized expert nodes lost the int8 mul band"
        pim8_ms = node_time(dag8.nodes["expert0"], "upmem_2556") * 1e3
        pim32_ms = node_time(dag.nodes["expert0"], "upmem_2556") * 1e3
        assert pim8_ms < pim32_ms, \
            "DPU does not price the int8 expert below the f32 expert"
        report.table([
            {"plan": f"int8 hybrid [{h8.method}]",
             "modeled ms": round(h8.total_s * 1e3, 3),
             "expert0 on-DPU ms (int8)": round(pim8_ms, 3),
             "expert0 on-DPU ms (f32)": round(pim32_ms, 3),
             "replay err %": _replay_err(dag8, h8)},
        ])
        report.note("int8 expert GEMMs carry the ('mul','int8') band end "
                    "to end — the dtype class the planner reprices at the "
                    "DPU's native 8x8 multiplier (2 cycles vs float's "
                    "32-cycle software ladder; sweep 7 gates the "
                    "paper-scale flip)")
        # sliding-window smoke (ISSUE-10): windowed vs full at reduced
        # dims — the same strict inequalities as sweep 9, small graphs
        report.section("QUICK: sliding-window attention (reduced dims, "
                       "window 8), windowed vs full-attention plans")
        _swa_sweep(report, workloads.SWA_REDUCED_DIMS,
                   **workloads.PREFILL_SWA_REDUCED)
        if trace_out:
            report.section("QUICK: execution tracing (measured dispatch "
                           "serving trace, overhead, fidelity)")
            _trace_section(report, trace_out, steps=10)
        return

    # -- sweep 1: the 16 PrIM workloads, one operator each ----------------
    report.section("PrIM workloads: planner device pick vs Fig.-4 grouping")
    rows, recovered = [], 0
    for counts in prim.all_ref_counts():
        g = workloads.prim_graph(counts)
        cpu = pure_plan(g, "xeon").total_s
        pim = pure_plan(g, "upmem_2556").total_s
        hyb = plan(g, devices=("xeon", "titan_v", "upmem_2556"))
        pick = hyb.assignment[counts.name]
        if not counts.pim_suitable and hyb.total_s < pim:
            recovered += 1
        rows.append({"workload": counts.name,
                     "suitable": "Y" if counts.pim_suitable else "n",
                     "cpu ms": round(cpu * 1e3, 2),
                     "pim ms": round(pim * 1e3, 2),
                     "planned ms": round(hyb.total_s * 1e3, 2),
                     "pick": pick,
                     "replay err %": _replay_err(g, hyb)})
    report.table(rows)
    report.note(f"planner recovers {recovered} of the "
                f"{sum(1 for c in prim.all_ref_counts() if not c.pim_suitable)}"
                " group-2 workloads pure PIM loses (picks a better device)")

    # -- sweep 2: mixed PrIM pipeline ------------------------------------
    report.section("Mixed PrIM pipeline (stream -> reorganize -> stream), "
                   "4096x4096 int32")
    g = workloads.mixed_pipeline(m=4096, concrete=False).graph()
    plans, _ = _three_way(report, g)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s, "hybrid>=cpu"
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s, "hybrid>=pim"
    report.note("hybrid strictly beats both pure plans: streams run "
                "bank-parallel, the transpose/rotate middle goes to the host")

    # -- sweep 3: LM decode step at paper scale --------------------------
    report.section("LM decode step (weight GEMVs + quantized KV attention), "
                   "4k d_model / 32 layers / 2k cache")
    dg = workloads.decode_pipeline(workloads.DecodeDims(),
                                   concrete=False).graph()
    plans, _ = _three_way(report, dg)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s, "hybrid>=cpu"
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s, "hybrid>=pim"
    n_pim = sum(1 for d in plans["hybrid"].assignment.values()
                if d.startswith("upmem"))
    report.note(f"{n_pim} of {len(dg.nodes)} decode operators placed "
                "bank-parallel (the KV-cache attention); float-mul GEMVs "
                "stay on the host (KT2)")

    # -- sweep 4: decode DAG + KV residency (the serving planner) --------
    report.section("Decode DAG (residuals kept, KV bank-resident), "
                   "exact frontier-DP plan vs steelmanned pures")
    dims = workloads.DecodeDims()
    dag = workloads.decode_dag(dims)                  # KV homed on PIM
    hybrid = plan(dag)
    cpu = pure_plan(workloads.decode_dag(dims, kv_home="xeon"), "xeon")
    pim = pure_plan(dag, "upmem_2556")
    report.table([
        {"plan": "pure_cpu (KV@host)", "modeled ms":
            round(cpu.total_s * 1e3, 3),
         "kv-migrate ms": round(cpu.migrate_s * 1e3, 3)},
        {"plan": "pure_pim (KV@pim)", "modeled ms":
            round(pim.total_s * 1e3, 3),
         "kv-migrate ms": round(pim.migrate_s * 1e3, 3)},
        {"plan": f"hybrid [{hybrid.method}]", "modeled ms":
            round(hybrid.total_s * 1e3, 3),
         "kv-migrate ms": round(hybrid.migrate_s * 1e3, 3)},
    ])
    # ISSUE-2 acceptance: dispatch-planned decode beats both pures at
    # paper scale, each pure given its best-case KV residency
    assert hybrid.total_s < cpu.total_s, "hybrid>=cpu on decode DAG"
    assert hybrid.total_s < pim.total_s, "hybrid>=pim on decode DAG"
    assert hybrid.method == "dag-dp", "decode DAG fell off the exact rung"
    report.note(f"{len(dag.nodes)}-node DAG (frontier width "
                f"{dag.max_frontier()}) planned exactly by the frontier "
                "DP; attention pinned to the KV home, residual/GEMV "
                "stream on the host")

    # -- sweep 5: chunked prefill DAG, serial vs overlapped objective ----
    report.section("Chunked prefill DAG (2048 tokens / 4x512 chunks, KV "
                   "bank-resident), serial vs overlapped objective")
    _, _, _, loop_s, pipe_s = _prefill_sweep(report, dims,
                                             prefill_len=2048, chunk=512)
    # ISSUE-4 acceptance: at the paper-scale config the pipelined executor
    # timeline STRICTLY beats the serial chunk loop's throughput
    assert pipe_s < loop_s, \
        "pipelined prefill does not beat the serial chunk loop at paper scale"

    # -- sweep 6: MoE decode DAG, routing as an exchange phase -----------
    report.section("MoE decode DAG (mixtral-8x7b dims: 8 experts top-2, "
                   "token/combine exchanges), hybrid vs steelmanned pures")
    _, f32_hybrid, _, _ = _moe_sweep(report, workloads.MOE_PAPER_DIMS)

    # -- sweep 7: the KT2 flip — int8 experts/KV vs the f32 hybrid -------
    report.section("Quantized MoE decode DAG (int8 experts + int8 KV), "
                   "the KT2 flip vs the f32 hybrid")
    quant_hybrid = _moe_quant_gate(report, f32_hybrid)

    # -- sweep 8: multi-rank scale-out + cross-step pipelining -----------
    report.section("Multi-rank scale-out (4-rank expert parallelism, "
                   "per-rank channels) + cross-step pipelining")
    _multi_rank_sweep(report, quant_hybrid)

    # -- sweep 9: long-context sliding-window attention ------------------
    report.section("Long-context sliding-window attention (32k prompt, "
                   "4k window): windowed vs full-attention plans")
    _swa_sweep(report, workloads.SWA_PAPER_DIMS, **workloads.PREFILL_SWA)

    # -- execute the plans for real (reduced scale) ----------------------
    report.section("Runtime validation (reduced scale, real execution)")
    from repro.core.bank_parallel import BankGrid, make_bank_mesh
    from repro.dispatch.runtime import check_phase_discipline, execute
    grid = BankGrid(make_bank_mesh())
    rows = []
    for pipe in (workloads.mixed_pipeline(m=256),
                 workloads.decode_pipeline()):
        pg = pipe.graph()
        p = plan(pg)
        rep = execute(pipe, p, grid)
        rows.append({"pipeline": pipe.name, "stages": len(pipe.stages),
                     "allclose vs reference": rep.matches,
                     "max |err|": f"{rep.max_abs_err:.2e}",
                     "local phases checked":
                         check_phase_discipline(pipe, grid)})
    report.table(rows)

    # -- dispatch-backed serving: planner-routed == fused jit ------------
    report.section("Dispatch-backed ServeEngine (reduced scale)")
    import jax
    import jax.numpy as jnp
    from repro.configs import REDUCED
    from repro.models import Shardings, init_params
    from repro.serve import Request, ServeEngine
    cfg = REDUCED["granite-3-8b"]
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    key = jax.random.PRNGKey(7)
    prompts = []
    for _ in range(6):
        key, k = jax.random.split(key)
        plen = 3 + int(jax.random.randint(k, (), 0, 6))
        prompts.append(jax.random.randint(k, (plen,), 0, cfg.vocab_size,
                                          dtype=jnp.int32))
    outs = {}
    for engine in ("jit", "dispatch"):
        # fused prefill here: this sweep demos decode-identity at the
        # default dtype (the dispatch-prefill gate is f32, test_serve.py)
        kw = ({"dispatch_kwargs": {"prefill_engine": "jit"}}
              if engine == "dispatch" else {})
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=shd,
                          engine=engine, **kw)
        done = eng.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
        outs[engine] = {r.rid: r.out_tokens for r in done}
    assert outs["jit"] == outs["dispatch"], \
        "dispatch-backed decode diverged from the jit engine"
    report.table([{"engine": e, "requests": len(outs[e]),
                   "tokens": sum(len(t) for t in outs[e].values())}
                  for e in outs])
    report.note("dispatch-backed decode is token-identical to the "
                "fused-jit engine over a continuous-batching run")

    # -- dispatch-backed MoE serving (ISSUE-5, f32 mixtral-reduced) ------
    report.section("Dispatch-backed MoE ServeEngine (mixtral-reduced, f32)")
    import dataclasses
    moe_cfg = dataclasses.replace(REDUCED["mixtral-8x7b"], dtype="float32")
    moe_params = init_params(jax.random.PRNGKey(0), moe_cfg, shd)
    moe_prompts = []
    key = jax.random.PRNGKey(17)
    for _ in range(5):
        key, k = jax.random.split(key)
        plen = 3 + int(jax.random.randint(k, (), 0, 6))
        moe_prompts.append(jax.random.randint(k, (plen,), 0,
                                              moe_cfg.vocab_size,
                                              dtype=jnp.int32))
    moe_outs = {}
    for engine in ("jit", "dispatch"):
        # fused prefill: chunked MoE prefill has per-chunk capacity
        # semantics (serve.dispatch_engine docstring); the decode path is
        # the planner-routed ladder under test
        kw = ({"dispatch_kwargs": {"prefill_engine": "jit"}}
              if engine == "dispatch" else {})
        eng = ServeEngine(moe_cfg, moe_params, batch_slots=2, max_len=48,
                          shd=shd, engine=engine, **kw)
        done = eng.serve([Request(i, p, 4)
                          for i, p in enumerate(moe_prompts)])
        moe_outs[engine] = {r.rid: r.out_tokens for r in done}
    assert moe_outs["jit"] == moe_outs["dispatch"], \
        "dispatch-backed MoE decode diverged from the jit engine"
    report.table([{"engine": e, "requests": len(moe_outs[e]),
                   "tokens": sum(len(t) for t in moe_outs[e].values())}
                  for e in moe_outs])
    report.note("dispatch-backed MoE decode (router -> exchange -> "
                "experts -> combine) is token-identical to the fused-jit "
                "engine at f32")

    # -- execution tracing: overhead + planner fidelity (ISSUE-6) --------
    report.section("Execution tracing: overhead budget and planner "
                   "fidelity on a measured serving trace")
    _trace_section(report, trace_out)
