"""Offload planning benchmark: pure-CPU vs pure-PIM vs hybrid plans.

Three sweeps over `repro.dispatch`:

  1. The 16 PrIM workloads at Fig.-4 granularity (one operator each):
     the planner's per-workload device pick vs the paper's suitability
     grouping — the hybrid (CPU+GPU+PIM) device choice recovers the
     group-2 workloads that pure PIM loses.
  2. The mixed PrIM pipeline (streaming -> transpose/rotate -> streaming):
     the DP plan beats BOTH pure placements by running the streams
     bank-parallel and handing the reorganization to the host.
  3. The LM decode step (serve.engine's workload) at paper scale: weight
     GEMVs on the host (float mul is a software routine on DPUs, KT2),
     quantized KV-cache attention bank-parallel (streaming int dots, KT1).
  4. The decode DAG (residual branches kept, KV-residency charged): the
     exact frontier-DP plan must beat both steelmanned pure baselines
     (pure CPU gets KV homed on the host) — the ISSUE-2 acceptance gate.

Finally the reduced-scale pipelines are actually executed through
`dispatch.runtime` — and a dispatch-backed `ServeEngine` decode run is
checked token-identical against the fused-jit engine.
"""

from __future__ import annotations

from repro import prim
from repro.dispatch import workloads
from repro.dispatch.placement import compare_plans, plan, pure_plan
from repro.dispatch.schedule import make_schedule


def _three_way(report, graph, devices=("xeon", "upmem_2556")):
    plans = compare_plans(graph, devices=devices)
    rows = [{"plan": k, "modeled ms": round(p.total_s * 1e3, 3),
             "compute ms": round(p.compute_s * 1e3, 3),
             "transfer ms": round(p.transfer_s * 1e3, 3),
             "launches": round(p.launch_s * 1e3, 3),
             "devices": "+".join(p.used_devices)}
            for k, p in plans.items()]
    report.table(rows)
    sched = make_schedule(graph, plans["hybrid"])
    report.raw(sched.render())
    return plans, sched


def run(report):
    # -- sweep 1: the 16 PrIM workloads, one operator each ----------------
    report.section("PrIM workloads: planner device pick vs Fig.-4 grouping")
    rows, recovered = [], 0
    for counts in prim.all_ref_counts():
        g = workloads.prim_graph(counts)
        cpu = pure_plan(g, "xeon").total_s
        pim = pure_plan(g, "upmem_2556").total_s
        hyb = plan(g, devices=("xeon", "titan_v", "upmem_2556"))
        pick = hyb.assignment[counts.name]
        if not counts.pim_suitable and hyb.total_s < pim:
            recovered += 1
        rows.append({"workload": counts.name,
                     "suitable": "Y" if counts.pim_suitable else "n",
                     "cpu ms": round(cpu * 1e3, 2),
                     "pim ms": round(pim * 1e3, 2),
                     "planned ms": round(hyb.total_s * 1e3, 2),
                     "pick": pick})
    report.table(rows)
    report.note(f"planner recovers {recovered} of the "
                f"{sum(1 for c in prim.all_ref_counts() if not c.pim_suitable)}"
                " group-2 workloads pure PIM loses (picks a better device)")

    # -- sweep 2: mixed PrIM pipeline ------------------------------------
    report.section("Mixed PrIM pipeline (stream -> reorganize -> stream), "
                   "4096x4096 int32")
    g = workloads.mixed_pipeline(m=4096, concrete=False).graph()
    plans, _ = _three_way(report, g)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s, "hybrid>=cpu"
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s, "hybrid>=pim"
    report.note("hybrid strictly beats both pure plans: streams run "
                "bank-parallel, the transpose/rotate middle goes to the host")

    # -- sweep 3: LM decode step at paper scale --------------------------
    report.section("LM decode step (weight GEMVs + quantized KV attention), "
                   "4k d_model / 32 layers / 2k cache")
    dg = workloads.decode_pipeline(workloads.DecodeDims(),
                                   concrete=False).graph()
    plans, _ = _three_way(report, dg)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s, "hybrid>=cpu"
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s, "hybrid>=pim"
    n_pim = sum(1 for d in plans["hybrid"].assignment.values()
                if d.startswith("upmem"))
    report.note(f"{n_pim} of {len(dg.nodes)} decode operators placed "
                "bank-parallel (the KV-cache attention); float-mul GEMVs "
                "stay on the host (KT2)")

    # -- sweep 4: decode DAG + KV residency (the serving planner) --------
    report.section("Decode DAG (residuals kept, KV bank-resident), "
                   "exact frontier-DP plan vs steelmanned pures")
    dims = workloads.DecodeDims()
    dag = workloads.decode_dag(dims)                  # KV homed on PIM
    hybrid = plan(dag)
    cpu = pure_plan(workloads.decode_dag(dims, kv_home="xeon"), "xeon")
    pim = pure_plan(dag, "upmem_2556")
    report.table([
        {"plan": "pure_cpu (KV@host)", "modeled ms":
            round(cpu.total_s * 1e3, 3),
         "kv-migrate ms": round(cpu.migrate_s * 1e3, 3)},
        {"plan": "pure_pim (KV@pim)", "modeled ms":
            round(pim.total_s * 1e3, 3),
         "kv-migrate ms": round(pim.migrate_s * 1e3, 3)},
        {"plan": f"hybrid [{hybrid.method}]", "modeled ms":
            round(hybrid.total_s * 1e3, 3),
         "kv-migrate ms": round(hybrid.migrate_s * 1e3, 3)},
    ])
    # ISSUE-2 acceptance: dispatch-planned decode beats both pures at
    # paper scale, each pure given its best-case KV residency
    assert hybrid.total_s < cpu.total_s, "hybrid>=cpu on decode DAG"
    assert hybrid.total_s < pim.total_s, "hybrid>=pim on decode DAG"
    assert hybrid.method == "dag-dp", "decode DAG fell off the exact rung"
    report.note(f"{len(dag.nodes)}-node DAG (frontier width "
                f"{dag.max_frontier()}) planned exactly by the frontier "
                "DP; attention pinned to the KV home, residual/GEMV "
                "stream on the host")

    # -- execute the plans for real (reduced scale) ----------------------
    report.section("Runtime validation (reduced scale, real execution)")
    from repro.core.bank_parallel import BankGrid, make_bank_mesh
    from repro.dispatch.runtime import check_phase_discipline, execute
    grid = BankGrid(make_bank_mesh())
    rows = []
    for pipe in (workloads.mixed_pipeline(m=256),
                 workloads.decode_pipeline()):
        pg = pipe.graph()
        p = plan(pg)
        rep = execute(pipe, p, grid)
        rows.append({"pipeline": pipe.name, "stages": len(pipe.stages),
                     "allclose vs reference": rep.matches,
                     "max |err|": f"{rep.max_abs_err:.2e}",
                     "local phases checked":
                         check_phase_discipline(pipe, grid)})
    report.table(rows)

    # -- dispatch-backed serving: planner-routed == fused jit ------------
    report.section("Dispatch-backed ServeEngine (reduced scale)")
    import jax
    import jax.numpy as jnp
    from repro.configs import REDUCED
    from repro.models import Shardings, init_params
    from repro.serve import Request, ServeEngine
    cfg = REDUCED["granite-3-8b"]
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    key = jax.random.PRNGKey(7)
    prompts = []
    for _ in range(6):
        key, k = jax.random.split(key)
        plen = 3 + int(jax.random.randint(k, (), 0, 6))
        prompts.append(jax.random.randint(k, (plen,), 0, cfg.vocab_size,
                                          dtype=jnp.int32))
    outs = {}
    for engine in ("jit", "dispatch"):
        eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=shd,
                          engine=engine)
        done = eng.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
        outs[engine] = {r.rid: r.out_tokens for r in done}
    assert outs["jit"] == outs["dispatch"], \
        "dispatch-backed decode diverged from the jit engine"
    report.table([{"engine": e, "requests": len(outs[e]),
                   "tokens": sum(len(t) for t in outs[e].values())}
                  for e in outs])
    report.note("dispatch-backed decode is token-identical to the "
                "fused-jit engine over a continuous-batching run")
