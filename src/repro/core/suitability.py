"""Workload suitability scoring — the paper's Key Takeaways 1-3 as code.

Given an `HloAnalysis` of any compiled workload (a PrIM kernel or an LM
train/prefill/decode step), score the three criteria the paper distills:

  KT1  memory-boundedness : operational intensity vs the machine balance
  KT2  op-mix simplicity  : fraction of simple (add/sub/bitwise/compare)
                            arithmetic vs mul/div/transcendental
  KT3  communication      : collective traffic per byte of local traffic

and produce the paper's verdict: a workload is PIM-suitable iff it is
memory-bound AND simple-op AND low-communication. The same scoring, run with
the TPU machine model, classifies which LM serving stage benefits from the
bank-parallel (weight-stationary, bandwidth-roof) execution path.
"""

from __future__ import annotations

import dataclasses

from .hlo_analysis import HloAnalysis, op_mix
from .pim_model import Machine, MACHINES


@dataclasses.dataclass
class SuitabilityReport:
    name: str
    machine: str
    operational_intensity: float      # flops / hbm byte
    machine_balance: float            # machine flops / byte
    memory_bound: bool                # KT1
    simple_frac: float
    complex_frac: float
    simple_ops: bool                  # KT2: <30% complex arithmetic
    comm_ratio: float                 # collective bytes / hbm bytes
    low_comm: bool                    # KT3: <5% of traffic is inter-bank
    pim_suitable: bool
    takeaways: list[str]


# paper-derived thresholds
COMPLEX_FRAC_THRESHOLD = 0.30
COMM_RATIO_THRESHOLD = 0.05


def score(analysis: HloAnalysis, *, name: str,
          machine: Machine | str = "upmem_2556") -> SuitabilityReport:
    m = MACHINES[machine] if isinstance(machine, str) else machine
    oi = analysis.flops / analysis.hbm_bytes if analysis.hbm_bytes else float("inf")
    mix = op_mix(analysis)
    comm = (analysis.collective_bytes / analysis.hbm_bytes
            if analysis.hbm_bytes else 0.0)

    memory_bound = oi < m.balance
    simple = mix["complex_frac"] < COMPLEX_FRAC_THRESHOLD
    low_comm = comm < COMM_RATIO_THRESHOLD
    takeaways = []
    takeaways.append(
        f"KT1: OI={oi:.3g} {'<' if memory_bound else '>='} balance "
        f"{m.balance:.3g} -> {'memory-bound (suitable)' if memory_bound else 'compute-bound'}")
    takeaways.append(
        f"KT2: complex-op fraction {mix['complex_frac']:.2f} -> "
        f"{'simple-op (suitable)' if simple else 'complex-op heavy'}")
    takeaways.append(
        f"KT3: inter-bank/local traffic {comm:.3g} -> "
        f"{'low-communication (suitable)' if low_comm else 'communication-heavy'}")
    return SuitabilityReport(
        name=name,
        machine=m.name,
        operational_intensity=oi,
        machine_balance=m.balance,
        memory_bound=memory_bound,
        simple_frac=mix["simple_frac"],
        complex_frac=mix["complex_frac"],
        simple_ops=simple,
        comm_ratio=comm,
        low_comm=low_comm,
        pim_suitable=memory_bound and simple and low_comm,
        takeaways=takeaways,
    )
