"""Three-term roofline analysis of compiled XLA programs.

Implements the paper's characterization methodology (Williams et al. roofline,
as applied by Gomez-Luna et al. to the UPMEM system) for compiled JAX steps:

    compute term    = HLO_FLOPs   / (chips x peak FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM bandwidth)
    collective term = coll_bytes  / (chips x link bandwidth)

All inputs come from `hlo_analysis.analyze_hlo` over `compiled.as_text()`
(a per-device module — so the per-chip division is already done) plus the
machine constants in `pim_model`. The dominant term is the bottleneck; the
"useful-compute ratio" MODEL_FLOPS / HLO_FLOPS catches remat and sharding
waste (HLO_FLOPS here is the global count: per-device x chips).
"""

from __future__ import annotations

import dataclasses
import json

from .hlo_analysis import HloAnalysis, analyze_hlo
from .pim_model import Machine, TPU_V5E


@dataclasses.dataclass
class RooflineReport:
    name: str
    machine: str
    n_chips: int
    # per-device raw quantities (from the SPMD module)
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    # the three terms, in seconds
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops: float            # analytic 6ND-style global count
    hlo_flops_global: float
    useful_compute_ratio: float   # model_flops / hlo_flops_global
    # achieved fraction of the dominant roof if the step ran at the
    # max(terms) bound (what fraction of roofline the step reaches if
    # perfectly overlapped: step_time = max(terms))
    roofline_fraction: float
    arithmetic_intensity: float   # flops/byte, per device
    collective_breakdown: dict
    # for memory-dominant steps (decode!): analytic minimum bytes the step
    # must stream (params + state, once) / bytes it actually streams —
    # 1.0 = bandwidth roof. 0 when the caller provides no model_bytes.
    memory_roof_fraction: float = 0.0
    model_bytes: float = 0.0
    note: str = ""

    def to_row(self) -> dict:
        return {
            "name": self.name,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_compute_ratio": self.useful_compute_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_roof_fraction": self.memory_roof_fraction,
            "arithmetic_intensity": self.arithmetic_intensity,
        }


def roofline_from_analysis(
    analysis: HloAnalysis,
    *,
    name: str,
    n_chips: int,
    model_flops: float,
    model_bytes: float = 0.0,
    machine: Machine = TPU_V5E,
    note: str = "",
) -> RooflineReport:
    compute_s = analysis.flops / machine.peak_flops
    memory_s = analysis.hbm_bytes / machine.hbm_bw
    collective_s = (analysis.collective_bytes / machine.link_bw
                    if machine.link_bw else 0.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = analysis.flops * n_chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    # if the step runs at max(terms) (perfect overlap), the fraction of the
    # compute roofline achieved on USEFUL flops is:
    step_time = max(terms.values())
    useful_flops_per_device = model_flops / n_chips
    roofline_fraction = (useful_flops_per_device / machine.peak_flops
                         / step_time if step_time else 0.0)
    return RooflineReport(
        name=name,
        machine=machine.name,
        n_chips=n_chips,
        flops_per_device=analysis.flops,
        hbm_bytes_per_device=analysis.hbm_bytes,
        collective_bytes_per_device=analysis.collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        hlo_flops_global=hlo_flops_global,
        useful_compute_ratio=useful,
        roofline_fraction=roofline_fraction,
        arithmetic_intensity=(analysis.flops / analysis.hbm_bytes
                              if analysis.hbm_bytes else 0.0),
        collective_breakdown=analysis.collective_breakdown,
        memory_roof_fraction=(model_bytes / n_chips / analysis.hbm_bytes
                              if model_bytes and analysis.hbm_bytes else 0.0),
        model_bytes=model_bytes,
        note=note,
    )


def roofline_of_compiled(
    compiled,
    *,
    name: str,
    n_chips: int,
    model_flops: float,
    machine: Machine = TPU_V5E,
    trip_count_fallback: int = 1,
    note: str = "",
) -> tuple[RooflineReport, HloAnalysis]:
    """Analyze a `jax.stages.Compiled` object end-to-end."""
    analysis = analyze_hlo(compiled.as_text(),
                           trip_count_fallback=trip_count_fallback)
    report = roofline_from_analysis(
        analysis, name=name, n_chips=n_chips, model_flops=model_flops,
        machine=machine, note=note)
    return report, analysis


def what_would_move_it(report: RooflineReport) -> str:
    """One-sentence §Roofline guidance for the dominant term."""
    if report.dominant == "compute":
        if report.useful_compute_ratio < 0.6:
            return ("compute-bound with low useful ratio "
                    f"({report.useful_compute_ratio:.2f}): cut remat recompute "
                    "and sharding-replicated matmuls before anything else")
        return ("compute-bound at high useful ratio: only larger per-chip "
                "tiles / lower precision move this")
    if report.dominant == "memory":
        return ("memory-bound: fuse elementwise chains, keep weights/KV in "
                "bf16 or lower, and raise arithmetic intensity (larger batch "
                "per chip) — the PIM-suitability regime of the paper")
    return ("collective-bound: reshard to cut the largest collective "
            f"({max(report.collective_breakdown, key=report.collective_breakdown.get) if report.collective_breakdown else 'n/a'}), "
            "overlap collectives with compute, or move the traffic to a "
            "bank-local phase (paper Takeaway 3)")


def render_markdown_table(reports: list[RooflineReport]) -> str:
    hdr = ("| cell | dominant | compute (s) | memory (s) | collective (s) | "
           "AI (F/B) | useful | roofline frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = []
    for r in reports:
        rows.append(
            f"| {r.name} | **{r.dominant}** | {r.compute_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | "
            f"{r.arithmetic_intensity:.1f} | {r.useful_compute_ratio:.2f} | "
            f"{r.roofline_fraction:.3f} | {r.note or what_would_move_it(r)} |")
    return "\n".join([hdr] + rows)


def dump_reports(reports: list[RooflineReport], path: str) -> None:
    with open(path, "w") as f:
        json.dump([dataclasses.asdict(r) for r in reports], f, indent=1)
