"""repro.core — the paper's contribution as a composable library.

Layers (DESIGN.md §3):
  * pim_model      — machine models (TPU v5e target; UPMEM/CPU/GPU baselines)
  * bank_parallel  — the UPMEM bank-parallel execution model on shard_map
  * hlo_analysis   — FLOP/byte/collective census of compiled XLA programs
  * roofline       — the three-term roofline characterization engine
  * suitability    — Key-Takeaway-1/2/3 workload scoring
  * perf_model     — calibrated cross-system comparison (paper Fig. 4)
"""

from .bank_parallel import BankGrid, make_bank_mesh, assert_local, BANK_AXIS
from .hlo_analysis import HloAnalysis, analyze_hlo, op_mix
from .pim_model import (DPUModel, Machine, MACHINES, TPU_V5E, TITAN_V,
                        UPMEM_2556, UPMEM_640, XEON_E3_1240)
from .perf_model import Comparison, Figure4, WorkloadCounts, compare
from .roofline import (RooflineReport, roofline_from_analysis,
                       roofline_of_compiled, render_markdown_table,
                       what_would_move_it)
from .suitability import SuitabilityReport, score
