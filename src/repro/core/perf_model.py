"""Calibrated cross-system performance/energy model (paper Fig. 4).

The container has no UPMEM parts, no Xeon E3-1240 and no Titan V, so the
paper's headline comparison is reproduced the way real-hardware studies are
reproduced offline: an analytic model over *measured workload counts*.

  * Workload counts (bytes streamed, op mix, inter-bank bytes) come from the
    PrIM implementations in `repro.prim` — each workload exposes `counts(n)`
    derived from its actual phase structure, cross-checked in tests against
    the HLO census of the compiled JAX implementation.
  * Machine constants come from `pim_model` (paper + public spec sheets).

Validation targets (tests/test_perf_model.py, EXPERIMENTS.md §Paper-claims):
  - 2556-DPU vs CPU average speedup ~= 23.2x   (paper KT4)
  - 640-DPU  vs CPU average speedup ~= 10.1x   (paper KT4)
  - 2556-DPU vs GPU ~= 2.54x on the 10 PIM-suitable benchmarks (paper KT4)
  - 640-DPU energy efficiency vs CPU > 1 on suitable workloads
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from .pim_model import (DPUModel, Machine, TITAN_V, UPMEM_2556, UPMEM_640,
                        XEON_E3_1240)


@dataclasses.dataclass(frozen=True)
class WorkloadCounts:
    """Analytic counts for one PrIM workload at a given input size."""
    name: str
    ops: dict            # {(op, dtype): count} across the whole workload
    bytes_streamed: float  # bytes each system must move through memory
    interbank_bytes: float  # inter-DPU traffic (through the host on UPMEM)
    flops_equiv: float     # flop-equivalent count for CPU/GPU compute bound
    pim_suitable: bool     # paper Fig. 4 grouping (for validation only)
    # optional overrides when a system's traffic differs (e.g. CPU caches
    # a small LUT that PIM must re-stream)
    bytes_cpu: float | None = None
    bytes_gpu: float | None = None


# --- system power draw (W), calibrated against the paper's energy anchor
# (640-DPU system 1.64x more energy-efficient than the CPU, KT4);
# documented in DESIGN.md §2 and EXPERIMENTS.md §Paper-claims ----------------
POWER = {
    "xeon": 90.0,             # E3-1240 TDP 72W + DRAM
    "titan_v": 340.0,         # 250W TDP + host
    "upmem_640": 520.0,       # host + 10 PIM DIMMs (whole-server draw)
    "upmem_2556": 1250.0,     # host + 40 PIM DIMMs
}


@dataclasses.dataclass
class SystemTime:
    system: str
    compute_s: float
    memory_s: float
    comm_s: float
    total_s: float
    energy_j: float


def time_on_pim(counts: WorkloadCounts, dpu: DPUModel) -> SystemTime:
    per_dpu_ops = {k: v / dpu.n_dpus for k, v in counts.ops.items()}
    t_compute = dpu.compute_time(per_dpu_ops)
    t_mem = dpu.mram_time(counts.bytes_streamed / dpu.n_dpus)
    t_comm = dpu.interdpu_time(counts.interbank_bytes)
    # DPU arithmetic shares the pipeline with WRAM loads: not overlappable.
    # MRAM DMA overlaps with compute across tasklets -> max().
    total = max(t_compute, t_mem) + t_comm + dpu.launch_overhead_s
    name = f"upmem_{dpu.n_dpus}"
    key = "upmem_640" if dpu.n_dpus <= 640 else "upmem_2556"
    return SystemTime(name, t_compute, t_mem, t_comm, total,
                      total * POWER[key])


def time_on_host(counts: WorkloadCounts, machine: Machine,
                 power_key: str) -> SystemTime:
    nbytes = counts.bytes_streamed
    if power_key == "xeon" and counts.bytes_cpu is not None:
        nbytes = counts.bytes_cpu
    if power_key == "titan_v" and counts.bytes_gpu is not None:
        nbytes = counts.bytes_gpu
    t_compute = counts.flops_equiv / machine.peak_flops
    t_mem = nbytes / machine.hbm_bw
    total = max(t_compute, t_mem)
    return SystemTime(machine.name, t_compute, t_mem, 0.0, total,
                      total * POWER[power_key])


@dataclasses.dataclass
class Comparison:
    name: str
    pim_suitable: bool
    times: dict          # system -> SystemTime
    speedup_vs_cpu_2556: float
    speedup_vs_cpu_640: float
    speedup_vs_gpu_2556: float
    energy_eff_vs_cpu_640: float


def compare(counts: WorkloadCounts) -> Comparison:
    t_cpu = time_on_host(counts, XEON_E3_1240, "xeon")
    t_gpu = time_on_host(counts, TITAN_V, "titan_v")
    t_2556 = time_on_pim(counts, UPMEM_2556)
    t_640 = time_on_pim(counts, UPMEM_640)
    return Comparison(
        name=counts.name,
        pim_suitable=counts.pim_suitable,
        times={"cpu": t_cpu, "gpu": t_gpu, "upmem_2556": t_2556,
               "upmem_640": t_640},
        speedup_vs_cpu_2556=t_cpu.total_s / t_2556.total_s,
        speedup_vs_cpu_640=t_cpu.total_s / t_640.total_s,
        speedup_vs_gpu_2556=t_gpu.total_s / t_2556.total_s,
        energy_eff_vs_cpu_640=t_cpu.energy_j / t_640.energy_j,
    )


def geomean(xs: Iterable[float]) -> float:
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


@dataclasses.dataclass
class Figure4:
    comparisons: list[Comparison]

    @property
    def avg_speedup_2556_vs_cpu(self) -> float:
        return geomean(c.speedup_vs_cpu_2556 for c in self.comparisons)

    @property
    def avg_speedup_640_vs_cpu(self) -> float:
        return geomean(c.speedup_vs_cpu_640 for c in self.comparisons)

    @property
    def avg_speedup_2556_vs_gpu_suitable(self) -> float:
        return geomean(c.speedup_vs_gpu_2556 for c in self.comparisons
                       if c.pim_suitable)

    @property
    def avg_energy_eff_640_vs_cpu(self) -> float:
        return geomean(c.energy_eff_vs_cpu_640 for c in self.comparisons)

    def render(self) -> str:
        lines = [
            "| benchmark | suitable | 2556-DPU/CPU | 640-DPU/CPU | "
            "2556-DPU/GPU | energy-eff 640/CPU |",
            "|---|---|---|---|---|---|",
        ]
        for c in self.comparisons:
            lines.append(
                f"| {c.name} | {'Y' if c.pim_suitable else 'n'} | "
                f"{c.speedup_vs_cpu_2556:8.2f}x | {c.speedup_vs_cpu_640:8.2f}x | "
                f"{c.speedup_vs_gpu_2556:8.2f}x | {c.energy_eff_vs_cpu_640:8.2f}x |")
        lines.append(
            f"| **geomean** |  | **{self.avg_speedup_2556_vs_cpu:.1f}x** "
            f"(paper: 23.2x) | **{self.avg_speedup_640_vs_cpu:.1f}x** "
            f"(paper: 10.1x) | **{self.avg_speedup_2556_vs_gpu_suitable:.2f}x** "
            f"suitable-only (paper: 2.54x) | "
            f"**{self.avg_energy_eff_640_vs_cpu:.2f}x** (paper: 1.64x) |")
        return "\n".join(lines)
