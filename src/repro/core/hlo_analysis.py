"""HLO text analysis: FLOPs, HBM traffic, and collective census from compiled HLO.

This module is the measurement engine behind the paper's methodology
(roofline characterization of a memory-centric system, Gomez-Luna et al.
2021). It parses ``compiled.as_text()`` — the post-SPMD-partitioning,
per-device HLO module — and produces:

  * ``flops``            — matmul-dominated FLOP count (dot/conv + elementwise
                           estimate), with ``while`` bodies multiplied by their
                           parsed trip counts (XLA's cost_analysis counts loop
                           bodies ONCE; we correct that).
  * ``hbm_bytes``        — per-instruction operand+output bytes (the
                           HloCostAnalysis "bytes accessed" convention), again
                           trip-count corrected. Under full fusion this is a
                           good model of HBM traffic.
  * ``collectives``      — every all-gather / all-reduce / reduce-scatter /
                           all-to-all / collective-permute with operand bytes,
                           group size, and replica-group structure.

Known caveats (documented in DESIGN.md §8): ``lowered.as_text()`` has no
collectives (pre-partitioning); only ``compiled.as_text()`` is useful here.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict
from typing import Callable, Iterable

# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2,
    "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "tuple": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


@dataclasses.dataclass(frozen=True)
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return int(self.elements * _DTYPE_BYTES.get(self.dtype, 4))


def parse_shapes(type_str: str) -> list[Shape]:
    """Parse all array shapes out of an HLO type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        dim_t = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append(Shape(dtype, dim_t))
    return out


def type_bytes(type_str: str) -> int:
    return sum(s.bytes for s in parse_shapes(type_str))


# ---------------------------------------------------------------------------
# HLO module parsing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HloOp:
    name: str
    type_str: str
    opcode: str
    operands: tuple[str, ...]
    attrs: str  # raw attribute tail (replica_groups=..., body=..., metadata=...)
    raw_operands: str = ""  # literal text inside the opcode parens
    is_root: bool = False

    @property
    def out_bytes(self) -> int:
        return type_bytes(self.type_str)

    @property
    def out_shapes(self) -> list[Shape]:
        return parse_shapes(self.type_str)

    def attr(self, key: str) -> str | None:
        m = re.search(rf"{key}=(%?[\w\.\-]+)", self.attrs)
        return m.group(1) if m else None


@dataclasses.dataclass
class HloComputation:
    name: str
    ops: dict[str, HloOp]
    order: list[str]


@dataclasses.dataclass
class HloModule:
    name: str
    computations: dict[str, HloComputation]
    entry: str


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_OP_RE = re.compile(r"^\s+(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")


def _split_type_and_rest(rhs: str) -> tuple[str, str]:
    """Split 'TYPE opcode(...)...' where TYPE may be a tuple with spaces."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1:].lstrip()
        return rhs, ""
    sp = rhs.find(" ")
    if sp < 0:
        return rhs, ""
    return rhs[:sp], rhs[sp + 1:].lstrip()


_OPCODE_RE = re.compile(r"^([a-z][\w\-]*)\(")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def parse_hlo_text(text: str) -> HloModule:
    module_name = "module"
    m = re.match(r"HloModule\s+([\w\.\-]+)", text)
    if m:
        module_name = m.group(1)

    computations: dict[str, HloComputation] = {}
    entry = ""
    cur_name: str | None = None
    cur_ops: dict[str, HloOp] = {}
    cur_order: list[str] = []

    for line in text.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            if line.startswith("}"):
                if cur_name is not None:
                    computations[cur_name] = HloComputation(cur_name, cur_ops, cur_order)
                cur_name, cur_ops, cur_order = None, {}, []
                continue
            hm = _COMP_HEADER_RE.match(line)
            if hm:
                cur_name = hm.group(1)
                cur_ops, cur_order = {}, []
                if line.startswith("ENTRY"):
                    entry = cur_name
            continue
        if cur_name is None:
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        is_root, op_name, rhs = bool(om.group(1)), om.group(2), om.group(3)
        type_str, rest = _split_type_and_rest(rhs)
        cm = _OPCODE_RE.match(rest)
        if not cm:
            continue
        opcode = cm.group(1)
        # operand list: balanced parens right after the opcode
        depth, start, end = 0, rest.find("("), len(rest)
        for i in range(start, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[start + 1: end]
        attrs = rest[end + 1:]
        operands = tuple(_OPERAND_NAME_RE.findall(operand_str))
        cur_ops[op_name] = HloOp(op_name, type_str, opcode, operands, attrs,
                                 operand_str, is_root)
        cur_order.append(op_name)

    if cur_name is not None:
        computations[cur_name] = HloComputation(cur_name, cur_ops, cur_order)
    if not entry and computations:
        entry = list(computations)[-1]
    return HloModule(module_name, computations, entry)


# ---------------------------------------------------------------------------
# FLOP / byte / collective accounting
# ---------------------------------------------------------------------------

COLLECTIVE_OPCODES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# opcodes that carry no HBM traffic of their own
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}

_ELEMENTWISE_FLOP_HINT = {
    # rough per-output-element flop counts for common non-dot compute
    "exponential": 8, "log": 8, "rsqrt": 4, "sqrt": 4, "tanh": 8,
    "logistic": 8, "divide": 4, "power": 10, "sine": 8, "cosine": 8,
    "erf": 8,
}


@dataclasses.dataclass
class CollectiveInfo:
    opcode: str
    bytes: int            # operand bytes (spec convention), x trip multiplier
    count: int            # dynamic count (trip-corrected)
    group_size: int
    replica_groups: str
    op_name: str          # HLO op name (first occurrence)


@dataclasses.dataclass
class HloAnalysis:
    flops: float
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    collectives: list[CollectiveInfo]
    op_census: Counter            # opcode -> dynamic count
    dot_details: list[dict]       # per-dot: flops, shapes, metadata name, count
    trip_counts: dict[str, int]   # while op name -> parsed trip count
    largest_tensors: list[tuple[int, str, str]]  # (bytes, opname, type)

    @property
    def collective_breakdown(self) -> dict[str, int]:
        d: dict[str, int] = defaultdict(int)
        for c in self.collectives:
            d[c.opcode] += c.bytes
        return dict(d)


def _parse_dims_attr(attrs: str, key: str) -> tuple[int, ...]:
    m = re.search(rf"{key}={{([0-9,]*)}}", attrs)
    if not m or not m.group(1):
        return ()
    return tuple(int(x) for x in m.group(1).split(","))


def _dot_flops(op: HloOp, comp: HloComputation) -> float:
    """2 * prod(output dims) * prod(lhs contracting dims)."""
    out = op.out_shapes
    if not out:
        return 0.0
    out_elems = out[0].elements
    lhs_name = op.operands[0] if op.operands else None
    lhs_op = comp.ops.get(lhs_name) if lhs_name else None
    k = 1
    if lhs_op is not None and lhs_op.out_shapes:
        lhs_shape = lhs_op.out_shapes[0]
        for d in _parse_dims_attr(op.attrs, "lhs_contracting_dims"):
            if d < len(lhs_shape.dims):
                k *= lhs_shape.dims[d]
    return 2.0 * out_elems * k


def _conv_flops(op: HloOp, comp: HloComputation) -> float:
    """Approximate: 2 * out_elems * (kernel spatial elems * in_channels)."""
    out = op.out_shapes
    rhs_name = op.operands[1] if len(op.operands) > 1 else None
    rhs_op = comp.ops.get(rhs_name) if rhs_name else None
    if not out or rhs_op is None or not rhs_op.out_shapes:
        return 0.0
    kernel_elems = rhs_op.out_shapes[0].elements
    # kernel = spatial x in_ch x out_ch; out includes out_ch, so divide by it
    out_shape = out[0]
    feature = out_shape.dims[-1] if out_shape.dims else 1
    return 2.0 * out_shape.elements * max(kernel_elems // max(feature, 1), 1)


def _group_size(attrs: str, fallback: int = 1) -> int:
    # iota form: replica_groups=[num_groups,group_size]<=[...]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2},{...}}
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return fallback


def _replica_groups_str(attrs: str) -> str:
    m = re.search(r"replica_groups=(\[[^ ]*|\{\{[^}]*\}[^,]*)", attrs)
    return m.group(1)[:80] if m else ""


class _Accumulator:
    def __init__(self, module: HloModule, trip_count_fallback: int):
        self.module = module
        self.flops = 0.0
        self.dot_flops = 0.0
        self.hbm_bytes = 0.0
        self.op_census: Counter = Counter()
        self.coll: dict[str, CollectiveInfo] = {}
        self.dot_details: list[dict] = []
        self.trip_counts: dict[str, int] = {}
        self.largest: list[tuple[int, str, str]] = []
        self.trip_count_fallback = trip_count_fallback
        self._raw_text_cache: dict[str, str] = {}

    def trip_count_of(self, op: HloOp) -> int:
        cond_name = (op.attr("condition") or "").lstrip("%")
        cond = self.module.computations.get(cond_name)
        if cond is None:
            return self.trip_count_fallback
        # scan conds hold the loop bound as an s32[] scalar constant whose
        # literal value sits in the operand parens: `s32[] constant(126)`
        best = 0
        for c_op in cond.ops.values():
            if c_op.opcode == "constant" and c_op.type_str.startswith("s32[]"):
                lit = c_op.raw_operands.strip()
                if lit.lstrip("-").isdigit():
                    best = max(best, int(lit))
        return best if best > 0 else self.trip_count_fallback

    def visit(self, comp_name: str, multiplier: float, for_traffic: bool = True):
        comp = self.module.computations.get(comp_name)
        if comp is None:
            return
        for op_name in comp.order:
            op = comp.ops[op_name]
            oc = op.opcode
            self.op_census[oc] += int(multiplier) if multiplier >= 1 else 1

            if oc == "while":
                body = (op.attr("body") or "").lstrip("%")
                tc = self.trip_count_of(op)
                self.trip_counts[op.name] = tc
                self.visit(body, multiplier * tc, for_traffic=for_traffic)
                continue
            if oc in ("call",):
                callee = (op.attr("to_apply") or "").lstrip("%")
                self.visit(callee, multiplier, for_traffic=for_traffic)
                continue
            if oc == "conditional":
                # visit all branches once (upper bound)
                for key in ("true_computation", "false_computation"):
                    br = (op.attr(key) or "").lstrip("%")
                    if br:
                        self.visit(br, multiplier, for_traffic=for_traffic)
                continue

            # --- FLOPs ---
            if oc == "dot":
                f = _dot_flops(op, comp) * multiplier
                self.flops += f
                self.dot_flops += f
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                self.dot_details.append({
                    "flops": f, "type": op.type_str, "count": multiplier,
                    "op_name": meta.group(1) if meta else op.name,
                })
            elif oc == "convolution":
                f = _conv_flops(op, comp) * multiplier
                self.flops += f
                self.dot_flops += f
            elif oc == "fusion":
                callee = (op.attr("calls") or "").lstrip("%")
                self._visit_fusion_flops(callee, multiplier)
                self.flops += op.out_shapes[0].elements * multiplier if op.out_shapes else 0
            elif oc in ("reduce", "reduce-window"):
                in_op = comp.ops.get(op.operands[0]) if op.operands else None
                if in_op is not None and in_op.out_shapes:
                    self.flops += in_op.out_shapes[0].elements * multiplier
            elif oc in _ELEMENTWISE_FLOP_HINT:
                self.flops += (op.out_shapes[0].elements if op.out_shapes else 0) \
                    * _ELEMENTWISE_FLOP_HINT[oc] * multiplier
            elif oc in ("add", "subtract", "multiply", "maximum", "minimum",
                        "and", "or", "xor", "select", "compare"):
                self.flops += (op.out_shapes[0].elements if op.out_shapes else 0) * multiplier

            # --- collectives ---
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVE_OPCODES and not oc.endswith("-done"):
                operand_bytes = 0
                for on in op.operands:
                    src = comp.ops.get(on)
                    if src is not None:
                        operand_bytes += src.out_bytes
                if operand_bytes == 0:
                    operand_bytes = op.out_bytes  # fallback
                gs = _group_size(op.attrs)
                key = f"{base}:{op.name}"
                info = self.coll.get(key)
                nbytes = int(operand_bytes * multiplier)
                if info is None:
                    self.coll[key] = CollectiveInfo(
                        base, nbytes, int(max(multiplier, 1)), gs,
                        _replica_groups_str(op.attrs), op.name)
                else:
                    info.bytes += nbytes
                    info.count += int(max(multiplier, 1))

            # --- HBM traffic ---
            if for_traffic and oc not in _NO_TRAFFIC:
                b = self._op_traffic(op, comp)
                self.hbm_bytes += b * multiplier
                if op.out_bytes > 0:
                    self.largest.append((op.out_bytes, op.name, op.type_str[:60]))

    # ------------------------------------------------------------------
    # traffic model: bytes an op actually moves through HBM. The naive
    # "operands + outputs at full size" convention overcounts slicing ops
    # catastrophically inside loops (a dynamic-slice reads its SLICE, but
    # its operand is the whole buffer — measured 95% of a 405B train
    # step's traffic before this correction). Slice-like ops are charged
    # at slice granularity; in-place update buffers are charged at update
    # granularity (the rest of the buffer is aliased, not copied).
    # ------------------------------------------------------------------

    _SLICE_READERS = ("dynamic-slice", "gather")
    _INPLACE = ("dynamic-update-slice", "scatter")
    # ops a pure layout/precision-change fusion may contain. XLA:CPU
    # legalizes bf16 dots by materializing f32 copies of their operands
    # (weights, KV caches) — kLoop convert fusions a TPU/Mosaic build never
    # emits. They are charged ZERO traffic (TPU projection); the residual
    # inflation is dots reading f32-sized operands (<= 2x), documented in
    # DESIGN.md §8.
    _LAYOUT_ONLY = {"parameter", "constant", "convert", "bitcast", "copy",
                    "transpose", "broadcast", "reshape", "tuple",
                    "get-tuple-element"}

    def _op_traffic(self, op: HloOp, comp: HloComputation) -> float:
        oc = op.opcode
        if oc == "fusion":
            return self._fusion_traffic(op, comp)
        if oc in self._SLICE_READERS:
            # read the slice + indices, write the slice
            return 2.0 * op.out_bytes
        if oc in self._INPLACE:
            # buffer (operand 0) is aliased; traffic = update read+write
            upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
            ub = upd.out_bytes if upd is not None else op.out_bytes
            return 2.0 * ub
        b = float(op.out_bytes)
        for on in op.operands:
            src = comp.ops.get(on)
            if src is not None and src.opcode not in ("constant",):
                b += src.out_bytes
        return b

    def _fusion_traffic(self, op: HloOp, comp: HloComputation) -> float:
        """Charge fused parameters at what the fused computation actually
        reads from them: slice-sized for params consumed only by
        dynamic-slice/gather, zero for in-place-updated buffers (aliased),
        full size otherwise. Output side: a fusion rooted in
        dynamic-update-slice writes only the update region."""
        callee = (op.attr("calls") or "").lstrip("%")
        fused = self.module.computations.get(callee)
        if fused is None:
            b = float(op.out_bytes)
            for on in op.operands:
                src = comp.ops.get(on)
                if src is not None:
                    b += src.out_bytes
            return b

        if all(f.opcode in self._LAYOUT_ONLY for f in fused.ops.values()):
            return 0.0      # CPU-backend bf16-legalization artifact

        # parameter index -> fused-computation op name
        param_names: dict[int, str] = {}
        for f_op in fused.ops.values():
            if f_op.opcode == "parameter":
                lit = f_op.raw_operands.strip()
                if lit.isdigit():
                    param_names[int(lit)] = f_op.name

        # consumers of each fused op
        consumers: dict[str, list[HloOp]] = defaultdict(list)
        for f_op in fused.ops.values():
            for on in f_op.operands:
                consumers[on].append(f_op)

        _PASS_THROUGH = ("convert", "bitcast", "copy", "reshape",
                         "transpose", "broadcast")

        def effective_consumers(name: str, depth: int = 0) -> list[HloOp]:
            """Consumers reached through pure layout/precision ops."""
            out: list[HloOp] = []
            for c in consumers.get(name, []):
                if c.opcode in _PASS_THROUGH and depth < 6:
                    out.extend(effective_consumers(c.name, depth + 1))
                else:
                    out.append(c)
            return out

        total = 0.0
        for i, on in enumerate(op.operands):
            src = comp.ops.get(on)
            if src is None or src.opcode == "constant":
                continue
            full = src.out_bytes
            pname = param_names.get(i)
            cons = effective_consumers(pname) if pname else []
            slice_like = self._SLICE_READERS + self._INPLACE
            if cons and all(c.opcode in slice_like for c in cons):
                # reads at slice granularity; in-place updates alias the
                # buffer (their write is charged on the output side)
                total += sum(c.out_bytes for c in cons
                             if c.opcode in self._SLICE_READERS)
            else:
                total += full

        # output: DUS-rooted fusions (possibly behind layout ops) write
        # the update region only
        root = next((fused.ops[n] for n in fused.order
                     if fused.ops[n].is_root), None)
        out_b = float(op.out_bytes)
        seen = 0
        while root is not None and root.opcode in _PASS_THROUGH \
                and root.operands and seen < 6:
            root = fused.ops.get(root.operands[0])
            seen += 1
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) > 1:
            upd = fused.ops.get(root.operands[1])
            if upd is not None:
                out_b = float(upd.out_bytes)
        return total + out_b

    def _visit_fusion_flops(self, comp_name: str, multiplier: float):
        comp = self.module.computations.get(comp_name)
        if comp is None:
            return
        for op in comp.ops.values():
            if op.opcode == "dot":
                f = _dot_flops(op, comp) * multiplier
                self.flops += f
                self.dot_flops += f
            elif op.opcode == "fusion":
                callee = (op.attr("calls") or "").lstrip("%")
                self._visit_fusion_flops(callee, multiplier)
            elif op.opcode not in _NO_TRAFFIC:
                # census fused elementwise ops for the Takeaway-2 op mix
                self.op_census[op.opcode] += int(max(multiplier, 1))


def analyze_hlo(text: str, trip_count_fallback: int = 1) -> HloAnalysis:
    """Analyze a post-partitioning HLO module (``compiled.as_text()``).

    Returns per-device FLOPs / bytes / collective census with while-loop
    bodies multiplied by parsed trip counts.
    """
    module = parse_hlo_text(text)
    acc = _Accumulator(module, trip_count_fallback)
    acc.visit(module.entry, 1.0)
    colls = sorted(acc.coll.values(), key=lambda c: -c.bytes)
    largest = sorted(acc.largest, key=lambda t: -t[0])[:20]
    return HloAnalysis(
        flops=acc.flops,
        dot_flops=acc.dot_flops,
        hbm_bytes=acc.hbm_bytes,
        collective_bytes=float(sum(c.bytes for c in colls)),
        collectives=colls,
        op_census=acc.op_census,
        dot_details=sorted(acc.dot_details, key=lambda d: -d["flops"])[:50],
        trip_counts=acc.trip_counts,
        largest_tensors=largest,
    )


def op_mix(analysis: HloAnalysis) -> dict[str, float]:
    """Paper Takeaway-2 style op-mix census: fraction of dynamic ops that are
    'simple' (add/sub/bitwise/compare) vs 'complex' (mul/div/transcendental)
    vs matmul."""
    simple = complex_ = matmul = other = 0
    simple_ops = {"add", "subtract", "and", "or", "xor", "not", "compare",
                  "select", "maximum", "minimum", "shift-left",
                  "shift-right-logical", "shift-right-arithmetic"}
    complex_ops = {"multiply", "divide", "power", "exponential", "log",
                   "rsqrt", "sqrt", "tanh", "logistic", "sine", "cosine",
                   "remainder", "erf", "atan2"}
    for oc, n in analysis.op_census.items():
        if oc in simple_ops:
            simple += n
        elif oc in complex_ops:
            complex_ += n
        elif oc in ("dot", "convolution"):
            matmul += n
        else:
            other += n
    total = max(simple + complex_ + matmul, 1)
    return {
        "simple_frac": simple / total,
        "complex_frac": complex_ / total,
        "matmul_frac": matmul / total,
        "total_arith_ops": simple + complex_ + matmul,
    }
