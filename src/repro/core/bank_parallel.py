"""Bank-parallel execution: the UPMEM programming model on shard_map.

UPMEM programs are structured as (paper §I, Fig. 1):

    host scatter -> [bank-local kernel on each DPU's MRAM shard]
                 -> host-mediated exchange (there is NO DPU<->DPU channel)
                 -> [bank-local kernel] -> ... -> host gather

We map this 1:1 onto a TPU mesh axis (DESIGN.md §2): a *bank* is one mesh
device, the bank's MRAM is its shard, and every inter-bank exchange is an
explicit collective at a phase boundary. The discipline "no communication
inside a local phase" is enforced by `assert_local` (lowering the phase and
checking the HLO census for collectives) and is exactly what makes a
workload PIM-suitable per Takeaway 3.

All 16 PrIM workloads in `repro.prim` are written against this API, with the
same phase structure as their UPMEM originals (e.g. RED = local reduce +
cross-bank tree; SCAN-SSA = local scan, exchange bank sums, local add).
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax >= 0.5 exposes jax.shard_map(check_vma=...); 0.4.x has the
# experimental path with the older check_rep spelling
if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:  # pragma: no cover - exercised on jax 0.4.x containers
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


BANK_AXIS = "banks"


def make_bank_mesh(n_banks: int | None = None, axis: str = BANK_AXIS) -> Mesh:
    """A 1-D mesh of banks over the available devices."""
    devs = jax.devices()
    n = n_banks or len(devs)
    if n > len(devs):
        raise ValueError(f"asked for {n} banks, have {len(devs)} devices")
    return jax.make_mesh((n,), (axis,))


@dataclasses.dataclass(frozen=True)
class BankGrid:
    """A bank-parallel execution context over one mesh axis."""
    mesh: Mesh
    axis: str = BANK_AXIS

    @property
    def n_banks(self) -> int:
        return self.mesh.shape[self.axis]

    def shard(self, *per_dim: bool):
        """PartitionSpec sharding dim 0 (or flagged dims) over banks."""
        if not per_dim:
            return NamedSharding(self.mesh, P(self.axis))
        spec = [self.axis if f else None for f in per_dim]
        return NamedSharding(self.mesh, P(*spec))

    def replicated(self):
        return NamedSharding(self.mesh, P())

    # ---------------------------------------------------------------
    # local phases
    # ---------------------------------------------------------------
    def local(self, fn: Callable, in_specs, out_specs,
              check_rep: bool = False) -> Callable:
        """A bank-local phase: fn runs on each bank's shard. Collectives
        inside `fn` are a programming error (Takeaway 3) — use exchange
        phases instead; `assert_local` verifies."""
        return _shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, **{_CHECK_KW: check_rep})

    def bank_map(self, fn: Callable) -> Callable:
        """Common case: every arg sharded on dim 0, every output too."""
        return self.local(fn, in_specs=P(self.axis), out_specs=P(self.axis))

    # ---------------------------------------------------------------
    # exchange phases (the "through the host" step on UPMEM; an ICI
    # collective here — the cost difference is what perf_model charges)
    # ---------------------------------------------------------------
    def exchange_reduce(self, x, op: str = "add"):
        """All banks end with the reduction of per-bank values."""
        def f(v):
            if op == "add":
                return jax.lax.psum(v, self.axis)
            if op == "max":
                return jax.lax.pmax(v, self.axis)
            if op == "min":
                return jax.lax.pmin(v, self.axis)
            raise ValueError(op)
        return self.local(f, in_specs=P(self.axis), out_specs=P(self.axis))(x)

    def exchange_gather(self, x):
        """Every bank receives the concatenation of all bank shards."""
        f = lambda v: jax.lax.all_gather(v, self.axis, axis=0, tiled=True)
        return self.local(f, in_specs=P(self.axis), out_specs=P())(x)

    def exchange_scan_sums(self, bank_vals):
        """Exclusive scan across banks of per-bank scalars (SCAN-SSA's
        host phase): bank i receives sum of banks [0, i)."""
        def f(v):
            idx = jax.lax.axis_index(self.axis)
            allv = jax.lax.all_gather(v, self.axis, axis=0)
            mask = (jnp.arange(self.n_banks) < idx)[(...,) + (None,) * (allv.ndim - 1)]
            return jnp.sum(jnp.where(mask, allv, 0), axis=0)
        return self.local(f, in_specs=P(self.axis), out_specs=P(self.axis))(bank_vals)

    def exchange_shift(self, x, offset: int = 1):
        """Neighbor handshake (NW's wavefront halo, TS's lookahead halo):
        bank i gets bank i-offset's value; edge banks get zeros."""
        def f(v):
            n = self.n_banks
            if offset >= 0:
                perm = [(i, i + offset) for i in range(n - offset)]
            else:
                perm = [(i, i + offset) for i in range(-offset, n)]
            return jax.lax.ppermute(v, self.axis, perm)
        return self.local(f, in_specs=P(self.axis), out_specs=P(self.axis))(x)


# ---------------------------------------------------------------------
# Phase-discipline verification (used by tests & suitability analysis)
# ---------------------------------------------------------------------

# matches both HLO ("all-reduce") and StableHLO ("stablehlo.all_reduce")
_COLLECTIVE_HLO = re.compile(
    r"\b(all[-_]gather|all[-_]reduce|reduce[-_]scatter|all[-_]to[-_]all|"
    r"collective[-_]permute)\b")


def count_collectives_in(fn: Callable, *example_args) -> int:
    """Lower fn and count collective ops — 0 for a legal bank-local phase."""
    txt = jax.jit(fn).lower(*example_args).as_text()
    return len(_COLLECTIVE_HLO.findall(txt))


def assert_local(fn: Callable, *example_args) -> None:
    n = count_collectives_in(fn, *example_args)
    if n:
        raise AssertionError(
            f"bank-local phase contains {n} collective op(s); inter-bank "
            "communication must go through an exchange phase (Takeaway 3)")
