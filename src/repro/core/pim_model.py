"""Machine models: TPU v5e target + the paper's four measured systems.

The UPMEM DPU model is an instruction-level analytic model calibrated against
the paper's published measurements (Figs. 2-4 of Gomez-Luna et al. 2021 and
the full arXiv:2105.03814 characterization):

  * DPU: 350 MHz in-order core, fine-grained multithreaded over tasklets;
    the 14-stage pipeline sustains ~1 instruction/cycle once >=11 tasklets
    are resident. Only integer add/sub/bitwise are native; 32-bit mul/div
    and all floating point are software routines (Takeaway 2).
  * MRAM streaming bandwidth ~630 MB/s/DPU sustained (700 MB/s theoretical).
  * No DPU<->DPU channel: inter-DPU traffic goes through the host over the
    DDR4 bus (Takeaway 3).

Validation targets (EXPERIMENTS.md §Paper-claims): 2556-DPU vs CPU ~ 23.2x,
640-DPU vs CPU ~ 10.1x, 2556-DPU vs Titan V ~ 2.54x on the 10 PIM-suitable
benchmarks.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Machine:
    """A roofline machine: peak compute, memory bandwidth, interconnect."""
    name: str
    peak_flops: float          # per chip, FLOP/s (dominant dtype)
    hbm_bw: float              # per chip, bytes/s
    link_bw: float             # per chip, bytes/s over the interconnect
    mem_per_chip: float        # bytes
    n_chips: int = 1

    @property
    def balance(self) -> float:
        """Machine balance point, FLOP/byte: workloads below it are
        memory-bound (paper Takeaway 1, inverted for TPU — see DESIGN.md)."""
        return self.peak_flops / self.hbm_bw


# --- the target machine for the dry-run roofline (per-spec constants) ------
TPU_V5E = Machine(
    name="tpu_v5e",
    peak_flops=197e12,         # bf16
    hbm_bw=819e9,
    link_bw=50e9,              # per ICI link
    mem_per_chip=16 * 2**30,
)

# --- the paper's processor-centric baselines -------------------------------
# Intel Xeon E3-1240 v6 (4C/8T, 2ch DDR4-2400): ~38.4 GB/s theoretical,
# ~25 GB/s STREAM; PrIM-class kernels (mixed stride, short loops) sustain
# ~0.6 of STREAM -> 15 GB/s (calibrated against the paper's Fig. 4 anchors,
# see EXPERIMENTS.md §Paper-claims).
XEON_E3_1240 = Machine("xeon_e3_1240v6", 460e9, 15e9, 0.0, 64 * 2**30)

# NVIDIA Titan V: 652.8 GB/s HBM2 peak; PrIM-class kernels achieve ~0.5 of
# peak (calibrated, same anchors) -> 324 GB/s. 13.8 TFLOP/s f32.
TITAN_V = Machine("titan_v", 13.8e12, 324e9, 0.0, 12 * 2**30)


# --- the UPMEM DPU ----------------------------------------------------------

#: software-routine cost of one arithmetic op, in pipeline instruction slots.
#: Calibrated so that (a) INT32 add at 1 op/element sustains ~70 MOPS/DPU,
#: matching the paper's measured ~58-70 MOPS band, (b) mul/div are roughly an
#: order of magnitude slower (paper Fig. 3), and (c) floating point lands in
#: the measured single-digit-MOPS bands of the full characterization
#: (arXiv:2105.03814 Fig. 3: FADD ~4 MOPS, FMUL ~2 MOPS, FDIV <1 MOPS/DPU —
#: every FP op is a software routine on the int-only pipeline).
#: "transc" is a software libm routine (exp/log/tanh/rsqrt...): range
#: reduction + polynomial, i.e. a dozen-plus FP mul/adds.
#: The "int8" band is the native one: the DPU ALU is 32-bit but the HW
#: multiplier is 8x8 -> an int8 x int8 product is a single multiplier pass
#: (arXiv:2105.03814 measures INT8 mul at the add-band MOPS, vs 32 slots
#: for the int32 software ladder) — this band is what makes quantized
#: expert GEMMs PIM-suitable (KT2 flipped, DESIGN.md §15).
DPU_OP_COST = {
    ("add", "int8"): 1, ("sub", "int8"): 1,
    ("bitwise", "int8"): 1, ("compare", "int8"): 1,
    ("mul", "int8"): 2, ("div", "int8"): 16,
    ("add", "int32"): 1, ("sub", "int32"): 1,
    ("bitwise", "int32"): 1, ("bitwise", "int64"): 2,
    ("compare", "int32"): 1, ("compare", "int64"): 2,
    ("add", "int64"): 2, ("sub", "int64"): 2,
    ("mul", "int32"): 32, ("mul", "int64"): 64,     # 8x8 HW multiplier only
    ("div", "int32"): 56, ("div", "int64"): 110,
    ("add", "float"): 90, ("sub", "float"): 90,
    ("mul", "float"): 175, ("div", "float"): 700,
    ("add", "double"): 180, ("sub", "double"): 180,
    ("mul", "double"): 360, ("div", "double"): 1400,
    ("compare", "float"): 45, ("compare", "double"): 80,
    ("transc", "float"): 2500, ("transc", "double"): 5000,
}

#: bookkeeping instructions per streamed element (WRAM ld/st + loop control)
DPU_LOOP_OVERHEAD = 4


@dataclasses.dataclass(frozen=True)
class DPUModel:
    """Analytic model of one UPMEM DPU (and of a whole UPMEM system)."""
    n_dpus: int
    freq_hz: float = 350e6
    ipc: float = 1.0                    # with >=11 resident tasklets
    mram_bw: float = 630e6              # bytes/s/DPU, sustained streaming
    wram_bytes: int = 64 * 1024
    mram_bytes: int = 64 * 2**20
    # host<->MRAM aggregate bandwidth for parallel transfers (full paper,
    # 2556-DPU system); scaled linearly in ranks for smaller systems.
    host_to_dpu_bw: float = 6.68e9
    dpu_to_host_bw: float = 4.74e9
    # fixed cost per DPU program launch + host sync (measured ~ms in the
    # full paper; this is what makes strong scaling sublinear from 640 to
    # 2556 DPUs — the paper's 10.1x vs 23.2x ratio is NOT linear in DPUs)
    launch_overhead_s: float = 5e-4

    def op_throughput(self, op: str, dtype: str, ops_per_elem: float = 1.0) -> float:
        """Sustained MOPS/DPU for a streaming kernel doing `ops_per_elem`
        ops of (op, dtype) per element held in WRAM (paper Fig. 3 setup)."""
        cost = DPU_OP_COST.get((op, dtype), 32)
        instr_per_elem = DPU_LOOP_OVERHEAD + cost * ops_per_elem
        elems_per_s = self.freq_hz * self.ipc / instr_per_elem
        return elems_per_s * ops_per_elem

    def compute_time(self, op_counts: dict[tuple[str, str], float]) -> float:
        """Seconds for op_counts {(op,dtype): n_ops} on ONE DPU. The
        per-element bookkeeping (WRAM ld/st + loop control) is charged once
        per streamed element — approximated by the LARGEST op count, since
        ops on the same element share one loop iteration."""
        instr = 0.0
        for (op, dtype), n in op_counts.items():
            instr += DPU_OP_COST.get((op, dtype), 32) * n
        if op_counts:
            instr += DPU_LOOP_OVERHEAD * max(op_counts.values())
        return instr / (self.freq_hz * self.ipc)

    def mram_time(self, bytes_streamed: float) -> float:
        """Seconds to stream bytes through one DPU's MRAM."""
        return bytes_streamed / self.mram_bw

    def interdpu_time(self, bytes_exchanged: float) -> float:
        """Inter-DPU communication = retrieve + re-copy through the host
        (Takeaway 3: no direct channel). host_to_dpu_bw/dpu_to_host_bw are
        the SYSTEM's measured parallel-transfer bandwidths (each UPMEM
        server has its own host; they do not scale with DPU count)."""
        return (bytes_exchanged / self.dpu_to_host_bw
                + bytes_exchanged / self.host_to_dpu_bw)

    @property
    def aggregate_mram_bw(self) -> float:
        return self.mram_bw * self.n_dpus

    def as_machine(self) -> Machine:
        """Roofline view of the whole UPMEM system: 'compute' measured in
        int32-add-equivalent ops/s."""
        add_peak = self.op_throughput("add", "int32", ops_per_elem=64.0)
        return Machine(
            name=f"upmem_{self.n_dpus}dpu",
            peak_flops=add_peak * self.n_dpus,
            hbm_bw=self.aggregate_mram_bw,
            link_bw=self.dpu_to_host_bw,
            mem_per_chip=self.mram_bytes,
            n_chips=self.n_dpus,
        )


UPMEM_2556 = DPUModel(n_dpus=2556)
UPMEM_640 = DPUModel(n_dpus=640)

MACHINES = {
    "tpu_v5e": TPU_V5E,
    "xeon": XEON_E3_1240,
    "titan_v": TITAN_V,
    "upmem_2556": UPMEM_2556.as_machine(),
    "upmem_640": UPMEM_640.as_machine(),
}
