"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler mitigation hooks.

`TrainLoop.run()` drives steps with:
  * periodic atomic checkpoints (train/checkpoint.py) + resume-from-latest,
  * exact restart (data pipeline is a pure function of step — data.py),
  * a failure injector for tests (`fail_at_step`) that kills the loop the
    way a preempted pod would (mid-interval, after optimizer update,
    before checkpoint) — the restart path must reproduce the exact same
    trajectory, which tests/test_fault_tolerance.py asserts bitwise,
  * straggler mitigation hook: per-step wall-time is tracked; steps slower
    than `straggler_factor` x the running median invoke `on_straggler`
    (on a real pod: re-shard away from the slow host / alert; here: logged
    and counted so the policy is testable).

Elasticity: `resume(mesh')` restores the latest checkpoint onto a different
mesh — checkpoints are mesh-agnostic (full-array leaves + target shardings
at restore), so scaling from 512 to 256 chips is a restore, not a reshard
script. The dry-run (launch/dryrun.py --mesh single) re-validates that every
arch compiles on the alternate mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from ..configs.shapes import ShapeConfig
from ..models import ModelConfig, Shardings, init_params
from . import checkpoint as ckpt_lib
from .data import DataConfig, make_batch
from .optimizer import HParams, adamw_init
from .step import make_train_step


class InjectedFailure(RuntimeError):
    """Stands in for a pod preemption / host crash in tests."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    log_every: int = 10
    fail_at_step: int | None = None       # failure injection (tests)
    straggler_factor: float = 3.0


@dataclasses.dataclass
class LoopState:
    params: Any
    opt: Any
    step: int


class TrainLoop:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 shd: Shardings, hp: HParams, loop: LoopConfig,
                 data: DataConfig = DataConfig(),
                 on_straggler: Callable[[int, float], None] | None = None):
        self.cfg, self.shape, self.shd = cfg, shape, shd
        self.hp, self.loop, self.data = hp, loop, data
        self.train_step = jax.jit(make_train_step(cfg, shd, hp))
        self.metrics_log: list[dict] = []
        self.straggler_steps: list[int] = []
        self._durations: list[float] = []
        self.on_straggler = on_straggler

    # ---------------------------------------------------------------- #
    def init_state(self, seed: int = 0) -> LoopState:
        params = init_params(jax.random.PRNGKey(seed), self.cfg, self.shd)
        opt = adamw_init(params, self.cfg)
        return LoopState(params, opt, 0)

    def resume_or_init(self, seed: int = 0) -> LoopState:
        latest = ckpt_lib.latest_step(self.loop.ckpt_dir)
        state = self.init_state(seed)
        if latest is None:
            return state
        tree = ckpt_lib.restore(self.loop.ckpt_dir, latest,
                                {"params": state.params, "opt": state.opt})
        return LoopState(tree["params"], tree["opt"], latest)

    # ---------------------------------------------------------------- #
    def _check_straggler(self, step: int, dt: float):
        self._durations.append(dt)
        if len(self._durations) < 8:
            return
        med = sorted(self._durations[-50:])[len(self._durations[-50:]) // 2]
        if dt > self.loop.straggler_factor * med:
            self.straggler_steps.append(step)
            if self.on_straggler is not None:
                self.on_straggler(step, dt)

    def run(self, state: LoopState) -> LoopState:
        """Run to total_steps (raises InjectedFailure at fail_at_step)."""
        while state.step < self.loop.total_steps:
            step = state.step
            if self.loop.fail_at_step is not None and step == self.loop.fail_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            batch = make_batch(self.cfg, self.shape, step, self.data, self.shd)
            t0 = time.perf_counter()
            params, opt, metrics = self.train_step(state.params, state.opt,
                                                   batch)
            jax.block_until_ready(metrics["loss"])
            self._check_straggler(step, time.perf_counter() - t0)
            state = LoopState(params, opt, step + 1)
            if (step + 1) % self.loop.log_every == 0 or step == 0:
                self.metrics_log.append(
                    {"step": step + 1,
                     **{k: float(v) for k, v in metrics.items()}})
            if (step + 1) % self.loop.ckpt_every == 0:
                ckpt_lib.save(self.loop.ckpt_dir, step + 1,
                              {"params": state.params, "opt": state.opt})
                ckpt_lib.prune(self.loop.ckpt_dir, self.loop.keep_ckpts)
        return state
