"""Sharded checkpointing with an atomic manifest — checkpoint/restart layer.

Format: `<dir>/step_<N>/` holds one raw-bytes file per leaf plus
`manifest.json` describing tree structure, shapes and dtypes. The manifest
is written LAST via tmp-file + atomic rename: a checkpoint directory is
valid iff its manifest exists, so a crash mid-write never yields a
half-readable checkpoint (restore scans for the newest *valid* step).

On a real multi-host pod each host writes only the leaves it owns
(addressable shards) and the manifest carries the global sharding; here the
single-process container writes full arrays but the save/restore API takes
the target shardings so restore re-places leaves onto the mesh (elastic
restart onto a different mesh shape re-validates through the same path —
see launch/dryrun.py --mesh).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    """Atomically save `tree` under ckpt_dir/step_<step>."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten_with_paths(tree)
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        path = os.path.join(tmp, f"leaf_{i}.bin")
        with open(path, "wb") as f:
            f.write(arr.tobytes())
        meta.append({"dtype": str(arr.dtype), "shape": list(arr.shape)})

    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "leaves": meta}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath + ".w", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".w", mpath)      # manifest atomic within tmp
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)               # directory atomic rename
    return final


def valid_steps(ckpt_dir: str) -> list[int]:
    """Steps with a complete (manifest-bearing) checkpoint, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree` (shapes/dtypes verified).

    `shardings`: optional tree of NamedSharding/None matching like_tree —
    leaves are device_put onto them (resume onto any mesh).
    """
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    like_leaves, treedef = jax.tree.flatten(like_tree)
    if manifest["n_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(like_leaves)} — architecture/optimizer mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0]
                    if shardings is not None else [None] * len(like_leaves))

    out = []
    for i, (like, meta) in enumerate(zip(like_leaves, manifest["leaves"])):
        path = os.path.join(d, f"leaf_{i}.bin")
        with open(path, "rb") as f:
            buf = f.read()
        arr = np.frombuffer(buf, dtype=np.dtype(meta["dtype"])) \
            .reshape(meta["shape"]).copy()
        want_shape = tuple(jnp.shape(like))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"leaf {i}: shape {arr.shape} != {want_shape}")
        x = jnp.asarray(arr)
        sh = shard_leaves[i]
        if sh is not None:
            x = jax.device_put(x, sh)
        out.append(x)
    return jax.tree.unflatten(treedef, out)


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest `keep` valid checkpoints."""
    steps = valid_steps(ckpt_dir)
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
