"""AdamW in pure JAX, sharded-state friendly.

Moments live in `cfg.opt_moment_dtype` (float32 default; bf16 for the 405B
per DESIGN.md §7 — the "gradient compression" trick recorded in §Perf) and
inherit the parameter's PartitionSpec, so optimizer state is sharded exactly
like the weights (ZeRO-style: FSDP axis shards both).

Update math follows Loshchilov & Hutter: decoupled weight decay, bias
correction; the whole update is a `jax.tree` map so it fuses into the
train step under jit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..models import ModelConfig


@dataclasses.dataclass(frozen=True)
class HParams:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(step, hp: HParams):
    """Linear warmup + cosine decay to min_lr_frac. step: int32 scalar."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(hp.warmup_steps, 1)
    t = (s - hp.warmup_steps) / jnp.maximum(hp.total_steps - hp.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = hp.min_lr_frac + (1 - hp.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return hp.lr * jnp.where(s < hp.warmup_steps, warm, cos)


def adamw_init(params, cfg: ModelConfig):
    """Zero moments in cfg.opt_moment_dtype, same tree/sharding as params."""
    mdt = jnp.dtype(cfg.opt_moment_dtype)

    def zeros_like_sharded(p):
        z = jnp.zeros(p.shape, mdt)
        if hasattr(p, "sharding") and p.sharding is not None:
            try:
                z = jax.device_put(z, p.sharding)
            except Exception:
                pass
        return z

    return {
        "m": jax.tree.map(zeros_like_sharded, params),
        "v": jax.tree.map(zeros_like_sharded, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads, clip: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def adamw_update(params, grads, opt, hp: HParams, cfg: ModelConfig):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    lr = schedule(step, hp)
    grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
    b1, b2 = hp.b1, hp.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.opt_moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + hp.eps) + hp.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    # unzip the 3-tuples back into trees
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_opt = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_opt, metrics


def opt_specs(param_specs_tree, moment_specs_tree=None):
    """PartitionSpec tree for the optimizer state, mirroring the params."""
    from jax.sharding import PartitionSpec as P
    mspec = moment_specs_tree if moment_specs_tree is not None else param_specs_tree
    return {"m": mspec, "v": mspec, "step": P()}
