"""Synthetic data pipeline: stateless, seeded, restart-exact.

A batch is a pure function of (seed, step): after a failure/restart the
pipeline resumes from the checkpointed step with bit-identical batches (the
fault-tolerance requirement — no data-loader state to snapshot). Tokens are
drawn from a Zipfian-ish mixture so the LM loss has structure to descend.

For the [vlm]/[audio] stub frontends the pipeline emits precomputed
embeddings (per the assignment: the modality frontend is a stub).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.shapes import ShapeConfig
from ..models import ModelConfig
from ..models.sharding import Shardings


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_exponent: float = 1.1


def _zipf_tokens(key, shape, vocab: int, exponent: float):
    """Zipf-distributed token ids via inverse-CDF on a uniform draw."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # approximate inverse CDF of zipf over [1, vocab]
    ids = jnp.floor(jnp.power(u, -1.0 / (exponent - 1.0))).astype(jnp.int32)
    return jnp.clip(ids, 0, vocab - 1)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
               data_cfg: DataConfig = DataConfig(),
               shd: Shardings | None = None) -> dict:
    """Batch for `step`, deterministically derived from (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(data_cfg.seed), step)
    k_tok, k_lab, k_emb, k_enc = jax.random.split(key, 4)
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(k_emb, (b, s, cfg.d_model),
                                            jnp.float32).astype(cfg.dtype)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
            batch["mrope_positions"] = jnp.broadcast_to(pos[None], (3, b, s))
    else:
        toks = _zipf_tokens(k_tok, (b, s + 1), cfg.vocab_size,
                            data_cfg.zipf_exponent)
        batch["tokens"] = toks[:, :-1]
    if cfg.input_mode == "embeds":
        batch["labels"] = _zipf_tokens(k_lab, (b, s), cfg.vocab_size,
                                       data_cfg.zipf_exponent)
    else:
        batch["labels"] = toks[:, 1:]
    if cfg.encoder_layers:
        batch["encoder_embeds"] = jax.random.normal(
            k_enc, (b, cfg.encoder_seq, cfg.d_model),
            jnp.float32).astype(cfg.dtype)
    if shd is not None and shd.mesh is not None:
        from jax.sharding import NamedSharding
        def place(name, x):
            spec = shd.batch_spec(x.shape)
            if name == "mrope_positions":
                spec = jax.sharding.PartitionSpec()
            return jax.device_put(x, NamedSharding(shd.mesh, spec))
        batch = {k: place(k, v) for k, v in batch.items()}
    return batch
