"""Train / eval step factories with explicit shardings.

`make_train_step` closes over (cfg, shd, hp) and returns a pure function
`(params, opt, batch) -> (params, opt, metrics)` suitable for jax.jit with
in_shardings/out_shardings from `train_shardings()`. Microbatch gradient
accumulation (`accum_steps`) runs as a lax.scan over batch slices — the
standard memory/comm trade for large global batches.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import ModelConfig, Shardings, forward, lm_loss, param_specs
from .optimizer import HParams, adamw_update


def _forward_kwargs(batch: dict) -> dict:
    return {k: v for k, v in batch.items() if k != "labels"}


def loss_fn(params, batch, cfg: ModelConfig, shd: Shardings):
    logits, _, aux = forward(params, cfg, shd, **_forward_kwargs(batch))
    return lm_loss(logits, batch["labels"], aux, cfg.router_aux_loss)


def make_train_step(cfg: ModelConfig, shd: Shardings, hp: HParams,
                    accum_steps: int = 1):
    def train_step(params, opt, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg, shd)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb, cfg, shd)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None
            split = lambda x: x.reshape((accum_steps, -1) + x.shape[1:])
            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = lsum / accum_steps
        params2, opt2, om = adamw_update(params, grads, opt, hp, cfg)
        metrics = {"loss": loss, **om}
        return params2, opt2, metrics
    return train_step


def make_eval_step(cfg: ModelConfig, shd: Shardings):
    def eval_step(params, batch):
        return loss_fn(params, batch, cfg, shd)
    return eval_step


def train_shardings(cfg: ModelConfig, shd: Shardings):
    """(params_specs, opt_specs, batch_spec_fn) for jit in_shardings."""
    pspecs = param_specs(cfg, shd)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    return pspecs, ospecs
