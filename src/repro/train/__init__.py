"""repro.train — optimizer, data, checkpointing, fault-tolerant loop."""

from .checkpoint import latest_step, prune, restore, save, valid_steps
from .data import DataConfig, make_batch
from .optimizer import (HParams, adamw_init, adamw_update,
                        clip_by_global_norm, global_norm, schedule)
from .runtime import InjectedFailure, LoopConfig, LoopState, TrainLoop
from .step import loss_fn, make_eval_step, make_train_step, train_shardings
