"""Dispatchable workloads: mixed PrIM pipelines + the LM decode chain.

Two pipeline families exercise the planner end-to-end:

  * `mixed_pipeline` — a PrIM-style chain interleaving the paper's two
    workload groups: streaming int phases (VA/SEL/TS/RED patterns — group
    1, PIM-suitable) around a data-reorganization middle (TRNS transpose +
    row rotation — exchange-heavy, the pattern group 2 loses on, KT3).
    Pure PIM pays the host-mediated exchange for every shuffle; pure CPU
    pays its thin memory bandwidth for every streaming pass; the hybrid
    plan runs the streams bank-parallel and hands the reorganization to
    the host, beating both.

  * `decode_pipeline` — the serving decode step (`serve.engine`'s
    workload) as a dispatchable chain: f32 weight GEMVs (qkv/o/up/down/
    head), quantized-integer KV-cache attention, rmsnorm glue. Float
    mul is a software routine on the DPU (KT2) so the weight GEMVs belong
    on the host, while the int-dot attention over the bank-resident KV
    cache is exactly the streaming pattern PIM wins — the hybrid split the
    PIM-for-LLM literature converges on. Residual adds are elided to keep
    the step a chain (the DP's exact case); this biases *against* the
    hybrid (residuals would add PIM-friendly streaming), so the modeled
    wins are conservative.

Both builders take `concrete=False` to build shape-only pipelines (params
as ShapeDtypeStructs): nothing is materialized or executed, but
`Pipeline.graph()` still lowers/compiles every stage for costing — that is
how the benchmarks model paper-scale inputs on the dev container.

The DAG builders below (`decode_dag`, `moe_decode_dag`, `prefill_dag`)
are what the serving planner consumes; MoE dims route each layer's MLP
through the exchange-phase ladder (router -> token exchange -> per-expert
FFN -> combine exchange), the planner's first data-dependent-routing
workload (DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
import functools
import types

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts
from ..models.layers import (CAPACITY_FACTOR as MOE_CAPACITY_FACTOR,
                             moe_combine, moe_dispatch, moe_expert_ffn,
                             moe_expert_ffn_q8)
from ..models.sharding import Shardings
from ..prim import trns as prim_trns

#: unsharded Shardings for the cost-model proxies (no mesh, `act` no-op)
_NO_SHARDING = Shardings(None)
from .graph import (OpGraph, OpNode, annotate_kv_residency,
                    annotate_kv_write, chain_graph, node_from_fn)
from .runtime import Pipeline, Stage


def _mk(key, shape, dtype, concrete: bool, lo=-100, hi=100):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, lo, hi, dtype)
    return (jax.random.normal(key, shape, dtype)
            / (shape[-1] ** 0.5)).astype(dtype)


# ---------------------------------------------------------------------------
# mixed PrIM pipeline (streaming -> reorganization -> streaming)
# ---------------------------------------------------------------------------

def _pim_roll(grid: BankGrid, x, shift: int):
    """Global row rotation crosses banks: host-mediated gather, then each
    bank takes its block of the rolled matrix (the re-scatter)."""
    full = grid.exchange_gather(x)

    def take(full_b):
        rows = full_b.shape[0] // grid.n_banks
        i = jax.lax.axis_index(grid.axis)
        rolled = jnp.roll(full_b, shift, axis=0)
        return jax.lax.dynamic_slice_in_dim(rolled, i * rows, rows, axis=0)

    return grid.local(take, in_specs=P(), out_specs=P(grid.axis))(full)


def mixed_pipeline(m: int = 2048, key=None, concrete: bool = True) -> Pipeline:
    """Streaming int32 phases around a transpose/rotate/transpose middle,
    on an (m, m) matrix; ends in a RED-style cross-bank sum."""
    key = key if key is not None else jax.random.PRNGKey(0)
    kx, kb, kc = jax.random.split(key, 3)
    x = _mk(kx, (m, m), jnp.int32, concrete)
    bias = _mk(kb, (m, m), jnp.int32, concrete)
    bias2 = _mk(kc, (m, m), jnp.int32, concrete)
    shift = m // 3
    nbytes = float(m * m * 4)

    def relu(v):
        return jnp.maximum(v, 0)

    def square(v):
        return v * v

    def total(v):
        # int32 sum: modular addition is order-independent, so the
        # bank-tree and the host reduction agree exactly
        return jnp.sum(v)

    def pim_sum(grid: BankGrid, v):
        part = grid.local(lambda vb: jnp.sum(vb)[None],
                          in_specs=P(grid.axis), out_specs=P(grid.axis))(v)
        return grid.exchange_reduce(part, op="add")[0]

    # cache-blocked host transpose still moves read+write (XLA folds it
    # into a zero-charged layout fusion, so charge it explicitly)
    stages = [
        Stage("va.add", lambda v, b: v + b, params=(bias,),
              local_fn=lambda v, b: v + b, kind="stream"),
        Stage("va.add2", lambda v, b: v + b, params=(bias2,),
              local_fn=lambda v, b: v + b, kind="stream"),
        Stage("sel.relu", relu, local_fn=relu, kind="stream"),
        Stage("trns.fwd", lambda v: v.T,
              pim=lambda grid, v: prim_trns.run_pim(grid, v),
              exchange="all_to_all", exchange_bytes=nbytes,
              hbm_bytes=2 * nbytes, kind="shuffle"),
        Stage("roll.rows", lambda v: jnp.roll(v, shift, axis=0),
              pim=functools.partial(_pim_roll, shift=shift),
              exchange="gather", exchange_bytes=nbytes, kind="shuffle"),
        Stage("trns.back", lambda v: v.T,
              pim=lambda grid, v: prim_trns.run_pim(grid, v),
              exchange="all_to_all", exchange_bytes=nbytes,
              hbm_bytes=2 * nbytes, kind="shuffle"),
        Stage("ts.square", square, local_fn=square, kind="stream"),
        Stage("red.sum", total, pim=pim_sum,
              exchange="reduce", exchange_bytes=8.0 * 64, kind="reduce"),
    ]
    return Pipeline("prim-mixed", stages, x)


# ---------------------------------------------------------------------------
# LM decode step as a dispatchable chain
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeDims:
    """Decode-step shape at serving time (KV cache length = seq).

    `n_kv_heads`/`kv_itemsize` size the *resident KV cache* (GQA caches
    fewer heads; real caches may be wider than int32) — they feed the
    migration charge. The modeled attention compute keeps the MHA int32
    proxy regardless (conservative for GQA: it can only overstate PIM's
    attention work, never understate the migration the planner trades it
    against).

    `window` (0 = full attention) is a sliding-window bound: the KV the
    model can ever attend is the last `min(seq, window)` positions, so
    the resident cache is a RING BUFFER of that many rows
    (`models.cache.cache_width`). Attention compute, KV residency, and
    migration charges all price `kv_len` rows, not `seq` — a 32k context
    under a 4k window costs 4k-row attention — and `prefill_dag` drops
    the cross-chunk KV edges a window makes dead (banded prefill)."""
    d_model: int = 4096
    n_heads: int = 32
    head_dim: int = 128
    d_ff: int = 16384
    seq: int = 2048
    vocab: int = 32000
    n_layers: int = 32
    batch: int = 2
    n_kv_heads: int | None = None      # None -> n_heads (MHA)
    kv_itemsize: int = 4
    n_experts: int = 0                 # 0 -> dense MLP layers
    top_k: int = 0
    moe_d_ff: int = 0                  # per-expert ffn width (0 -> d_ff)
    # "" | "int8": int8 expert weights (symmetric per-channel, int32
    # accumulation — models.layers.moe_expert_ffn_q8) and int8 KV storage;
    # pair with kv_itemsize=1 so residency/migration charges shrink 4x
    quant: str = ""
    window: int = 0                    # sliding window (0 = full attention)

    @property
    def kv_heads(self) -> int:
        """Cached KV head count (GQA when n_kv_heads is set, else MHA)."""
        return self.n_kv_heads or self.n_heads

    @property
    def kv_len(self) -> int:
        """Resident KV rows a decode step attends: the ring-buffer width
        `min(seq, window)` under a sliding window, else the full `seq` —
        what sizes the attention proxies and the residency/migration
        byte charges."""
        return min(self.seq, self.window) if self.window else self.seq

    @property
    def expert_ff(self) -> int:
        """Per-expert FFN width (MoE layers; `moe_d_ff` or `d_ff`)."""
        return self.moe_d_ff or self.d_ff


#: reduced dims for executable runtime tests (same graph structure)
REDUCED_DIMS = DecodeDims(d_model=64, n_heads=4, head_dim=16, d_ff=128,
                          seq=32, vocab=128, n_layers=2, batch=2)

#: reduced MoE dims (mixtral-reduced-shaped: 4 experts top-2)
MOE_REDUCED_DIMS = DecodeDims(d_model=64, n_heads=4, head_dim=16, d_ff=128,
                              seq=32, vocab=128, n_layers=2, batch=2,
                              n_experts=4, top_k=2, moe_d_ff=128)

#: paper-scale MoE dims (mixtral-8x7b-shaped: 8 experts top-2, GQA kv8)
MOE_PAPER_DIMS = DecodeDims(d_model=4096, n_heads=32, head_dim=128,
                            d_ff=14336, seq=2048, vocab=32000, n_layers=32,
                            batch=2, n_kv_heads=8, n_experts=8, top_k=2,
                            moe_d_ff=14336)

#: the KT2-flip configuration: same MoE shapes with int8 expert weights
#: (int32 accumulation) and an int8 KV cache — what moves expert FFNs
#: into the DPU-native integer cost band (DESIGN.md §15)
MOE_PAPER_DIMS_INT8 = dataclasses.replace(MOE_PAPER_DIMS, kv_itemsize=1,
                                          quant="int8")
MOE_REDUCED_DIMS_INT8 = dataclasses.replace(MOE_REDUCED_DIMS, kv_itemsize=1,
                                            quant="int8")

#: long-context sliding-window dims (mistral-style 4k window over a 32k
#: context): attention and KV residency price the 4096-row ring, not the
#: 32768-row context — the planner's long-context workload shape
SWA_PAPER_DIMS = DecodeDims(seq=32768, window=4096)
SWA_REDUCED_DIMS = dataclasses.replace(REDUCED_DIMS, window=8)

#: windowed MoE at the KT2-flip configuration (int8 experts + int8 KV):
#: the 32k-context mixtral shape whose resident KV is the 4k ring
MOE_SWA_PAPER_DIMS_INT8 = dataclasses.replace(MOE_PAPER_DIMS_INT8,
                                              seq=32768, window=4096)
MOE_SWA_REDUCED_DIMS_INT8 = dataclasses.replace(MOE_REDUCED_DIMS_INT8,
                                                window=8)

_Q_SCALE = 64.0          # activation quantization step for int attention


def _rmsnorm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _gemv(x, w):
    return x @ w


def _pim_gemv(grid: BankGrid, x, w):
    """Column-partitioned weight-stationary GEMV (the prim MLP layout):
    each bank owns a column block of W; the activation is re-gathered for
    the next stage through the host (KT3's per-layer cost)."""
    return grid.local(_gemv, in_specs=(P(), P(None, grid.axis)),
                      out_specs=P(None, grid.axis))(x, w)


def _attend(qkv, kq, vq, dims: DecodeDims):
    """Quantized-integer attention over the resident KV cache: int32 dot
    products for scores and AV (DPU-native mul/add), float softmax.

    The batch size comes from the input, not `dims`: under `_pim_attend`
    this body runs on a per-bank shard of `dims.batch / n_banks` rows.

    The cache may be stored int8 (`DecodeDims.quant == "int8"`, 4x
    smaller residency): compute upcasts to the int32 accumulator either
    way — the convert is free at node granularity, only storage
    shrinks."""
    h, dh = dims.n_heads, dims.head_dim
    kq, vq = kq.astype(jnp.int32), vq.astype(jnp.int32)
    b = qkv.shape[0]
    q = qkv.reshape(b, 3, h, dh)[:, 0]
    qq = jnp.round(q * _Q_SCALE).astype(jnp.int32)
    scores_i = jnp.einsum("bhd,shd->bhs", qq, kq)
    scores = scores_i.astype(jnp.float32) / (_Q_SCALE * _Q_SCALE * dh ** 0.5)
    w = jax.nn.softmax(scores, axis=-1)
    wq = jnp.round(w * 256.0).astype(jnp.int32)
    out_i = jnp.einsum("bhs,shd->bhd", wq, vq)
    return out_i.astype(jnp.float32).reshape(b, h * dh) / (256.0 * _Q_SCALE)


def _pim_attend(grid: BankGrid, qkv, kq, vq, dims: DecodeDims):
    """Batch-partitioned attention: each bank holds its sequences' KV
    cache shard (continuous batching across banks) — a pure local phase."""
    f = functools.partial(_attend, dims=dims)
    return grid.local(f, in_specs=(P(grid.axis), P(), P()),
                      out_specs=P(grid.axis))(qkv, kq, vq)


def decode_pipeline(dims: DecodeDims = REDUCED_DIMS, key=None,
                    concrete: bool = True) -> Pipeline:
    """The serving decode step as a stage chain: rmsnorm -> qkv GEMV ->
    quantized KV attention -> o/up/down GEMVs per layer, then final norm
    and the vocab head. Tokens enter from the host; logits return to the
    host (the `serve.engine` sampling loop)."""
    d = dims
    key = key if key is not None else jax.random.PRNGKey(0)
    keys = iter(jax.random.split(key, 8 * d.n_layers + 4))
    f32, i32 = jnp.float32, jnp.int32

    tokens = _mk(next(keys), (d.batch,), i32, concrete, 0, d.vocab)
    table = _mk(next(keys), (d.vocab, d.d_model), f32, concrete)

    def embed(t, tab):
        return tab[t]

    def pim_embed(grid, t, tab):
        return grid.local(embed, in_specs=(P(grid.axis), P()),
                          out_specs=P(grid.axis))(t, tab)

    stages = [Stage("embed", embed, params=(table,), pim=pim_embed,
                    kind="embed")]
    act_bytes = float(d.batch * d.d_model * 4)
    for i in range(d.n_layers):
        wqkv = _mk(next(keys), (d.d_model, 3 * d.n_heads * d.head_dim), f32,
                   concrete)
        kq = _mk(next(keys), (d.seq, d.n_heads, d.head_dim), i32, concrete,
                 -64, 64)
        vq = _mk(next(keys), (d.seq, d.n_heads, d.head_dim), i32, concrete,
                 -64, 64)
        wo = _mk(next(keys), (d.n_heads * d.head_dim, d.d_model), f32,
                 concrete)
        wup = _mk(next(keys), (d.d_model, d.d_ff), f32, concrete)
        wdown = _mk(next(keys), (d.d_ff, d.d_model), f32, concrete)
        attend = functools.partial(_attend, dims=d)
        stages += [
            Stage(f"ln{i}", _rmsnorm, local_fn=_rmsnorm, kind="norm"),
            Stage(f"qkv{i}", _gemv, params=(wqkv,), pim=_pim_gemv,
                  exchange="gather", exchange_bytes=3 * act_bytes,
                  kind="gemv_qkv"),
            Stage(f"attn{i}", attend, params=(kq, vq),
                  pim=functools.partial(_pim_attend, dims=d),
                  kind="attn"),
            Stage(f"o{i}", _gemv, params=(wo,), pim=_pim_gemv,
                  exchange="gather", exchange_bytes=act_bytes,
                  kind="gemv_o"),
            Stage(f"up{i}", lambda x, w: jax.nn.gelu(x @ w), params=(wup,),
                  pim=lambda grid, x, w: grid.local(
                      lambda xx, ww: jax.nn.gelu(xx @ ww),
                      in_specs=(P(), P(None, grid.axis)),
                      out_specs=P(None, grid.axis))(x, w),
                  exchange="gather",
                  exchange_bytes=float(d.batch * d.d_ff * 4),
                  kind="gemv_up"),
            Stage(f"down{i}", _gemv, params=(wdown,), pim=_pim_gemv,
                  exchange="gather", exchange_bytes=act_bytes,
                  kind="gemv_down"),
        ]
    whead = _mk(next(keys), (d.d_model, d.vocab), f32, concrete)
    stages += [
        Stage("lnf", _rmsnorm, local_fn=_rmsnorm, kind="norm"),
        Stage("head", _gemv, params=(whead,), pim=_pim_gemv,
              exchange="gather", exchange_bytes=float(d.batch * d.vocab * 4),
              kind="gemv_head"),
    ]
    return Pipeline("lm-decode", stages, tokens)


# ---------------------------------------------------------------------------
# MoE routing as an exchange phase (router -> dispatch -> experts -> combine)
# ---------------------------------------------------------------------------

#: GShard-style token capacity headroom — aliased from the executable
#: MoE layer (top-of-file import) so the planner's buffer shapes and
#: exchange volumes can never drift from what `serve.dispatch_engine`
#: actually runs
_MOE_CAPACITY_FACTOR = MOE_CAPACITY_FACTOR


def moe_capacity(tokens_per_seq: int, n_experts: int, top_k: int) -> int:
    """Per-expert token capacity of one sequence row — the
    `models.layers.CAPACITY_FACTOR` semantics the serving stages share:
    `max(int(cf * k * s / e), 1)`."""
    return max(int(_MOE_CAPACITY_FACTOR * top_k * tokens_per_seq
                   / n_experts), 1)


def moe_exchange_bytes(tokens: int, d_model: int, top_k: int,
                       itemsize: int = 4) -> float:
    """Bytes one MoE token exchange re-distributes across banks (each of
    the dispatch and the combine moves this much): every token's `top_k`
    dispatched copies at capacity-factor headroom. The volume scales with
    tokens x capacity (`cf * k * tokens` rows of `d_model`), NOT with the
    expert count — empty capacity slots never travel, so adding experts
    spreads the same rows thinner instead of multiplying traffic."""
    return float(_MOE_CAPACITY_FACTOR * top_k * tokens * d_model * itemsize)


def _moe_router(x, wr, *, seq: int, top_k: int):
    """Costing proxy for the MoE router + top-k gate + dispatch scatter:
    float gate math (softmax over expert logits — transcendental, KT2),
    integer position bookkeeping (row-local cumsum), and the capacity
    scatter into the (B, E, C, D) dispatch buffer — the tensor the token
    exchange re-distributes. `x` is (rows, d) flattened tokens with `seq`
    tokens per sequence row (decode: seq=1 per slot). The math IS
    `models.layers.moe_dispatch` — the same slice the serving stages
    execute, so the cost model can never drift from the runtime."""
    n, d = x.shape
    b = n // seq
    cfg = types.SimpleNamespace(n_experts=wr.shape[1], top_k=top_k)
    buf, topi, pos, w, _ = moe_dispatch(x.reshape(b, seq, d), wr, cfg)
    return buf, topi, pos, w


def _moe_expert(buf, wu, wg, wd):
    """Costing proxy for the per-expert gated FFN over the dispatched
    (B, E, C, D) buffer — dense float GEMMs (software mul on DPUs, KT2),
    embarrassingly parallel over the expert axis (the bank shard). Runs
    `models.layers.moe_expert_ffn` itself (unsharded)."""
    cfg = types.SimpleNamespace(gated_mlp=True, mlp_act="silu")
    return moe_expert_ffn(buf, {"wu": wu, "wg": wg, "wd": wd}, cfg,
                          _NO_SHARDING)


def _moe_expert_q8(buf, wuq, su, wgq, sg, wdq, sd):
    """Costing proxy for the QUANTIZED per-expert FFN: PRE-quantized int8
    weights as inputs (4x smaller weight bytes), int8 x int8 dots
    accumulating in int32, f32 dequant — the compiled HLO the cost model
    prices lands in the DPU's native integer band instead of the float
    software routines, which is the whole KT2 flip. Runs
    `models.layers.moe_expert_ffn_q8` itself (the slice the dispatch
    serving stages execute), so cost and runtime cannot drift. Weights
    arrive quantized because in-body quantization would be priced at the
    float band and charged every step (DESIGN.md §15)."""
    cfg = types.SimpleNamespace(gated_mlp=True, mlp_act="silu")
    return moe_expert_ffn_q8(buf, wuq, su, wdq, sd, cfg, _NO_SHARDING,
                             wgq, sg)


def _moe_combine(x, out_buf, topi, pos, w, *, seq: int):
    """Costing proxy for the combine: gather each token's expert outputs
    back from the (B, E, C, D) buffer (the combine exchange's payload,
    `models.layers.moe_combine`), weight by the normalized gates, and
    add into the residual stream."""
    n, d = x.shape
    y = moe_combine(out_buf, topi, pos, w, x.dtype)
    return x + y.reshape(n, d)


# ---------------------------------------------------------------------------
# LM decode step as a DAG (residual branches + attention fan-out)
# ---------------------------------------------------------------------------

def _decode_protos(d: DecodeDims, expert_shards: int = 1) -> dict:
    """Compile each distinct decode-stage shape once — later layers (and
    later steps of `decode_steps_dag`) are renamed copies. With
    `expert_shards=R > 1` the expert proto is ONE shard's FFN: the
    dispatch buffer and weight stacks sliced to `n_experts / R` experts
    (what an expert-parallel rank holds), and the router's `out_bytes`
    shrink to one shard's slice — each shard pulls only its experts'
    rows, so R rank crossings move the same total payload the single
    crossing did."""
    f32, i32 = jnp.float32, jnp.int32
    q8 = d.quant == "int8"
    kv_dt = jnp.int8 if q8 else i32
    S = jax.ShapeDtypeStruct
    dm, hdh = d.d_model, d.n_heads * d.head_dim
    act_bytes = float(d.batch * dm * 4)

    tokens = S((d.batch,), i32)
    table = S((d.vocab, dm), f32)
    x = S((d.batch, dm), f32)
    qkv_out = S((d.batch, 3 * hdh), f32)
    attn_out = S((d.batch, hdh), f32)
    wqkv = S((dm, 3 * hdh), f32)
    # a sliding window bounds the attended KV to the ring width: the
    # decode step's scores/AV run over kv_len rows, never the full seq
    kq = S((d.kv_len, d.n_heads, d.head_dim), kv_dt)
    vq = S((d.kv_len, d.n_heads, d.head_dim), kv_dt)
    wo = S((hdh, dm), f32)
    wup, wdown = S((dm, d.d_ff), f32), S((d.d_ff, dm), f32)
    whead = S((dm, d.vocab), f32)

    def f_embed(t, tab):
        return tab[t]

    def f_qkv(v, w):
        return _rmsnorm(v) @ w

    attend = functools.partial(_attend, dims=d)

    def f_o(a, res, w):
        return res + a @ w

    def f_mlp(v, wu, wd):
        return v + jax.nn.gelu(_rmsnorm(v) @ wu) @ wd

    def f_head(v, w):
        return _rmsnorm(v) @ w

    protos = {
        "embed": node_from_fn("embed", f_embed, tokens, table,
                              kind="embed"),
        "qkv": node_from_fn("qkv", f_qkv, x, wqkv, kind="gemv_qkv",
                            exchange_bytes=3 * act_bytes),
        "attn": node_from_fn("attn", attend, qkv_out, kq, vq, kind="attn"),
        "o": node_from_fn("o", f_o, attn_out, x, wo, kind="gemv_o",
                          exchange_bytes=act_bytes),
    }
    moe = d.n_experts > 0
    if moe:
        e, k, fe = d.n_experts, d.top_k, d.expert_ff
        es = e // expert_shards        # experts one shard holds
        cap = moe_capacity(1, e, k)    # decode: 1 token per slot row
        wr = S((dm, e), f32)
        wu_e, wg_e = S((es, dm, fe), f32), S((es, dm, fe), f32)
        wd_e = S((es, fe, dm), f32)
        buf = S((d.batch, e, cap, dm), f32)
        buf_shard = S((d.batch, es, cap, dm), f32)
        topi = S((d.batch, 1, k), i32)
        pos_ = S((d.batch, 1, k), i32)
        gate_w = S((d.batch, 1, k), f32)
        router_fn = functools.partial(_moe_router, seq=1, top_k=k)
        combine_fn = functools.partial(_moe_combine, seq=1)
        if q8:      # pre-quantized int8 weights + per-channel f32 scales
            wu_e, wg_e = S((es, dm, fe), jnp.int8), S((es, dm, fe), jnp.int8)
            wd_e = S((es, fe, dm), jnp.int8)
            su_e, sg_e = S((es, 1, fe), f32), S((es, 1, fe), f32)
            sd_e = S((es, 1, dm), f32)
            expert_proto = node_from_fn(
                "expert", _moe_expert_q8, buf_shard, wu_e, su_e, wg_e,
                sg_e, wd_e, sd_e, kind="moe_expert")
        else:
            expert_proto = node_from_fn("expert", _moe_expert, buf_shard,
                                        wu_e, wg_e, wd_e,
                                        kind="moe_expert")
        router_proto = node_from_fn("router", router_fn, x, wr,
                                    kind="moe_router")
        if expert_shards > 1:
            # each shard's stage-in pulls only its slice of the dispatch
            # buffer — the rank-parallel all-to-all moves the original
            # total volume, split across R rank channels
            router_proto = dataclasses.replace(
                router_proto, out_bytes=router_proto.out_bytes
                / expert_shards)
        protos.update({
            "router": router_proto,
            "expert": expert_proto,
            # the combine's compute is over the FULL reassembled buffer
            # regardless of sharding
            "combine": node_from_fn("combine", combine_fn, x, buf, topi,
                                    pos_, gate_w, kind="moe_combine"),
        })
    else:
        protos["mlp"] = node_from_fn(
            "mlp", f_mlp, x, wup, wdown, kind="mlp",
            exchange_bytes=float(d.batch * d.d_ff * 4) + act_bytes)
    protos["head"] = node_from_fn(
        "head", f_head, x, whead, kind="gemv_head",
        exchange_bytes=float(d.batch * d.vocab * 4))
    return protos


def _check_decode_dims(d: DecodeDims, expert_shards: int) -> None:
    if expert_shards < 1:
        raise ValueError(f"need expert_shards >= 1, got {expert_shards}")
    if expert_shards > 1:
        if d.n_experts <= 0:
            raise ValueError("expert_shards > 1 needs MoE dims "
                             f"(n_experts > 0), got {d}")
        if d.n_experts % expert_shards:
            raise ValueError(f"n_experts={d.n_experts} not divisible by "
                             f"expert_shards={expert_shards}")


def _add_decode_step(g: OpGraph, d: DecodeDims, protos: dict, *,
                     kv_home: str | None, expert_shards: int = 1,
                     sfx: str = "", prev_attns: list[str] | None = None,
                     prev_head: str | None = None) -> tuple[str, list[str]]:
    """Add one decode step's node ladder to `g`, every name suffixed
    `sfx` (`decode_steps_dag`'s `"/s{k}"`; empty for the single-step
    `decode_dag`). `prev_attns` adds the per-layer KV-order edges from
    the previous step's attention (step k+1 attends over a cache that
    includes step k's row); `prev_head` adds the sampled-token edge
    (greedy decode: step k+1's embed waits on step k's logits). Returns
    (head name, attention names) for the next step's wiring."""
    moe = d.n_experts > 0
    R = expert_shards
    # migrating a layer's cache off-home moves every slot's K and V rows
    # at the cache's real width (GQA heads, real itemsize); under a
    # sliding window only the ring buffer is resident (kv_len rows)
    kv_bytes = 2.0 * d.batch * d.kv_len * d.kv_heads * d.head_dim \
        * d.kv_itemsize
    xbytes = moe_exchange_bytes(d.batch, d.d_model, d.top_k) if moe else 0.0

    def layer_node(kind, name):
        return dataclasses.replace(protos[kind], name=name,
                                   ops=dict(protos[kind].ops),
                                   meta=dict(protos[kind].meta))

    embed_preds = (prev_head,) if prev_head else ()
    g.add(layer_node("embed", f"embed{sfx}"), *embed_preds)
    res = f"embed{sfx}"                # the residual stream's producer
    attns: list[str] = []
    for i in range(d.n_layers):
        g.add(layer_node("qkv", f"qkv{i}{sfx}"), res)
        attn_preds = [f"qkv{i}{sfx}"]
        if prev_attns is not None:     # KV order across decode steps
            attn_preds.append(prev_attns[i])
        attn = g.add(layer_node("attn", f"attn{i}{sfx}"), *attn_preds)
        attns.append(attn.name)
        if kv_home is not None:
            annotate_kv_residency(attn, kv_bytes, kv_home)
        g.add(layer_node("o", f"o{i}{sfx}"), f"attn{i}{sfx}", res)
        if moe:
            g.add(layer_node("router", f"router{i}{sfx}"), f"o{i}{sfx}")
            # the token exchanges: dispatch buffer out, expert outputs
            # back; R shards split the same total volume R ways
            if R == 1:
                g.add(layer_node("expert", f"expert{i}{sfx}"),
                      f"router{i}{sfx}")
                g.add(layer_node("combine", f"combine{i}{sfx}"),
                      f"expert{i}{sfx}", f"router{i}{sfx}", f"o{i}{sfx}")
                g.annotate_exchange(f"router{i}{sfx}", f"expert{i}{sfx}",
                                    xbytes)
                g.annotate_exchange(f"expert{i}{sfx}", f"combine{i}{sfx}",
                                    xbytes)
            else:
                shards = [f"expert{i}@r{j}{sfx}" for j in range(R)]
                for sn in shards:
                    g.add(layer_node("expert", sn), f"router{i}{sfx}")
                    g.annotate_exchange(f"router{i}{sfx}", sn, xbytes / R)
                g.add(layer_node("combine", f"combine{i}{sfx}"),
                      *shards, f"router{i}{sfx}", f"o{i}{sfx}")
                for sn in shards:
                    g.annotate_exchange(sn, f"combine{i}{sfx}", xbytes / R)
            res = f"combine{i}{sfx}"
        else:
            g.add(layer_node("mlp", f"mlp{i}{sfx}"), f"o{i}{sfx}")
            res = f"mlp{i}{sfx}"
    head = g.add(layer_node("head", f"head{sfx}"), res)
    return head.name, attns


def _decode_dag_name(d: DecodeDims, expert_shards: int) -> str:
    base = "lm-moe-decode-dag" if d.n_experts > 0 else "lm-decode-dag"
    return base + ("-int8" if d.quant == "int8" else "") \
        + (f"-swa{d.window}" if 0 < d.window < d.seq else "") \
        + (f"-ep{expert_shards}" if expert_shards > 1 else "")


def decode_dag(dims: DecodeDims = REDUCED_DIMS, *,
               kv_home: str | None = "upmem_2556",
               expert_shards: int = 1) -> OpGraph:
    """The full decode-step DAG the serving planner consumes.

    Unlike `decode_pipeline` (which elides residuals to stay a chain, the
    old DP's exact case), this keeps the real dataflow: each layer's
    residual stream fans out to both the qkv projection and the post-
    attention add, so the graph is series-parallel with frontier width 2 —
    squarely inside the frontier DP's exact class. Node names match the
    executable stages of `serve.dispatch_engine` ("embed", "qkv{i}",
    "attn{i}", "o{i}", "mlp{i}", "head"), so a plan over this graph routes
    that engine directly.

    `kv_home` annotates every attention node with its layer's KV-cache
    residency (`graph.annotate_kv_residency`): placing attn{i} away from
    `kv_home` charges migrating the slot's KV over the measured transfer
    channel. None disables residency (pure dataflow comparison).

    MoE dims (`dims.n_experts > 0`, see `moe_decode_dag`) replace each
    layer's dense `mlp{i}` with the routed ladder `router{i}` (gate +
    dispatch scatter) -> `expert{i}` (per-expert FFN over the dispatch
    buffer) -> `combine{i}` (gather + weighted residual add), with the
    router->expert and expert->combine edges annotated as token
    EXCHANGES (`OpGraph.annotate_exchange`): re-distributing the
    dispatch buffer across banks relays through the host, the volume
    scaling with tokens x capacity (`moe_exchange_bytes`).

    `expert_shards=R > 1` (MoE dims only, `n_experts % R == 0`) splits
    each layer's expert FFN into R shard nodes `expert{i}@r{j}`, each
    over `n_experts / R` experts (`parse_stage_name` strips the suffix;
    `stage_shard` recovers j). The router fans out to all R shards and
    the combine fans them back in, with the dispatch/combine exchange
    volume split R ways — the expert-parallel shape whose shards a
    multi-rank `placement.Topology` places on distinct ranks
    (`expert_parallel_plan`), putting each shard's stage-in, launch, and
    exchange on its own rank channel."""
    d = dims
    _check_decode_dims(d, expert_shards)
    protos = _decode_protos(d, expert_shards)
    g = OpGraph(_decode_dag_name(d, expert_shards),
                input_bytes=float(d.batch * 4))
    _add_decode_step(g, d, protos, kv_home=kv_home,
                     expert_shards=expert_shards)
    return g


def moe_decode_dag(dims: DecodeDims = MOE_REDUCED_DIMS, *,
                   kv_home: str | None = "upmem_2556",
                   expert_shards: int = 1) -> OpGraph:
    """The MoE decode-step DAG (`decode_dag` with routed expert layers):
    per layer `router{i}` -> token exchange -> `expert{i}` -> combine
    exchange -> `combine{i}`, the planner's first data-dependent-routing
    workload. Requires MoE dims (`dims.n_experts > 0`); see `decode_dag`
    for the exchange-edge and `expert_shards` semantics."""
    if dims.n_experts <= 0 or dims.top_k <= 0:
        raise ValueError("moe_decode_dag needs MoE dims "
                         f"(n_experts/top_k), got {dims}")
    return decode_dag(dims, kv_home=kv_home, expert_shards=expert_shards)


def decode_steps_dag(dims: DecodeDims = REDUCED_DIMS, *, n_steps: int = 2,
                     kv_home: str | None = "upmem_2556",
                     sampled: bool = False,
                     expert_shards: int = 1) -> OpGraph:
    """`n_steps` consecutive decode steps unrolled into ONE plannable DAG
    — cross-step pipelining (the open PR-4 item), step k's nodes suffixed
    `"/s{k}"` (`stage_step`).

    The default `sampled=False` models the scoring / speculative-
    verification contract: every step's input token is known up front
    (prompt scoring, draft-tree verification), so step k+1's embed has NO
    edge from step k's head. The only cross-step edges are the per-layer
    KV-order edges `attn{i}/s{k}` -> `attn{i}/s{k+1}` (step k+1 attends
    over a cache that includes step k's row; same-device, so they cost
    nothing and only order the timeline). That is what lets the pipelined
    event sim run step k+1's host ladder and stage-ins under step k's
    tail PIM work — `pipelined_s` of the unrolled DAG beats
    `n_steps * pipelined_s` of the single-step DAG wherever the plan
    alternates devices (benchmarks/dispatch_bench.py sweep 8 reports the
    margin).

    `sampled=True` is the honest greedy-decode contract: step k's
    sampled token IS step k+1's input, so `head/s{k}` ->
    `embed/s{k+1}` serializes the ladders and only transfer/compute
    tails overlap. Cross-step pipelining is a scoring/verification
    speedup, not an autoregressive one."""
    d = dims
    if n_steps < 1:
        raise ValueError(f"need n_steps >= 1, got {n_steps}")
    _check_decode_dims(d, expert_shards)
    protos = _decode_protos(d, expert_shards)
    name = _decode_dag_name(d, expert_shards) + f"-steps{n_steps}" \
        + ("-sampled" if sampled else "")
    g = OpGraph(name, input_bytes=float(d.batch * 4) * n_steps)
    prev_attns: list[str] | None = None
    prev_head: str | None = None
    for s in range(n_steps):
        head, attns = _add_decode_step(
            g, d, protos, kv_home=kv_home, expert_shards=expert_shards,
            sfx=f"/s{s}", prev_attns=prev_attns,
            prev_head=prev_head if sampled else None)
        prev_attns, prev_head = attns, head
    return g


def expert_parallel_plan(graph: OpGraph, topology, *, source: str = "xeon",
                         sink: str = "xeon",
                         objective: str = "overlapped"):
    """Construct (rather than search for) the expert-parallel plan of an
    `expert_shards`-sharded decode DAG under a multi-rank
    `placement.Topology`.

    The serial and overlapped objectives sum launch groups one after
    another, so rank concurrency — which only shows up in the pipelined
    event simulation — never improves the scores the planner ladder
    searches by, and the ladder keeps every expert shard on one rank.
    This helper encodes the placement the topology is FOR: plan the
    single-rank placement as usual, then rotate each PIM-placed expert
    shard j (`stage_shard`) onto rank `j % n_ranks`. Shard stage-ins,
    launches, and exchanges then land on per-rank channels, and the
    pipelined timeline prices the rank-parallel win
    (benchmarks/dispatch_bench.py sweep 8 gates it strictly beating the
    single-rank plan). Returns an `evaluate`d Plan (method
    `"expert-parallel"`); shards the base plan kept on the host stay
    there."""
    from .placement import _is_pim, evaluate
    from .placement import plan as plan_placement
    base = plan_placement(graph, devices=(source, topology.base),
                          source=source, sink=sink, objective=objective)
    assignment = dict(base.assignment)
    for n in assignment:
        j = stage_shard(n)
        if j is not None and _is_pim(assignment[n]):
            assignment[n] = topology.rank_device(j % topology.n_ranks)
    return evaluate(graph, assignment, topology.dpu, source, sink,
                    method="expert-parallel")


# ---------------------------------------------------------------------------
# chunked LM prefill as a DAG (per-chunk fan-out, KV write residency)
# ---------------------------------------------------------------------------

def _attend_prefill(qkv, kq, vq, dims: DecodeDims, t: int, q0: int,
                    k0: int = 0, window: int = 0):
    """Costing proxy for one prefill chunk's attention: `t` query rows at
    positions q0..q0+t-1 attend causally over the keys written so far
    (prior chunks + this one), with the same quantized-int dot /
    float-softmax mix as the decode `_attend` — the op profile the DPU
    cost model prices. int8-stored caches (`dims.quant == "int8"`) upcast
    to the int32 accumulator on entry, same as the decode `_attend`.

    Under a sliding `window` the banded prefill DAG drops chunks whose
    KV the window makes dead, so the key tensor starts at absolute
    position `k0` (the first live chunk's offset) instead of 0, and the
    mask adds the window bound `q_pos - k_pos < window` on top of
    causality. Both are python-gated: the `k0=0, window=0` jaxpr is
    byte-identical to the pre-window proxy."""
    h, dh = dims.n_heads, dims.head_dim
    kq, vq = kq.astype(jnp.int32), vq.astype(jnp.int32)
    b = qkv.shape[0] // t
    q = qkv.reshape(b, t, 3, h, dh)[:, :, 0]
    qq = jnp.round(q * _Q_SCALE).astype(jnp.int32)
    scores_i = jnp.einsum("bthd,shd->bhts", qq, kq)
    scores = scores_i.astype(jnp.float32) / (_Q_SCALE * _Q_SCALE * dh ** 0.5)
    q_pos = q0 + jnp.arange(t)
    k_pos = (k0 + jnp.arange(kq.shape[0])) if k0 else jnp.arange(kq.shape[0])
    mask = q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    wq = jnp.round(w * 256.0).astype(jnp.int32)
    out_i = jnp.einsum("bhts,shd->bthd", wq, vq)
    return (out_i.astype(jnp.float32).reshape(b * t, h * dh)
            / (256.0 * _Q_SCALE))



def parse_stage_name(name: str) -> tuple[str, int | None, int | None]:
    """Split a planner/executor stage name into (kind, layer, chunk).

    The routing contract between DAG builders and the serving steps:
    decode names are `"{kind}{layer}"` (`"qkv3"` -> `("qkv", 3, None)`),
    prefill names append the chunk (`"attn2/c1"` -> `("attn", 2, 1)`),
    and the unnumbered stages parse as `("embed", None, ...)` /
    `("head", None, None)`. Two optional suffixes extend the grammar to
    `"{kind}{layer}[@r{shard}][/c{chunk}][/s{step}]"`: expert-parallel
    shard DAGs append `"@r{shard}"` (`decode_dag(expert_shards=...)`;
    recover it with `stage_shard`) and cross-step DAGs append
    `"/s{step}"` (`decode_steps_dag`; recover it with `stage_step`) —
    both are stripped here, so (kind, layer, chunk) routing is
    shard/step-agnostic."""
    base, _, _s = name.partition("/s")
    base, _, c = base.partition("/c")
    base, _, _r = base.partition("@r")
    kind = base.rstrip("0123456789")
    layer = int(base[len(kind):]) if len(base) > len(kind) else None
    return kind, layer, (int(c) if c else None)


def stage_shard(name: str) -> int | None:
    """The expert-parallel shard index of a stage name (`"expert1@r2"` ->
    2; None for unsharded stages) — which slice of the expert stack the
    node computes, and which topology rank `expert_parallel_plan` places
    it on."""
    base, _, _s = name.partition("/s")
    base, _, _c = base.partition("/c")
    _, _, r = base.partition("@r")
    return int(r) if r else None


def stage_step(name: str) -> int | None:
    """The cross-step index of a `decode_steps_dag` stage name
    (`"qkv3/s1"` -> 1; None outside step-unrolled DAGs)."""
    _, _, s = name.partition("/s")
    return int(s) if s else None


def stage_kind(name: str) -> str:
    """The stage *kind* of a planner/executor node name (`"qkv3/c1"` ->
    `"qkv"`) — the key into the executor's per-kind stage library."""
    return parse_stage_name(name)[0]


def prefill_serial_order(graph: OpGraph) -> list[str]:
    """The chunk-major linearization of a prefill DAG's nodes — chunk 0's
    full ladder, then chunk 1's, ... — i.e. the strictly serial chunk
    loop the dispatch prefill executed before the unified executor.
    Derived from the graph itself (a stable sort of its topological
    order by chunk index, un-chunked nodes like the head last), so it
    can never drift from the builder's node names. A valid topological
    order: intra-chunk relative order is preserved and cross-chunk edges
    only ever point to later chunks. Used by
    `benchmarks/dispatch_bench.py` as the baseline
    `make_schedule(..., order=...)` prices the pipelined timeline
    against."""
    order = graph.topo_order()
    pos = {n: i for i, n in enumerate(order)}

    def key(name):
        chunk = parse_stage_name(name)[2]
        return (chunk if chunk is not None else len(order), pos[name])
    return sorted(order, key=key)


def prefill_chunk_splits(s_len: int, chunk: int) -> list[int]:
    """Chunk lengths a `s_len`-token prompt is processed in: full `chunk`
    slices plus a possibly ragged tail. The single source of truth for
    both the prefill DAG's chunk grid and the executable chunking in
    `serve.dispatch_engine.DispatchPrefillStep` — the
    `"{stage}{layer}/c{chunk}"` routing contract depends on the two
    agreeing. A prompt shorter than one chunk is a single ragged chunk."""
    if chunk < 1 or s_len < 1:
        raise ValueError(f"need chunk >= 1 and s_len >= 1, got "
                         f"chunk={chunk}, s_len={s_len}")
    splits = [chunk] * (s_len // chunk)
    if s_len % chunk:
        splits.append(s_len % chunk)
    return splits


def prefill_live_from(splits, window: int) -> list[int]:
    """Per-chunk banding bound for windowed prefill: `live_from[c]` is
    the FIRST chunk index whose KV chunk `c`'s queries can still attend
    under a sliding `window` — chunk `j < c` is dead for chunk `c` when
    even its last key position (`offs[j+1] - 1`) falls outside the
    oldest key chunk `c`'s first query may read (`offs[c] - window + 1`,
    the `q_pos - k_pos < window` bound of `models/layers.py`). All
    zeros when `window == 0` (full attention: every prior chunk live).

    The single source of truth for the banded prefill DAG's dropped
    cross-chunk edges AND the executable banded KV prefix in
    `serve.dispatch_engine.DispatchPrefillStep` — the two must agree or
    the executor would feed a chunk keys the plan never priced (or
    vice versa). Whole chunks stay live even when only partially inside
    the window: the mask (not the fan-in) handles sub-chunk
    granularity."""
    offs = [0]
    for t in splits:
        offs.append(offs[-1] + int(t))
    if not window:
        return [0] * len(splits)
    live = []
    for c in range(len(splits)):
        j = c
        while j > 0 and offs[j] - 1 >= offs[c] - window + 1:
            j -= 1
        live.append(j)
    return live


def prefill_dag(dims: DecodeDims = REDUCED_DIMS, *,
                prefill_len: int | None = None, chunk: int | None = None,
                batch: int = 1, kv_home: str | None = "upmem_2556",
                costed: bool = True) -> OpGraph:
    """Chunked prefill as the operator DAG the serving planner consumes.

    The prompt (`prefill_len` tokens, default `dims.seq`) is split into
    ceil(prefill_len/chunk) chunks (default 4 chunks; the last may be
    ragged). Each chunk runs the per-layer stage ladder the decode DAG
    uses — qkv -> attn -> o -> mlp with the residual stream fanning out to
    both qkv and the post-attention add — and every chunk's qkv output
    additionally *fans out across chunks* to all later chunks' attention
    at the same layer: that edge is the freshly written KV rows the later
    chunks read. Only the last chunk feeds the vocab head (the engine
    samples from the prompt's final position); earlier chunks' terminal
    residuals are retrieved to the sink (conservative — serving may
    return prompt logprobs).

    KV residency (`kv_home`, a `placement.DEVICES` name; None disables):
    attention of chunk c *reads* the c prior chunks' rows resident at
    `kv_home` (`annotate_kv_residency` — placing it elsewhere migrates
    them) and *writes* its own chunk's rows (`annotate_kv_write` —
    running it elsewhere ships them back). Node names follow
    `"{stage}{layer}/c{chunk}"` (`"embed/c0"`, `"qkv3/c1"`, ...), the
    routing contract `serve.dispatch_engine.DispatchPrefillStep` executes.

    Sliding-window dims (`0 < dims.window < prefill_len`) build the
    BANDED (block-sparse) variant: chunk c's attention fans in KV only
    from chunks within the window (`prefill_live_from` — the same bound
    the executable banded prefix in `dispatch_engine` uses), dead
    chunks' qkv edges / residency charges / `kv_writers` waits are
    dropped, the resident-read charge shrinks to the live prior rows,
    and the write-back charge to the ring's `min(t, window)` surviving
    rows. The graph name gains `-swa{window}`; a window that never
    binds (>= prefill_len) builds the byte-identical full DAG.

    Planner note: the cross-chunk fan-in widens the topological frontier
    to ~2*n_chunks+1, so DAGs beyond 2 chunks typically exceed the
    frontier DP's default state budget and fall to branch-and-bound —
    the ladder behaves as designed (DESIGN.md §10).

    MoE dims (`dims.n_experts > 0`) give every chunk's layer the routed
    ladder instead of `mlp`: `router{i}/c{c}` -> `expert{i}/c{c}` ->
    `combine{i}/c{c}`, with the router->expert and expert->combine edges
    annotated as token exchanges (`OpGraph.annotate_exchange`, volume
    tokens x capacity per chunk — see `decode_dag`). Capacity is per
    chunk (`moe_capacity(t, ...)`): chunked MoE prefill drops overflow
    tokens per chunk, not per prompt, so it is NOT output-equivalent to
    the fused whole-prompt forward (serve.dispatch_engine docstring).

    `costed=False` builds the same node names / edges / insertion order
    with zero-cost nodes and no stage compilation — the structural
    skeleton `dispatch.executor.PlanExecutor` groups a ragged prompt's
    execution timeline from (DESIGN.md §11); exchange-edge annotations
    are kept (the executor's host gather/scatter reads them). Attention
    readers also carry `meta["kv_writers"]` (the earlier same-layer
    chunks' attention names): the pipelined timeline may not start a
    reader before those writers' KV write-backs have landed at the
    home."""
    d = dims
    S_len = prefill_len if prefill_len is not None else d.seq
    c_len = chunk if chunk is not None else max(1, -(-S_len // 4))
    splits = prefill_chunk_splits(S_len, c_len)
    # banded (block-sparse) variant: a sliding window narrower than the
    # prompt makes old chunks' KV dead — their cross-chunk edges,
    # residency charges, and write-back waits are dropped. A window that
    # never binds (>= the prompt) builds the identical full-attention DAG.
    win = d.window if 0 < d.window < S_len else 0
    live_from = prefill_live_from(splits, win)
    offs = [0]
    for t in splits:
        offs.append(offs[-1] + t)

    f32, i32 = jnp.float32, jnp.int32
    q8 = d.quant == "int8"
    kv_dt = jnp.int8 if q8 else i32
    S = jax.ShapeDtypeStruct
    dm, hdh = d.d_model, d.n_heads * d.head_dim
    kv_row_bytes = 2.0 * batch * d.kv_heads * d.head_dim * d.kv_itemsize

    def f_embed(t, tab):
        return tab[t]

    def f_qkv(v, w):
        return _rmsnorm(v) @ w

    def f_o(a, res, w):
        return res + a @ w

    def f_mlp(v, wu, wd):
        return v + jax.nn.gelu(_rmsnorm(v) @ wu) @ wd

    def f_head(v, w):
        return _rmsnorm(v) @ w

    wqkv = S((dm, 3 * hdh), f32)
    wo = S((hdh, dm), f32)
    wup, wdown = S((dm, d.d_ff), f32), S((d.d_ff, dm), f32)
    whead = S((dm, d.vocab), f32)
    table = S((d.vocab, dm), f32)

    # compile each distinct stage shape once; same-shape chunks share it
    protos: dict[tuple, OpNode] = {}

    def proto(kind, key, build):
        if not costed:                 # structural skeleton: names/edges
            key = "struct"             # only, no stage compilation
        if (kind, key) not in protos:
            protos[(kind, key)] = build() if costed else OpNode(
                name=kind, kind=kind, flops=0.0, hbm_bytes=0.0,
                out_bytes=0.0)
        src = protos[(kind, key)]
        return dataclasses.replace(src, ops=dict(src.ops),
                                   meta=dict(src.meta))

    base_name = "lm-moe-prefill-dag" if d.n_experts else "lm-prefill-dag"
    g = OpGraph(base_name + ("-int8" if q8 else "")
                + (f"-swa{win}" if win else ""),
                input_bytes=float(batch * S_len * 4))
    res: list[str | None] = [None] * len(splits)  # chunk residual producers
    for c, t in enumerate(splits):
        tokens = S((batch * t,), i32)
        node = proto("embed", t, lambda: node_from_fn(
            "embed", f_embed, tokens, table, kind="embed"))
        g.add(dataclasses.replace(node, name=f"embed/c{c}"))
        res[c] = f"embed/c{c}"
    for i in range(d.n_layers):
        qkv_names: list[str] = []
        c0 = 0
        for c, t in enumerate(splits):
            rows = batch * t
            # banding: keys start at the first live chunk's offset, not 0
            k0 = offs[live_from[c]]
            prefix = c0 + t - k0
            x = S((rows, dm), f32)
            qkv_out = S((rows, 3 * hdh), f32)
            attn_out = S((rows, hdh), f32)
            kq = S((prefix, d.n_heads, d.head_dim), kv_dt)
            vq = S((prefix, d.n_heads, d.head_dim), kv_dt)
            act_bytes = float(rows * dm * 4)

            node = proto("qkv", t, lambda: node_from_fn(
                "qkv", f_qkv, x, wqkv, kind="gemv_qkv",
                exchange_bytes=3 * act_bytes))
            qkv = g.add(dataclasses.replace(node, name=f"qkv{i}/c{c}"),
                        res[c])
            qkv_names.append(qkv.name)

            attend = functools.partial(_attend_prefill, dims=d, t=t,
                                       q0=c0, k0=k0, window=win)
            node = proto("attn", (t, prefix), lambda: node_from_fn(
                "attn", attend, qkv_out, kq, vq, kind="attn"))
            # fan-in: this chunk's qkv plus every LIVE earlier chunk's
            # (their written KV rows) — the cross-chunk edges of the
            # DAG; a window drops the dead chunks' edges entirely
            attn = g.add(dataclasses.replace(node, name=f"attn{i}/c{c}"),
                         *qkv_names[live_from[c]:])
            if kv_home is not None:
                if c0 - k0:
                    annotate_kv_residency(attn, kv_row_bytes * (c0 - k0),
                                          kv_home)
                    # the rows this chunk reads from the home were written
                    # by the earlier LIVE chunks' attention — the
                    # pipelined timeline waits for their write-backs only
                    attn.meta["kv_writers"] = [f"attn{i}/c{j}"
                                               for j in range(live_from[c],
                                                              c)]
                # the ring keeps at most `win` of this chunk's rows —
                # only those are ever shipped back to the home
                annotate_kv_write(attn, kv_row_bytes * (min(t, win) if win
                                                        else t), kv_home)

            node = proto("o", t, lambda: node_from_fn(
                "o", f_o, attn_out, x, wo, kind="gemv_o",
                exchange_bytes=act_bytes))
            g.add(dataclasses.replace(node, name=f"o{i}/c{c}"),
                  f"attn{i}/c{c}", res[c])
            if d.n_experts:            # routed MoE ladder for this chunk
                e, k = d.n_experts, d.top_k
                cap = moe_capacity(t, e, k)
                wr = S((dm, e), f32)
                fe = d.expert_ff
                wu_e, wg_e = S((e, dm, fe), f32), S((e, dm, fe), f32)
                wd_e = S((e, fe, dm), f32)
                buf = S((batch, e, cap, dm), f32)
                topi = S((batch, t, k), i32)
                pos_ = S((batch, t, k), i32)
                gate_w = S((batch, t, k), f32)
                r_fn = functools.partial(_moe_router, seq=t, top_k=k)
                c_fn = functools.partial(_moe_combine, seq=t)
                node = proto("router", t, lambda: node_from_fn(
                    "router", r_fn, x, wr, kind="moe_router"))
                g.add(dataclasses.replace(node, name=f"router{i}/c{c}"),
                      f"o{i}/c{c}")
                if q8:
                    wu_q = S((e, dm, fe), jnp.int8)
                    wg_q = S((e, dm, fe), jnp.int8)
                    wd_q = S((e, fe, dm), jnp.int8)
                    su_e, sg_e = S((e, 1, fe), f32), S((e, 1, fe), f32)
                    sd_e = S((e, 1, dm), f32)
                    node = proto("expert", t, lambda: node_from_fn(
                        "expert", _moe_expert_q8, buf, wu_q, su_e, wg_q,
                        sg_e, wd_q, sd_e, kind="moe_expert"))
                else:
                    node = proto("expert", t, lambda: node_from_fn(
                        "expert", _moe_expert, buf, wu_e, wg_e, wd_e,
                        kind="moe_expert"))
                g.add(dataclasses.replace(node, name=f"expert{i}/c{c}"),
                      f"router{i}/c{c}")
                node = proto("combine", t, lambda: node_from_fn(
                    "combine", c_fn, x, buf, topi, pos_, gate_w,
                    kind="moe_combine"))
                g.add(dataclasses.replace(node, name=f"combine{i}/c{c}"),
                      f"expert{i}/c{c}", f"router{i}/c{c}", f"o{i}/c{c}")
                xbytes = moe_exchange_bytes(rows, dm, k)
                g.annotate_exchange(f"router{i}/c{c}", f"expert{i}/c{c}",
                                    xbytes)
                g.annotate_exchange(f"expert{i}/c{c}", f"combine{i}/c{c}",
                                    xbytes)
                res[c] = f"combine{i}/c{c}"
            else:
                node = proto("mlp", t, lambda: node_from_fn(
                    "mlp", f_mlp, x, wup, wdown, kind="mlp",
                    exchange_bytes=float(rows * d.d_ff * 4) + act_bytes))
                g.add(dataclasses.replace(node, name=f"mlp{i}/c{c}"),
                      f"o{i}/c{c}")
                res[c] = f"mlp{i}/c{c}"
            c0 += t
    t_last = splits[-1]
    x_last = S((batch * t_last, dm), f32)
    head = (node_from_fn("head", f_head, x_last, whead, kind="gemv_head",
                         exchange_bytes=float(batch * t_last * d.vocab * 4))
            if costed else OpNode(name="head", kind="gemv_head", flops=0.0,
                                  hbm_bytes=0.0, out_bytes=0.0))
    g.add(head, res[-1])
    return g


# ---------------------------------------------------------------------------
# the 16 PrIM workloads as one-operator graphs
# ---------------------------------------------------------------------------

def node_from_counts(c: WorkloadCounts) -> OpNode:
    """Lift a PrIM workload's analytic counts into a single OpNode (the
    whole workload is one operator — Fig. 4's granularity)."""
    return OpNode(name=c.name, kind="prim", flops=c.flops_equiv,
                  hbm_bytes=c.bytes_streamed, out_bytes=0.0,
                  ops=dict(c.ops), exchange_bytes=c.interbank_bytes,
                  meta={"pim_suitable": c.pim_suitable,
                        "bytes_cpu": c.bytes_cpu, "bytes_gpu": c.bytes_gpu})


def prim_graph(c: WorkloadCounts) -> OpGraph:
    """A PrIM workload as a one-node OpGraph (the planner's unit case)."""
    return chain_graph(c.name, [node_from_counts(c)])


# ---------------------------------------------------------------------------
# the shipped-graph registry
# ---------------------------------------------------------------------------

#: planner device sets the shipped goldens were pinned under
_TWO_DEV = ("xeon", "upmem_2556")
_THREE_DEV = ("xeon", "titan_v", "upmem_2556")
#: multi-rank device sets (ISSUE-9): rank 0 is the bare base name, so the
#: single-rank placements inside them are the exact pre-topology plans
_RANKED_2 = ("xeon", "upmem_2556", "upmem_2556:1")
_RANKED_4 = ("xeon", "upmem_2556", "upmem_2556:1", "upmem_2556:2",
             "upmem_2556:3")

#: paper-scale prefill golden shape: 2 chunks keeps the cross-chunk
#: frontier inside the exact frontier-DP rung (DESIGN.md §10); the
#: 4-chunk B&B shape is exercised by benchmarks/dispatch_bench.py
PREFILL_PAPER = dict(prefill_len=2048, chunk=1024)

#: long-context banded-prefill golden shape: a 32k prompt under the 4k
#: window in 8k chunks — chunk c >= 2 drops chunk c-2's dead KV
#: (`prefill_live_from` = [0, 0, 1, 2]), so the band structure is
#: golden-pinned while the chunk count stays at the 4-chunk B&B shape
#: the bench already exercises
PREFILL_SWA = dict(prefill_len=32768, chunk=8192)
#: reduced banded shape with the same live_from band ([0, 0, 0, 1]:
#: chunk 3 drops chunk 0 under the window-8 bound)
PREFILL_SWA_REDUCED = dict(prefill_len=16, chunk=4)


def shipped_graphs() -> dict:
    """Registry of every shipped graph: name -> (builder, planner device
    set). The single source of truth three gates share — the golden-plan
    pins (tests/test_golden_plans.py), the planner-fidelity gate
    (tests/test_trace.py, `trace.replay.fidelity` over each entry), and
    ad-hoc benchmark sweeps. Names are stable identifiers: golden files
    key on them, so renaming an entry is a golden regeneration."""
    from .. import prim
    builders = {
        "prim-mixed": (
            lambda: mixed_pipeline(m=4096, concrete=False).graph(),
            _TWO_DEV),
        "lm-decode-chain": (
            lambda: decode_pipeline(DecodeDims(), concrete=False).graph(),
            _TWO_DEV),
        "lm-decode-dag": (
            lambda: decode_dag(DecodeDims()), _TWO_DEV),
        "lm-decode-dag-kv-on-host": (
            lambda: decode_dag(DecodeDims(), kv_home="xeon"), _TWO_DEV),
        "lm-prefill-dag": (
            lambda: prefill_dag(DecodeDims(), **PREFILL_PAPER), _TWO_DEV),
        "lm-prefill-dag-reduced": (
            lambda: prefill_dag(REDUCED_DIMS, prefill_len=8, chunk=4),
            _TWO_DEV),
        # ISSUE-5: MoE routing as an exchange phase — decode + prefill,
        # paper (mixtral-8x7b dims) and reduced
        "lm-moe-decode-dag": (
            lambda: moe_decode_dag(MOE_PAPER_DIMS), _TWO_DEV),
        "lm-moe-decode-dag-reduced": (
            lambda: moe_decode_dag(MOE_REDUCED_DIMS), _TWO_DEV),
        "lm-moe-prefill-dag": (
            lambda: prefill_dag(MOE_PAPER_DIMS, **PREFILL_PAPER), _TWO_DEV),
        "lm-moe-prefill-dag-reduced": (
            lambda: prefill_dag(MOE_REDUCED_DIMS, prefill_len=8, chunk=4),
            _TWO_DEV),
        # ISSUE-8: the KT2-flip configurations — int8 expert weights
        # (int32 accumulation) + int8 KV storage; the paper-scale decode
        # golden pins the quantized experts ON PIM
        "lm-moe-decode-dag-int8": (
            lambda: moe_decode_dag(MOE_PAPER_DIMS_INT8), _TWO_DEV),
        "lm-moe-decode-dag-int8-reduced": (
            lambda: moe_decode_dag(MOE_REDUCED_DIMS_INT8), _TWO_DEV),
        "lm-moe-prefill-dag-int8": (
            lambda: prefill_dag(MOE_PAPER_DIMS_INT8, **PREFILL_PAPER),
            _TWO_DEV),
        "lm-moe-prefill-dag-int8-reduced": (
            lambda: prefill_dag(MOE_REDUCED_DIMS_INT8, prefill_len=8,
                                chunk=4), _TWO_DEV),
        # ISSUE-9: multi-rank scale-out — expert-parallel shard DAGs
        # planned over rank-qualified device sets (per-rank channels),
        # and cross-step pipelining (2 decode steps, scoring contract)
        "lm-moe-decode-dag-reduced-ep2": (
            lambda: moe_decode_dag(MOE_REDUCED_DIMS, expert_shards=2),
            _RANKED_2),
        "lm-moe-decode-dag-int8-reduced-ep4": (
            lambda: moe_decode_dag(MOE_REDUCED_DIMS_INT8, expert_shards=4),
            _RANKED_4),
        "lm-decode-steps-dag-reduced": (
            lambda: decode_steps_dag(REDUCED_DIMS, n_steps=2), _TWO_DEV),
        "lm-moe-decode-steps-int8-reduced": (
            lambda: decode_steps_dag(MOE_REDUCED_DIMS_INT8, n_steps=2),
            _TWO_DEV),
        # ISSUE-10: long-context sliding-window workloads — decode prices
        # the 4k-row ring (not the 32k context), prefill is the banded
        # block-sparse DAG with dead cross-chunk KV edges dropped
        "lm-decode-dag-swa4096": (
            lambda: decode_dag(SWA_PAPER_DIMS), _TWO_DEV),
        "lm-decode-dag-swa8-reduced": (
            lambda: decode_dag(SWA_REDUCED_DIMS), _TWO_DEV),
        "lm-moe-decode-dag-int8-swa4096": (
            lambda: moe_decode_dag(MOE_SWA_PAPER_DIMS_INT8), _TWO_DEV),
        "lm-moe-decode-dag-int8-swa8-reduced": (
            lambda: moe_decode_dag(MOE_SWA_REDUCED_DIMS_INT8), _TWO_DEV),
        "lm-prefill-dag-swa4096-32k": (
            lambda: prefill_dag(SWA_PAPER_DIMS, **PREFILL_SWA), _TWO_DEV),
        "lm-prefill-dag-swa8-reduced": (
            lambda: prefill_dag(SWA_REDUCED_DIMS, **PREFILL_SWA_REDUCED),
            _TWO_DEV),
    }
    for counts in prim.all_ref_counts():
        builders[f"prim/{counts.name}"] = (
            (lambda c=counts: prim_graph(c)), _THREE_DEV)
    return builders
