"""repro.dispatch — heterogeneous offload planner + hybrid dispatch runtime.

The paper's central finding is that PIM suitability is *per-operator*, not
per-program (Takeaways 1-3, Fig. 4's two workload groups). This package
turns the one-shot analyses of `repro.core` into an end-to-end pipeline:

    graph      build an operator graph (flops / bytes / OI / op mix per op)
    placement  assign every op to xeon / titan_v / upmem_* minimizing
               modeled end-to-end latency, charging host<->DPU boundary
               transfers (DP over chains, greedy over DAGs)
    schedule   coalesce consecutive PIM stages into one launch, batch
               parallel transfers, overlap compute with transfers
    runtime    execute a plan in JAX: PIM stages as BankGrid local/exchange
               phases, host stages under plain jit, validated vs reference
    workloads  mixed PrIM pipelines + the LM decode chain as dispatchable
               pipelines/graphs

Everything later PRs serve or scale dispatches through this layer.
"""

from .graph import OpNode, OpGraph, node_from_fn, ops_from_hlo
from .placement import (DEVICES, Plan, compare_plans, plan, pure_plan,
                        node_time, transfer_time)
from .schedule import LaunchGroup, Schedule, make_schedule
from .runtime import Pipeline, Stage, execute, reference
from . import workloads
