"""repro.dispatch — heterogeneous offload planner + hybrid dispatch runtime.

The paper's central finding is that PIM suitability is *per-operator*, not
per-program (Takeaways 1-3, Fig. 4's two workload groups). This package
turns the one-shot analyses of `repro.core` into an end-to-end pipeline:

    graph      build an operator graph (flops / bytes / OI / op mix per op,
               KV-residency read AND write annotations on cache-touching
               nodes)
    placement  assign every op to xeon / titan_v / upmem_* minimizing
               modeled end-to-end latency (seconds), charging host<->DPU
               boundary transfers and KV-cache migration/write-back off
               its home device. Planner ladder: chain DP -> exact
               frontier DP (series-parallel / out-tree DAGs) -> bounded
               branch-and-bound -> greedy (see placement docstring). Two
               objectives: the additive serial sum (default) or the
               scheduler's overlapped wall-clock
               (`plan(..., objective="overlapped")`)
    schedule   coalesce consecutive PIM stages into one launch, batch
               parallel transfers, overlap compute with transfers (the
               GPU<->DPU host-relay hop and KV write-backs stay
               serialized); two execution disciplines over one timeline:
               serial groups (`overlapped_s`) and the dependency-aware
               pipeline (`pipelined_s`, `make_schedule(...,
               pipelined=True)`)
    executor   the ONE execution loop for any plan: walk the Schedule's
               launch groups in timeline order — host stages per-kind
               jits, PIM stages BankGrid faces, boundary tensors staged
               ahead of each PIM group (double-buffered slots)
    runtime    execute a chain Pipeline in JAX: PIM stages as BankGrid
               local/exchange phases, host stages under plain jit,
               validated vs reference
    workloads  mixed PrIM pipelines + the LM decode chain/DAG + the
               chunked prefill DAG as dispatchable pipelines/graphs
    plan_cache LRU cache of planner products keyed by batch signature
               (live-slot count, bucketed KV length, chunk splits) —
               `FaceCache`'s compile-sharing idiom lifted to plans, so
               serving replans amortize as batch composition churns
    trace      observability over the whole spine: measured/modeled
               execution traces (JSON + Chrome trace_event), the
               what-if replayer re-pricing recorded timelines under the
               pipelined discipline, least-squares calibration of the
               cost constants, and the planner-fidelity gate

Unit conventions across the package: every modeled cost is SECONDS
(fields/locals suffixed `_s`), every payload is BYTES (`*_bytes`), and
device names come from `placement.DEVICES` (`"xeon"`, `"titan_v"`,
`"upmem_2556"`, `"upmem_640"`).

The serving engine dispatches BOTH phases through this layer
(`repro.serve.dispatch_engine`, `ServeEngine(engine="dispatch")`): decode
over `workloads.decode_dag`, chunked prefill over `workloads.prefill_dag`.
"""

from .graph import (OpNode, OpGraph, annotate_kv_residency,
                    annotate_kv_write, node_from_fn, ops_from_hlo)
from .placement import (DEVICES, Plan, compare_plans, cost_constants,
                        greedy_plan, kv_migration_time, node_bytes,
                        node_time, placed_time, plan, pure_plan,
                        transfer_hops, transfer_time)
from .schedule import LaunchGroup, Schedule, make_schedule
from .executor import FaceCache, PlanExecutor, StageDef
from .plan_cache import PlanCache, batch_signature
from .runtime import Pipeline, Stage, bank_face, execute, reference
from . import workloads
from . import trace
