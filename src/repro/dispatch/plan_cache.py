"""Plan cache: planner products keyed by batch signature.

`FaceCache` (executor.py) amortizes stage *compiles* across executors;
this module extends the same sharing idiom one level up, to planner
*plans*: planning a DAG costs a frontier-DP / branch-and-bound solve per
call (milliseconds of host work, growing with graph size), while a
serving batch's composition churns every admission and eviction — the
live-slot count grows and shrinks, per-slot positions advance every
step, and ragged prompts split into different chunk grids.
`batch_signature` canonicalizes that churn into a coarse key (live-slot
count, bucketed position, chunk splits, channel-topology shape) so
equal-shaped compositions
share one solve, and `PlanCache` LRU-holds whatever the solve produced
(a `Plan`, a priced (graph, plan, seconds) bundle, a `PlanExecutor`)
with FaceCache-style hit/miss accounting.

Users: the serving gateway (`repro.serve.gateway`) prices every decode
step and every candidate admission through one of these, and
`serve.dispatch_engine.DispatchPrefillStep` holds its per-chunk-split
executors in one. Modeled times stored by builders are SECONDS; keys
are plain tuples.
"""

from __future__ import annotations

import collections
from typing import Any, Callable, Hashable, Iterable, Sequence


def batch_signature(n_live: int, positions: Iterable[int] = (), *,
                    pos_bucket: int = 64, splits: Sequence[int] = (),
                    phase: str = "decode", topology: Any = (),
                    window: int = 0) -> tuple:
    """Canonical plan-cache key for one batch composition:
    `(phase, live-slot count, bucketed KV length, chunk splits,
    topology shape[, window])`.

    The KV length is the max position rounded UP to a multiple of
    `pos_bucket` (the sequence length the priced DAG assumes —
    conservative: the model never underestimates resident KV), so a slot
    advancing within a bucket is a cache hit and only bucket crossings
    replan. `splits` carries the chunked-prefill grid
    (`workloads.prefill_chunk_splits`); leave it empty for decode.
    `topology` carries the channel-topology shape the priced plan
    assumes — a `placement.Topology` (its `.signature`, `(base,
    n_ranks)`) or an already-hashable shape tuple — so plans priced
    under different rank counts never alias; the empty default means
    the single-channel topology. `window` is the sliding-window bound
    the priced DAG assumes (`DecodeDims.window`; 0 = full attention):
    a windowed and a full-attention batch with identical
    `(n_live, positions, splits)` price DIFFERENT graphs (ring-width
    KV, banded prefill) and must never serve each other's plan. The
    zero default appends nothing, keeping every pre-window signature
    byte-identical."""
    if pos_bucket < 1:
        raise ValueError(f"pos_bucket must be >= 1, got {pos_bucket}")
    mx = max((int(p) for p in positions), default=0)
    kv_len = (mx // pos_bucket + 1) * pos_bucket
    topo = getattr(topology, "signature", topology)
    sig = (str(phase), int(n_live), int(kv_len),
           tuple(int(s) for s in splits), tuple(topo))
    return sig + (int(window),) if window else sig


class PlanCache:
    """LRU cache of planner products keyed by batch signature.

    `get_or_plan(key, builder)` is the whole interface: a hit returns
    the cached value and a miss runs `builder()` once (the amortized
    planner solve), evicting the stalest entry beyond `maxsize`. The
    cache accounts for itself like `FaceCache` does — `stats` exposes
    calls/hits/misses/evictions and the hit rate the gateway bench
    gates (>80% at steady state under batch-composition churn)."""

    def __init__(self, maxsize: int = 64):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._entries: "collections.OrderedDict[Hashable, Any]" = \
            collections.OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    def get_or_plan(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        """Return the entry cached under `key`, calling `builder()` to
        create it on a miss; LRU-evicts beyond `maxsize`. The entry is
        whatever `builder` returns — a `Plan`, a priced bundle with its
        modeled seconds, a `PlanExecutor` — the cache never inspects
        it."""
        if key in self._entries:
            self._hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self._misses += 1
        value = builder()
        while len(self._entries) >= self.maxsize:
            self._entries.popitem(last=False)
            self._evictions += 1
        self._entries[key] = value
        return value

    def __len__(self) -> int:
        """Number of cached entries (<= maxsize)."""
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        """True when `key` is cached (no stats bump, no LRU touch)."""
        return key in self._entries

    @property
    def stats(self) -> dict:
        """FaceCache-style accounting: `{"calls", "hits", "misses",
        "evictions", "size", "hit_rate"}`. `hit_rate` is hits/calls
        (0.0 before the first call) — the steady-state quantity the
        gateway bench's churn sweep gates."""
        calls = self._hits + self._misses
        return {"calls": calls, "hits": self._hits,
                "misses": self._misses, "evictions": self._evictions,
                "size": len(self._entries),
                "hit_rate": (self._hits / calls) if calls else 0.0}
