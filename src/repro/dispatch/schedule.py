"""Launch/transfer scheduling: turn a placement into an execution timeline.

The paper's programming recommendations, made mechanical:

  * **Launch coalescing** — consecutive operators on the same device merge
    into one launch group; the fixed `launch_overhead_s` (the cost that
    makes 640->2556-DPU scaling sublinear, KT4) is paid once per group,
    not once per operator.
  * **Parallel-transfer batching** — every tensor crossing into a group is
    shipped in ONE batched parallel transfer (the paper's
    `dpu_push_xfer`-style interface): the per-call setup cost is paid once
    and the payload moves at the full parallel-transfer bandwidth, instead
    of one serial call per tensor.
  * **Compute/transfer overlap** — within a group, streaming input chunks
    double-buffer against compute, so a group costs
    `max(compute, transfer)` instead of `compute + transfer` (dependent
    groups can never prefetch each other — only intra-group streaming
    overlaps, which is why the overlapped total still sums over groups).
    GPU<->DPU tensors relay through host DRAM (Takeaway 3, both hops
    charged by `placement.transfer_time`); only the *final* hop streams
    into the group's device, so only it may hide under compute — the
    host-relay hop (`LaunchGroup.relay_s`) is serialized in front of the
    overlap window.

`make_schedule(graph, plan)` emits the timeline; `Schedule.total_s` (and
the optimistic `overlapped_s`) is the modeled wall-clock the benchmarks
report next to the plan's serial estimate. `overlapped_s` is also the
objective `placement.plan(..., objective="overlapped")` optimizes. KV
rows written off their home device (a prefill chunk's attention,
`graph.annotate_kv_write`) ship back as one batched transfer serialized
after the group — later chunks read them from the home, so the write-back
can never hide under this group's compute. Exchange edges
(`OpGraph.exchange_edges`, MoE token dispatch/combine) between
same-PIM-device endpoints are booked to the consuming member's group as
`LaunchGroup.exchange_s`: transfer-channel-only occupancy (host gather +
re-scatter) that the consumer waits on, so it is serialized into
`overlapped_s` and occupies the shared channel in the pipelined sim.

Two execution disciplines are modeled over the same group timeline:

  * **serial groups** (`total_s` / `overlapped_s`) — groups run one after
    another, each paying its own (optionally overlapped) cost; this is
    what a serial stage loop over the plan costs.
  * **pipelined groups** (`pipelined_s`, `make_schedule(...,
    pipelined=True)`) — a dependency-aware event simulation: each device
    is a serial resource, host<->device traffic occupies each rank's own
    transfer channel (`placement.channel_of`; single-rank plans book the
    one shared `"channel"`, the pre-topology degenerate case), a group
    starts when its crossing producers are done (and,
    for KV readers, when the rows they read have landed at their home —
    `meta["kv_writers"]`), and KV write-backs occupy only the channel, so
    later groups' compute runs under them. This is the discipline
    `dispatch.executor.PlanExecutor` executes, and the number
    `benchmarks/dispatch_bench.py` reports against the serial chunk loop.
"""

from __future__ import annotations

import dataclasses

from ..core.pim_model import DPUModel, UPMEM_2556
from .graph import OpGraph
from .placement import (Plan, _dpu_system, _is_pim, channel_of,
                        exchange_time, launch_overhead, node_time,
                        transfer_hops, transfer_time)

#: fixed cost of one host<->device transfer call (API + sync); batching N
#: buffers into one parallel transfer pays this once instead of N times
TRANSFER_SETUP_S = 2e-5


def _crossing_channels(src: str, dst: str) -> tuple[str, str]:
    """(relay-hop channel, final-hop channel) resources of one crossing.

    Rank-qualified PIM devices own their channel (`placement.channel_of`);
    rank 0, host-class devices, and the PCIe leg keep the historical
    shared `"channel"` — so every single-rank schedule books exactly the
    pre-topology resources. The relay channel only matters when
    `transfer_hops` returns a nonzero relay hop (GPU<->DPU via PCIe,
    rank->rank via host DRAM)."""
    if _is_pim(src) and _is_pim(dst):
        return channel_of(src), channel_of(dst)
    if _is_pim(src):
        # retrieve over the source rank's channel; a GPU destination adds
        # the PCIe final hop, which rides the legacy shared channel
        ch = channel_of(src)
        return (ch, "channel") if dst == "titan_v" else (ch, ch)
    if _is_pim(dst):
        ch = channel_of(dst)
        return ("channel", ch) if src == "titan_v" else (ch, ch)
    return "channel", "channel"


@dataclasses.dataclass
class LaunchGroup:
    """A maximal run of consecutive same-device operators: one launch, one
    batched input transfer. All `*_s` fields are modeled seconds; `*_bytes`
    are bytes."""
    device: str
    nodes: list[str]
    compute_s: float                  # sum of member operator times
    in_bytes: float                   # payload crossing into the group
    n_in_tensors: int                 # tensors batched into one transfer
    in_transfer_s: float              # batched: one setup + payload/bw
    serial_transfer_s: float          # unbatched: per-tensor setup (for the
                                      # "what batching buys" delta)
    launch_s: float
    relay_s: float = 0.0              # host-relay hop of GPU<->DPU inputs
    writeback_s: float = 0.0          # KV rows shipped back to their home
    n_writebacks: int = 0             # member nodes writing KV off-home
    exchange_s: float = 0.0           # host-relayed bank exchanges whose
                                      # consumer is a member (incl. setups)
    n_exchanges: int = 0              # exchange edges booked to this group
    exchange_bytes: float = 0.0       # payload of those exchange edges
    #: producer node names whose tensors cross into this group — what the
    #: executor stages ahead of the group (the batched input transfer)
    in_producers: list[str] = dataclasses.field(default_factory=list)
    #: (member node, seconds, channel resource) of each off-home KV
    #: write-back, in member order — the pipelined simulation issues them
    #: as the node finishes
    node_writebacks: list[tuple[str, float, str]] = dataclasses.field(
        default_factory=list, repr=False)
    #: per-channel occupancy breakdown of the batched input transfer
    #: (multi-rank topologies): relay-side hops (source-rank retrieves,
    #: PCIe relays) and final-side hops + setups, channel resource ->
    #: seconds. Single-rank schedules book everything on `"channel"`,
    #: and the two dicts always sum to `in_transfer_s`.
    chan_src_s: dict = dataclasses.field(default_factory=dict, repr=False)
    chan_dst_s: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def serial_s(self) -> float:
        """Group seconds with no intra-group overlap (transfer + launch +
        compute + KV write-back + bank exchanges, summed)."""
        return (self.in_transfer_s + self.launch_s + self.compute_s
                + self.writeback_s + self.exchange_s)

    @property
    def overlapped_s(self) -> float:
        """Group seconds with streaming double-buffering: input chunks
        hide under compute — but the host-relay hop of a GPU<->DPU path
        finishes before the final hop starts streaming, so it cannot hide
        under this group's compute and is serialized in front of the
        overlap window. KV write-backs are serialized after the group:
        the cache home must hold the rows before any later consumer (the
        next prefill chunk's attention) may read them. Bank exchanges
        (`exchange_s`) are transfer-channel-only occupancy that the
        consuming member waits on, so they can never hide under this
        group's own compute either."""
        return (self.relay_s
                + max(self.compute_s, self.in_transfer_s - self.relay_s)
                + self.launch_s + self.writeback_s + self.exchange_s)


@dataclasses.dataclass
class Schedule:
    """A plan's execution timeline: launch groups plus three modeled
    wall-clock totals (seconds). `overlapped_s` is the objective the
    planner's `objective="overlapped"` knob optimizes."""
    graph_name: str
    groups: list[LaunchGroup]
    out_transfer_s: float             # final retrieve to the sink
    total_s: float                    # batched, serial groups
    overlapped_s: float               # batched + intra-group overlap
    unbatched_s: float                # per-tensor transfers (the bad API)
    pipelined_s: float | None = None  # dependency-aware group pipeline
                                      # (make_schedule(..., pipelined=True))
    #: resource name -> busy seconds: per device, launch + compute it
    #: executes; "channel" aggregates every transfer-channel occupancy
    #: (batched inputs, KV write-backs, exchanges, the final retrieve)
    busy_s: dict = dataclasses.field(default_factory=dict)

    @property
    def n_launches(self) -> int:
        """Number of launch groups (= device launches paid)."""
        return len(self.groups)

    def utilization(self, wall_s: float | None = None) -> dict:
        """Resource name -> busy fraction of the wall-clock. Defaults to
        the tightest modeled wall available (`pipelined_s` when the event
        simulation ran, else `overlapped_s`); the remainder is idle —
        pipeline stalls on dependencies, the channel, or launch gaps."""
        wall = wall_s if wall_s is not None else \
            (self.pipelined_s if self.pipelined_s is not None
             else self.overlapped_s)
        if not wall:
            return {}
        return {r: b / wall for r, b in sorted(self.busy_s.items())}

    def render(self, max_groups: int = 12) -> str:
        """Multi-line human-readable timeline (ms totals, per-group rows,
        per-resource busy/idle occupancy)."""
        pipe = ("" if self.pipelined_s is None
                else f"pipelined={self.pipelined_s * 1e3:.3f}ms  ")
        lines = [f"schedule[{self.graph_name}] {self.n_launches} launch "
                 f"group(s): total={self.total_s * 1e3:.3f}ms  "
                 f"overlapped={self.overlapped_s * 1e3:.3f}ms  {pipe}"
                 f"(unbatched transfers would be "
                 f"{self.unbatched_s * 1e3:.3f}ms)"]
        util = self.utilization()
        if util:
            basis = ("pipelined" if self.pipelined_s is not None
                     else "overlapped")
            lines.append(
                f"  occupancy of {basis} wall: "
                + "  ".join(f"{r} {frac * 100.0:.1f}% busy"
                            for r, frac in util.items())
                + "  (rest idle: dependency/channel/launch stalls)")
        shown = self.groups[:max_groups]
        for g in shown:
            lines.append(
                f"  [{g.device:12s}] {len(g.nodes):3d} ops  "
                f"compute {g.compute_s * 1e3:8.3f}ms  in "
                f"{g.in_bytes / 1e6:8.2f}MB/{g.n_in_tensors} tensor(s) "
                f"{g.in_transfer_s * 1e3:7.3f}ms  "
                f"launch {g.launch_s * 1e6:6.1f}us  :: "
                + " ".join(g.nodes[:6]) + (" ..." if len(g.nodes) > 6 else ""))
        if len(self.groups) > max_groups:
            lines.append(f"  ... (+{len(self.groups) - max_groups} more "
                         "groups, same layer pattern)")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()


def _node_s(graph: OpGraph, n: str, dev: str, dpu: DPUModel | None,
            node_times: dict | None) -> float:
    """One member's modeled seconds, honoring a `node_times` override —
    how the trace replayer prices a timeline with measured durations."""
    if node_times is not None and n in node_times:
        return node_times[n]
    return node_time(graph.nodes[n], dev, dpu)


def make_schedule(graph: OpGraph, plan: Plan, dpu: DPUModel | None = None,
                  source: str = "xeon", sink: str = "xeon", *,
                  pipelined: bool = False,
                  order: list[str] | None = None,
                  node_times: dict | None = None,
                  events: list | None = None) -> Schedule:
    """Group a plan's topological order into launch groups and model the
    batched/overlapped timeline. `source`/`sink` must match the ones the
    plan was evaluated with for the two totals to correspond. With
    `pipelined=True` the dependency-aware event simulation also runs and
    fills `Schedule.pipelined_s` (off by default: the overlapped-objective
    coordinate descent calls this many times per plan). `order` costs an
    alternative linearization (must be a valid topological order of
    `graph`) — how `benchmarks/dispatch_bench.py` prices the old
    chunk-serial prefill loop against the executor's pipelined timeline.
    `node_times` overrides per-node compute seconds (name -> seconds; the
    trace replayer's measured-duration re-pricing); `events`, when a list
    and `pipelined=True`, receives the simulation's timeline as event
    dicts (`{"kind", "name", "resource", "t0", "t1", "group", "attrs"}` —
    the schema `trace.replay.modeled_trace` wraps into a `Trace`)."""
    pim_dev = next((d for d in plan.assignment.values()
                    if d.startswith("upmem")), None)
    dpu = dpu or (_dpu_system(pim_dev) if pim_dev else UPMEM_2556)
    preds = graph.preds
    if order is None:
        order = graph.topo_order()
    else:                               # an invalid linearization would
        pos = {n: i for i, n in enumerate(order)}   # silently mis-group
        if len(order) != len(graph.nodes) or set(pos) != set(graph.nodes) \
                or any(pos[p] >= pos[n] for n in order for p in preds[n]):
            raise ValueError(f"order is not a topological order of "
                             f"{graph.name}")

    groups: list[LaunchGroup] = []
    members: dict[str, int] = {}      # node -> group index
    for n in order:
        dev = plan.assignment[n]
        if not groups or groups[-1].device != dev:
            groups.append(LaunchGroup(dev, [], 0.0, 0.0, 0, 0.0, 0.0,
                                      launch_overhead(dev, dpu)))
        g = groups[-1]
        g.nodes.append(n)
        members[n] = len(groups) - 1
        g.compute_s += _node_s(graph, n, dev, dpu, node_times)

    # boundary transfers: every tensor entering a group is priced on its
    # producer's actual channel (data already resident on the group's
    # device crosses nothing); one batched transfer call per source
    # channel amortizes the setup cost. Migrated KV-cache shards are
    # boundary transfers too: a member node whose KV home is not the
    # group's device pulls its kv_bytes over the home's channel (the
    # plan's migrate_s term, kept in the timeline so Schedule and Plan
    # totals agree on KV-annotated graphs)
    for gi, g in enumerate(groups):
        crossing: list[tuple[str, float]] = []   # (src device, bytes)
        entered: set[str] = set()                # producers already shipped
        for n in g.nodes:
            for p in preds[n]:
                if members[p] != gi and plan.assignment[p] != g.device \
                        and p not in entered:
                    entered.add(p)
                    g.in_producers.append(p)
                    crossing.append((plan.assignment[p],
                                     graph.nodes[p].out_bytes))
                # a bank exchange between same-device endpoints occupies
                # only the transfer channel (host gather + re-scatter,
                # Takeaway 3); the consuming member's group books it —
                # push + pull are one parallel-transfer call each
                ex_bytes = graph.exchange_edges.get((p, n), 0.0)
                ex_t = exchange_time(plan.assignment[p], g.device,
                                     ex_bytes, dpu)
                if ex_t:
                    g.exchange_s += ex_t + 2 * TRANSFER_SETUP_S
                    g.n_exchanges += 1
                    g.exchange_bytes += ex_bytes
            meta = graph.nodes[n].meta
            kv_bytes = float(meta.get("kv_bytes") or 0.0)
            kv_home = meta.get("kv_home")
            if kv_bytes and kv_home and kv_home != g.device:
                crossing.append((kv_home, kv_bytes))
            # KV rows written off their home ship back over the measured
            # channel (the plan's write-back term, kept in the timeline so
            # Schedule and Plan totals agree on prefill DAGs); batched into
            # one transfer call per group, serialized after the group's
            # compute (later chunks read them from the home)
            wb_bytes = float(meta.get("kv_write_bytes") or 0.0)
            wb_home = meta.get("kv_write_home")
            if wb_bytes and wb_home and wb_home != g.device:
                wb_s = transfer_time(g.device, wb_home, wb_bytes, dpu)
                g.writeback_s += wb_s
                g.n_writebacks += 1
                g.node_writebacks.append(
                    (n, wb_s, _crossing_channels(g.device, wb_home)[0]))
        if g.n_writebacks:
            g.writeback_s += TRANSFER_SETUP_S
        if gi == 0 and graph.input_bytes and g.device != source:
            crossing.append((source, graph.input_bytes))
        if crossing:
            g.in_bytes = sum(b for _, b in crossing)
            g.n_in_tensors = len(crossing)
            # per-crossing hop split: the relay hop (source-rank retrieve /
            # PCIe leg) and the final hop each occupy their own channel
            # resource; the hop sum equals `transfer_time` term-for-term,
            # so single-channel payloads are bit-identical to the
            # pre-topology aggregate
            payload_s = 0.0
            for src, b in crossing:
                r_s, f_s = transfer_hops(src, g.device, b, dpu)
                r_ch, f_ch = _crossing_channels(src, g.device)
                payload_s += r_s + f_s
                g.relay_s += r_s
                if r_s:
                    g.chan_src_s[r_ch] = g.chan_src_s.get(r_ch, 0.0) + r_s
                if f_s:
                    g.chan_dst_s[f_ch] = g.chan_dst_s.get(f_ch, 0.0) + f_s
            # one batched parallel-transfer call per distinct crossing
            # source; a rank->rank crossing is two calls (retrieve on the
            # source rank's channel + push on the destination's), matching
            # the exchange model's retrieve+push setup pair
            n_setups = 0
            for src in {s for s, _ in crossing}:
                r_ch, f_ch = _crossing_channels(src, g.device)
                if _is_pim(src) and _is_pim(g.device):
                    n_setups += 2
                    g.chan_src_s[r_ch] = g.chan_src_s.get(r_ch, 0.0) \
                        + TRANSFER_SETUP_S
                else:
                    n_setups += 1
                g.chan_dst_s[f_ch] = g.chan_dst_s.get(f_ch, 0.0) \
                    + TRANSFER_SETUP_S
            n_srcs = len({s for s, _ in crossing})
            g.in_transfer_s = n_setups * TRANSFER_SETUP_S + payload_s
            g.serial_transfer_s = (len(crossing) + (n_setups - n_srcs)) \
                * TRANSFER_SETUP_S + payload_s

    succs = graph.succs
    out_transfer = 0.0
    out_channels: dict[str, float] = {}
    for leaf in (n for n in order if not succs[n]):
        t = transfer_time(plan.assignment[leaf], sink,
                          graph.nodes[leaf].out_bytes, dpu)
        if t:
            out_transfer += t + TRANSFER_SETUP_S
            ch = _crossing_channels(plan.assignment[leaf], sink)[0]
            out_channels[ch] = out_channels.get(ch, 0.0) \
                + t + TRANSFER_SETUP_S

    total = sum(g.serial_s for g in groups) + out_transfer
    overlapped = sum(g.overlapped_s for g in groups) + out_transfer
    unbatched = sum(g.serial_transfer_s + g.launch_s + g.compute_s
                    + g.writeback_s + g.exchange_s
                    + max(g.n_writebacks - 1, 0) * TRANSFER_SETUP_S
                    for g in groups) + out_transfer
    busy: dict[str, float] = {}
    for g in groups:
        busy[g.device] = busy.get(g.device, 0.0) + g.launch_s + g.compute_s
    chan_names: set[str] = set(out_channels)
    for g in groups:
        chan_names.update(g.chan_src_s, g.chan_dst_s,
                          (ch for _, _, ch in g.node_writebacks))
        if g.exchange_s:
            chan_names.add(channel_of(g.device))
    if chan_names <= {"channel"}:
        # single-channel topologies keep the historical aggregate
        # arithmetic so busy_s stays bit-identical to pre-topology runs
        chan_busy = sum(g.in_transfer_s + g.writeback_s + g.exchange_s
                        for g in groups) + out_transfer
        if chan_busy:
            busy["channel"] = chan_busy
    else:
        for g in groups:
            for ch, s in g.chan_src_s.items():
                busy[ch] = busy.get(ch, 0.0) + s
            for ch, s in g.chan_dst_s.items():
                busy[ch] = busy.get(ch, 0.0) + s
            for i, (_, wb_s, ch) in enumerate(g.node_writebacks):
                busy[ch] = busy.get(ch, 0.0) + wb_s \
                    + (TRANSFER_SETUP_S if i == 0 else 0.0)
            if g.exchange_s:
                ech = channel_of(g.device)
                busy[ech] = busy.get(ech, 0.0) + g.exchange_s
        for ch, s in out_channels.items():
            busy[ch] = busy.get(ch, 0.0) + s
    sched = Schedule(graph_name=graph.name, groups=groups,
                     out_transfer_s=out_transfer, total_s=total,
                     overlapped_s=overlapped, unbatched_s=unbatched,
                     busy_s=busy)
    if pipelined:
        sched.pipelined_s = _pipelined_total(graph, plan, groups, dpu, sink,
                                             node_times=node_times,
                                             events=events)
    return sched


def _pipelined_total(graph: OpGraph, plan: Plan, groups: list[LaunchGroup],
                     dpu: DPUModel | None, sink: str, *,
                     node_times: dict | None = None,
                     events: list | None = None) -> float:
    """Event-simulate the group timeline with pipelined resources.

    Resources: every device is a serial executor (groups on it run in
    timeline order), and every transfer-channel resource is serial too —
    single-rank plans book all host<->device traffic (batched group
    inputs, KV write-backs, the final retrieve) on ONE shared `"channel"`
    (all DPU traffic relays through the host, Takeaway 3), while rank
    r > 0 of a multi-rank topology owns its own `"channel:r"` resource
    (`placement.channel_of`), so transfers into different ranks run in
    parallel (arXiv:2105.03814). A group's batched input transfer starts
    once its crossing producers have finished and every involved channel
    is free; relay-side hops (source-rank retrieves, PCIe legs) run
    concurrently on their own channels and are serialized in front of the
    group, and the final hops still double-buffer under the group's
    compute (the same per-group algebra as `LaunchGroup.overlapped_s`,
    applied per channel). KV write-backs are issued as each writing
    member finishes and occupy only their channel — the device moves on
    to its next group, which is what lets chunk i+1's qkv ladder run
    under chunk i's write-back. A KV *reader* (a node whose
    `meta["kv_writers"]` names earlier writers) cannot start its group
    before those writers' rows have landed at the home. Returns the
    makespan in seconds; never exceeds the serial-group `overlapped_s`
    total (the serial timeline is this event system with every resource
    globally serialized). When `events` is a list, every resource
    occupancy is appended to it as an event dict (the modeled trace
    `trace.replay.modeled_trace` packages); events on each channel
    resource are mutually exclusive by construction — the per-rank
    exclusivity invariant the golden-trace test pins."""

    def emit(kind, name, resource, t0, t1, group=-1, **attrs):
        if events is not None:
            events.append({"kind": kind, "name": name, "resource": resource,
                           "t0": t0, "t1": t1, "group": group,
                           "attrs": attrs})

    done: dict[str, float] = {}
    wb_done: dict[str, float] = {}
    dev_free: dict[str, float] = {}
    chan: dict[str, float] = {}       # channel resource -> free time
    member = {n: gi for gi, g in enumerate(groups) for n in g.nodes}
    for gi, g in enumerate(groups):
        ready = 0.0
        for p in g.in_producers:
            ready = max(ready, done[p])
        for n in g.nodes:
            for w in graph.nodes[n].meta.get("kv_writers", ()):
                if member[w] == gi:    # same-group writers stay local
                    continue
                if w in wb_done:       # rows shipped back to the home
                    ready = max(ready, wb_done[w])
                elif w in done:        # writer ran AT the home: no ship
                    ready = max(ready, done[w])
                else:                  # reader scheduled before writer —
                    raise ValueError(  # a physically impossible timeline
                        f"{n} reads KV rows of {w}, which the timeline "
                        "has not executed yet")
        involved = set(g.chan_src_s) | set(g.chan_dst_s)
        if g.in_transfer_s and involved <= {"channel"}:
            # single-channel stage-in: the pre-topology aggregate algebra,
            # verbatim — one event, one channel booking, bit-identical
            # wall-clocks and event streams for every single-rank plan
            tx_start = max(chan.get("channel", 0.0), ready)
            chan["channel"] = tx_start + g.in_transfer_s
            start = max(dev_free.get(g.device, 0.0),
                        tx_start + g.relay_s)
            emit("stage_in", f"g{gi}", "channel", tx_start,
                 chan["channel"], gi, bytes=g.in_bytes,
                 n_tensors=g.n_in_tensors, device=g.device,
                 relay_s=g.relay_s, producers=list(g.in_producers))
            span = max(g.compute_s, g.in_transfer_s - g.relay_s)
        elif g.in_transfer_s:
            # multi-channel stage-in: relay-side hops run concurrently on
            # their own channels once every involved channel is free and
            # the producers are done; final hops then stream concurrently
            # into the destination, and only the destination-side span may
            # hide under the group's compute
            tx_start = max([ready] + [chan.get(ch, 0.0) for ch in involved])
            relay_end = tx_start
            for ch, s in sorted(g.chan_src_s.items()):
                chan[ch] = tx_start + s
                relay_end = max(relay_end, chan[ch])
                emit("stage_in", f"g{gi}/relay", ch, tx_start, chan[ch],
                     gi, bytes=g.in_bytes, device=g.device, side="relay")
            dst_span = 0.0
            for ch, s in sorted(g.chan_dst_s.items()):
                chan[ch] = relay_end + s
                dst_span = max(dst_span, s)
                emit("stage_in", f"g{gi}", ch, relay_end, chan[ch], gi,
                     bytes=g.in_bytes, n_tensors=g.n_in_tensors,
                     device=g.device, relay_s=relay_end - tx_start,
                     producers=list(g.in_producers))
            start = max(dev_free.get(g.device, 0.0), relay_end)
            span = max(g.compute_s, dst_span)
        else:
            start = max(dev_free.get(g.device, 0.0), ready)
            span = g.compute_s
        compute_start = start + g.launch_s
        if g.launch_s:
            emit("launch", f"g{gi}", g.device, start, compute_start, gi)
        if g.exchange_s:
            # bank exchanges occupy ONLY the consuming device's channel,
            # but the consuming member waits on them, so the group's
            # device span stretches by the exchange (plus any channel
            # contention) — other devices' compute, and other RANKS'
            # exchanges, are what run under an exchange. The exchange
            # queues after the group's own overlap window (the
            # serial-group algebra serializes it there): gating on the
            # raw channel-free time instead would re-charge the window's
            # already-counted input streaming on transfer-bound groups
            ex_ch = channel_of(g.device)
            ex_start = max(chan.get(ex_ch, 0.0), compute_start + span)
            span = (ex_start - compute_start) + g.exchange_s
            chan[ex_ch] = ex_start + g.exchange_s
            emit("exchange", f"g{gi}", ex_ch, ex_start, chan[ex_ch], gi,
                 n_exchanges=g.n_exchanges, bytes=g.exchange_bytes,
                 device=g.device)
        dev_free[g.device] = compute_start + span
        # member finish times stretch over the overlap window so the last
        # member lands exactly at the group end (the serial-group algebra)
        cum = 0.0
        prev = compute_start
        for n in g.nodes:
            cum += _node_s(graph, n, g.device, dpu, node_times)
            frac = cum / g.compute_s if g.compute_s else 1.0
            done[n] = compute_start + frac * span
            emit("compute", n, g.device, prev, done[n], gi)
            prev = done[n]
        first_wb = True
        for n, wb_s, wb_ch in g.node_writebacks:
            wb_start = max(chan.get(wb_ch, 0.0), done[n])
            chan[wb_ch] = wb_start + wb_s \
                + (TRANSFER_SETUP_S if first_wb else 0.0)
            first_wb = False
            wb_done[n] = chan[wb_ch]
            emit("writeback", n, wb_ch, wb_start, chan[wb_ch], gi,
                 seconds=wb_s)
    succs = graph.succs
    for leaf in (n for n in graph.topo_order() if not succs[n]):
        t = transfer_time(plan.assignment[leaf], sink,
                          graph.nodes[leaf].out_bytes, dpu)
        if t:
            ch = _crossing_channels(plan.assignment[leaf], sink)[0]
            out_start = max(chan.get(ch, 0.0), done[leaf])
            chan[ch] = out_start + t + TRANSFER_SETUP_S
            emit("transfer_out", leaf, ch, out_start, chan[ch],
                 sink=sink, bytes=graph.nodes[leaf].out_bytes)
    return max([0.0] + list(chan.values()) + list(dev_free.values())
               + list(wb_done.values()) + list(done.values()))
