"""Launch/transfer scheduling: turn a placement into an execution timeline.

The paper's programming recommendations, made mechanical:

  * **Launch coalescing** — consecutive operators on the same device merge
    into one launch group; the fixed `launch_overhead_s` (the cost that
    makes 640->2556-DPU scaling sublinear, KT4) is paid once per group,
    not once per operator.
  * **Parallel-transfer batching** — every tensor crossing into a group is
    shipped in ONE batched parallel transfer (the paper's
    `dpu_push_xfer`-style interface): the per-call setup cost is paid once
    and the payload moves at the full parallel-transfer bandwidth, instead
    of one serial call per tensor.
  * **Compute/transfer overlap** — within a group, streaming input chunks
    double-buffer against compute, so a group costs
    `max(compute, transfer)` instead of `compute + transfer` (dependent
    groups can never prefetch each other — only intra-group streaming
    overlaps, which is why the overlapped total still sums over groups).
    GPU<->DPU tensors relay through host DRAM (Takeaway 3, both hops
    charged by `placement.transfer_time`); only the *final* hop streams
    into the group's device, so only it may hide under compute — the
    host-relay hop (`LaunchGroup.relay_s`) is serialized in front of the
    overlap window.

`make_schedule(graph, plan)` emits the timeline; `Schedule.total_s` (and
the optimistic `overlapped_s`) is the modeled wall-clock the benchmarks
report next to the plan's serial estimate. `overlapped_s` is also the
objective `placement.plan(..., objective="overlapped")` optimizes. KV
rows written off their home device (a prefill chunk's attention,
`graph.annotate_kv_write`) ship back as one batched transfer serialized
after the group — later chunks read them from the home, so the write-back
can never hide under this group's compute.
"""

from __future__ import annotations

import dataclasses

from ..core.pim_model import DPUModel, UPMEM_2556
from .graph import OpGraph
from .placement import (Plan, _DPU_SYSTEMS, launch_overhead, node_time,
                        transfer_hops, transfer_time)

#: fixed cost of one host<->device transfer call (API + sync); batching N
#: buffers into one parallel transfer pays this once instead of N times
TRANSFER_SETUP_S = 2e-5


@dataclasses.dataclass
class LaunchGroup:
    """A maximal run of consecutive same-device operators: one launch, one
    batched input transfer. All `*_s` fields are modeled seconds; `*_bytes`
    are bytes."""
    device: str
    nodes: list[str]
    compute_s: float                  # sum of member operator times
    in_bytes: float                   # payload crossing into the group
    n_in_tensors: int                 # tensors batched into one transfer
    in_transfer_s: float              # batched: one setup + payload/bw
    serial_transfer_s: float          # unbatched: per-tensor setup (for the
                                      # "what batching buys" delta)
    launch_s: float
    relay_s: float = 0.0              # host-relay hop of GPU<->DPU inputs
    writeback_s: float = 0.0          # KV rows shipped back to their home
    n_writebacks: int = 0             # member nodes writing KV off-home

    @property
    def serial_s(self) -> float:
        """Group seconds with no intra-group overlap (transfer + launch +
        compute + KV write-back, summed)."""
        return (self.in_transfer_s + self.launch_s + self.compute_s
                + self.writeback_s)

    @property
    def overlapped_s(self) -> float:
        """Group seconds with streaming double-buffering: input chunks
        hide under compute — but the host-relay hop of a GPU<->DPU path
        finishes before the final hop starts streaming, so it cannot hide
        under this group's compute and is serialized in front of the
        overlap window. KV write-backs are serialized after the group:
        the cache home must hold the rows before any later consumer (the
        next prefill chunk's attention) may read them."""
        return (self.relay_s
                + max(self.compute_s, self.in_transfer_s - self.relay_s)
                + self.launch_s + self.writeback_s)


@dataclasses.dataclass
class Schedule:
    """A plan's execution timeline: launch groups plus three modeled
    wall-clock totals (seconds). `overlapped_s` is the objective the
    planner's `objective="overlapped"` knob optimizes."""
    graph_name: str
    groups: list[LaunchGroup]
    out_transfer_s: float             # final retrieve to the sink
    total_s: float                    # batched, serial groups
    overlapped_s: float               # batched + intra-group overlap
    unbatched_s: float                # per-tensor transfers (the bad API)

    @property
    def n_launches(self) -> int:
        """Number of launch groups (= device launches paid)."""
        return len(self.groups)

    def render(self, max_groups: int = 12) -> str:
        """Multi-line human-readable timeline (ms totals, per-group rows)."""
        lines = [f"schedule[{self.graph_name}] {self.n_launches} launch "
                 f"group(s): total={self.total_s * 1e3:.3f}ms  "
                 f"overlapped={self.overlapped_s * 1e3:.3f}ms  "
                 f"(unbatched transfers would be "
                 f"{self.unbatched_s * 1e3:.3f}ms)"]
        shown = self.groups[:max_groups]
        for g in shown:
            lines.append(
                f"  [{g.device:12s}] {len(g.nodes):3d} ops  "
                f"compute {g.compute_s * 1e3:8.3f}ms  in "
                f"{g.in_bytes / 1e6:8.2f}MB/{g.n_in_tensors} tensor(s) "
                f"{g.in_transfer_s * 1e3:7.3f}ms  "
                f"launch {g.launch_s * 1e6:6.1f}us  :: "
                + " ".join(g.nodes[:6]) + (" ..." if len(g.nodes) > 6 else ""))
        if len(self.groups) > max_groups:
            lines.append(f"  ... (+{len(self.groups) - max_groups} more "
                         "groups, same layer pattern)")
        return "\n".join(lines)


def make_schedule(graph: OpGraph, plan: Plan, dpu: DPUModel | None = None,
                  source: str = "xeon", sink: str = "xeon") -> Schedule:
    """Group a plan's topological order into launch groups and model the
    batched/overlapped timeline. `source`/`sink` must match the ones the
    plan was evaluated with for the two totals to correspond."""
    pim_dev = next((d for d in plan.assignment.values()
                    if d.startswith("upmem")), None)
    dpu = dpu or (_DPU_SYSTEMS[pim_dev] if pim_dev else UPMEM_2556)
    order = graph.topo_order()
    preds = graph.preds

    groups: list[LaunchGroup] = []
    members: dict[str, int] = {}      # node -> group index
    for n in order:
        dev = plan.assignment[n]
        if not groups or groups[-1].device != dev:
            groups.append(LaunchGroup(dev, [], 0.0, 0.0, 0, 0.0, 0.0,
                                      launch_overhead(dev, dpu)))
        g = groups[-1]
        g.nodes.append(n)
        members[n] = len(groups) - 1
        g.compute_s += node_time(graph.nodes[n], dev, dpu)

    # boundary transfers: every tensor entering a group is priced on its
    # producer's actual channel (data already resident on the group's
    # device crosses nothing); one batched transfer call per source
    # channel amortizes the setup cost. Migrated KV-cache shards are
    # boundary transfers too: a member node whose KV home is not the
    # group's device pulls its kv_bytes over the home's channel (the
    # plan's migrate_s term, kept in the timeline so Schedule and Plan
    # totals agree on KV-annotated graphs)
    for gi, g in enumerate(groups):
        crossing: list[tuple[str, float]] = []   # (src device, bytes)
        entered: set[str] = set()                # producers already shipped
        for n in g.nodes:
            for p in preds[n]:
                if members[p] != gi and plan.assignment[p] != g.device \
                        and p not in entered:
                    entered.add(p)
                    crossing.append((plan.assignment[p],
                                     graph.nodes[p].out_bytes))
            meta = graph.nodes[n].meta
            kv_bytes = float(meta.get("kv_bytes") or 0.0)
            kv_home = meta.get("kv_home")
            if kv_bytes and kv_home and kv_home != g.device:
                crossing.append((kv_home, kv_bytes))
            # KV rows written off their home ship back over the measured
            # channel (the plan's write-back term, kept in the timeline so
            # Schedule and Plan totals agree on prefill DAGs); batched into
            # one transfer call per group, serialized after the group's
            # compute (later chunks read them from the home)
            wb_bytes = float(meta.get("kv_write_bytes") or 0.0)
            wb_home = meta.get("kv_write_home")
            if wb_bytes and wb_home and wb_home != g.device:
                g.writeback_s += transfer_time(g.device, wb_home, wb_bytes,
                                               dpu)
                g.n_writebacks += 1
        if g.n_writebacks:
            g.writeback_s += TRANSFER_SETUP_S
        if gi == 0 and graph.input_bytes and g.device != source:
            crossing.append((source, graph.input_bytes))
        if crossing:
            g.in_bytes = sum(b for _, b in crossing)
            g.n_in_tensors = len(crossing)
            payload_s = sum(transfer_time(src, g.device, b, dpu)
                            for src, b in crossing)
            g.relay_s = sum(transfer_hops(src, g.device, b, dpu)[0]
                            for src, b in crossing)
            n_channels = len({src for src, _ in crossing})
            g.in_transfer_s = n_channels * TRANSFER_SETUP_S + payload_s
            g.serial_transfer_s = len(crossing) * TRANSFER_SETUP_S \
                + payload_s

    succs = graph.succs
    out_transfer = 0.0
    for leaf in (n for n in order if not succs[n]):
        t = transfer_time(plan.assignment[leaf], sink,
                          graph.nodes[leaf].out_bytes, dpu)
        if t:
            out_transfer += t + TRANSFER_SETUP_S

    total = sum(g.serial_s for g in groups) + out_transfer
    overlapped = sum(g.overlapped_s for g in groups) + out_transfer
    unbatched = sum(g.serial_transfer_s + g.launch_s + g.compute_s
                    + g.writeback_s
                    + max(g.n_writebacks - 1, 0) * TRANSFER_SETUP_S
                    for g in groups) + out_transfer
    return Schedule(graph_name=graph.name, groups=groups,
                    out_transfer_s=out_transfer, total_s=total,
                    overlapped_s=overlapped, unbatched_s=unbatched)
