"""Hybrid dispatch runtime for chain pipelines: execute a plan in JAX.

This module executes CHAIN-shaped workloads (`Pipeline`: the mixed PrIM
chain, the decode chain) stage-by-stage. Operator-DAG workloads — the
serving decode/prefill DAGs — execute through the unified plan executor
instead (`dispatch.executor.PlanExecutor`), which walks the scheduler's
launch-group timeline; `bank_face` here is the leading-axis (batch)
special case of the `StageDef` shard-axis faces that executor builds.

A `Pipeline` is a chain of `Stage`s, each with two executable faces:

  * `fn(x, *params)`    — host semantics, run under plain `jit` when the
                          plan places the stage on xeon/titan_v;
  * `pim(grid, x, ...)` — the bank-parallel face, run as BankGrid
                          local/exchange phases when the plan places it on
                          a UPMEM system. Defaults to `grid.bank_map(fn)`
                          (the pure-streaming case); stages with
                          communication provide their own, built from
                          `grid.local` + `grid.exchange_*` exactly like
                          the `repro.prim` workloads.

Phase discipline is enforced the same way the PrIM suite enforces it: a
stage's declared bank-local body must lower with zero collectives
(`core.bank_parallel.assert_local`); inter-bank traffic must go through an
exchange phase (Takeaway 3) and is what `Stage.exchange`/`exchange_bytes`
charge in the cost model.

`execute(pipeline, plan, grid)` runs every stage on its assigned device
and `validate` checks the hybrid result against the single-device
reference (`reference(pipeline)`) with `allclose` — the acceptance gate
for every plan the benchmarks report.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid, assert_local
from .graph import OpGraph, _struct_bytes as _nbytes, chain_graph, \
    node_from_fn


def bank_face(grid: BankGrid, fn: Callable, batched: tuple[bool, ...],
              n_out: int = 1) -> Callable:
    """Build a stage's bank-parallel face from its host face: args flagged
    True shard their leading (batch) dim over banks, others replicate to
    every bank (weights / rope tables / scalars); every output is
    batch-sharded. This is the continuous-batching-across-banks layout of
    DESIGN.md §4 — each bank owns its slots' activations and KV rows, so
    the body stays a pure local phase (Takeaway 3)."""
    in_specs = tuple(P(grid.axis) if b else P() for b in batched)
    out_specs = tuple(P(grid.axis) for _ in range(n_out)) if n_out > 1 \
        else P(grid.axis)
    return grid.local(fn, in_specs=in_specs, out_specs=out_specs)


@dataclasses.dataclass
class Stage:
    """One dispatchable operator with host and bank-parallel faces."""
    name: str
    fn: Callable                       # fn(x, *params) -> y   (host face)
    params: tuple = ()
    pim: Callable | None = None        # pim(grid, x, *params) -> y
    local_fn: Callable | None = None   # bank-local body, for assert_local
    exchange: str | None = None        # exchange phase kind, if any (KT3)
    exchange_bytes: float | None = None  # None + exchange -> out_bytes
    hbm_bytes: float | None = None     # override analyze_hlo traffic (e.g.
                                       # transposes, which XLA folds into
                                       # zero-charged layout fusions)
    kind: str = "stage"
    _jit: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def run_host(self, x):
        """Execute the host face under a cached per-stage jit."""
        if self._jit is None:          # one trace cache per stage
            self._jit = jax.jit(self.fn)
        return self._jit(x, *self.params)

    def run_pim(self, grid: BankGrid, x):
        """Execute the bank-parallel face on `grid` (default: bank_map
        of the host face — the pure-streaming case)."""
        if self.pim is not None:
            return self.pim(grid, x, *self.params)
        return grid.bank_map(self.fn)(x, *self.params)


@dataclasses.dataclass
class Pipeline:
    """A chain of stages plus its example input — the executable twin of a
    chain OpGraph."""
    name: str
    stages: list[Stage]
    x: Any                             # input array (flows through stage 0)

    def stage(self, name: str) -> Stage:
        """The stage with the given name (StopIteration if absent)."""
        return next(s for s in self.stages if s.name == name)

    # -----------------------------------------------------------------
    def graph(self, shapes_only: bool = True) -> OpGraph:
        """Lower every stage in isolation and cost it as an OpNode.
        Params are explicit lowering arguments (never closed-over
        constants) so weights show up as device-resident streams, while
        only the flowing activation prices the stage boundary."""
        def struct(a):
            return jax.tree.map(
                lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), a)
        x = struct(self.x) if shapes_only else self.x
        nodes, cache = [], {}
        for s in self.stages:
            args = (x, *(struct(p) if shapes_only else p for p in s.params))
            out = jax.eval_shape(lambda x_, *p: s.fn(x_, *p), *args)
            xb = _nbytes(out)
            # repeated layers produce identical stage shapes: compile once;
            # the cached prototype stays pristine, per-stage overrides only
            # ever touch the copy
            key = (_fn_key(s.fn), tuple((tuple(t.shape), str(t.dtype))
                                        for t in jax.tree.leaves(args)))
            if key not in cache:
                cache[key] = node_from_fn(s.name, s.fn, *args, kind=s.kind)
            node = dataclasses.replace(cache[key], name=s.name, kind=s.kind)
            node.exchange_bytes = (s.exchange_bytes if s.exchange_bytes
                                   is not None else (xb if s.exchange else 0.0))
            if s.hbm_bytes is not None:
                node.hbm_bytes = s.hbm_bytes
            nodes.append(node)
            x = out
        return chain_graph(self.name, nodes, input_bytes=_nbytes(self.x))


def _fn_key(fn) -> Any:
    """Cache identity for a stage fn: per-layer lambdas/partials built at
    the same source site share one compile."""
    if isinstance(fn, functools.partial):
        return ("partial", _fn_key(fn.func))
    return getattr(fn, "__code__", fn)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def reference(pipeline: Pipeline):
    """Single-device oracle: the whole chain under one jit."""
    def chain(x, params):
        for s, p in zip(pipeline.stages, params):
            x = s.fn(x, *p)
        return x
    return jax.jit(chain)(pipeline.x, [s.params for s in pipeline.stages])


@dataclasses.dataclass
class ExecutionReport:
    """Outcome of a hybrid execution: the result, the single-device
    reference, and the allclose verdict (`max_abs_err` in the output's
    own units)."""
    result: Any
    reference: Any
    matches: bool
    max_abs_err: float
    stage_devices: dict[str, str]


def execute(pipeline: Pipeline, plan, grid: BankGrid, *,
            validate: bool = True, rtol: float = 1e-4,
            atol: float = 1e-4) -> ExecutionReport:
    """Run the pipeline under a placement plan: PIM stages as BankGrid
    phases, host stages under jit; optionally validate vs the reference."""
    x = pipeline.x
    devices = {}
    for s in pipeline.stages:
        dev = plan.assignment[s.name]
        devices[s.name] = dev
        x = s.run_pim(grid, x) if dev.startswith("upmem") else s.run_host(x)
    ref = reference(pipeline) if validate else None
    matches, err = True, 0.0
    if validate:
        a = jnp.asarray(x, dtype=jnp.result_type(ref, jnp.float32))
        b = jnp.asarray(ref, dtype=a.dtype)
        err = float(jnp.max(jnp.abs(a - b)))
        matches = bool(jnp.allclose(a, b, rtol=rtol, atol=atol))
        if not matches:
            raise AssertionError(
                f"hybrid execution of {pipeline.name} diverged from the "
                f"single-device reference (max |err| = {err:.3g})")
    return ExecutionReport(result=x, reference=ref, matches=matches,
                           max_abs_err=err, stage_devices=devices)


def check_phase_discipline(pipeline: Pipeline, grid: BankGrid) -> int:
    """assert_local every declared bank-local body: lower it on per-bank
    shard shapes and census for collectives (Takeaway 3's discipline,
    same mechanism the PrIM tests use). Returns #stages checked."""
    def shard_struct(t):
        shape = tuple(t.shape)
        if shape and shape[0] % grid.n_banks == 0:
            shape = (shape[0] // grid.n_banks,) + shape[1:]
        return jax.ShapeDtypeStruct(shape, t.dtype)

    x = jax.tree.map(lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype),
                     pipeline.x)
    checked = 0
    for s in pipeline.stages:
        if s.local_fn is not None:
            args = (jax.tree.map(shard_struct, x),
                    *(jax.tree.map(shard_struct, p) for p in s.params))
            assert_local(s.local_fn, *args)
            checked += 1
        x = jax.eval_shape(lambda x_, *p: s.fn(x_, *p), x, *s.params)
    return checked
