"""Operator graphs for offload planning.

An `OpGraph` is the unit the placement planner works on: nodes carry the
per-operator quantities the paper's takeaways are phrased in — flops, bytes
moved, operational intensity, op mix (simple vs mul/div/float vs
transcendental), and the inter-bank traffic the op would generate if it ran
bank-parallel on PIM. Edges carry the bytes that flow between operators,
i.e. what a host<->DPU boundary crossing costs if the two ends are placed
on different devices.

Two granularities:

  * `OpGraph.from_hlo(text)` — one node per HLO instruction of a compiled
    module's entry computation (fusions kept whole, costed by walking the
    fused computation). Used for inspecting real compiled steps.
  * `node_from_fn(name, fn, *args)` — one node per *stage* of a dispatch
    pipeline (runtime.Stage), costed by compiling the stage alone and
    running `core.hlo_analysis.analyze_hlo` over it. This is the
    granularity the runtime can actually execute, so it is what the
    planner and scheduler consume.

Per-element op counts (`OpNode.ops`, keyed like `pim_model.DPU_OP_COST`)
are extracted by `ops_from_hlo`, which walks the parsed `HloModule` and
charges every arithmetic instruction at output-element granularity — the
quantity `DPUModel.compute_time` wants.
"""

from __future__ import annotations

import dataclasses

from collections import defaultdict
from typing import Any, Callable, Iterable

from ..core.hlo_analysis import (HloComputation, HloModule, HloOp,
                                 _Accumulator, _dot_flops, analyze_hlo,
                                 parse_hlo_text)
from ..core.suitability import COMM_RATIO_THRESHOLD, COMPLEX_FRAC_THRESHOLD

# ---------------------------------------------------------------------------
# opcode -> (op-class, dtype-class) categorization
# ---------------------------------------------------------------------------

#: HLO opcode -> DPU_OP_COST op class. Anything unlisted is charged nothing
#: (layout / control / pure data movement — it shows up in bytes, not ops).
_OP_CLASS = {
    "add": "add", "subtract": "sub", "negate": "sub",
    "multiply": "mul", "divide": "div", "remainder": "div",
    "and": "bitwise", "or": "bitwise", "xor": "bitwise", "not": "bitwise",
    "shift-left": "bitwise", "shift-right-logical": "bitwise",
    "shift-right-arithmetic": "bitwise",
    "compare": "compare", "select": "compare", "maximum": "compare",
    "minimum": "compare", "clamp": "compare", "abs": "compare",
    "floor": "compare", "ceiling": "compare", "round-nearest-afz": "compare",
    "round-nearest-even": "compare", "sign": "compare",
    "exponential": "transc", "exponential-minus-one": "transc",
    "log": "transc", "log-plus-one": "transc", "rsqrt": "transc",
    "sqrt": "transc", "cbrt": "transc", "tanh": "transc",
    "logistic": "transc", "sine": "transc", "cosine": "transc",
    "tan": "transc", "erf": "transc", "power": "transc", "atan2": "transc",
}

_SIMPLE_CLASSES = {"add", "sub", "bitwise", "compare"}
_COMPLEX_CLASSES = {"mul", "div", "transc"}


def _dtype_class(dtype: str) -> str:
    """HLO dtype -> DPU_OP_COST dtype class (Fig. 3's bands, plus the
    native int8 band of the extended characterization)."""
    if dtype in ("f64", "c128"):
        return "double"
    if dtype[0] in ("f", "b", "c"):      # f16/f32/bf16/f8*/c64
        return "float"
    if dtype in ("s64", "u64"):
        return "int64"
    if dtype in ("s8", "u8", "pred"):    # native 8x8-multiplier band
        return "int8"
    return "int32"


_INT_WIDTH = {"int8": 0, "int32": 1, "int64": 2}


_WIDEN_PLUMBING = {"convert", "copy", "bitcast", "transpose", "reshape",
                   "broadcast"}


def _storage_class(module: HloModule, comp: HloComputation, name: str,
                   depth: int = 12, env=None):
    """Dtype class of the VALUES flowing through an integer dot operand.

    XLA's CPU pipeline rewrites `dot(s8, s8) -> s32` into widening
    converts plus an s32 dot (and fuses a quantize chain's
    `convert(f32->s8); convert(s8->s32)` into one kLoop fusion), so the
    operand's own out dtype says int32 even when every factor fits in 8
    bits — exactly the case the DPU's 8x8 HW multiplier serves in one
    pass. Walk through widening/layout plumbing (convert / copy / bitcast
    / transpose / reshape / broadcast), descend into fusion roots
    (mapping fusion parameters back to the caller's operands via `env`),
    and return the NARROWEST integer class the values pass through — a
    narrowing convert truncates, so the narrower side always governs.
    Returns None when the operand can't be resolved."""
    op = comp.ops.get(name)
    if op is None or not op.out_shapes:
        return None
    c = _dtype_class(op.out_shapes[0].dtype)
    if c not in _INT_WIDTH or depth <= 0:
        return c

    def narrower(inner):
        if inner in _INT_WIDTH and _INT_WIDTH[inner] < _INT_WIDTH[c]:
            return inner
        return c

    if op.opcode in _WIDEN_PLUMBING and op.operands:
        return narrower(_storage_class(module, comp, op.operands[0],
                                       depth - 1, env))
    if op.opcode == "parameter" and env is not None:
        caller_comp, caller_operands, caller_env = env
        try:
            idx = int((op.raw_operands or "").strip() or op.operands[0])
        except (ValueError, IndexError):
            return c
        if 0 <= idx < len(caller_operands):
            return narrower(_storage_class(module, caller_comp,
                                           caller_operands[idx], depth - 1,
                                           caller_env))
        return c
    if op.opcode == "fusion":
        callee = (op.attr("calls") or "").lstrip("%")
        sub = module.computations.get(callee)
        if sub is not None:
            root = next((o for o in sub.ops.values() if o.is_root), None)
            if root is not None:
                return narrower(_storage_class(
                    module, sub, root.name, depth - 1,
                    (comp, op.operands, env)))
    return c


def _dot_mul_class(op: HloOp, comp: HloComputation, module: HloModule,
                   out_class: str) -> str:
    """Dtype class a dot's MULTIPLIES run at. Integer dots accumulate
    wider than they multiply (int8 x int8 -> int32 on the DPU's 8x8 HW
    multiplier), so the mul band is the WIDEST integer OPERAND class
    (resolved through XLA's widening-convert plumbing, `_storage_class`)
    while the adds stay at the accumulator (output) class. Float dots —
    and any dot whose operand shapes can't be resolved — price at the
    output class, the previous behaviour."""
    if out_class not in _INT_WIDTH:
        return out_class
    classes = []
    for name in op.operands[:2]:
        c = _storage_class(module, comp, name)
        if c is None or c not in _INT_WIDTH:
            return out_class
        classes.append(c)
    if not classes:
        return out_class
    return max(classes, key=_INT_WIDTH.__getitem__)


def _reduce_class(module: HloModule, op: HloOp) -> str:
    """A reduce's per-element op is whatever its reducer computation does."""
    reducer = module.computations.get((op.attr("to_apply") or "").lstrip("%"))
    if reducer is not None:
        for r_op in reducer.ops.values():
            if r_op.opcode in _OP_CLASS:
                return _OP_CLASS[r_op.opcode]
    return "add"


def ops_from_hlo(text_or_module: str | HloModule,
                 trip_count_fallback: int = 1) -> dict[tuple[str, str], float]:
    """Per-element arithmetic op counts {(op, dtype): n} for a compiled
    module — the operand `DPUModel.compute_time` consumes. Dots are
    decomposed into mul+add pairs over their contraction; while bodies are
    multiplied by parsed trip counts (same convention as `analyze_hlo`)."""
    module = (text_or_module if isinstance(text_or_module, HloModule)
              else parse_hlo_text(text_or_module))
    # reuse analyze_hlo's trip-count parser rather than re-deriving it
    tc = _Accumulator(module, trip_count_fallback)
    acc: dict[tuple[str, str], float] = defaultdict(float)

    def visit(comp_name: str, mult: float):
        comp = module.computations.get(comp_name)
        if comp is None:
            return
        for op_name in comp.order:
            op = comp.ops[op_name]
            oc = op.opcode
            if oc == "while":
                visit((op.attr("body") or "").lstrip("%"),
                      mult * tc.trip_count_of(op))
            elif oc == "call":
                visit((op.attr("to_apply") or "").lstrip("%"), mult)
            elif oc == "fusion":
                visit((op.attr("calls") or "").lstrip("%"), mult)
            elif oc == "conditional":
                for key in ("true_computation", "false_computation"):
                    visit((op.attr(key) or "").lstrip("%"), mult)
            elif oc in ("dot", "convolution"):
                shapes = op.out_shapes
                if not shapes:
                    continue
                pairs = _dot_flops(op, comp) / 2.0 if oc == "dot" else \
                    float(shapes[0].elements)
                dt = _dtype_class(shapes[0].dtype)
                mul_dt = (_dot_mul_class(op, comp, module, dt)
                          if oc == "dot" else dt)
                acc[("mul", mul_dt)] += pairs * mult
                acc[("add", dt)] += pairs * mult
            elif oc in ("reduce", "reduce-window"):
                in_op = comp.ops.get(op.operands[0]) if op.operands else None
                if in_op is not None and in_op.out_shapes:
                    s = in_op.out_shapes[0]
                    acc[(_reduce_class(module, op), _dtype_class(s.dtype))] \
                        += float(s.elements) * mult
            elif oc in _OP_CLASS:
                if op.out_shapes:
                    s = op.out_shapes[0]
                    acc[(_OP_CLASS[oc], _dtype_class(s.dtype))] \
                        += float(s.elements) * mult

    visit(module.entry, 1.0)
    return dict(acc)


# ---------------------------------------------------------------------------
# nodes and graphs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpNode:
    """One schedulable operator: the quantities KT1-3 are phrased in."""
    name: str
    kind: str                          # opcode / stage kind label
    flops: float                       # host-style flop count
    hbm_bytes: float                   # device-local memory traffic
    out_bytes: float                   # bytes handed to each consumer
    ops: dict = dataclasses.field(default_factory=dict)
    exchange_bytes: float = 0.0        # inter-bank bytes if run on PIM (KT3)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def oi(self) -> float:
        """Operational intensity, flop/byte (KT1)."""
        return self.flops / self.hbm_bytes if self.hbm_bytes else float("inf")

    @property
    def complex_frac(self) -> float:
        """Fraction of arithmetic that is mul/div/transcendental (KT2)."""
        simple = sum(n for (op, _), n in self.ops.items()
                     if op in _SIMPLE_CLASSES)
        cplx = sum(n for (op, _), n in self.ops.items()
                   if op in _COMPLEX_CLASSES)
        total = simple + cplx
        return cplx / total if total else 0.0

    @property
    def comm_ratio(self) -> float:
        """Inter-bank bytes per local byte (KT3)."""
        return (self.exchange_bytes / self.hbm_bytes
                if self.hbm_bytes else 0.0)

    def pim_suitable(self, balance: float) -> bool:
        """The paper's three-way verdict for this single operator."""
        return (self.oi < balance
                and self.complex_frac < COMPLEX_FRAC_THRESHOLD
                and self.comm_ratio < COMM_RATIO_THRESHOLD)


@dataclasses.dataclass
class OpGraph:
    """A DAG of OpNodes; edges carry the producer's out_bytes.

    `exchange_edges` marks a subset of edges as *exchange phases*: the
    producer's tensor is not merely handed to the consumer, it must be
    RE-DISTRIBUTED across PIM banks (an MoE token dispatch/combine, a
    transpose's all-to-all). There is no inter-DPU channel (Takeaway 3),
    so when both endpoints sit on the same UPMEM system the bytes still
    round-trip through host DRAM — `placement.exchange_time` charges it,
    `schedule.py` books it as transfer-channel-only occupancy, and
    `dispatch.executor.PlanExecutor` executes it as a host gather/scatter
    stage. On one host-class device the exchange is a local shuffle
    (free beyond the node's own memory traffic); across devices the
    ordinary boundary transfer already relays through the host."""
    name: str
    nodes: dict[str, OpNode] = dataclasses.field(default_factory=dict)
    edges: list[tuple[str, str]] = dataclasses.field(default_factory=list)
    input_bytes: float = 0.0           # bytes entering the graph from host
    #: (producer, consumer) -> bytes re-distributed across banks
    exchange_edges: dict[tuple[str, str], float] = dataclasses.field(
        default_factory=dict)

    def add(self, node: OpNode, *preds: str) -> OpNode:
        """Insert `node` with edges from the named predecessors."""
        self.nodes[node.name] = node
        for p in preds:
            self.edges.append((p, node.name))
        return node

    def annotate_exchange(self, u: str, v: str, nbytes: float) -> None:
        """Mark existing edge (u, v) as an exchange phase moving `nbytes`
        across banks (the first-class exchange-edge annotation). The
        volume is the caller's to model — for MoE token routing it scales
        with tokens x capacity (`workloads.moe_exchange_bytes`), NOT with
        the expert count: only dispatched rows travel, empty capacity
        slots do not."""
        if (u, v) not in set(self.edges):
            raise ValueError(f"no edge {u!r}->{v!r} in graph {self.name}")
        self.exchange_edges[(u, v)] = float(nbytes)

    def _derived(self) -> dict:
        """Adjacency/topo structures, memoized per (node, edge) count —
        planners and the overlapped-objective search re-read these many
        times per plan (do NOT mutate the returned dicts; `add` is the
        only supported mutation and invalidates by changing the counts)."""
        key = (len(self.nodes), len(self.edges))
        cached = getattr(self, "_dcache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        preds: dict[str, list[str]] = {n: [] for n in self.nodes}
        succs: dict[str, list[str]] = {n: [] for n in self.nodes}
        for u, v in self.edges:
            preds[v].append(u)
            succs[u].append(v)
        pending = {n: set(ps) for n, ps in preds.items()}
        order, ready = [], [n for n in self.nodes if not pending[n]]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for s in succs[n]:
                pending[s].discard(n)
                if not pending[s]:
                    ready.append(s)
        if len(order) != len(self.nodes):
            raise ValueError(f"cycle in op graph {self.name}")
        d = {"preds": preds, "succs": succs, "topo": order}
        self._dcache = (key, d)
        return d

    @property
    def preds(self) -> dict[str, list[str]]:
        """node name -> list of predecessor names (edge sources)."""
        return self._derived()["preds"]

    @property
    def succs(self) -> dict[str, list[str]]:
        """node name -> list of successor names (edge destinations)."""
        return self._derived()["succs"]

    def topo_order(self) -> list[str]:
        """Kahn topological order (FIFO ties); raises on cycles."""
        return list(self._derived()["topo"])

    def last_use_positions(self, order: list[str] | None = None
                           ) -> dict[str, int]:
        """Topo-order position of each producer's last consumer (-1 for
        leaves) — when the walk passes it, the producer's tensor is no
        longer awaited. Shared bookkeeping between `max_frontier` and the
        placement planner's frontier DP (`placement._DagWalk`), so the
        reported width and the DP's actual state space cannot drift."""
        order = order if order is not None else self.topo_order()
        pos = {n: i for i, n in enumerate(order)}
        return {u: max((pos[v] for v in ss), default=-1)
                for u, ss in self.succs.items()}

    def max_frontier(self) -> int:
        """Largest number of already-visited producers still awaited by an
        unvisited consumer at any point of the topological order. The
        frontier DP's state space is exponential in this width — chains
        and stars are 1, the decode DAG's residual braid is 2, wide
        parallel compositions grow with their branch count."""
        order = self.topo_order()
        preds, succs = self.preds, self.succs
        last_use = self.last_use_positions(order)
        open_now, widest = set(), 0
        for i, n in enumerate(order):
            for u in preds[n]:
                if last_use[u] == i:
                    open_now.discard(u)
            if succs[n]:
                open_now.add(n)
            widest = max(widest, len(open_now))
        return widest

    @property
    def is_chain(self) -> bool:
        """True when the graph is a linear chain (the chain DP's case)."""
        if len(self.edges) != len(self.nodes) - 1:
            return False
        return (all(len(p) <= 1 for p in self.preds.values())
                and all(len(s) <= 1 for s in self.succs.values()))

    def chain(self) -> list[str]:
        """The chain's node order; asserts the graph IS a chain."""
        assert self.is_chain, f"{self.name} is not a chain"
        return self.topo_order()

    @property
    def total_flops(self) -> float:
        """Sum of per-node host-style flop counts."""
        return sum(n.flops for n in self.nodes.values())

    @property
    def total_bytes(self) -> float:
        """Sum of per-node device-local memory traffic (bytes)."""
        return sum(n.hbm_bytes for n in self.nodes.values())

    # -----------------------------------------------------------------
    # builders
    # -----------------------------------------------------------------

    #: instruction-graph nodes we skip entirely (no work, no data of note)
    _SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "copy",
             "convert", "broadcast", "reshape", "transpose"}

    @classmethod
    def from_hlo(cls, text: str, name: str = "hlo",
                 trip_count_fallback: int = 1) -> "OpGraph":
        """Fine-grained graph: one node per entry-computation instruction
        (fusions stay whole and are costed by walking their callee)."""
        module = parse_hlo_text(text)
        g = cls(name)
        entry = module.computations[module.entry]
        for op_name in entry.order:
            op = entry.ops[op_name]
            if op.opcode in cls._SKIP:
                continue
            node = _node_from_hlo_op(module, entry, op, trip_count_fallback)
            # dedup: an operand used twice is one tensor crossing once
            preds = [p for p in dict.fromkeys(op.operands) if p in g.nodes]
            g.add(node, *preds)
        g.input_bytes = sum(o.out_bytes for o in entry.ops.values()
                            if o.opcode == "parameter")
        return g


def _node_from_hlo_op(module: HloModule, comp: HloComputation, op: HloOp,
                      trip_fallback: int) -> OpNode:
    """Cost one entry-computation instruction as an OpNode."""
    ops: dict[tuple[str, str], float] = defaultdict(float)
    flops = 0.0
    if op.opcode == "dot":
        pairs = _dot_flops(op, comp) / 2.0
        dt = _dtype_class(op.out_shapes[0].dtype) if op.out_shapes else "float"
        ops[("mul", _dot_mul_class(op, comp, module, dt))] += pairs
        ops[("add", dt)] += pairs
        flops = 2.0 * pairs
    elif op.opcode in ("reduce", "reduce-window"):
        in_op = comp.ops.get(op.operands[0]) if op.operands else None
        if in_op is not None and in_op.out_shapes:
            s = in_op.out_shapes[0]
            ops[(_reduce_class(module, op), _dtype_class(s.dtype))] = \
                float(s.elements)
            flops = float(s.elements)
    elif op.opcode == "fusion":
        callee = (op.attr("calls") or "").lstrip("%")
        sub = module.computations.get(callee)
        if sub is not None:
            sub_module = HloModule(callee, module.computations, callee)
            for k, v in ops_from_hlo(sub_module, trip_fallback).items():
                ops[k] += v
        flops = sum(ops.values())
    elif op.opcode in _OP_CLASS and op.out_shapes:
        s = op.out_shapes[0]
        ops[(_OP_CLASS[op.opcode], _dtype_class(s.dtype))] = float(s.elements)
        flops = float(s.elements)
    # bytes: operands + output (the planner only needs relative magnitude
    # here; stage-level nodes get the full analyze_hlo traffic model)
    nbytes = float(op.out_bytes)
    for on in op.operands:
        src = comp.ops.get(on)
        if src is not None and src.opcode != "constant":
            nbytes += src.out_bytes
    return OpNode(name=op.name, kind=op.opcode, flops=flops,
                  hbm_bytes=nbytes, out_bytes=float(op.out_bytes),
                  ops=dict(ops))


# ---------------------------------------------------------------------------
# stage-level node builder (what the runtime executes)
# ---------------------------------------------------------------------------

def _struct_bytes(tree: Any) -> float:
    import jax
    return float(sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(tree)))


def node_from_fn(name: str, fn: Callable, *example_args,
                 kind: str = "stage", exchange_bytes: float = 0.0,
                 trip_count_fallback: int = 1) -> OpNode:
    """Compile `fn` on example args (arrays or ShapeDtypeStructs — nothing
    is executed) and cost it as one OpNode via analyze_hlo + ops_from_hlo."""
    import jax
    compiled = jax.jit(fn).lower(*example_args).compile()
    text = compiled.as_text()
    analysis = analyze_hlo(text, trip_count_fallback=trip_count_fallback)
    out = jax.eval_shape(fn, *example_args)
    return OpNode(
        name=name, kind=kind,
        flops=analysis.flops,
        hbm_bytes=analysis.hbm_bytes,
        out_bytes=_struct_bytes(out),
        ops=ops_from_hlo(text, trip_count_fallback),
        exchange_bytes=exchange_bytes,
        meta={"analysis": analysis},
    )


def annotate_kv_residency(node: OpNode, kv_bytes: float,
                          home: str) -> OpNode:
    """Mark a node as reading `kv_bytes` (bytes) of cache resident on
    `home` (a `placement.DEVICES` name). The planner
    (`placement.kv_migration_time`) charges moving those bytes over the
    measured channel whenever the node is placed elsewhere — the
    data-placement term of the decode/prefill DAG objectives."""
    node.meta["kv_bytes"] = float(kv_bytes)
    node.meta["kv_home"] = home
    return node


def annotate_kv_write(node: OpNode, kv_bytes: float, home: str) -> OpNode:
    """Mark a node as *writing* `kv_bytes` (bytes) of KV-cache rows whose
    residency is `home` (a `placement.DEVICES` name). Placing the node on
    any other device charges shipping the freshly produced rows back to the
    home over the measured channel (`placement.kv_migration_time`'s
    write-back term) — the cost a chunked prefill pays to keep the cache
    bank-resident while its compute runs elsewhere."""
    node.meta["kv_write_bytes"] = float(kv_bytes)
    node.meta["kv_write_home"] = home
    return node


def chain_graph(name: str, nodes: Iterable[OpNode],
                input_bytes: float = 0.0) -> OpGraph:
    """Link nodes into a linear chain (the common pipeline shape)."""
    g = OpGraph(name, input_bytes=input_bytes)
    prev: str | None = None
    for node in nodes:
        g.add(node, *( [prev] if prev else [] ))
        prev = node.name
    return g
