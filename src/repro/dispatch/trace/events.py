"""Structured execution traces: the event stream everything else reads.

A `Trace` is an append-only list of `TraceEvent` spans over named
*resources* — device names from `placement.DEVICES` plus the two
pseudo-resources `"channel"` (the ONE shared host<->device transfer
channel of the pipelined discipline, DESIGN.md §13) and `"engine"` (the
serving loop). The executor (`dispatch.executor.PlanExecutor.run(...,
tracer=...)`) records *measured* spans with `time.perf_counter`; the
scheduler's pipelined event simulation (`trace.replay.modeled_trace`)
records *modeled* spans in cost-model seconds. Both produce the same
schema, which is what lets `trace.calibrate` fit cost constants from
measured traces and `trace.replay` re-price recorded timelines.

All timestamps and durations are SECONDS relative to the trace origin;
payload attributes are BYTES. Traces serialize to a versioned JSON
document (`Trace.save` / `Trace.load`) and to Chrome's `trace_event`
format (`Trace.save_chrome`) loadable in chrome://tracing or Perfetto.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

#: bump when the serialized event schema changes shape (golden traces and
#: archived benchmark artifacts pin the version they were written with)
TRACE_SCHEMA_VERSION = 1

#: every event kind the tracer emits; `compute`/`launch` occupy a device,
#: `stage_in`/`exchange`/`writeback`/`transfer_out` occupy the shared
#: transfer channel, `compile`/`cache_hit` are FaceCache accounting, and
#: `prefill_step`/`decode_step` are per-slot serving-loop latencies
EVENT_KINDS = ("compute", "launch", "stage_in", "exchange", "writeback",
               "transfer_out", "compile", "cache_hit", "prefill_step",
               "decode_step")


@dataclasses.dataclass
class TraceEvent:
    """One timestamped span: `kind` (see `EVENT_KINDS`) of `name` on
    `resource`, from `t0` to `t1` (seconds since trace origin; `t0 == t1`
    for instant events). `group` is the launch-group index the span
    belongs to (-1 when not group-scoped); `attrs` carries kind-specific
    payload (bytes, producer names, stage kind, ...)."""

    kind: str
    name: str
    resource: str
    t0: float
    t1: float
    group: int = -1
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        """Span duration in seconds (0.0 for instant events)."""
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        """Plain-JSON form (the schema `Trace.save` writes)."""
        return {"kind": self.kind, "name": self.name,
                "resource": self.resource, "t0": self.t0, "t1": self.t1,
                "group": self.group, "attrs": self.attrs}

    @classmethod
    def from_dict(cls, d: dict) -> "TraceEvent":
        """Inverse of `to_dict` (used by `Trace.load`)."""
        return cls(kind=d["kind"], name=d["name"], resource=d["resource"],
                   t0=d["t0"], t1=d["t1"], group=d.get("group", -1),
                   attrs=dict(d.get("attrs") or {}))


class Trace:
    """An execution trace: event recorder + serializer.

    Recording is append-only and cheap (one `perf_counter` call and one
    list append per event) so a tracer can stay attached to the serving
    hot loop — the <5% overhead budget benchmarks/dispatch_bench.py
    measures. `meta` carries run-level context (graph name, assignment,
    whether spans are modeled or measured)."""

    def __init__(self, name: str = "trace", meta: dict | None = None):
        self.name = name
        self.meta: dict = dict(meta or {})
        self.events: list[TraceEvent] = []
        self._origin = time.perf_counter()

    def now(self) -> float:
        """Seconds since the trace origin (monotonic, `perf_counter`)."""
        return time.perf_counter() - self._origin

    def add(self, kind: str, name: str, resource: str, t0: float,
            t1: float | None = None, group: int = -1,
            **attrs: Any) -> TraceEvent:
        """Record a span; `t1=None` closes it at the current clock (the
        measured-span idiom: grab `t0 = tracer.now()`, do the work, then
        `tracer.add(...)`). Returns the recorded event."""
        ev = TraceEvent(kind, name, resource, t0,
                        self.now() if t1 is None else t1, group, attrs)
        self.events.append(ev)
        return ev

    def instant(self, kind: str, name: str, resource: str, group: int = -1,
                **attrs: Any) -> TraceEvent:
        """Record a zero-duration event at the current clock."""
        t = self.now()
        return self.add(kind, name, resource, t, t, group, **attrs)

    def by_kind(self, kind: str) -> list[TraceEvent]:
        """Every recorded event of one kind, in recorded order."""
        return [e for e in self.events if e.kind == kind]

    def resources(self) -> list[str]:
        """Sorted resource names the trace touches."""
        return sorted({e.resource for e in self.events})

    def to_json(self) -> dict:
        """The versioned JSON document (`{"schema", "name", "meta",
        "events"}`) golden traces and `--trace` outputs are written as."""
        return {"schema": TRACE_SCHEMA_VERSION, "name": self.name,
                "meta": self.meta,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_json(cls, doc: dict) -> "Trace":
        """Rebuild a trace from `to_json`'s document (schema-checked)."""
        if doc.get("schema") != TRACE_SCHEMA_VERSION:
            raise ValueError(f"trace schema {doc.get('schema')!r} != "
                             f"supported {TRACE_SCHEMA_VERSION}")
        t = cls(name=doc.get("name", "trace"), meta=doc.get("meta"))
        t.events = [TraceEvent.from_dict(d) for d in doc["events"]]
        return t

    def save(self, path) -> None:
        """Write the versioned JSON document to `path`."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path) -> "Trace":
        """Read a trace written by `save`."""
        with open(path) as f:
            return cls.from_json(json.load(f))

    def to_chrome(self) -> dict:
        """Chrome `trace_event` form: one pseudo-thread per resource
        (named via `thread_name` metadata events), spans as complete
        (`ph="X"`) events, instants as `ph="i"`; timestamps in
        microseconds as the format requires."""
        tids = {r: i + 1 for i, r in enumerate(self.resources())}
        out: list[dict] = [
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
             "args": {"name": r}} for r, tid in tids.items()]
        for e in self.events:
            rec: dict = {"name": f"{e.kind}:{e.name}", "cat": e.kind,
                         "pid": 1, "tid": tids[e.resource],
                         "ts": e.t0 * 1e6,
                         "args": {"group": e.group, **e.attrs}}
            if e.t1 > e.t0:
                rec.update(ph="X", dur=(e.t1 - e.t0) * 1e6)
            else:
                rec.update(ph="i", s="t")
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"trace": self.name, **{
                    k: v for k, v in self.meta.items()
                    if isinstance(v, (str, int, float, bool))}}}

    def save_chrome(self, path) -> None:
        """Write the Chrome `trace_event` JSON to `path` (open it in
        chrome://tracing or https://ui.perfetto.dev)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
