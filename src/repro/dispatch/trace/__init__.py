"""repro.dispatch.trace — execution tracing, replay, and calibration.

The observability layer over the planner->schedule->executor spine
(DESIGN.md §13). Three pieces share one event schema (`events.Trace`,
versioned, JSON + Chrome `trace_event` export):

  * **record** — `PlanExecutor.run(..., tracer=Trace())` measures the
    executed timeline (compute spans per node, channel occupancy per
    staging/exchange, FaceCache compile-vs-hit); the serving engine
    layers per-slot decode-step latencies on top
    (`ServeEngine.attach_tracer`).
  * **replay** — `replay.replay` re-prices a recorded linearization +
    assignment under the pipelined event-sim discipline (queue per
    device, ONE shared transfer channel), including on what-if hardware
    (`replay.what_if`); `replay.fidelity` gates the planner's predicted
    `pipelined_s` against the replayed makespan (`FIDELITY_BAND`).
  * **calibrate** — `calibrate.fit_trace` least-squares-fits the cost
    constants (`placement.cost_constants`) from measured spans and
    reports per-constant drift vs the Fig.-4 anchors.

Units everywhere: seconds and bytes; device names from
`placement.DEVICES` plus the pseudo-resources `"channel"`/`"engine"`.
"""

from .events import EVENT_KINDS, TRACE_SCHEMA_VERSION, Trace, TraceEvent
from .replay import (FIDELITY_BAND, FidelityReport, ReplayResult,
                     executed_order, fidelity, measured_node_times,
                     modeled_trace, replay, what_if)
from .calibrate import (CalibrationReport, ConstantFit, anchor_trace,
                        fit_trace)
