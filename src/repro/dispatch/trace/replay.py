"""Trace replay: re-price a recorded timeline under the pipelined
event-sim discipline, and gate planner fidelity on the result.

The replayer's contract is the methodology profiling-replay systems use
for distributed training (record once on real hardware, then re-simulate
the dependency graph under a queue-per-resource discipline to price
what-ifs): a trace fixes WHAT ran — the executed node linearization and
the device assignment — and `make_schedule(..., pipelined=True)` re-prices
WHEN, with every device a serial queue and all host<->device traffic on
ONE shared transfer channel (DESIGN.md §13). Because prices come from the
cost model, a replay can swap the hardware out from under a recorded run:
`replay(trace, graph, dpu=what_if(channel_scale=2.0))` prices the same
execution on a machine with a doubled transfer channel without running
it.

`fidelity` is the planner-fidelity gate's primitive: the planner's
predicted `Schedule.pipelined_s` must stay within `FIDELITY_BAND`
relative error of the replayed trace — drift between what the planner
promises and what the executed timeline re-prices to fails CI the same
way golden-plan drift does. All times are seconds.
"""

from __future__ import annotations

import dataclasses

from ...core.pim_model import DPUModel, UPMEM_2556
from ..graph import OpGraph
from ..placement import Plan
from ..schedule import Schedule, make_schedule
from .events import Trace

#: the documented relative-error band of the planner-fidelity gate:
#: |replayed - predicted| / predicted must stay inside it for every
#: shipped golden graph (tests/test_trace.py, the CI fidelity-gate step)
FIDELITY_BAND = 0.10


def modeled_trace(graph: OpGraph, plan: Plan, dpu: DPUModel | None = None,
                  *, source: str = "xeon", sink: str = "xeon",
                  order: list | None = None,
                  node_times: dict | None = None) -> Trace:
    """Run the pipelined event simulation and capture its timeline as a
    `Trace` — the modeled twin of a measured executor trace (same event
    schema, timestamps in cost-model seconds instead of wall-clock)."""
    events: list[dict] = []
    sched = make_schedule(graph, plan, dpu, source, sink, pipelined=True,
                          order=order, node_times=node_times, events=events)
    t = Trace(name=f"{graph.name}:modeled")
    t.meta.update(modeled=True, graph=graph.name,
                  assignment=dict(plan.assignment),
                  pipelined_s=sched.pipelined_s)
    for ev in events:
        t.add(ev["kind"], ev["name"], ev["resource"], ev["t0"], ev["t1"],
              group=ev["group"], **ev["attrs"])
    return t


def executed_order(trace: Trace) -> list[str]:
    """The node linearization a trace records: compute-event names in
    recorded order (the executor appends them as it dispatches, so this
    is the order that actually ran)."""
    return [e.name for e in trace.events if e.kind == "compute"]


def measured_node_times(trace: Trace) -> dict:
    """Per-node compute seconds a trace measured (name -> seconds; the
    last recorded span per node wins, i.e. post-warmup steps of a
    multi-step serving trace)."""
    out: dict = {}
    for e in trace.events:
        if e.kind == "compute":
            out[e.name] = e.dur_s
    return out


def what_if(dpu: DPUModel | None = None, *, n_dpus: int | None = None,
            mram_bw: float | None = None,
            launch_overhead_s: float | None = None,
            channel_scale: float | None = None) -> DPUModel:
    """A hypothetical UPMEM system for what-if replay: start from `dpu`
    (default the 2556-DPU system) and override fields; `channel_scale`
    multiplies BOTH host<->DPU channel bandwidths (bytes/s) — 'what if
    the transfer channel were 2x faster' is `channel_scale=2.0`."""
    base = dpu or UPMEM_2556
    kw: dict = {}
    if n_dpus is not None:
        kw["n_dpus"] = n_dpus
    if mram_bw is not None:
        kw["mram_bw"] = mram_bw
    if launch_overhead_s is not None:
        kw["launch_overhead_s"] = launch_overhead_s
    if channel_scale is not None:
        kw["host_to_dpu_bw"] = base.host_to_dpu_bw * channel_scale
        kw["dpu_to_host_bw"] = base.dpu_to_host_bw * channel_scale
    return dataclasses.replace(base, **kw)


@dataclasses.dataclass
class ReplayResult:
    """A re-priced timeline: the replayed linearization, the full
    re-priced `Schedule`, and its pipelined makespan in seconds."""

    graph_name: str
    order: list
    schedule: Schedule
    total_s: float


def replay(trace: Trace, graph: OpGraph, assignment: dict | None = None,
           *, dpu: DPUModel | None = None, source: str = "xeon",
           sink: str = "xeon", use_measured_times: bool = False) -> \
        ReplayResult:
    """Re-price a recorded timeline under the pipelined event-sim
    discipline (each device a serial queue, one shared transfer channel).

    The trace supplies the executed linearization (`executed_order`) and,
    via `trace.meta["assignment"]` when `assignment` is None, the device
    placement; `make_schedule(..., pipelined=True, order=...)` re-prices
    it. A multi-step serving trace (node names repeating once per decode
    step) replays its LAST step — the post-warmup steady state. Pass a
    what-if `dpu` (see `what_if`) to price the same execution on
    different hardware; `use_measured_times=True` prices compute with the
    trace's measured spans instead of the cost model (channel traffic
    stays modeled)."""
    assignment = assignment or trace.meta.get("assignment")
    if not assignment:
        raise ValueError("no assignment: pass one or record it in "
                         "trace.meta['assignment']")
    order = executed_order(trace)
    n = len(graph.nodes)
    if len(order) > n:
        order = order[-n:]          # multi-step trace: replay the last step
    if sorted(order) != sorted(graph.nodes):
        order = []                  # partial/mixed trace (e.g. prefill
                                    # spans of another DAG): planner order
    node_times = measured_node_times(trace) if use_measured_times else None
    sched = make_schedule(graph, Plan.stub(graph.name, assignment,
                                           method="replay"),
                          dpu, source, sink, pipelined=True,
                          order=order or None, node_times=node_times)
    return ReplayResult(graph_name=graph.name, order=list(order),
                        schedule=sched, total_s=sched.pipelined_s)


@dataclasses.dataclass
class FidelityReport:
    """Predicted-vs-replayed comparison for one graph (seconds): the
    planner's `pipelined_s` prediction, the trace-replayed makespan, and
    the gate band the comparison is judged against."""

    graph_name: str
    predicted_s: float
    replayed_s: float
    band: float = FIDELITY_BAND

    @property
    def rel_err(self) -> float:
        """|replayed - predicted| / predicted — the gated quantity."""
        return abs(self.replayed_s - self.predicted_s) / self.predicted_s

    @property
    def ok(self) -> bool:
        """True when the relative error sits inside the gate's band."""
        return self.rel_err <= self.band

    def render(self) -> str:
        """One human-readable gate line (ms, err %, PASS/FAIL)."""
        return (f"fidelity[{self.graph_name}] predicted "
                f"{self.predicted_s * 1e3:.3f}ms vs replayed "
                f"{self.replayed_s * 1e3:.3f}ms: err "
                f"{self.rel_err * 100.0:.2f}% "
                f"({'PASS' if self.ok else 'FAIL'} @ {self.band:.0%})")


def fidelity(graph: OpGraph, plan: Plan, *, trace: Trace | None = None,
             dpu: DPUModel | None = None, source: str = "xeon",
             sink: str = "xeon", band: float = FIDELITY_BAND) -> \
        FidelityReport:
    """The planner-fidelity gate's primitive: compare the plan's
    predicted `Schedule.pipelined_s` against the re-priced replay of an
    execution trace. With `trace=None` the plan's own modeled trace is
    replayed (the record->replay round trip — drift means the replayer
    and the simulation disagree); pass a MEASURED executor trace to gate
    the planner against the order/assignment that actually ran (drift
    means the executor diverged from the planned timeline)."""
    predicted = make_schedule(graph, plan, dpu, source, sink,
                              pipelined=True).pipelined_s
    tr = trace if trace is not None else \
        modeled_trace(graph, plan, dpu, source=source, sink=sink)
    rep = replay(tr, graph, plan.assignment, dpu=dpu, source=source,
                 sink=sink)
    return FidelityReport(graph_name=graph.name, predicted_s=predicted,
                          replayed_s=rep.total_s, band=band)
