"""Least-squares calibration of the planner's cost constants from traces.

Every constant in `placement.cost_constants` is hand-anchored to the
paper's measured bands (Fig. 4 op throughputs, the host<->DPU channel
bandwidths, MRAM streaming). This module closes the loop the source
characterization warns about when moving from microbenchmarks to
end-to-end workloads: fit the same constants back out of a measured
execution trace and report per-constant drift against the anchors.

Each event class maps to one linear model in the unknown constant, so
every fit is a closed-form least squares:

  * host `compute` spans — classified memory-bound vs flop-bound at the
    anchor roofline; memory-bound spans fit `t ~ bytes / hbm_bw`,
    flop-bound spans fit `t ~ flops / peak_flops`;
  * PIM `compute` spans — one multiplicative time scale `alpha` against
    the full DPU model (`t ~ alpha * node_time`), reported both as
    `dpu.time_scale` and as the implied `dpu.mram_bw` (streaming ops are
    MRAM-bound, so throughput scales as 1/alpha). Spans whose node is
    int8-dominant (quantized expert GEMMs — the KT2-flip band) fit a
    SEPARATE scale `dpu.int8_time_scale`: the int8 band prices the DPU's
    native 8x8 multiplier, whose drift is independent of the int32
    software-ladder band's (DESIGN.md §15);
  * `stage_in` channel spans — the affine batched-transfer model
    `t ~ setup_s + bytes / host_to_dpu_bw` (two unknowns, fit jointly
    when the trace has >= 2 distinct payload sizes);
  * `exchange` channel spans — the host-relayed round trip
    `t ~ bytes / roundtrip_bw` after subtracting the per-call setups.

Feeding a trace priced exactly at the anchors (`anchor_trace`) must
recover them with ~0 drift — the round-trip property
tests/test_trace.py pins. All times are seconds, payloads bytes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ...core.pim_model import DPUModel, MACHINES, UPMEM_2556
from ..graph import OpGraph
from ..placement import (cost_constants, exchange_time, node_bytes,
                         node_time, transfer_time)
from ..schedule import TRANSFER_SETUP_S
from .events import Trace


def _lsq_through_origin(pts) -> float:
    """Closed-form least squares for `t ~ x * v` through the origin over
    `(t, v)` pairs; returns the slope x (0.0 with no usable points)."""
    num = sum(t * v for t, v in pts)
    den = sum(v * v for _, v in pts)
    return num / den if den else 0.0


def _int8_dominant(node) -> bool:
    """True when a node's MULTIPLIES are majority int8-band — the
    classifier routing a PIM compute span to the `dpu.int8_time_scale`
    fit instead of the pooled `dpu.time_scale` one. Muls are the band's
    discriminator: an int8 GEMM's int32 accumulator adds always match its
    mul count (so no GEMM is ever majority-int8 over ALL slots), but the
    muls are exactly what the 8x8-multiplier band reprices."""
    muls = {dt: cnt for (op, dt), cnt in node.ops.items() if op == "mul"}
    total = sum(muls.values())
    return total > 0 and 2 * muls.get("int8", 0) > total


def _lsq_affine(pts) -> tuple[float, float]:
    """Least squares for `t ~ a + x * v` over `(t, v)` pairs; returns
    `(a, x)` (intercept, slope) via numpy lstsq."""
    mat = np.array([[1.0, v] for _, v in pts])
    y = np.array([t for t, _ in pts])
    a, x = np.linalg.lstsq(mat, y, rcond=None)[0]
    return float(a), float(x)


@dataclasses.dataclass
class ConstantFit:
    """One calibrated cost constant: the shipped Fig.-4-anchored value vs
    the least-squares fit from a trace, with the sample count behind it.
    Units follow the constant's suffix (`*_bw` bytes/s, `*_flops`
    FLOP/s, `*_s` seconds, `*_scale` dimensionless)."""

    name: str
    anchor: float
    fitted: float
    n_events: int
    unit: str

    @property
    def drift(self) -> float:
        """Relative drift of the fit vs the anchor: fitted/anchor - 1."""
        return self.fitted / self.anchor - 1.0


@dataclasses.dataclass
class CalibrationReport:
    """Fits for every constant a trace had evidence for (constants with
    no matching events are simply absent — calibration never invents
    data)."""

    trace_name: str
    fits: list

    def fitted_constants(self) -> dict:
        """Constant name -> fitted value (keys are a subset of
        `placement.cost_constants`'s)."""
        return {f.name: f.fitted for f in self.fits}

    def render(self) -> str:
        """Human-readable drift table (one line per fitted constant)."""
        lines = [f"calibration[{self.trace_name}] "
                 f"{len(self.fits)} constant(s) fit:"]
        for f in self.fits:
            lines.append(
                f"  {f.name:24s} anchor {f.anchor:10.4g} {f.unit:6s} -> "
                f"fitted {f.fitted:10.4g}  drift {f.drift:+7.1%}  "
                f"(n={f.n_events})")
        return "\n".join(lines)


def anchor_trace(graph: OpGraph, assignment: dict,
                 dpu: DPUModel | None = None) -> Trace:
    """A synthetic measured trace priced exactly at the anchors: every
    compute span lasts `node_time`, every boundary batch lasts one setup
    plus payload over the measured channel, every exchange the
    host-relayed round trip. Feeding it to `fit_trace` must recover the
    anchors (drift ~ 0) — the estimator-correctness property the test
    suite pins; also a convenient fixture for replay/what-if demos."""
    d = dpu or UPMEM_2556
    t = Trace(name=f"{graph.name}:anchor")
    t.meta.update(modeled=True, anchor=True, graph=graph.name,
                  assignment=dict(assignment))
    preds = graph.preds
    clock = 0.0
    for n in graph.topo_order():
        dev = assignment[n]
        by_src: dict = {}
        for p in preds[n]:
            if assignment[p] != dev:
                by_src.setdefault(assignment[p], []).append(
                    graph.nodes[p].out_bytes)
        for src, payloads in sorted(by_src.items()):
            dur = TRANSFER_SETUP_S + sum(transfer_time(src, dev, b, d)
                                         for b in payloads)
            t.add("stage_in", f"{src}->{n}", "channel", clock, clock + dur,
                  bytes=float(sum(payloads)), device=dev, src=src)
            clock += dur
        dur = node_time(graph.nodes[n], dev, d)
        t.add("compute", n, dev, clock, clock + dur)
        clock += dur
    for (u, v), nb in sorted(graph.exchange_edges.items()):
        ex_t = exchange_time(assignment[u], assignment[v], nb, d)
        if ex_t:
            end = clock + ex_t + 2 * TRANSFER_SETUP_S
            t.add("exchange", f"{u}->{v}", "channel", clock, end,
                  bytes=float(nb), n_exchanges=1)
            clock = end
    return t


def fit_trace(trace: Trace, graph: OpGraph, assignment: dict,
              dpu: DPUModel | None = None) -> CalibrationReport:
    """Fit the cost-table constants from a trace's measured spans and
    report drift vs the anchors (`placement.cost_constants`).

    `graph`/`assignment` supply each compute span's regressors (flops,
    effective bytes, device); spans whose names are not graph nodes are
    ignored. Multi-step serving traces contribute every repetition as a
    sample. The channel fit assumes `stage_in` spans are host->DPU
    batches (the executor's only staging path); destination devices are
    read from the events' `device` attr."""
    d = dpu or UPMEM_2556
    anchors = cost_constants(d)
    fits: list[ConstantFit] = []

    for device in ("xeon", "titan_v"):
        m = MACHINES[device]
        mem: list = []
        flop: list = []
        for e in trace.events:
            if e.kind != "compute" or e.name not in graph.nodes:
                continue
            if assignment.get(e.name) != device or e.dur_s <= 0:
                continue
            node = graph.nodes[e.name]
            b, f = node_bytes(node, device), node.flops
            if b / m.hbm_bw >= f / m.peak_flops:
                if b > 0:
                    mem.append((e.dur_s, b))
            elif f > 0:
                flop.append((e.dur_s, f))
        x = _lsq_through_origin(mem)
        if x > 0:
            fits.append(ConstantFit(f"{device}.hbm_bw",
                                    anchors[f"{device}.hbm_bw"], 1.0 / x,
                                    len(mem), "B/s"))
        x = _lsq_through_origin(flop)
        if x > 0:
            fits.append(ConstantFit(f"{device}.peak_flops",
                                    anchors[f"{device}.peak_flops"],
                                    1.0 / x, len(flop), "FLOP/s"))

    spans = [(e.dur_s, node_time(graph.nodes[e.name], assignment[e.name], d),
              graph.nodes[e.name])
             for e in trace.events
             if e.kind == "compute" and e.name in graph.nodes
             and str(assignment.get(e.name, "")).startswith("upmem")]
    spans = [(t, mdl, n) for t, mdl, n in spans if t > 0 and mdl > 0]
    # int8-dominant spans (quantized expert GEMMs) fit their own scale:
    # the 8x8-multiplier band and the int32 software-ladder band drift
    # independently on real hardware, so one pooled alpha would let a
    # miscalibrated int8 band hide inside float-dominated traces
    pim = [(t, mdl) for t, mdl, n in spans if not _int8_dominant(n)]
    pim8 = [(t, mdl) for t, mdl, n in spans if _int8_dominant(n)]
    if pim:
        alpha = _lsq_through_origin(pim)
        if alpha > 0:
            fits.append(ConstantFit("dpu.time_scale", 1.0, alpha,
                                    len(pim), "x"))
            fits.append(ConstantFit("dpu.mram_bw", anchors["dpu.mram_bw"],
                                    anchors["dpu.mram_bw"] / alpha,
                                    len(pim), "B/s"))
    if pim8:
        alpha8 = _lsq_through_origin(pim8)
        if alpha8 > 0:
            fits.append(ConstantFit("dpu.int8_time_scale",
                                    anchors["dpu.int8_time_scale"], alpha8,
                                    len(pim8), "x"))

    chan = [(e.dur_s, float(e.attrs.get("bytes") or 0.0))
            for e in trace.events if e.kind == "stage_in"
            and str(e.attrs.get("device", "upmem")).startswith("upmem")]
    chan = [(t, b) for t, b in chan if t > 0 and b > 0]
    if chan:
        if len({b for _, b in chan}) >= 2:
            a, x = _lsq_affine(chan)
            if x > 0:
                fits.append(ConstantFit("dpu.host_to_dpu_bw",
                                        anchors["dpu.host_to_dpu_bw"],
                                        1.0 / x, len(chan), "B/s"))
                fits.append(ConstantFit("channel.setup_s",
                                        anchors["channel.setup_s"],
                                        max(a, 0.0), len(chan), "s"))
        else:                        # one payload size: pin the setup,
            setup = anchors["channel.setup_s"]        # fit bandwidth only
            x = _lsq_through_origin([(max(t - setup, 0.0), b)
                                     for t, b in chan])
            if x > 0:
                fits.append(ConstantFit("dpu.host_to_dpu_bw",
                                        anchors["dpu.host_to_dpu_bw"],
                                        1.0 / x, len(chan), "B/s"))

    ex = [(e.dur_s - 2.0 * anchors["channel.setup_s"]
           * int(e.attrs.get("n_exchanges") or 1),
           float(e.attrs.get("bytes") or 0.0))
          for e in trace.events if e.kind == "exchange"]
    ex = [(t, b) for t, b in ex if t > 0 and b > 0]
    if ex:
        x = _lsq_through_origin(ex)
        if x > 0:
            fits.append(ConstantFit("exchange.roundtrip_bw",
                                    anchors["exchange.roundtrip_bw"],
                                    1.0 / x, len(ex), "B/s"))
    return CalibrationReport(trace_name=trace.name, fits=fits)
