"""Offload placement: assign every operator to a device, minimizing modeled
end-to-end latency.

Devices are the paper's measured systems (`core.pim_model`): the Xeon host,
the Titan V, and one UPMEM system. Per-node costs come straight from the
calibrated models — `DPUModel.compute_time`/`mram_time`/`interdpu_time` for
PIM, the roofline `max(flops/peak, bytes/bw)` for host-class machines (the
same arithmetic as `perf_model.time_on_pim`/`time_on_host`, at operator
granularity). Crossing a device boundary charges the producer's `out_bytes`
over the measured channel: the UPMEM parallel-transfer bandwidths for
host<->DPU, PCIe for host<->GPU, and both hops for GPU<->DPU (all DPU
traffic goes through the host — Takeaway 3).

Entering a device also pays that device's launch overhead *unless the
previous operator already ran there* — so the optimizer itself discovers
the paper's launch-coalescing recommendation: consecutive PIM operators
merge into one DPU launch.

Nodes that read a resident KV-cache shard (the decode attention) carry
`meta["kv_bytes"]` / `meta["kv_home"]`: placing such a node on any device
other than the cache's home charges migrating the slot's KV over the
measured transfer channel (`kv_migration_time`) — the data-placement cost
the decode DAG planner trades against compute. Nodes that *write* KV rows
(a prefill chunk's attention) carry `meta["kv_write_bytes"]` /
`meta["kv_write_home"]` symmetrically: running them off the cache's home
charges shipping the fresh rows back. Weights/params stay device-resident
(weight-stationary serving): only activations and migrated KV cross
boundaries.

Exchange edges (`OpGraph.exchange_edges` — MoE token dispatch/combine)
charge `exchange_time`: when producer and consumer share a PIM device the
re-distribution still round-trips through host DRAM (all-to-all is the
worst case for the architecture, Takeaway 3) — the cost that lets the
planner decide host-vs-bank expert placement instead of guessing. The
charge is per-edge (no dedup) and flows through every ladder rung.

Multi-rank scale-out (`Topology`): a plan may target several RANKS of one
UPMEM base system — rank devices are ordinary placement names
(`"upmem_2556"` is rank 0, `"upmem_2556:1"` rank 1, ...), each a full DPU
array behind its own host memory channel with the base system's measured
per-rank constants (CPU<->DPU bandwidth scales near-linearly with ranks
driven in parallel, arXiv:2105.03814). Because ranks are plain device
names, every planner rung below prices expert-parallel and layer-parallel
multi-rank plans unchanged; inter-rank traffic relays through host DRAM
(`transfer_hops` — there is no direct rank-to-rank path, Takeaway 3), and
the per-rank channel concurrency is realized by the pipelined event sim
(`schedule._pipelined_total`, one transfer-channel resource per rank).

Two objectives (the `objective` knob of `plan`): `"serial"` minimizes the
additive end-to-end sum `evaluate` computes — the ladder below is exact
for it; `"overlapped"` scores candidates by the scheduler's modeled
wall-clock (`Schedule.overlapped_s`: batched transfers double-buffered
under group compute, relay hops pinned serial). For CHAIN graphs the
overlapped objective is planned *exactly* by a DP over launch-group
aggregates (`_plan_chain_overlapped_dp`, method `"dp-overlap"` — the
group boundary resets the overlap max()'s running sums, restoring the
decomposition); general DAGs fall to a deterministic local search seeded
with the serial plan (DESIGN.md §10-§11).

Planner ladder (each rung exact for its class, the next a fallback):

  1. chain DP over (position, device)         — chains (`is_chain`)
  2. frontier DP over the topological order   — exact for ANY DAG whose
     open-producer frontier stays small (series-parallel decompositions,
     out-trees, the decode DAG's residual braid); aborts past a state
     budget
  3. bounded branch-and-bound                 — general DAGs; seeded with
     the greedy incumbent and an admissible per-node lower bound, so its
     answer is never worse than greedy and exact if the budget suffices
  4. greedy topological sweep                 — the always-available floor
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from ..core.pim_model import DPUModel, MACHINES, UPMEM_2556, UPMEM_640
from .graph import OpGraph, OpNode

#: every placeable BASE device; at most one upmem_* base system per plan,
#: but a plan may target several RANKS of it ("upmem_2556:1", ...) — see
#: `Topology` / `device_rank`
DEVICES = ("xeon", "titan_v", "upmem_2556", "upmem_640")

#: Titan V PCIe 3.0 x16 effective host<->GPU bandwidth
PCIE_BW = 12e9

#: fixed cost of starting work on a device when the previous operator ran
#: elsewhere (kernel launch / DPU program launch + host sync)
_HOST_LAUNCH_S = {"xeon": 0.0, "titan_v": 2e-5}

_DPU_SYSTEMS = {"upmem_2556": UPMEM_2556, "upmem_640": UPMEM_640}


def _is_pim(device: str) -> bool:
    return device.startswith("upmem")


def device_rank(device: str) -> tuple[str, int]:
    """Split a (possibly rank-qualified) device name into (base, rank).

    Multi-rank scale-out names ranks by suffix: `"upmem_2556"` IS rank 0
    — the exact degenerate case every pre-topology plan was priced under
    — and `"upmem_2556:1"`, `"upmem_2556:2"`, ... are further ranks of
    the same base system. Each rank is a full DPU array behind its own
    host memory channel: the extended UPMEM characterization
    (arXiv:2105.03814) measures CPU-DPU/DPU-CPU bandwidth scaling
    near-linearly with the number of ranks driven in parallel, so ranks
    do NOT share the per-rank setup/bandwidth constants."""
    base, _, r = device.partition(":")
    return (base, int(r)) if r else (base, 0)


def _dpu_system(device: str) -> DPUModel:
    """The DPU model behind a (possibly rank-qualified) PIM device name."""
    return _DPU_SYSTEMS[device_rank(device)[0]]


def channel_of(device: str) -> str:
    """The transfer-channel resource a device's host traffic occupies.

    Rank 0 and every host-class device keep the historical shared
    `"channel"` resource (so single-rank schedules, goldens, and traces
    are byte-identical to the pre-topology model); rank r > 0 owns
    `"channel:r"` — the per-rank parallelism the scale-out model prices
    and the pipelined event sim enforces exclusivity on."""
    base, r = device_rank(device)
    return "channel" if r == 0 else f"channel:{r}"


@dataclasses.dataclass(frozen=True)
class Topology:
    """A multi-rank channel topology: `n_ranks` full copies of one UPMEM
    base system, each behind its own host<->DPU transfer channel with the
    base system's measured per-rank setup/bandwidth constants
    (rank-parallel CPU<->DPU transfers, arXiv:2105.03814). Inter-rank
    exchanges have no direct path — they relay through host DRAM
    (Takeaway 3): `transfer_hops` prices a rank->rank crossing as a
    retrieve on the source rank's channel plus a push on the destination
    rank's channel.

    `Topology(n_ranks=1)` is the exact degenerate single-channel model
    every existing plan/golden was priced under. Rank devices are plain
    placement names (`rank_device`), so every planner rung prices
    multi-rank plans without topology-specific code paths."""
    base: str = "upmem_2556"
    n_ranks: int = 1

    def __post_init__(self):
        if self.base not in _DPU_SYSTEMS:
            raise ValueError(f"unknown UPMEM base {self.base!r} "
                             f"(know {sorted(_DPU_SYSTEMS)})")
        if self.n_ranks < 1:
            raise ValueError(f"need n_ranks >= 1, got {self.n_ranks}")

    def rank_device(self, r: int) -> str:
        """Placement name of rank `r` (rank 0 is the bare base name)."""
        if not 0 <= r < self.n_ranks:
            raise ValueError(f"rank {r} outside 0..{self.n_ranks - 1}")
        return self.base if r == 0 else f"{self.base}:{r}"

    @property
    def rank_devices(self) -> tuple[str, ...]:
        """Every rank's placement name, rank order."""
        return tuple(self.rank_device(r) for r in range(self.n_ranks))

    def devices(self, hosts: tuple[str, ...] = ("xeon",)) -> tuple[str, ...]:
        """The planner device set: host-class devices + every rank."""
        return tuple(hosts) + self.rank_devices

    @property
    def dpu(self) -> DPUModel:
        """The per-rank DPU system model (all ranks are identical)."""
        return _DPU_SYSTEMS[self.base]

    @property
    def signature(self) -> tuple[str, int]:
        """Hashable identity for plan caching (`plan_cache`): plans priced
        under different topologies must never alias."""
        return (self.base, self.n_ranks)


def node_bytes(node: OpNode, device: str) -> float:
    """Effective bytes an operator streams on `device` — `hbm_bytes` with
    the per-device meta overrides (`bytes_cpu`/`bytes_gpu`, e.g. TRNS
    strided writes) applied. The payload term of `node_time`'s roofline,
    and the regressor `trace.calibrate` fits host bandwidths against."""
    nbytes = node.hbm_bytes
    if device == "xeon" and node.meta.get("bytes_cpu"):
        nbytes = node.meta["bytes_cpu"]
    if device == "titan_v" and node.meta.get("bytes_gpu"):
        nbytes = node.meta["bytes_gpu"]
    return nbytes


def node_time(node: OpNode, device: str,
              dpu: DPUModel | None = None) -> float:
    """Modeled seconds for one operator on one device (no transfers)."""
    if _is_pim(device):
        d = dpu or _dpu_system(device)
        per_dpu = {k: v / d.n_dpus for k, v in node.ops.items()}
        t_c = d.compute_time(per_dpu)
        t_m = d.mram_time(node.hbm_bytes / d.n_dpus)
        # MRAM DMA overlaps compute across tasklets; inter-bank traffic
        # serializes through the host channel (Takeaway 3)
        return max(t_c, t_m) + d.interdpu_time(node.exchange_bytes)
    m = MACHINES[device]
    return max(node.flops / m.peak_flops, node_bytes(node, device) / m.hbm_bw)


def transfer_time(src: str, dst: str, nbytes: float,
                  dpu: DPUModel | None = None) -> float:
    """Seconds to move nbytes from src's memory to dst's memory."""
    if src == dst or nbytes <= 0:
        return 0.0
    d = dpu or UPMEM_2556
    t = 0.0
    if _is_pim(src):
        t += nbytes / d.dpu_to_host_bw
    if _is_pim(dst):
        t += nbytes / d.host_to_dpu_bw
    if "titan_v" in (src, dst):
        t += nbytes / PCIE_BW
    return t


def transfer_hops(src: str, dst: str, nbytes: float,
                  dpu: DPUModel | None = None) -> tuple[float, float]:
    """Split a transfer into (relay_s, final_hop_s), both seconds.

    GPU<->DPU traffic has no direct channel: it relays through host DRAM
    (Takeaway 3), and the relay hop must complete before the final hop can
    start streaming into the destination — the scheduler may only overlap
    the *final* hop with destination compute. Single-hop paths have
    relay_s == 0. The two components always sum to `transfer_time`.

    A rank->rank crossing (two PIM devices — necessarily ranks of one
    base system) also has no direct path: the retrieve into host DRAM is
    the relay hop (the source rank's channel) and the push into the
    destination rank is the final hop (the destination rank's channel) —
    the host-DRAM-relayed inter-rank exchange of the scale-out model."""
    if src == dst or nbytes <= 0:
        return 0.0, 0.0
    d = dpu or UPMEM_2556
    if _is_pim(src) and _is_pim(dst):
        return nbytes / d.dpu_to_host_bw, nbytes / d.host_to_dpu_bw
    if _is_pim(src) and dst == "titan_v":
        return nbytes / d.dpu_to_host_bw, nbytes / PCIE_BW
    if src == "titan_v" and _is_pim(dst):
        return nbytes / PCIE_BW, nbytes / d.host_to_dpu_bw
    return 0.0, transfer_time(src, dst, nbytes, dpu)


def exchange_time(src_dev: str, dst_dev: str, nbytes: float,
                  dpu: DPUModel | None = None) -> float:
    """Seconds to re-distribute `nbytes` across banks for an exchange edge
    (`OpGraph.exchange_edges`) whose producer runs on `src_dev` and
    consumer on `dst_dev`.

    Only the same-PIM-device case costs anything: there is no inter-DPU
    channel (Takeaway 3), so an all-to-all between banks round-trips
    through host DRAM — one parallel retrieve plus one parallel push over
    the measured channels. On one host-class device the shuffle is local
    (already inside the node's memory traffic); across devices the
    ordinary boundary transfer (`transfer_time`) relays through the host
    anyway, so the re-distribution rides it for free. Endpoints on two
    RANKS of one base system are distinct devices: their re-distribution
    rides the rank->rank boundary transfer (`transfer_hops` prices both
    host-DRAM-relay hops), so it is also not double-charged here."""
    if nbytes <= 0 or src_dev != dst_dev or not _is_pim(src_dev):
        return 0.0
    d = dpu or _dpu_system(src_dev)
    return nbytes / d.dpu_to_host_bw + nbytes / d.host_to_dpu_bw


def kv_migration_time(node: OpNode, device: str,
                      dpu: DPUModel | None = None) -> float:
    """Seconds of KV-residency traffic for placing `node` on `device`.

    Two terms, both zero when the node sits on the annotated home device:
    reads (`meta["kv_bytes"]`/`meta["kv_home"]`, the decode attention's
    resident cache) charge pulling the bytes *from* the home; writes
    (`meta["kv_write_bytes"]`/`meta["kv_write_home"]`, a prefill chunk's
    freshly produced KV rows) charge shipping the bytes back *to* the
    home. Both move over the measured channel (`transfer_time`)."""
    t = 0.0
    kv_bytes = float(node.meta.get("kv_bytes") or 0.0)
    home = node.meta.get("kv_home")
    if kv_bytes and home and home != device:
        t += transfer_time(home, device, kv_bytes, dpu)
    wb_bytes = float(node.meta.get("kv_write_bytes") or 0.0)
    wb_home = node.meta.get("kv_write_home")
    if wb_bytes and wb_home and wb_home != device:
        t += transfer_time(device, wb_home, wb_bytes, dpu)
    return t


def placed_time(node: OpNode, device: str,
                dpu: DPUModel | None = None) -> float:
    """node_time plus the KV-residency migration charge, in seconds — the
    per-(node, device) additive term every planner rung optimizes
    against."""
    return node_time(node, device, dpu) + kv_migration_time(node, device, dpu)


def launch_overhead(device: str, dpu: DPUModel | None = None) -> float:
    """Seconds to start work on `device` when the previous operator ran
    elsewhere (DPU program launch / kernel launch + host sync)."""
    if _is_pim(device):
        return (dpu or _dpu_system(device)).launch_overhead_s
    return _HOST_LAUNCH_S[device]


def cost_constants(dpu: DPUModel | None = None) -> dict[str, float]:
    """The calibratable cost-table anchors, name -> shipped value.

    One flat registry of every hand-anchored constant the planner's cost
    functions price with (the paper's Fig.-4/Table-style measurements),
    so `trace.calibrate.fit_trace` can report per-constant drift against
    a measured trace without reaching into three modules. Units by
    suffix: `*_bw` bytes/s, `*_flops` FLOP/s, `*_s` seconds,
    `*_scale` dimensionless (anchor 1.0)."""
    from .schedule import TRANSFER_SETUP_S  # local: schedule imports us
    d = dpu or UPMEM_2556
    return {
        "xeon.hbm_bw": MACHINES["xeon"].hbm_bw,
        "xeon.peak_flops": MACHINES["xeon"].peak_flops,
        "titan_v.hbm_bw": MACHINES["titan_v"].hbm_bw,
        "titan_v.peak_flops": MACHINES["titan_v"].peak_flops,
        "pcie.bw": PCIE_BW,
        "dpu.host_to_dpu_bw": d.host_to_dpu_bw,
        "dpu.dpu_to_host_bw": d.dpu_to_host_bw,
        "dpu.mram_bw": d.mram_bw,
        "dpu.launch_overhead_s": d.launch_overhead_s,
        "dpu.time_scale": 1.0,
        # separate multiplicative scale for int8-dominant PIM spans: the
        # int8 band prices the HW-multiplier path (pim_model.DPU_OP_COST),
        # so its drift is fit from int-band spans only (DESIGN.md §15)
        "dpu.int8_time_scale": 1.0,
        "channel.setup_s": TRANSFER_SETUP_S,
        "exchange.roundtrip_bw": 1.0 / (1.0 / d.dpu_to_host_bw
                                        + 1.0 / d.host_to_dpu_bw),
    }


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    """A full placement and its cost breakdown.

    `assignment` maps node name -> device name (the `DEVICES` vocabulary:
    `"xeon"`, `"titan_v"`, `"upmem_2556"`, `"upmem_640"`). All `*_s`
    fields are modeled seconds under the *serial* objective (the additive
    sum `evaluate` computes); when the plan was optimized for the
    schedule-aware objective, `objective == "overlapped"` and
    `overlapped_s` holds the `Schedule.overlapped_s` score it was chosen
    by (None for serial plans)."""
    graph_name: str
    assignment: dict[str, str]         # node name -> device
    method: str                        # dp | dag-dp | bnb | greedy | pure
    total_s: float
    compute_s: float
    transfer_s: float
    launch_s: float
    node_s: dict[str, float]
    migrate_s: float = 0.0             # KV-residency migration charges
    exchange_s: float = 0.0            # host-relayed bank exchanges (MoE)
    objective: str = "serial"          # which objective picked this plan
    overlapped_s: float | None = None  # Schedule score, overlapped plans

    @property
    def n_boundary_crossings(self) -> int:
        """Number of distinct producer->consumer device crossings."""
        return len({(u, v) for u, v in self._crossings})

    _crossings: list = dataclasses.field(default_factory=list, repr=False)

    @classmethod
    def stub(cls, graph_name: str, assignment: dict,
             method: str = "stub") -> "Plan":
        """A zero-cost Plan shell around a fixed assignment — what the
        executor and the trace replayer hand to `make_schedule` when only
        the placement matters, not the planner's cost breakdown (all
        `*_s` fields 0.0)."""
        return cls(graph_name=graph_name, assignment=dict(assignment),
                   method=method, total_s=0.0, compute_s=0.0,
                   transfer_s=0.0, launch_s=0.0, node_s={})

    def device_of(self, node: str) -> str:
        """Device name the plan assigns to `node`."""
        return self.assignment[node]

    @property
    def used_devices(self) -> tuple[str, ...]:
        """Sorted device names the plan actually places operators on."""
        return tuple(sorted(set(self.assignment.values())))

    @property
    def is_hybrid(self) -> bool:
        """True when the plan spans more than one device."""
        return len(set(self.assignment.values())) > 1

    def render(self) -> str:
        """Multi-line human-readable plan listing (milliseconds per term)."""
        lines = [f"plan[{self.graph_name}] method={self.method} "
                 f"total={self.total_s * 1e3:.3f}ms  "
                 f"(compute {self.compute_s * 1e3:.3f} + transfer "
                 f"{self.transfer_s * 1e3:.3f} + launch "
                 f"{self.launch_s * 1e3:.3f} + kv-migrate "
                 f"{self.migrate_s * 1e3:.3f} + exchange "
                 f"{self.exchange_s * 1e3:.3f})"]
        for node, dev in self.assignment.items():
            lines.append(f"  {node:28s} -> {dev:12s} "
                         f"{self.node_s[node] * 1e6:10.1f}us")
        return "\n".join(lines)


def evaluate(graph: OpGraph, assignment: dict[str, str],
             dpu: DPUModel | None = None, source: str = "xeon",
             sink: str = "xeon", method: str = "fixed") -> Plan:
    """Cost a full assignment: node times + boundary transfers + launches.

    This is the single source of truth the DP optimizes against — launches
    are charged whenever the topological predecessor ran elsewhere (i.e.
    consecutive same-device operators coalesce into one launch)."""
    order = graph.topo_order()
    preds = graph.preds
    succs = graph.succs
    node_s, compute, migrate = {}, 0.0, 0.0
    for n in order:
        t = node_time(graph.nodes[n], assignment[n], dpu)
        m = kv_migration_time(graph.nodes[n], assignment[n], dpu)
        node_s[n] = t + m
        compute += t
        migrate += m

    # exchange edges: bank re-distribution relays through the host even
    # when both endpoints share a PIM device (per-edge, no dedup — every
    # exchange is its own all-to-all)
    exchange = sum(
        exchange_time(assignment[u], assignment[v], b, dpu)
        for (u, v), b in graph.exchange_edges.items())

    transfer, crossings = 0.0, []
    roots = [n for n in order if not preds[n]]
    for r in roots:
        t = transfer_time(source, assignment[r],
                          graph.input_bytes / max(len(roots), 1), dpu)
        transfer += t
        if t:
            crossings.append((source, r))
    # a producer's tensor crosses to a given device once, no matter how
    # many ops consume it there
    seen: set[tuple[str, str]] = set()
    for u, v in graph.edges:
        key = (u, assignment[v])
        if key in seen:
            continue
        seen.add(key)
        t = transfer_time(assignment[u], assignment[v],
                          graph.nodes[u].out_bytes, dpu)
        transfer += t
        if t:
            crossings.append((u, v))
    for leaf in (n for n in order if not succs[n]):
        t = transfer_time(assignment[leaf], sink,
                          graph.nodes[leaf].out_bytes, dpu)
        transfer += t
        if t:
            crossings.append((leaf, sink))

    launch, prev_dev = 0.0, None
    for n in order:
        if assignment[n] != prev_dev:
            launch += launch_overhead(assignment[n], dpu)
        prev_dev = assignment[n]

    return Plan(graph_name=graph.name, assignment=dict(assignment),
                method=method,
                total_s=compute + transfer + launch + migrate + exchange,
                compute_s=compute, transfer_s=transfer, launch_s=launch,
                node_s=node_s, migrate_s=migrate, exchange_s=exchange,
                _crossings=crossings)


def _resolve(devices: Iterable[str]) -> tuple[tuple[str, ...], DPUModel | None]:
    """Validate a planner device set: any number of host-class devices
    plus any number of RANKS of at most one UPMEM base system (ranks of
    two different bases would need two DPU models per plan)."""
    devices = tuple(devices)
    bases: set[str] = set()
    for d in devices:
        base, r = device_rank(d)
        if base not in DEVICES:
            raise ValueError(f"unknown device {d!r} (know {DEVICES})")
        if r and not _is_pim(base):
            raise ValueError(f"only UPMEM systems have ranks, got {d!r}")
        if _is_pim(base):
            bases.add(base)
    if len(bases) > 1:
        raise ValueError(f"at most one UPMEM system per plan, "
                         f"got {sorted(bases)}")
    base = next(iter(bases), None)
    return devices, (_DPU_SYSTEMS[base] if base else None)


def plan(graph: OpGraph, devices: Iterable[str] = ("xeon", "upmem_2556"),
         source: str = "xeon", sink: str = "xeon", *,
         state_budget: int = 200_000, bnb_budget: int = 200_000,
         objective: str = "serial") -> Plan:
    """Minimize modeled end-to-end latency (seconds) over per-operator
    placements.

    `objective="serial"` (default) minimizes the additive sum `evaluate`
    computes, via the fallback ladder (module docstring): chain DP when
    the graph is a chain; otherwise the exact frontier DP while its
    per-step state count stays under `state_budget`; otherwise
    branch-and-bound limited to `bnb_budget` node expansions, seeded with
    the greedy incumbent (so the result is never worse than greedy).

    `objective="overlapped"` scores candidate plans by the *scheduler's*
    modeled wall-clock instead — `Schedule.overlapped_s`, which credits
    batched parallel transfers double-buffering under each launch group's
    compute (relay hops and KV write-backs stay serialized). Chains are
    planned exactly (DP over launch-group aggregates, method
    `"dp-overlap"`); elsewhere the serial ladder's plan seeds a
    deterministic coordinate-descent search over single-node device
    moves, so the returned plan's `overlapped_s` is never worse than
    scheduling the serial-objective plan (pinned in
    tests/test_golden_plans.py)."""
    if objective not in ("serial", "overlapped"):
        raise ValueError(f"objective must be 'serial' or 'overlapped', "
                         f"got {objective!r}")
    devices, dpu = _resolve(devices)
    if objective == "overlapped" and graph.is_chain:
        # exact rung: the serial ladder's assignment would be discarded
        return _plan_chain_overlapped_dp(graph, devices, dpu, source, sink)
    if graph.is_chain:
        assignment = _plan_chain_dp(graph, devices, dpu, source, sink)
        method = "dp"
    else:
        assignment = _plan_dag_frontier_dp(graph, devices, dpu, source,
                                           sink, state_budget)
        method = "dag-dp"
        if assignment is None:
            assignment = _plan_dag_bnb(graph, devices, dpu, source, sink,
                                       bnb_budget)
            method = "bnb"
    if objective == "overlapped":
        return _refine_overlapped(graph, assignment, devices, dpu,
                                  source, sink, method)
    return evaluate(graph, assignment, dpu, source, sink, method=method)


def greedy_plan(graph: OpGraph,
                devices: Iterable[str] = ("xeon", "upmem_2556"),
                source: str = "xeon", sink: str = "xeon") -> Plan:
    """The ladder's floor, exposed for bound tests and B&B seeding."""
    devices, dpu = _resolve(devices)
    assignment = _plan_greedy(graph, devices, dpu, source)
    return evaluate(graph, assignment, dpu, source, sink, method="greedy")


def pure_plan(graph: OpGraph, device: str, source: str = "xeon",
              sink: str = "xeon") -> Plan:
    """Baseline: every operator on one device (one coalesced launch)."""
    assignment = {n: device for n in graph.nodes}
    return evaluate(graph, assignment,
                    _dpu_system(device) if _is_pim(device) else None,
                    source, sink, method="pure")


def _plan_chain_dp(graph: OpGraph, devices: tuple[str, ...],
                   dpu: DPUModel | None, source: str,
                   sink: str) -> dict[str, str]:
    order = graph.chain()
    n0 = order[0]
    cost = {d: transfer_time(source, d, graph.input_bytes, dpu)
            + launch_overhead(d, dpu)
            + placed_time(graph.nodes[n0], d, dpu) for d in devices}
    back: list[dict[str, str]] = []
    for i in range(1, len(order)):
        node, prev = graph.nodes[order[i]], graph.nodes[order[i - 1]]
        ex_b = graph.exchange_edges.get((order[i - 1], order[i]), 0.0)
        nxt, choice = {}, {}
        for d in devices:
            t_node = placed_time(node, d, dpu)
            best, best_p = float("inf"), devices[0]
            for p in devices:
                c = cost[p] + transfer_time(p, d, prev.out_bytes, dpu) \
                    + exchange_time(p, d, ex_b, dpu) \
                    + (launch_overhead(d, dpu) if d != p else 0.0) + t_node
                if c < best:
                    best, best_p = c, p
            nxt[d], choice[d] = best, best_p
        cost = nxt
        back.append(choice)
    last = graph.nodes[order[-1]]
    final = {d: cost[d] + transfer_time(d, sink, last.out_bytes, dpu)
             for d in devices}
    d = min(final, key=final.get)
    assignment = {order[-1]: d}
    for i in range(len(order) - 1, 0, -1):
        d = back[i - 1][d]
        assignment[order[i - 1]] = d
    return {n: assignment[n] for n in order}


def _plan_greedy(graph: OpGraph, devices: tuple[str, ...],
                 dpu: DPUModel | None, source: str) -> dict[str, str]:
    """Topological sweep; each operator takes the device minimizing its own
    time + incoming transfers + (launch if no predecessor is there)."""
    assignment: dict[str, str] = {}
    preds = graph.preds
    for n in graph.topo_order():
        node = graph.nodes[n]
        best, best_d = float("inf"), devices[0]
        for d in devices:
            c = placed_time(node, d, dpu)
            if preds[n]:
                for p in preds[n]:
                    c += transfer_time(assignment[p], d,
                                       graph.nodes[p].out_bytes, dpu)
                    c += exchange_time(
                        assignment[p], d,
                        graph.exchange_edges.get((p, n), 0.0), dpu)
                if all(assignment[p] != d for p in preds[n]):
                    c += launch_overhead(d, dpu)
            else:
                c += transfer_time(source, d, graph.input_bytes, dpu)
                c += launch_overhead(d, dpu)
            if c < best:
                best, best_d = c, d
        assignment[n] = best_d
    return assignment


# ---------------------------------------------------------------------------
# exact DAG planning: frontier DP + bounded branch-and-bound
# ---------------------------------------------------------------------------

class _DagWalk:
    """Incremental evaluation of `evaluate`'s objective along the fixed
    topological order. The walk state is the *frontier*: producers already
    placed whose tensors are still awaited by an unprocessed consumer, each
    carrying (device, set of devices already shipped to) — exactly the
    information `evaluate`'s transfer dedup key `(producer, dest_device)`
    needs. Summing `step` deltas over the order reproduces `evaluate`'s
    total for the same assignment."""

    def __init__(self, graph: OpGraph, dpu: DPUModel | None,
                 source: str, sink: str):
        self.graph = graph
        self.dpu = dpu
        self.source, self.sink = source, sink
        self.order = graph.topo_order()
        self.preds = graph.preds
        self.succs = graph.succs
        self.n_roots = max(sum(1 for n in self.order if not self.preds[n]), 1)
        # when the walk passes a producer's last consumer it leaves the
        # frontier (shared bookkeeping with OpGraph.max_frontier)
        self.last_use = graph.last_use_positions(self.order)

    def step(self, idx: int, d: str, prev: str | None,
             open_map: dict[str, tuple[str, frozenset]],
             ) -> tuple[float, dict[str, tuple[str, frozenset]]]:
        """Cost of placing order[idx] on `d` given the frontier, and the
        frontier after the step."""
        v = self.order[idx]
        node = self.graph.nodes[v]
        c = placed_time(node, d, self.dpu)
        if d != prev:
            c += launch_overhead(d, self.dpu)
        new_open = dict(open_map)
        if not self.preds[v]:
            c += transfer_time(self.source, d,
                               self.graph.input_bytes / self.n_roots,
                               self.dpu)
        for u in self.preds[v]:
            du, shipped = new_open[u]
            if d not in shipped:
                c += transfer_time(du, d, self.graph.nodes[u].out_bytes,
                                   self.dpu)
                new_open[u] = (du, shipped | {d})
            # exchange edges are per-edge (no dedup): every exchange is
            # its own host-relayed bank re-distribution
            c += exchange_time(du, d,
                               self.graph.exchange_edges.get((u, v), 0.0),
                               self.dpu)
        if not self.succs[v]:
            c += transfer_time(d, self.sink, node.out_bytes, self.dpu)
        for u in self.preds[v]:
            if self.last_use[u] == idx:
                del new_open[u]
        if self.succs[v]:
            # pre-seed the producer's own device: shipping to it is free,
            # so this merges cost-equivalent DP states instead of keeping
            # ({}, {d}) duplicates that double the frontier state count
            new_open[v] = (d, frozenset((d,)))
        return c, new_open


def _freeze(open_map: dict[str, tuple[str, frozenset]]) -> frozenset:
    return frozenset((n, d, s) for n, (d, s) in open_map.items())


def _plan_dag_frontier_dp(graph: OpGraph, devices: tuple[str, ...],
                          dpu: DPUModel | None, source: str, sink: str,
                          state_budget: int) -> dict[str, str] | None:
    """Exact DP over (frontier state, previous device) along the topo
    order. State count is ~ |devices|^frontier_width, so series-parallel /
    out-tree-like graphs (decode DAG: width <= 2) stay tiny; returns None
    when a step exceeds `state_budget` states (wide general DAGs)."""
    walk = _DagWalk(graph, dpu, source, sink)
    # layers[i]: state key -> (cost, previous key, device placed at step i-1)
    start_key = (None, frozenset())
    layers: list[dict[tuple, tuple[float, tuple | None, str | None]]] = [
        {start_key: (0.0, None, None)}]
    total_states = 1                   # budget caps the SUM across steps
    for idx in range(len(walk.order)):
        nxt: dict[tuple, tuple[float, tuple | None, str | None]] = {}
        for key, (cost, _, _) in layers[-1].items():
            prev, open_key = key
            open_map = {n: (d, s) for n, d, s in open_key}
            for d in devices:
                dc, new_open = walk.step(idx, d, prev, open_map)
                nk = (d, _freeze(new_open))
                c = cost + dc
                if nk not in nxt or c < nxt[nk][0]:
                    nxt[nk] = (c, key, d)
            if total_states + len(nxt) > state_budget:
                return None            # every retained layer counts: the
                                       # back-pointer tables are what the
                                       # budget is actually bounding
        total_states += len(nxt)
        layers.append(nxt)
    key = min(layers[-1], key=lambda k: layers[-1][k][0])
    assignment: dict[str, str] = {}
    for idx in range(len(walk.order), 0, -1):
        _, prev_key, d = layers[idx][key]
        assignment[walk.order[idx - 1]] = d
        key = prev_key
    return assignment


def _plan_dag_bnb(graph: OpGraph, devices: tuple[str, ...],
                  dpu: DPUModel | None, source: str, sink: str,
                  bnb_budget: int) -> dict[str, str]:
    """Depth-first branch-and-bound along the topo order.

    Incumbent = the greedy sweep (so the returned assignment never costs
    more than greedy's); lower bound = prefix cost + sum of each remaining
    node's cheapest placed_time (admissible: transfers and launches are
    non-negative). Stops refining after `bnb_budget` expansions."""
    walk = _DagWalk(graph, dpu, source, sink)
    n = len(walk.order)
    suffix_lb = [0.0] * (n + 1)
    for i in range(n - 1, -1, -1):
        node = graph.nodes[walk.order[i]]
        suffix_lb[i] = suffix_lb[i + 1] + min(
            placed_time(node, d, dpu) for d in devices)

    best = _plan_greedy(graph, devices, dpu, source)
    best_cost = evaluate(graph, best, dpu, source, sink).total_s
    expansions = 0

    # iterative DFS: (idx, prev device, frontier, prefix cost, assignment)
    stack = [(0, None, {}, 0.0, {})]
    while stack and expansions < bnb_budget:
        idx, prev, open_map, cost, assign = stack.pop()
        if idx == n:
            if cost < best_cost:
                best_cost, best = cost, assign
            continue
        children = []
        for d in devices:
            expansions += 1
            dc, new_open = walk.step(idx, d, prev, open_map)
            c = cost + dc
            if c + suffix_lb[idx + 1] >= best_cost - 1e-15:
                continue
            children.append((c, (idx + 1, d, new_open, c,
                                 {**assign, walk.order[idx]: d})))
        # cheapest child explored first (LIFO: push in reverse)
        for _, child in sorted(children, key=lambda t: t[0], reverse=True):
            stack.append(child)
    return best


# ---------------------------------------------------------------------------
# schedule-aware objective (objective="overlapped")
# ---------------------------------------------------------------------------

def _overlapped_score(graph: OpGraph, assignment: dict[str, str],
                      dpu: DPUModel | None, source: str,
                      sink: str) -> float:
    """`Schedule.overlapped_s` (seconds) of an assignment: the scheduler's
    modeled wall-clock with batched transfers double-buffered under each
    launch group's compute. The scheduler reads only the assignment, so
    the trial plan is a zero-cost stub — the coordinate descent calls
    this O(passes * nodes * devices) times and a full `evaluate` per
    trial would double its cost. Local import: schedule imports
    placement."""
    from .schedule import make_schedule
    stub = Plan(graph_name=graph.name, assignment=assignment,
                method="trial", total_s=0.0, compute_s=0.0,
                transfer_s=0.0, launch_s=0.0, node_s={})
    return make_schedule(graph, stub, dpu, source, sink).overlapped_s


def _refine_overlapped(graph: OpGraph, seed: dict[str, str],
                       devices: tuple[str, ...], dpu: DPUModel | None,
                       source: str, sink: str, method: str,
                       max_passes: int = 4) -> Plan:
    """Pick the assignment minimizing `Schedule.overlapped_s`.

    Candidates: the serial ladder's plan (`seed`), every pure placement,
    and the greedy sweep; the best then seeds a deterministic coordinate
    descent — sweep the topological order, move one node at a time to the
    device that most improves the schedule score, until a full pass makes
    no move (or `max_passes`). The seed is always in the candidate set,
    so the result is never worse (under overlapped_s) than scheduling the
    serial-objective plan. Exhaustive for one-operator graphs (the
    Hamming-1 neighborhood is the whole space); a heuristic elsewhere —
    the overlap max() couples non-adjacent operators, which breaks the DP
    decompositions the serial ladder's exactness rests on (DESIGN §10)."""
    candidates = [dict(seed), _plan_greedy(graph, devices, dpu, source)]
    candidates += [{n: d for n in graph.nodes} for d in devices]
    scored = [(_overlapped_score(graph, a, dpu, source, sink), i, a)
              for i, a in enumerate(candidates)]
    best_s, _, best = min(scored)

    order = graph.topo_order()
    for _ in range(max_passes):
        moved = False
        for n in order:
            cur = best[n]
            for d in devices:
                if d == cur:
                    continue
                trial = dict(best)
                trial[n] = d
                s = _overlapped_score(graph, trial, dpu, source, sink)
                if s < best_s - 1e-15:
                    best_s, best, moved = s, trial, True
                    cur = d
        if not moved:
            break

    p = evaluate(graph, best, dpu, source, sink,
                 method=f"{method}+overlap")
    p.objective = "overlapped"
    p.overlapped_s = best_s
    return p


def _plan_chain_overlapped_dp(graph: OpGraph, devices: tuple[str, ...],
                              dpu: DPUModel | None, source: str,
                              sink: str) -> Plan:
    """EXACT overlapped-objective planning for chain graphs: DP over
    launch-group aggregates.

    The overlap `max(compute, transfer - relay)` couples every operator
    inside a launch group, which is what breaks the serial chain DP
    (its per-position state cannot carry an unbounded group's running
    sums). But a *group boundary* resets those sums — so for a chain the
    DP can walk group extents instead of single nodes: `best[j][d]` is
    the cheapest schedule of the first `j` operators whose last group
    runs on `d`, and a transition extends a candidate group `[i, j)` on
    `d != p` one node at a time, maintaining the group's aggregates
    (compute, batched-transfer payload + per-channel setups, relay,
    KV write-backs) in O(1) — exactly the algebra `make_schedule` books
    per `LaunchGroup`, so the DP's objective IS `Schedule.overlapped_s`
    (asserted in tests against both the scheduler and brute force).
    O(n^2 * |devices|^2) over the chain length; method `"dp-overlap"`."""
    # local import: schedule imports placement (same pattern as
    # _overlapped_score)
    from .schedule import TRANSFER_SETUP_S
    order = graph.chain()
    n = len(order)
    INF = float("inf")
    # best[j]: device of the group ending at j-1 -> (cost, back-pointer);
    # the back-pointer is (group start i, previous group's device)
    best: list[dict[str | None, float]] = [{} for _ in range(n + 1)]
    back: list[dict[str | None, tuple[int, str | None]]] = \
        [{} for _ in range(n + 1)]
    best[0] = {None: 0.0}
    for i in range(n):                     # group start position
        for p, base in best[i].items():
            if i and p is None:
                continue
            for d in devices:
                if d == p:                 # maximal runs: groups alternate
                    continue
                compute = payload = relay = wb = exch = 0.0
                srcs: set[str] = set()
                n_wb = 0
                if i == 0:
                    if graph.input_bytes and d != source:
                        payload += transfer_time(source, d,
                                                 graph.input_bytes, dpu)
                        relay += transfer_hops(source, d,
                                               graph.input_bytes, dpu)[0]
                        srcs.add(source)
                else:
                    prev = graph.nodes[order[i - 1]]
                    payload += transfer_time(p, d, prev.out_bytes, dpu)
                    relay += transfer_hops(p, d, prev.out_bytes, dpu)[0]
                    srcs.add(p)
                launch = launch_overhead(d, dpu)
                for j in range(i, n):      # extend the group to order[j]
                    node = graph.nodes[order[j]]
                    compute += node_time(node, d, dpu)
                    if j > i:              # intra-group exchange edges
                        ex_t = exchange_time(
                            d, d,
                            graph.exchange_edges.get((order[j - 1],
                                                      order[j]), 0.0), dpu)
                        if ex_t:           # channel-only: push + pull call
                            exch += ex_t + 2 * TRANSFER_SETUP_S
                    kv_b = float(node.meta.get("kv_bytes") or 0.0)
                    kv_h = node.meta.get("kv_home")
                    if kv_b and kv_h and kv_h != d:
                        payload += transfer_time(kv_h, d, kv_b, dpu)
                        relay += transfer_hops(kv_h, d, kv_b, dpu)[0]
                        srcs.add(kv_h)
                    wb_b = float(node.meta.get("kv_write_bytes") or 0.0)
                    wb_h = node.meta.get("kv_write_home")
                    if wb_b and wb_h and wb_h != d:
                        wb += transfer_time(d, wb_h, wb_b, dpu)
                        n_wb += 1
                    in_transfer = len(srcs) * TRANSFER_SETUP_S + payload
                    group_s = relay + max(compute, in_transfer - relay) \
                        + launch + wb + (TRANSFER_SETUP_S if n_wb else 0.0) \
                        + exch
                    c = base + group_s
                    if c < best[j + 1].get(d, INF):
                        best[j + 1][d] = c
                        back[j + 1][d] = (i, p)
    last = graph.nodes[order[-1]]
    final: dict[str, float] = {}
    for d, c in best[n].items():
        t = transfer_time(d, sink, last.out_bytes, dpu)
        final[d] = c + (t + TRANSFER_SETUP_S if t else 0.0)
    d = min(sorted(final), key=final.get)
    score = final[d]
    assignment: dict[str, str] = {}
    pos = n
    while pos > 0:
        i, p = back[pos][d]
        for k in range(i, pos):
            assignment[order[k]] = d
        pos, d = i, p
    p = evaluate(graph, {m: assignment[m] for m in order}, dpu, source,
                 sink, method="dp-overlap")
    p.objective = "overlapped"
    p.overlapped_s = score
    return p


def compare_plans(graph: OpGraph,
                  devices: Iterable[str] = ("xeon", "upmem_2556"),
                  pim: str = "upmem_2556") -> dict[str, Plan]:
    """The paper's Fig.-4 question asked end-to-end: pure-CPU vs pure-PIM
    vs the planner's hybrid, on one operator graph."""
    return {
        "pure_cpu": pure_plan(graph, "xeon"),
        "pure_pim": pure_plan(graph, pim),
        "hybrid": plan(graph, devices=devices),
    }
