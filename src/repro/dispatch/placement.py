"""Offload placement: assign every operator to a device, minimizing modeled
end-to-end latency.

Devices are the paper's measured systems (`core.pim_model`): the Xeon host,
the Titan V, and one UPMEM system. Per-node costs come straight from the
calibrated models — `DPUModel.compute_time`/`mram_time`/`interdpu_time` for
PIM, the roofline `max(flops/peak, bytes/bw)` for host-class machines (the
same arithmetic as `perf_model.time_on_pim`/`time_on_host`, at operator
granularity). Crossing a device boundary charges the producer's `out_bytes`
over the measured channel: the UPMEM parallel-transfer bandwidths for
host<->DPU, PCIe for host<->GPU, and both hops for GPU<->DPU (all DPU
traffic goes through the host — Takeaway 3).

Entering a device also pays that device's launch overhead *unless the
previous operator already ran there* — so the optimizer itself discovers
the paper's launch-coalescing recommendation: consecutive PIM operators
merge into one DPU launch.

For chain graphs (every pipeline in `dispatch.workloads`) the planner runs
exact dynamic programming over (node, device); for general DAGs it falls
back to a greedy topological sweep. Weights/params are treated as
device-resident (weight-stationary serving): only activations cross
boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from ..core.pim_model import DPUModel, MACHINES, UPMEM_2556, UPMEM_640
from .graph import OpGraph, OpNode

#: every placeable device; at most one upmem_* system per plan
DEVICES = ("xeon", "titan_v", "upmem_2556", "upmem_640")

#: Titan V PCIe 3.0 x16 effective host<->GPU bandwidth
PCIE_BW = 12e9

#: fixed cost of starting work on a device when the previous operator ran
#: elsewhere (kernel launch / DPU program launch + host sync)
_HOST_LAUNCH_S = {"xeon": 0.0, "titan_v": 2e-5}

_DPU_SYSTEMS = {"upmem_2556": UPMEM_2556, "upmem_640": UPMEM_640}


def _is_pim(device: str) -> bool:
    return device.startswith("upmem")


def node_time(node: OpNode, device: str,
              dpu: DPUModel | None = None) -> float:
    """Modeled seconds for one operator on one device (no transfers)."""
    if _is_pim(device):
        d = dpu or _DPU_SYSTEMS[device]
        per_dpu = {k: v / d.n_dpus for k, v in node.ops.items()}
        t_c = d.compute_time(per_dpu)
        t_m = d.mram_time(node.hbm_bytes / d.n_dpus)
        # MRAM DMA overlaps compute across tasklets; inter-bank traffic
        # serializes through the host channel (Takeaway 3)
        return max(t_c, t_m) + d.interdpu_time(node.exchange_bytes)
    m = MACHINES[device]
    nbytes = node.hbm_bytes
    if device == "xeon" and node.meta.get("bytes_cpu"):
        nbytes = node.meta["bytes_cpu"]         # e.g. TRNS strided writes
    if device == "titan_v" and node.meta.get("bytes_gpu"):
        nbytes = node.meta["bytes_gpu"]
    return max(node.flops / m.peak_flops, nbytes / m.hbm_bw)


def transfer_time(src: str, dst: str, nbytes: float,
                  dpu: DPUModel | None = None) -> float:
    """Seconds to move nbytes from src's memory to dst's memory."""
    if src == dst or nbytes <= 0:
        return 0.0
    d = dpu or UPMEM_2556
    t = 0.0
    if _is_pim(src):
        t += nbytes / d.dpu_to_host_bw
    if _is_pim(dst):
        t += nbytes / d.host_to_dpu_bw
    if "titan_v" in (src, dst):
        t += nbytes / PCIE_BW
    return t


def launch_overhead(device: str, dpu: DPUModel | None = None) -> float:
    if _is_pim(device):
        return (dpu or _DPU_SYSTEMS[device]).launch_overhead_s
    return _HOST_LAUNCH_S[device]


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    graph_name: str
    assignment: dict[str, str]         # node name -> device
    method: str                        # dp | greedy | pure
    total_s: float
    compute_s: float
    transfer_s: float
    launch_s: float
    node_s: dict[str, float]

    @property
    def n_boundary_crossings(self) -> int:
        return len({(u, v) for u, v in self._crossings})

    _crossings: list = dataclasses.field(default_factory=list, repr=False)

    def device_of(self, node: str) -> str:
        return self.assignment[node]

    @property
    def used_devices(self) -> tuple[str, ...]:
        return tuple(sorted(set(self.assignment.values())))

    @property
    def is_hybrid(self) -> bool:
        return len(set(self.assignment.values())) > 1

    def render(self) -> str:
        lines = [f"plan[{self.graph_name}] method={self.method} "
                 f"total={self.total_s * 1e3:.3f}ms  "
                 f"(compute {self.compute_s * 1e3:.3f} + transfer "
                 f"{self.transfer_s * 1e3:.3f} + launch "
                 f"{self.launch_s * 1e3:.3f})"]
        for node, dev in self.assignment.items():
            lines.append(f"  {node:28s} -> {dev:12s} "
                         f"{self.node_s[node] * 1e6:10.1f}us")
        return "\n".join(lines)


def evaluate(graph: OpGraph, assignment: dict[str, str],
             dpu: DPUModel | None = None, source: str = "xeon",
             sink: str = "xeon", method: str = "fixed") -> Plan:
    """Cost a full assignment: node times + boundary transfers + launches.

    This is the single source of truth the DP optimizes against — launches
    are charged whenever the topological predecessor ran elsewhere (i.e.
    consecutive same-device operators coalesce into one launch)."""
    order = graph.topo_order()
    preds = graph.preds
    succs = graph.succs
    node_s, compute = {}, 0.0
    for n in order:
        t = node_time(graph.nodes[n], assignment[n], dpu)
        node_s[n] = t
        compute += t

    transfer, crossings = 0.0, []
    roots = [n for n in order if not preds[n]]
    for r in roots:
        t = transfer_time(source, assignment[r],
                          graph.input_bytes / max(len(roots), 1), dpu)
        transfer += t
        if t:
            crossings.append((source, r))
    # a producer's tensor crosses to a given device once, no matter how
    # many ops consume it there
    seen: set[tuple[str, str]] = set()
    for u, v in graph.edges:
        key = (u, assignment[v])
        if key in seen:
            continue
        seen.add(key)
        t = transfer_time(assignment[u], assignment[v],
                          graph.nodes[u].out_bytes, dpu)
        transfer += t
        if t:
            crossings.append((u, v))
    for leaf in (n for n in order if not succs[n]):
        t = transfer_time(assignment[leaf], sink,
                          graph.nodes[leaf].out_bytes, dpu)
        transfer += t
        if t:
            crossings.append((leaf, sink))

    launch, prev_dev = 0.0, None
    for n in order:
        if assignment[n] != prev_dev:
            launch += launch_overhead(assignment[n], dpu)
        prev_dev = assignment[n]

    return Plan(graph_name=graph.name, assignment=dict(assignment),
                method=method, total_s=compute + transfer + launch,
                compute_s=compute, transfer_s=transfer, launch_s=launch,
                node_s=node_s, _crossings=crossings)


def _resolve(devices: Iterable[str]) -> tuple[tuple[str, ...], DPUModel | None]:
    devices = tuple(devices)
    pim = [d for d in devices if _is_pim(d)]
    if len(pim) > 1:
        raise ValueError(f"at most one UPMEM system per plan, got {pim}")
    for d in devices:
        if d not in DEVICES:
            raise ValueError(f"unknown device {d!r} (know {DEVICES})")
    return devices, (_DPU_SYSTEMS[pim[0]] if pim else None)


def plan(graph: OpGraph, devices: Iterable[str] = ("xeon", "upmem_2556"),
         source: str = "xeon", sink: str = "xeon") -> Plan:
    """Minimize modeled end-to-end latency over per-operator placements.

    Exact DP over (position, device) when the graph is a chain — the cost
    structure (node + boundary transfer + coalesced launch) only couples
    adjacent operators, so the chain DP is optimal. Greedy topological
    sweep otherwise."""
    devices, dpu = _resolve(devices)
    if graph.is_chain:
        assignment = _plan_chain_dp(graph, devices, dpu, source, sink)
        method = "dp"
    else:
        assignment = _plan_greedy(graph, devices, dpu, source)
        method = "greedy"
    return evaluate(graph, assignment, dpu, source, sink, method=method)


def pure_plan(graph: OpGraph, device: str, source: str = "xeon",
              sink: str = "xeon") -> Plan:
    """Baseline: every operator on one device (one coalesced launch)."""
    assignment = {n: device for n in graph.nodes}
    return evaluate(graph, assignment, _DPU_SYSTEMS.get(device),
                    source, sink, method="pure")


def _plan_chain_dp(graph: OpGraph, devices: tuple[str, ...],
                   dpu: DPUModel | None, source: str,
                   sink: str) -> dict[str, str]:
    order = graph.chain()
    n0 = order[0]
    cost = {d: transfer_time(source, d, graph.input_bytes, dpu)
            + launch_overhead(d, dpu)
            + node_time(graph.nodes[n0], d, dpu) for d in devices}
    back: list[dict[str, str]] = []
    for i in range(1, len(order)):
        node, prev = graph.nodes[order[i]], graph.nodes[order[i - 1]]
        nxt, choice = {}, {}
        for d in devices:
            t_node = node_time(node, d, dpu)
            best, best_p = float("inf"), devices[0]
            for p in devices:
                c = cost[p] + transfer_time(p, d, prev.out_bytes, dpu) \
                    + (launch_overhead(d, dpu) if d != p else 0.0) + t_node
                if c < best:
                    best, best_p = c, p
            nxt[d], choice[d] = best, best_p
        cost = nxt
        back.append(choice)
    last = graph.nodes[order[-1]]
    final = {d: cost[d] + transfer_time(d, sink, last.out_bytes, dpu)
             for d in devices}
    d = min(final, key=final.get)
    assignment = {order[-1]: d}
    for i in range(len(order) - 1, 0, -1):
        d = back[i - 1][d]
        assignment[order[i - 1]] = d
    return {n: assignment[n] for n in order}


def _plan_greedy(graph: OpGraph, devices: tuple[str, ...],
                 dpu: DPUModel | None, source: str) -> dict[str, str]:
    """Topological sweep; each operator takes the device minimizing its own
    time + incoming transfers + (launch if no predecessor is there)."""
    assignment: dict[str, str] = {}
    preds = graph.preds
    for n in graph.topo_order():
        node = graph.nodes[n]
        best, best_d = float("inf"), devices[0]
        for d in devices:
            c = node_time(node, d, dpu)
            if preds[n]:
                for p in preds[n]:
                    c += transfer_time(assignment[p], d,
                                       graph.nodes[p].out_bytes, dpu)
                if all(assignment[p] != d for p in preds[n]):
                    c += launch_overhead(d, dpu)
            else:
                c += transfer_time(source, d, graph.input_bytes, dpu)
                c += launch_overhead(d, dpu)
            if c < best:
                best, best_d = c, d
        assignment[n] = best_d
    return assignment


def compare_plans(graph: OpGraph,
                  devices: Iterable[str] = ("xeon", "upmem_2556"),
                  pim: str = "upmem_2556") -> dict[str, Plan]:
    """The paper's Fig.-4 question asked end-to-end: pure-CPU vs pure-PIM
    vs the planner's hybrid, on one operator graph."""
    return {
        "pure_cpu": pure_plan(graph, "xeon"),
        "pure_pim": pure_plan(graph, pim),
        "hybrid": plan(graph, devices=devices),
    }
