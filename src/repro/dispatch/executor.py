"""Unified plan executor: run the planner's Schedule, not a serial loop.

The planner chooses a `Plan`, the scheduler turns it into a `Schedule`
timeline of launch groups — and until this module existed, the serving
steps ignored both and re-ran their own private serial stage loops, so
the overlap the planner optimized (`Schedule.overlapped_s`) never shaped
what actually executed. `PlanExecutor` closes that gap: it is the ONE
execution loop for any plan over any operator DAG, and it walks the
schedule's launch groups in timeline order.

Three pieces:

  * `StageDef` — one stage *kind* (e.g. `"qkv"`): the host body plus the
    per-argument/per-output bank-shard axes that define its PIM face
    (`None` replicates — weights, the KV prefix; an integer shards that
    axis over banks — decode shards batch slots on axis 0, prefill shards
    a chunk's token rows on axis 1).
  * `FaceCache` — compiled faces per kind, shared across executors: host
    faces are per-stage jits (one trace per kind, all layers/chunks share
    it), PIM faces are jitted `shard_map` local phases over the BankGrid
    (built lazily — grid lowering). Sharing the cache is what keeps a
    ragged prompt's per-split executors from re-tracing every stage.
  * `PlanExecutor` — binds a graph + assignment to the `Schedule` group
    timeline and runs it: for each group, consume staged inputs, dispatch
    every member stage on the group's device, then *stage the next
    group's boundary tensors* (`LaunchGroup.in_producers`) while this
    group's async dispatch is still in flight — the batched transfer
    issued ahead of the group that consumes it, double-buffered through
    two staging slots whose previous buffers are dropped (donated) on
    reuse. Relay hops and KV write-backs keep the serialization
    `schedule.py` books for them — the executor never reorders nodes
    across their graph dependencies, it only follows the timeline.

The caller supplies a `bind(name, env)` callback mapping a node name and
the environment of prior results to the stage's argument tuple — that is
the whole workload-specific surface, which is why
`serve.dispatch_engine`'s decode and prefill steps are thin adapters over
this module (DESIGN.md §11). Executing the timeline is a pure
reordering of independent stages, so results are bitwise identical to
any serial execution of the same faces.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Sequence

import jax
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from .graph import OpGraph
from .placement import Plan
from .schedule import Schedule, make_schedule
from .workloads import stage_kind


@dataclasses.dataclass(frozen=True)
class StageDef:
    """One executable stage kind: host body + PIM-face shard axes.

    `arg_banks` / `out_banks` give, per argument / per output, the axis
    that shards over the BankGrid's banks (`None` replicates). The PIM
    face is usable for a call only when every sharded argument's axis
    length divides the bank count — otherwise the executor falls back to
    the host face (ragged prefill tails)."""
    kind: str
    fn: Callable
    arg_banks: tuple[int | None, ...]
    out_banks: tuple[int | None, ...]

    @property
    def n_out(self) -> int:
        """Number of outputs the stage body returns."""
        return len(self.out_banks)


def _axis_spec(axis: int | None, grid: BankGrid) -> P:
    """PartitionSpec placing the bank axis at `axis` (None = replicate)."""
    if axis is None:
        return P()
    return P(*([None] * axis + [grid.axis]))


class FaceCache:
    """Compiled per-kind stage faces, shared across `PlanExecutor`s.

    Host faces are plain per-stage jits; PIM faces are jitted BankGrid
    local phases built from the `StageDef`'s shard axes. One cache per
    serving step keeps distinct prompt shapes from re-tracing stages.
    The cache accounts for itself: every face call and every retrace
    (a jit cache miss re-executes the wrapped stage body, bumping the
    compile counter exactly once per compiled specialization) is counted
    per (face, kind) and exposed through `stats`; with a `tracer`
    attached (`trace.Trace`, set by `PlanExecutor.run(..., tracer=...)`)
    each call additionally records a `compile` span or `cache_hit`
    instant event."""

    def __init__(self, stages: Sequence[StageDef], grid: BankGrid):
        self.grid = grid
        kinds = [s.kind for s in stages]
        dup = sorted({k for k in kinds if kinds.count(k) > 1})
        if dup:                                   # e.g. MoE + dense "mlp"
            raise ValueError(f"duplicate StageDef kinds {dup}: two stage "
                             "bodies would silently share one compiled face")
        self.stages = {s.kind: s for s in stages}
        self.tracer = None                       # trace.Trace | None
        self._calls: dict[tuple[str, str], int] = {}
        self._compiles: dict[tuple[str, str], int] = {}
        self._host = {k: self._face("host", k, jax.jit(
            self._counted("host", k, s.fn)))
            for k, s in self.stages.items()}
        self._pim: dict[str, Callable] = {}      # lazy: grid lowering

    def _counted(self, face, kind, fn):
        """Wrap a stage body so executing its trace bumps the compile
        counter — jit re-executes the body once per new specialization,
        which is exactly when a compile happens."""
        key = (face, kind)

        def body(*args):
            self._compiles[key] = self._compiles.get(key, 0) + 1
            return fn(*args)
        return body

    def _face(self, face, kind, jitted):
        """Wrap a jitted face with call accounting and (when a tracer is
        attached) compile-vs-cache-hit events."""
        key = (face, kind)

        def call(*args):
            self._calls[key] = self._calls.get(key, 0) + 1
            tr = self.tracer
            if tr is None:
                return jitted(*args)
            before = self._compiles.get(key, 0)
            t0 = tr.now()
            out = jitted(*args)
            if self._compiles.get(key, 0) > before:
                tr.add("compile", kind, face, t0)
            else:
                tr.instant("cache_hit", kind, face)
            return out
        return call

    @property
    def stats(self) -> dict:
        """Cache accounting: `{"calls", "compiles", "hits"}` totals plus
        per-face (`"host"`/`"pim"`) and per-kind (`"by_kind"`)
        breakdowns. A *hit* is a call served by an already-compiled face;
        `compiles` counts misses (each triggers exactly one retrace of
        the stage body) — the recompile-regression gates assert through
        this, not by monkeypatching stage bodies."""
        out = {"calls": 0, "compiles": 0, "hits": 0,
               "host": {"calls": 0, "compiles": 0},
               "pim": {"calls": 0, "compiles": 0},
               "by_kind": {}}
        for (face, kind), n in self._calls.items():
            out["calls"] += n
            out[face]["calls"] += n
            k = out["by_kind"].setdefault(kind, {"calls": 0, "compiles": 0})
            k["calls"] += n
        for (face, kind), n in self._compiles.items():
            out["compiles"] += n
            out[face]["compiles"] += n
            k = out["by_kind"].setdefault(kind, {"calls": 0, "compiles": 0})
            k["compiles"] += n
        out["hits"] = out["calls"] - out["compiles"]
        return out

    def host(self, kind: str) -> Callable:
        """The jitted host face for a stage kind."""
        return self._host[kind]

    def pim(self, kind: str) -> Callable:
        """The jitted bank-parallel face for a stage kind (built lazily)."""
        if kind not in self._pim:
            s = self.stages[kind]
            in_specs = tuple(_axis_spec(a, self.grid) for a in s.arg_banks)
            out = tuple(_axis_spec(a, self.grid) for a in s.out_banks)
            out_specs = out if s.n_out > 1 else out[0]
            self._pim[kind] = self._face("pim", kind, jax.jit(
                self.grid.local(self._counted("pim", kind, s.fn),
                                in_specs=in_specs, out_specs=out_specs)))
        return self._pim[kind]

    def pim_ok(self, kind: str, args: tuple) -> bool:
        """True when every bank-sharded argument axis divides the bank
        count — the predicate for routing a call to the PIM face."""
        n = self.grid.n_banks
        for arg, axis in zip(args, self.stages[kind].arg_banks):
            if axis is None:
                continue
            for leaf in jax.tree.leaves(arg):
                if leaf.shape[axis] % n:
                    return False
        return True


class PlanExecutor:
    """Execute a placement over an operator DAG in Schedule timeline order.

    Built once per (graph, assignment): the timeline is
    `make_schedule`'s launch-group sequence for the (possibly
    force-overridden) assignment, so the executed group order is exactly
    the order the golden schedules pin. `run(bind)` walks it; `bind`
    supplies each node's argument tuple from the environment of already-
    computed results."""

    def __init__(self, graph: OpGraph, assignment: dict[str, str],
                 faces: FaceCache, *, kind_of: Callable[[str], str]
                 = stage_kind, source: str = "xeon", sink: str = "xeon"):
        self.graph = graph
        self.assignment = dict(assignment)
        self.faces = faces
        self.kind_of = kind_of
        missing = [n for n in graph.nodes
                   if kind_of(n) not in faces.stages]
        if missing:
            raise ValueError(f"no StageDef for nodes {sorted(missing)[:6]}; "
                             "stage kinds drifted from the DAG's node names")
        stub = Plan.stub(graph.name, self.assignment, method="executor")
        self.schedule: Schedule = make_schedule(graph, stub, source=source,
                                                sink=sink)
        self.timeline = [(g.device, tuple(g.nodes), tuple(g.in_producers))
                         for g in self.schedule.groups]
        # last group that reads each node's output (its own group for
        # leaves): run() frees dead entries past this point, keeping the
        # live environment at the serial loops' O(frontier) footprint
        member = {n: k for k, (_, nodes, _) in enumerate(self.timeline)
                  for n in nodes}
        self._dead_after: list[list[str]] = [[] for _ in self.timeline]
        for n, succs in graph.succs.items():
            last = max((member[s] for s in succs), default=member[n])
            self._dead_after[last].append(n)
        # exchange edges between same-PIM-device endpoints execute as an
        # explicit host gather/scatter: the producer's tensor is pulled
        # back to host memory and re-pushed (replicated over the mesh)
        # before the consumer's face runs — the executable twin of the
        # host-relayed all-to-all the scheduler books as
        # `LaunchGroup.exchange_s` (there is no inter-DPU channel)
        self._exchange_in: dict[str, list[str]] = {}
        for (u, v), nbytes in graph.exchange_edges.items():
            if nbytes > 0 and self.assignment[u] == self.assignment[v] \
                    and self.assignment[u].startswith("upmem"):
                self._exchange_in.setdefault(v, []).append(u)

    def executed_order(self) -> list[tuple[str, list[str]]]:
        """The (device, member nodes) launch groups in execution order —
        the contract the golden schedules pin against executor drift."""
        return [(dev, list(nodes)) for dev, nodes, _ in self.timeline]

    def devices_used(self) -> dict[str, str]:
        """Node name -> device name the executor routes it through."""
        return dict(self.assignment)

    def _dispatch(self, name: str, device: str, args: tuple) -> Any:
        kind = self.kind_of(name)
        if device.startswith("upmem") and self.faces.pim_ok(kind, args):
            return self.faces.pim(kind)(*args)
        return self.faces.host(kind)(*args)

    def _stage_in(self, producers: tuple[str, ...], env: dict,
                  slot: dict) -> None:
        """Issue the next group's boundary transfers into a staging slot:
        producer outputs are placed replicated over the grid mesh (the
        batched host->bank push) while the current group's async dispatch
        is still in flight. Clearing the slot first drops the previous
        round's buffers — the double-buffer donation."""
        slot.clear()
        placement = self.faces.grid.replicated()
        for p in producers:
            if p in env:
                slot[p] = jax.tree.map(
                    lambda x: jax.device_put(x, placement), env[p])

    def run(self, bind: Callable[[str, dict], tuple],
            env: dict | None = None,
            keep: Iterable[str] = (), *,
            tracer=None, block: bool = False) -> dict:
        """Execute every launch group in timeline order; returns the
        environment mapping node name -> stage output(s). `bind(name,
        env)` must return the argument tuple for `name`'s stage kind —
        the only workload-specific logic. Entries are freed once their
        last GRAPH-EDGE consumer's group has dispatched (the serial
        loops' live-set footprint) — so `bind` may only read a node's
        edge-declared predecessors from `env`; any off-graph read (e.g.
        rotary tables every layer re-reads) and every output the caller
        reads after the run (a KV assembly, the head's logits) must be
        pinned by name in `keep`.

        `tracer` (a `trace.Trace`) records the measured timeline: a
        `compute` span per dispatched node, a `stage_in` span (resource
        `"channel"`) per boundary staging, an `exchange` span per host
        relay, plus the FaceCache's compile/cache-hit events; the
        untraced path is untouched (the <5% overhead budget). `block`
        additionally waits on every stage's outputs so compute spans
        measure execution rather than async dispatch — calibration runs
        set it, the serving hot loop must not."""
        env = dict(env or {})
        keep = set(keep)
        staging: list[dict] = [{}, {}]           # double-buffered slots
        prev_tracer = self.faces.tracer
        if tracer is not None:
            self.faces.tracer = tracer
        try:
            for k, (device, nodes, _) in enumerate(self.timeline):
                for p, v in staging[k % 2].items():
                    env[p] = v                   # consume staged inputs
                for name in nodes:
                    relays = self._exchange_in.get(name, ())
                    if relays and tracer is not None:
                        t0 = tracer.now()
                        nb = 0
                    for p in relays:
                        if p in env:             # the exchange's host relay:
                            env[p] = jax.tree.map(  # gather back+re-scatter
                                lambda x: jax.device_put(
                                    x, self.faces.grid.replicated()), env[p])
                            if tracer is not None:
                                nb += sum(x.nbytes for x
                                          in jax.tree.leaves(env[p]))
                    if relays and tracer is not None:
                        tracer.add("exchange", name, "channel", t0, group=k,
                                   bytes=float(nb), n_exchanges=len(relays))
                    if tracer is None:
                        env[name] = self._dispatch(name, device,
                                                   bind(name, env))
                    else:
                        t0 = tracer.now()
                        out = self._dispatch(name, device, bind(name, env))
                        if block:
                            out = jax.block_until_ready(out)
                        tracer.add("compute", name, device, t0, group=k,
                                   stage=self.kind_of(name))
                        env[name] = out
                if k + 1 < len(self.timeline):
                    nxt_dev, _, nxt_producers = self.timeline[k + 1]
                    slot = staging[(k + 1) % 2]
                    if nxt_dev.startswith("upmem"):
                        if tracer is None:
                            self._stage_in(nxt_producers, env, slot)
                        else:
                            t0 = tracer.now()
                            self._stage_in(nxt_producers, env, slot)
                            if block and slot:
                                jax.block_until_ready(list(slot.values()))
                            nb = sum(x.nbytes for v in slot.values()
                                     for x in jax.tree.leaves(v))
                            tracer.add("stage_in", f"g{k + 1}", "channel",
                                       t0, group=k + 1, bytes=float(nb),
                                       device=nxt_dev,
                                       producers=sorted(slot))
                    else:
                        slot.clear()
                for name in self._dead_after[k]:
                    if name not in keep:
                        env.pop(name, None)
        finally:
            self.faces.tracer = prev_tracer
        return env
