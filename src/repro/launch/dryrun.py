import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, prove memory fits, and emit the roofline terms (EXPERIMENTS.md
§Dry-run / §Roofline read the JSON this writes).

The two lines above MUST run before any other import (jax locks the device
count at first init) — that is why they precede the module docstring's
imports and why this env var is never set globally.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out runs/dryrun.json
    ... --arch llama3-405b --shape train_4k --mesh multi -v
    ... --policy kv_layout=batch --policy seq_parallel_acts=1   # hillclimbs
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch
from ..configs.shapes import (ShapeConfig, cache_specs, input_specs,
                              skip_reason, tokens_in)
from ..core.hlo_analysis import analyze_hlo
from ..core.pim_model import TPU_V5E
from ..core.roofline import (RooflineReport, roofline_from_analysis,
                             render_markdown_table, what_would_move_it)
from ..models import (DECODE_POLICY, TRAIN_POLICY, ModelConfig, Policy,
                      Shardings, param_shape_structs, param_specs)
from ..serve import make_decode_step, make_prefill_step
from ..train import HParams, make_train_step
from .mesh import make_production_mesh


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def _opt_structs_and_specs(cfg: ModelConfig, shd: Shardings):
    pstructs = param_shape_structs(cfg)
    pspecs = param_specs(cfg, shd)
    mdt = jnp.dtype(cfg.opt_moment_dtype)
    mstructs = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt),
                            pstructs)
    ostructs = {"m": mstructs, "v": mstructs,
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    return ostructs, ospecs


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               policy: Policy | None = None, verbose: bool = False):
    """Lower + compile one (arch, shape, mesh) cell. Returns a record dict."""
    t0 = time.perf_counter()
    pol = policy or (TRAIN_POLICY if shape.kind == "train" else DECODE_POLICY)
    shd = Shardings(mesh, pol)
    n_chips = mesh.size

    pspecs = param_specs(cfg, shd)
    pstructs = param_shape_structs(cfg)
    in_structs, in_spec_tree = input_specs(cfg, shape, shd)
    p_sh = _named(mesh, pspecs)
    b_sh = _named(mesh, in_spec_tree)

    if shape.kind == "train":
        ostructs, ospecs = _opt_structs_and_specs(cfg, shd)
        o_sh = _named(mesh, ospecs)
        step = make_train_step(cfg, shd, HParams())
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
        lowered = jitted.lower(pstructs, ostructs, in_structs)
    else:
        cstructs, cspecs = cache_specs(cfg, shape, shd)
        c_sh = _named(mesh, cspecs)
        logits_sh = NamedSharding(
            mesh, shd.spec((shape.global_batch, cfg.vocab_size),
                           ("batch", "vocab"), "logits"))
        if shape.kind == "prefill":
            step = make_prefill_step(cfg, shd)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=((logits_sh, c_sh)))
            lowered = jitted.lower(pstructs, cstructs, in_structs)
        else:  # decode
            step = make_decode_step(cfg, shd)
            tok_sh = b_sh["tokens"]
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh),
                             out_shardings=((logits_sh, c_sh)))
            lowered = jitted.lower(pstructs, cstructs, in_structs["tokens"])

    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    # --- memory / cost analysis (proves it fits; feeds §Roofline) -------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    try:
        cost = compiled.cost_analysis()
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "utilization operand 0 {}",
                 "optimal_seconds")} or \
               {k: float(v) for k, v in list(cost.items())[:8]
                if isinstance(v, (int, float))}
    except Exception as e:
        cost = {"error": str(e)}

    analysis = analyze_hlo(compiled.as_text(),
                           trip_count_fallback=cfg.n_blocks)
    mf = cfg.model_flops(tokens=tokens_in(shape),
                         train=(shape.kind == "train"))
    name = f"{cfg.name}/{shape.name}"
    # analytic minimum bytes the step must stream (global; roofline.py
    # divides by chips): params once (+grads/moments for train, active
    # params only for MoE decode), plus the KV/state cache for serving
    bp = jnp.dtype(cfg.dtype).itemsize
    bm = jnp.dtype(cfg.opt_moment_dtype).itemsize
    if shape.kind == "train":
        model_bytes = cfg.param_count() * (3 * bp + 4 * bm)
    else:
        active = cfg.param_count(active_only=(shape.kind == "decode"))
        cache_b = sum(
            s.size * s.dtype.itemsize
            for s in jax.tree.leaves(cache_specs(cfg, shape, None)[0]))
        model_bytes = active * bp + cache_b
    report = roofline_from_analysis(analysis, name=name, n_chips=n_chips,
                                    model_flops=mf, model_bytes=model_bytes)
    # HBM residency per device: params (+moments when training) + cache
    bytes_per_param = jnp.dtype(cfg.dtype).itemsize
    resident = cfg.param_count() * bytes_per_param
    if shape.kind == "train":
        resident += 2 * cfg.param_count() * jnp.dtype(cfg.opt_moment_dtype).itemsize
    resident /= n_chips

    rec = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names))),
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "resident_bytes_per_device_est": int(resident),
        "dropped_shardings": shd.dropped[:20],
        "roofline": report.to_row(),
        "collectives": [dataclasses.asdict(c) for c in analysis.collectives[:12]],
        "flops_per_device": analysis.flops,
        "hbm_bytes_per_device": analysis.hbm_bytes,
        "collective_bytes_per_device": analysis.collective_bytes,
        "guidance": what_would_move_it(report),
    }
    if verbose:
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis:   {cost}")
        print(f"  roofline:        {report.to_row()}")
        print(f"  guidance:        {rec['guidance']}")
    return rec, report


def _parse_policy(kvs: list[str], base: Policy) -> Policy:
    changes = {}
    for kv in kvs:
        k, v = kv.split("=", 1)
        f = {f.name: f for f in dataclasses.fields(Policy)}[k]
        if f.type == "bool" or isinstance(getattr(base, k), bool):
            changes[k] = v not in ("0", "false", "False")
        elif isinstance(getattr(base, k), tuple):
            changes[k] = tuple(x for x in v.split(",") if x)
        else:
            changes[k] = v
    return dataclasses.replace(base, **changes)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--policy", action="append", default=[],
                    help="Policy overrides, e.g. kv_layout=batch")
    ap.add_argument("--remat-group", type=int, default=0,
                    help="override every arch's remat_group (0 = config)")
    ap.add_argument("--out", default="")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records, reports = [], []
    failures = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "multi(2,16,16)" if multi_pod else "single(16,16)"
        for arch in archs:
            cfg = get_arch(arch)
            if args.remat_group:
                cfg = dataclasses.replace(cfg, remat_group=args.remat_group)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                reason = skip_reason(cfg, shape)
                tag = f"{cfg.name:22s} x {shape.name:12s} @ {mesh_name}"
                if reason:
                    print(f"SKIP {tag}: {reason}")
                    records.append({"arch": cfg.name, "shape": shape.name,
                                    "mesh": mesh_name, "status": "skip",
                                    "reason": reason})
                    continue
                try:
                    pol_base = (TRAIN_POLICY if shape.kind == "train"
                                else DECODE_POLICY)
                    pol = _parse_policy(args.policy, pol_base) \
                        if args.policy else None
                    rec, rep = lower_cell(cfg, shape, mesh, pol,
                                          args.verbose)
                    rec["mesh_name"] = mesh_name
                    records.append(rec)
                    if not multi_pod:
                        reports.append(rep)  # roofline table: single-pod
                    r = rec["roofline"]
                    print(f"OK   {tag}: compile={rec['compile_s']:.1f}s "
                          f"dominant={r['dominant']} "
                          f"frac={r['roofline_fraction']:.3f}")
                except Exception as e:
                    failures += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                    if args.verbose:
                        traceback.print_exc()
                    records.append({"arch": cfg.name, "shape": shape.name,
                                    "mesh": mesh_name, "status": "fail",
                                    "error": f"{type(e).__name__}: {e}"})

    if reports:
        print("\n## Roofline (single-pod)\n")
        print(render_markdown_table(reports))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"\nwrote {len(records)} records -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
