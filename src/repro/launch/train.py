"""Training launcher: `python -m repro.launch.train --arch mixtral-8x7b`.

Runs the fault-tolerant TrainLoop on the available devices (reduced configs
on this CPU container; the same driver code path runs full configs on a
real pod — the mesh and shardings come from launch/mesh.py either way).

    PYTHONPATH=src python -m repro.launch.train \
        --arch mixtral-8x7b --reduced --steps 100 --batch 8 --seq 128 \
        --ckpt-dir /tmp/run1    # rerun resumes from the latest checkpoint
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from ..configs import get_arch
from ..configs.shapes import ShapeConfig
from ..models import Shardings, TRAIN_POLICY
from ..train import DataConfig, HParams, LoopConfig, TrainLoop
from .mesh import make_smoke_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", action="store_true",
                    help="shard over all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, reduced=args.reduced)
    mesh = make_smoke_mesh() if args.mesh else None
    shd = Shardings(mesh, TRAIN_POLICY)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    hp = HParams(lr=args.lr, warmup_steps=args.warmup,
                 total_steps=args.steps)
    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, log_every=10)
    loop = TrainLoop(cfg, shape, shd, hp, loop_cfg)

    state = loop.resume_or_init(args.seed)
    if state.step:
        print(f"resumed from step {state.step}")
    t0 = time.perf_counter()
    state = loop.run(state)
    dt = time.perf_counter() - t0
    toks = (args.steps - 0) * args.batch * args.seq
    for m in loop.metrics_log:
        print(json.dumps(m))
    print(f"done: {state.step} steps, {dt:.1f}s, "
          f"{toks / max(dt, 1e-9):.0f} tok/s, "
          f"stragglers={len(loop.straggler_steps)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
