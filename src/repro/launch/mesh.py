"""Production meshes. Functions, not module constants — importing this
module never touches jax device state (the dry-run sets
xla_force_host_platform_device_count BEFORE any jax call).

Topology (TPU v5e pods):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"model" is the innermost axis (fastest ICI ring) — tensor-parallel
collectives are the latency-critical ones. "pod" is outermost: only
data-parallel gradient all-reduces cross the inter-pod links (the paper's
Takeaway-3 discipline applied to the mesh: high-rate traffic stays on the
local axis, cross-pod traffic is one all-reduce per step).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n: int | None = None):
    """A tiny mesh over whatever devices exist (tests / examples)."""
    devs = jax.devices()
    n = n or len(devs)
    return jax.make_mesh((1, n), ("data", "model"))
