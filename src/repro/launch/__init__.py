"""repro.launch — mesh construction, dry-run, train/serve drivers.

NOTE: do not import .dryrun from here — it sets
xla_force_host_platform_device_count at import time and must only be run
as a main module (`python -m repro.launch.dryrun`).
"""

from .mesh import make_production_mesh, make_smoke_mesh
