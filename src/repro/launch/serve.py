"""Serving launcher: batched continuous-batching decode over a model.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch rwkv6-3b --reduced --requests 8 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_arch
from ..models import Shardings, init_params
from ..serve import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, reduced=args.reduced)
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(args.seed), cfg, shd)
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.max_len, shd=shd,
                         temperature=args.temperature)
    key = jax.random.PRNGKey(args.seed + 1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        plen = 4 + int(jax.random.randint(k, (), 0, 12))
        prompt = jax.random.randint(k, (plen,), 0, cfg.vocab_size,
                                    dtype=jnp.int32)
        reqs.append(Request(i, prompt, args.max_new))

    t0 = time.perf_counter()
    done = engine.serve(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    print(f"{len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, "
          f"continuous batching over {args.slots} slots)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
