"""Elastic-restart validation: prove a checkpoint taken on one mesh
restores and trains on a different mesh (scale-down after losing a pod,
scale-up after repair) — the runnability requirement behind
"checkpoint-restore onto a smaller mesh" in DESIGN.md §7.

Checkpoints are mesh-agnostic by construction (full-array leaves; target
shardings are supplied at restore), so elasticity = restore with the new
mesh's shardings + one dry-run-style compile on the new mesh. This module
demonstrates it end-to-end on the reduced configs with local devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.elastic --arch granite-3-8b

It trains 4 steps on a (2,4) mesh, checkpoints, restores onto (1,4) and
(4,2) meshes, trains 2 more steps on each, and asserts the losses match
the continuation on the original mesh (same data pipeline, same math —
sharding must not change the trajectory beyond dtype reassociation)."""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_arch
from ..configs.shapes import ShapeConfig
from ..models import Shardings, TRAIN_POLICY, init_params, param_specs
from ..train import (DataConfig, HParams, adamw_init, make_batch,
                     make_train_step, restore, save)


def _mesh(shape):
    return jax.make_mesh(shape, ("data", "model")[:len(shape)] if
                         len(shape) == 2 else ("data", "model"))


def run_on_mesh(cfg, mesh, state, shape_cfg, hp, steps, start_step):
    shd = Shardings(mesh, TRAIN_POLICY)
    step_fn = jax.jit(make_train_step(cfg, shd, hp))
    params, opt = state
    losses = []
    for s in range(start_step, start_step + steps):
        batch = make_batch(cfg, shape_cfg, s, DataConfig(), shd)
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    return (params, opt), losses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--ckpt", default="/tmp/repro_elastic")
    args = ap.parse_args(argv)

    n = len(jax.devices())
    if n < 8:
        print(f"need 8 host devices (have {n}); run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return 1

    cfg = get_arch(args.arch, reduced=True)
    hp = HParams(lr=1e-3, warmup_steps=2, total_steps=100)
    shape_cfg = ShapeConfig("t", 32, 8, "train")

    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    shd_a = Shardings(mesh_a, TRAIN_POLICY)
    params = init_params(jax.random.PRNGKey(0), cfg, shd_a)
    opt = adamw_init(params, cfg)
    (params, opt), pre = run_on_mesh(cfg, mesh_a, (params, opt),
                                     shape_cfg, hp, 4, 0)
    save(args.ckpt, 4, {"params": params, "opt": opt})
    print(f"trained 4 steps on (2,4), losses {np.round(pre, 4)}")

    # continuation on the ORIGINAL mesh = reference trajectory
    _, ref = run_on_mesh(cfg, mesh_a, (params, opt), shape_cfg, hp, 2, 4)

    for new_shape in ((1, 8), (4, 2)):
        mesh_b = jax.make_mesh(new_shape, ("data", "model"))
        shd_b = Shardings(mesh_b, TRAIN_POLICY)
        pspecs = param_specs(cfg, shd_b)
        from jax.sharding import NamedSharding, PartitionSpec as P
        named = jax.tree.map(
            lambda s: NamedSharding(mesh_b, s if s is not None else P()),
            pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)
        tree = restore(args.ckpt, 4, {"params": params, "opt": opt},
                       {"params": named,
                        "opt": {"m": named, "v": named,
                                "step": NamedSharding(mesh_b, P())}})
        _, post = run_on_mesh(cfg, mesh_b, (tree["params"], tree["opt"]),
                              shape_cfg, hp, 2, 4)
        drift = max(abs(a - b) for a, b in zip(ref, post))
        print(f"resumed on {new_shape}: losses {np.round(post, 4)} "
              f"(drift vs original mesh {drift:.2e})")
        assert drift < 5e-2, drift
    print("elastic restart OK: same trajectory on every mesh")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
