"""rwkv6-3b (Finch) — attention-free SSM: 32L d2560 ff8960 V65536,
data-dependent decay, head size 64 (40 heads) [arXiv:2404.05892]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab_size=65536, rope="none", ssm_type="rwkv6", rwkv_head_size=64,
    norm_eps=1e-5,
    remat_group=4,
)

REDUCED = ModelConfig(
    name="rwkv6-reduced", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=224,
    vocab_size=512, rope="none", ssm_type="rwkv6", rwkv_head_size=16,
    q_chunk=8, kv_chunk=8,
)
