"""Assigned input shapes and ShapeDtypeStruct input specs per (arch, shape).

Shapes (one set, paired with every LM arch):
    train_4k     seq 4096,   global_batch 256   (training)
    prefill_32k  seq 32768,  global_batch 32    (inference prefill)
    decode_32k   seq 32768,  global_batch 128   (one token, 32k KV cache)
    long_500k    seq 524288, global_batch 1     (long-context decode)

`long_500k` needs sub-quadratic attention: it RUNS for ssm/hybrid archs and
for sliding-window archs (bounded ring cache), and is SKIPPED for pure
full-attention archs (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import ModelConfig
from ..models.cache import cache_defs
from ..models.sharding import Shardings, tree_shape_structs, tree_specs


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def long_context_capable(cfg: ModelConfig) -> bool:
    """Sub-quadratic context: SSM/hybrid state or a sliding window."""
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not long_context_capable(cfg):
        return "pure full-attention arch: 500k dense KV is quadratic-cost"
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                shd: Shardings | None = None) -> tuple[dict, dict]:
    """(ShapeDtypeStruct stand-ins, PartitionSpecs) for one cell.

    Stub frontends per the assignment: [vlm]/[audio] get precomputed
    patch/frame embeddings instead of raw pixels/audio.
    """
    b, s = shape.global_batch, shape.seq_len
    tok = lambda shp: jax.ShapeDtypeStruct(shp, jnp.int32)
    emb = lambda shp: jax.ShapeDtypeStruct(shp, jnp.dtype(cfg.dtype))

    specs: dict = {}
    if shape.kind == "train":
        if cfg.input_mode == "embeds":          # vlm backbone stub
            specs["embeds"] = emb((b, s, cfg.d_model))
            if cfg.rope == "mrope":
                specs["mrope_positions"] = tok((3, b, s))
        else:
            specs["tokens"] = tok((b, s))
        specs["labels"] = tok((b, s))
        if cfg.encoder_layers:                  # audio backbone stub
            specs["encoder_embeds"] = emb((b, cfg.encoder_seq, cfg.d_model))
    elif shape.kind == "prefill":
        if cfg.input_mode == "embeds":
            specs["embeds"] = emb((b, s, cfg.d_model))
            if cfg.rope == "mrope":
                specs["mrope_positions"] = tok((3, b, s))
        else:
            specs["tokens"] = tok((b, s))
        if cfg.encoder_layers:
            specs["encoder_embeds"] = emb((b, cfg.encoder_seq, cfg.d_model))
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = tok((b, 1))

    def shard_of(name: str, st):
        if shd is None:
            return None
        if name == "mrope_positions":   # (3, B, S): replicated
            return None
        return shd.batch_spec(st.shape)
    shards = {k: shard_of(k, v) for k, v in specs.items()}
    return specs, shards


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                shd: Shardings | None = None):
    """(ShapeDtypeStructs, PartitionSpecs) for the decode/prefill cache."""
    defs = cache_defs(cfg, shape.global_batch, shape.seq_len)

    def dt(d):
        if d.dtype is not None:
            return d.dtype
        if d.name.endswith((".h", ".wkv")):
            return "float32"
        return cfg.dtype
    structs = jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(dt(d))), defs,
        is_leaf=lambda x: hasattr(x, "kinds"))
    specs = tree_specs(shd, defs) if shd is not None else None
    return structs, specs


def tokens_in(shape: ShapeConfig) -> int:
    if shape.kind == "train" or shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence
