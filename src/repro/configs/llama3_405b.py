"""llama3-405b — dense: 126L d16384 128H(kv8) ff53248 V128256
[arXiv:2407.21783]. bf16 Adam moments so params+opt fit 16 GB/chip HBM on
the single-pod mesh (DESIGN.md §5)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab_size=128256, rope_theta=5e5, norm_eps=1e-5,
    opt_moment_dtype="bfloat16", remat_group=7,
)

REDUCED = ModelConfig(
    name="llama3-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, rope_theta=5e5, q_chunk=8, kv_chunk=8,
)
