"""Assigned architectures (10) + input shapes. `--arch <id>` selects one."""

from . import (deepseek_coder_33b, granite_3_8b, jamba_15_large,
               llama3_405b, mixtral_8x7b, qwen2_moe_a27b, qwen2_vl_72b,
               rwkv6_3b, starcoder2_7b, whisper_tiny)
from .shapes import (SHAPES, ShapeConfig, cache_specs, input_specs,
                     long_context_capable, skip_reason, tokens_in)

_MODULES = [qwen2_vl_72b, mixtral_8x7b, qwen2_moe_a27b, jamba_15_large,
            rwkv6_3b, deepseek_coder_33b, starcoder2_7b, granite_3_8b,
            llama3_405b, whisper_tiny]

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
REDUCED = {m.CONFIG.name: m.REDUCED for m in _MODULES}


def get_arch(name: str, reduced: bool = False):
    table = REDUCED if reduced else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]
