"""granite-3-8b — dense: 40L d4096 32H(kv8) ff12800 V49155, GQA
[hf:ibm-granite/granite-3.0-2b-base family]. Vocab 49155 is not divisible
by the model axis: vocab-parallel logits are dropped (recorded)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
    vocab_size=49155, rope_theta=1e7, norm_eps=1e-5,
    remat_group=4,
)

REDUCED = ModelConfig(
    name="granite-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=515, q_chunk=8, kv_chunk=8,
)
