"""deepseek-coder-33b — dense llama-arch: 62L d7168 56H(kv8) ff19200
V32256 [arXiv:2401.14196]. 56 q-heads don't divide the 16-way model axis:
head TP is dropped for q (recorded by the sharding planner)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab_size=32256, rope_theta=1e5, norm_eps=1e-6,
    remat_group=2,
)

REDUCED = ModelConfig(
    name="deepseek-coder-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, rope_theta=1e5, q_chunk=8, kv_chunk=8,
)
