"""starcoder2-7b — dense: 32L d4608 36H(kv4) ff18432 V49152, GQA + RoPE,
sliding window 4096, layernorm + non-gated gelu MLP, attention bias
[arXiv:2402.19173]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab_size=49152, rope_theta=1e5, sliding_window=4096, attn_bias=True,
    mlp_act="gelu", gated_mlp=False, norm_eps=1e-5,
    remat_group=4,
)

REDUCED = ModelConfig(
    name="starcoder2-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, sliding_window=16, attn_bias=True, mlp_act="gelu",
    gated_mlp=False, q_chunk=8, kv_chunk=8,
)
