"""jamba-1.5-large-398b — hybrid: 72L d8192 64H(kv8) ff24576 V65536,
attn:mamba 1:7 interleave (attention at block position 4), MoE 16e top-2
every other layer [arXiv:2403.19887]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536, rope="none",
    attn_layer_period=8, attn_layer_offset=4,
    n_experts=16, top_k=2, moe_d_ff=24576,
    moe_layer_period=2, moe_layer_offset=1,
    ssm_type="mamba", ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
    ssm_dt_rank=256, norm_eps=1e-6,
    opt_moment_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="jamba-reduced", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, rope="none", attn_layer_period=8, attn_layer_offset=4,
    n_experts=4, top_k=2, moe_d_ff=160, moe_layer_period=2,
    moe_layer_offset=1, ssm_type="mamba", ssm_dt_rank=8, ssm_chunk=8,
    q_chunk=8, kv_chunk=8,
)
