"""qwen2-moe-a2.7b — MoE: 24L d2048 16H(kv16) expert-ff1408 V151936,
60 routed experts top-4 + 4 shared (shared ff 5632)
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=5632,
    vocab_size=151936, rope_theta=1e6, attn_bias=True,
    n_experts=60, top_k=4, moe_d_ff=1408,
    n_shared_experts=4, shared_d_ff=5632, norm_eps=1e-6,
)

REDUCED = ModelConfig(
    name="qwen2-moe-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512, attn_bias=True, n_experts=6, top_k=2, moe_d_ff=48,
    n_shared_experts=2, shared_d_ff=160, q_chunk=8, kv_chunk=8,
)
