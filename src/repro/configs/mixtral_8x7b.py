"""mixtral-8x7b — MoE: 32L d4096 32H(kv8) ff14336 V32000, 8 experts top-2,
sliding-window attention (4096) [arXiv:2401.04088]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, rope_theta=1e6, sliding_window=4096,
    n_experts=8, top_k=2, moe_d_ff=14336, norm_eps=1e-5,
)

REDUCED = ModelConfig(
    name="mixtral-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, sliding_window=16, n_experts=4, top_k=2, moe_d_ff=160,
    q_chunk=8, kv_chunk=8,
)
