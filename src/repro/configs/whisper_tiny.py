"""whisper-tiny — enc-dec audio backbone: 4L enc + 4L dec, d384 6H(kv6)
ff1536 V51865 [arXiv:2212.04356]. The conv frontend is a stub: input_specs
provides precomputed frame embeddings (B, 1500, 384). TPU adaptation:
decoder uses RoPE instead of learned positions (DESIGN.md §2)."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51865, rope_theta=1e4, mlp_act="gelu", gated_mlp=False,
    encoder_layers=4, encoder_seq=1500, tie_embeddings=True,
    norm_eps=1e-5,
)

REDUCED = ModelConfig(
    name="whisper-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab_size=512, mlp_act="gelu", gated_mlp=False, encoder_layers=2,
    encoder_seq=24, tie_embeddings=True, q_chunk=8, kv_chunk=8,
)
