"""qwen2-vl-72b — VLM backbone: 80L d8192 64H(kv8) ff29568 V152064, M-RoPE,
dynamic-resolution frontend stubbed to patch embeddings [arXiv:2409.12191]."""
from ..models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, rope="mrope", rope_theta=1e6, attn_bias=True,
    input_mode="embeds", norm_eps=1e-6,
    remat_group=5,
)

REDUCED = ModelConfig(
    name="qwen2-vl-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, rope="mrope", rope_theta=1e6, attn_bias=True,
    input_mode="embeds", q_chunk=8, kv_chunk=8,
)
