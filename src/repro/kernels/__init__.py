"""repro.kernels — Pallas TPU kernels for the PrIM hot-spots + the LM
decode path, each validated against ref.py in interpret mode.

Kernels: va, gemv, reduction, scan (2-phase SSA), histogram, ts, trns,
decode_attention (flash-decode, GQA-grouped), microbench (Fig-2 OI sweep).
Public API in ops.py (padding/reshape/jit); oracles in ref.py."""

from . import ops, ref
