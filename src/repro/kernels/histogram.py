"""HST Pallas kernel: streaming histogram (PrIM HST-S/L bank-local phase).

The WRAM-private-histogram trick maps to VMEM: the (1, BINS) counts block
stays VMEM-resident across the whole sequential grid while (BLOCK_ROWS,
128) input tiles stream through. Binning uses a one-hot compare + sum
(VPU-friendly; no data-dependent scatter, which the TPU vector unit does
not do) — the TPU-native replacement for the UPMEM scatter loop
(DESIGN.md §2 hardware adaptation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 32
LANES = 128
SHIFT = 12          # values are < 2**SHIFT


def _hst_kernel(x_ref, o_ref, *, bins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.uint32).reshape(-1)     # (R*128,)
    idx = ((x * bins) >> SHIFT).astype(jnp.int32)
    onehot = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (idx.shape[0], bins), 1))
    o_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0,
                          keepdims=True)


def histogram_2d(x, bins: int, *, interpret: bool = False):
    """x: (R, 128) uint32 < 2**SHIFT -> (bins,) int32 counts."""
    import functools
    r, l = x.shape
    assert l == LANES and r % BLOCK_ROWS == 0, (x.shape,)
    out = pl.pallas_call(
        functools.partial(_hst_kernel, bins=bins),
        grid=(r // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, bins), jnp.int32),
        interpret=interpret,
    )(x)
    return out[0]
