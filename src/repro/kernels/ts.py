"""TS Pallas kernel: sliding-window distance (PrIM TS bank-local phase).

Each grid step owns BLOCK windows. The halo (first M-1 elements of the
NEXT block) arrives as a second BlockSpec on the same input with a +1
index map — overlapping reads without any host-side copy. The M-step
window loop is unrolled in-kernel (M is small and static), each step a
shifted VPU subtract-square-accumulate."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 512


def _ts_kernel(x_ref, halo_ref, q_ref, o_ref, *, m: int):
    seg = jnp.concatenate([x_ref[0], halo_ref[0]])     # (2*BLOCK,) f32-able
    q = q_ref[...]                                     # (1, m)
    acc = jnp.zeros((BLOCK,), jnp.float32)
    for j in range(m):                                 # static unroll
        d = seg[j:j + BLOCK].astype(jnp.float32) - q[0, j].astype(jnp.float32)
        acc += d * d
    o_ref[...] = acc[None]


def ts_dists_tiled(series, query, *, interpret: bool = False):
    """series: (N,) with N % BLOCK == 0; query: (m,), m <= BLOCK.
    Returns (N,) f32 distances; entries past N-m+1 are garbage — callers
    mask them (ops.ts_min does)."""
    n = series.shape[0]
    m = query.shape[0]
    assert n % BLOCK == 0 and m <= BLOCK, (n, m)
    nb = n // BLOCK
    x2d = series[None, :]                              # (1, N)
    kern = functools.partial(_ts_kernel, m=m)
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            # halo: next block (clamped at the edge)
            pl.BlockSpec((1, BLOCK), lambda i: (0, jnp.minimum(i + 1, nb - 1))),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(x2d, x2d, query[None, :])
    return out[0]
