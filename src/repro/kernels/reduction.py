"""RED Pallas kernel: streaming sum (PrIM RED, bank-local phase).

Each grid step streams a (BLOCK_ROWS, 128) tile into VMEM, reduces it on
the VPU and accumulates into a (1, 1) f32 output that stays VMEM-resident
across the whole (sequential) grid — the tree-reduce across banks happens
outside (core.bank_parallel.exchange_reduce)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 512
LANES = 128


def _red_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...].astype(jnp.float32))


def reduce_2d(x, *, interpret: bool = False):
    """x: (R, 128), R % BLOCK_ROWS == 0 -> f32 scalar."""
    r, l = x.shape
    assert l == LANES and r % BLOCK_ROWS == 0, (x.shape,)
    out = pl.pallas_call(
        _red_kernel,
        grid=(r // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, 0]
