"""SCAN Pallas kernels: the two bank-local phases of PrIM SCAN-SSA.

Phase 1 (`scan_blocks`): per-block inclusive scan + block totals. The scan
runs along the 128-lane axis of an (8, 128) tile via cumsum (log-depth
shifts on the VPU); rows of a (BLOCK_ROWS, 128) tile are chained with a
row-offset cumsum so a whole tile scans in one pass.
Phase 2 (`add_offsets`): adds the exclusive-scanned block offsets back.

The cross-block exclusive scan between the phases is tiny (n_blocks
elements) and runs as plain jnp in ops.py — on the real machine it is the
host/ICI exchange of SCAN-SSA."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 64
LANES = 128


def _scan_kernel(x_ref, s_ref, t_ref):
    x = x_ref[...].astype(jnp.float32)              # (R, 128)
    lane_scan = jnp.cumsum(x, axis=1)               # scan within rows
    row_tot = lane_scan[:, -1]                      # (R,)
    row_off = jnp.cumsum(row_tot) - row_tot         # exclusive over rows
    full = lane_scan + row_off[:, None]
    s_ref[...] = full.astype(s_ref.dtype)
    t_ref[...] = full[-1:, -1:].astype(t_ref.dtype)


def scan_blocks(x, *, interpret: bool = False):
    """x: (R, 128) -> (row-major inclusive scan per BLOCK_ROWS-tile,
    per-tile totals (n_tiles,))."""
    r, l = x.shape
    assert l == LANES and r % BLOCK_ROWS == 0, (x.shape,)
    n = r // BLOCK_ROWS
    scans, totals = pl.pallas_call(
        _scan_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.float32),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return scans, totals[:, 0]


def _add_kernel(s_ref, off_ref, o_ref):
    o_ref[...] = s_ref[...] + off_ref[0, 0]


def add_offsets(scans, offsets, *, interpret: bool = False):
    """scans: (R, 128); offsets: (n_tiles,) exclusive block offsets."""
    r, l = scans.shape
    n = r // BLOCK_ROWS
    return pl.pallas_call(
        _add_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(scans.shape, scans.dtype),
        interpret=interpret,
    )(scans, offsets[:, None])
