"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile alignment, 1-D <-> 2-D lane reshapes, and dtype
plumbing. `interpret` defaults to True off-TPU (this container validates
kernel bodies in interpret mode; on a v5e the same calls compile to
Mosaic)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _da
from . import gemv as _gemv
from . import histogram as _hst
from . import microbench as _mb
from . import reduction as _red
from . import scan_block as _scan
from . import trns as _trns
from . import ts as _ts
from . import va as _va


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_lanes(x, rows_mult: int):
    """(N,) -> ((R, 128), pad) with R % rows_mult == 0."""
    lanes = 128
    n = x.shape[0]
    per = rows_mult * lanes
    pad = (-n) % per
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, lanes), pad


@functools.partial(jax.jit, static_argnames=("interpret",))
def va(a, b, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    a2, pad = _to_lanes(a, _va.BLOCK_ROWS)
    b2, _ = _to_lanes(b, _va.BLOCK_ROWS)
    out = _va.va_2d(a2, b2, interpret=interpret).reshape(-1)
    return out[:a.shape[0]]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gemv(A, x, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    m, k = A.shape
    pm, pk = (-m) % _gemv.BM, (-k) % _gemv.BK
    if pm or pk:
        A = jnp.pad(A, ((0, pm), (0, pk)))
        x = jnp.pad(x, (0, pk))
    out = _gemv.gemv_tiled(A, x, interpret=interpret)
    return out[:m].astype(A.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def reduction(x, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    x2, _ = _to_lanes(x, _red.BLOCK_ROWS)
    return _red.reduce_2d(x2, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scan(x, interpret: bool | None = None):
    """Full prefix sum via the SCAN-SSA phase structure, f32 accumulate."""
    interpret = default_interpret() if interpret is None else interpret
    n = x.shape[0]
    x2, _ = _to_lanes(x, _scan.BLOCK_ROWS)
    scans, totals = _scan.scan_blocks(x2, interpret=interpret)
    offsets = (jnp.cumsum(totals) - totals).astype(jnp.float32)
    full = _scan.add_offsets(scans, offsets, interpret=interpret)
    return full.reshape(-1)[:n].astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bins", "interpret"))
def histogram(x, bins: int, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    n = x.shape[0]
    per = _hst.BLOCK_ROWS * 128
    pad = (-n) % per
    xp = jnp.pad(x, (0, pad), constant_values=0)
    out = _hst.histogram_2d(xp.reshape(-1, 128), bins, interpret=interpret)
    if pad:  # remove the pad zeros counted into bin 0
        out = out.at[0].add(-pad)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def ts_min(series, query, interpret: bool | None = None):
    """(min squared distance, argmin window) via the TS kernel."""
    interpret = default_interpret() if interpret is None else interpret
    n, m = series.shape[0], query.shape[0]
    pad = (-n) % _ts.BLOCK
    sp = jnp.pad(series, (0, pad))
    d = _ts.ts_dists_tiled(sp, query, interpret=interpret)
    nwin = n - m + 1
    d = jnp.where(jnp.arange(d.shape[0]) < nwin, d, jnp.inf)
    i = jnp.argmin(d)
    return d[i], i.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def transpose(A, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    m, n = A.shape
    pm, pn = (-m) % _trns.BT, (-n) % _trns.BT
    if pm or pn:
        A = jnp.pad(A, ((0, pm), (0, pn)))
    out = _trns.transpose_tiled(A, interpret=interpret)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, length, interpret: bool | None = None):
    """q: (B, H, hd); k, v: (B, W, KVH, hd); length: int32 scalar.
    Pads W to the kernel chunk; GQA grouping handled here."""
    interpret = default_interpret() if interpret is None else interpret
    b, h, hd = q.shape
    w, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    pad_w = (-w) % _da.BW
    if pad_w:
        k = jnp.pad(k, ((0, 0), (0, pad_w), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_w), (0, 0), (0, 0)))
    qg = q.reshape(b, kvh, g, hd)
    out = _da.decode_attention_grouped(qg, k, v, length,
                                       interpret=interpret)
    return out.reshape(b, h, hd)


@functools.partial(jax.jit, static_argnames=("ops_per_elem", "interpret"))
def stream_ops(x, ops_per_elem: int, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    n = x.shape[0]
    x2, _ = _to_lanes(x, _mb.BLOCK_ROWS)
    return _mb.stream_ops(x2, ops_per_elem,
                          interpret=interpret).reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    """q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd). GQA handled by
    repeating KV here (the grouped-ref pattern is in decode_attention).
    Pads Sq/Skv to the kernel tiles; pad k-rows are masked by causality
    when causal, and sliced off the output either way."""
    from . import flash_attention as _fa
    interpret = default_interpret() if interpret is None else interpret
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    pq, pk = (-sq) % _fa.BQ, (-skv) % _fa.BK
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # (B, S, H, hd) -> (B*H, S, hd)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(-1, x.shape[1], hd)
    o = _fa.flash_attention_fwd(fold(q), fold(k), fold(v), causal=causal,
                                window=window, valid_k=skv,
                                interpret=interpret)
    o = o.reshape(b, h, q.shape[1], hd).transpose(0, 2, 1, 3)
    return o[:, :sq]
