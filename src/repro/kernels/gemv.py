"""GEMV Pallas kernel — the bank-parallel decode hot-spot (PrIM GEMV; one
chip's shard of the weight-stationary decode matmul).

Tiling: A is walked in (BM, BK) VMEM tiles, x in (1, BK) slivers; the
kernel accumulates the partial dot into a f32 (BM, 1) output block that
stays resident across the K-grid dimension (revisiting accumulation — the
K axis is the innermost, sequential grid dim). BM/BK are MXU-aligned
(multiples of 128 lanes / 8 sublanes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 256
BK = 512


def _gemv_kernel(a_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)          # (BM, BK)
    x = x_ref[...].astype(jnp.float32)          # (1, BK)
    o_ref[...] += jnp.sum(a * x, axis=1, keepdims=True)


def gemv_tiled(A, x, *, interpret: bool = False):
    """A: (M, K); x: (K,). M % BM == 0, K % BK == 0. Returns f32 (M,)."""
    m, k = A.shape
    assert m % BM == 0 and k % BK == 0, (A.shape,)
    grid = (m // BM, k // BK)
    out = pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j: (i, j)),
            pl.BlockSpec((1, BK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BM, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(A, x[None, :])
    return out[:, 0]
