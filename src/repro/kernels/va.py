"""VA Pallas kernel: streaming elementwise add (the paper's simplest
memory-bound workload, PrIM VA on TPU).

Tiling: (8, 128) f32/int32 VREG-aligned blocks; one row-block of BLOCK_ROWS
sublanes per grid step streams HBM->VMEM->HBM with zero reuse — the pure
bandwidth-roof point of the roofline (operational intensity 1/12 op/byte)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _va_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def va_2d(a, b, *, interpret: bool = False):
    """a, b: (R, 128) with R % BLOCK_ROWS == 0."""
    r, l = a.shape
    assert l == LANES and r % BLOCK_ROWS == 0, (a.shape,)
    grid = (r // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _va_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)
