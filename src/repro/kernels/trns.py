"""TRNS Pallas kernel: tiled matrix transpose (PrIM TRNS bank-local step).

(BT, BT) tiles stream through VMEM; the in-VMEM transpose is a register
shuffle on the VPU. The out BlockSpec swaps the grid indices — the
HBM-level coarse transpose — exactly the PrIM decomposition (host does
tile-granular placement, DPU transposes within the tile)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = 128


def _trns_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...].T


def transpose_tiled(A, *, interpret: bool = False):
    """A: (M, N), both % BT == 0 -> (N, M)."""
    m, n = A.shape
    assert m % BT == 0 and n % BT == 0, (A.shape,)
    return pl.pallas_call(
        _trns_kernel,
        grid=(m // BT, n // BT),
        in_specs=[pl.BlockSpec((BT, BT), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((BT, BT), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((n, m), A.dtype),
        interpret=interpret,
    )(A)
