"""Fig-2 microbenchmark kernel: arithmetic throughput vs operational
intensity. Streams (BLOCK_ROWS, 128) tiles and performs a *dependent* chain
of `ops_per_elem` adds on each element — sweeping ops_per_elem sweeps the
operational intensity (op/byte) axis of the roofline, exactly the paper's
Fig. 2 experiment (there on a DPU; here the same sweep positions the TPU's
balance point). benchmarks/microbench.py runs the sweep."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _stream_kernel(x_ref, o_ref, *, ops_per_elem: int):
    y = x_ref[...]
    for i in range(ops_per_elem):     # dependent chain, static unroll
        y = y + jnp.asarray(i + 1, y.dtype)
    o_ref[...] = y


def stream_ops(x, ops_per_elem: int, *, interpret: bool = False):
    """x: (R, 128) int32/f32."""
    r, l = x.shape
    assert l == LANES and r % BLOCK_ROWS == 0, (x.shape,)
    kern = functools.partial(_stream_kernel, ops_per_elem=ops_per_elem)
    spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(r // BLOCK_ROWS,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
