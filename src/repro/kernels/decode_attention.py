"""Flash-decode Pallas kernel — the serving hot-spot (DESIGN.md §4).

One decode step's attention for one chip's KV shard: q (B, KVH, G, hd)
attends over a (B, W, KVH, hd) KV cache, streamed in (BW, KVH, hd) chunks
through VMEM with an online-softmax running state (m, l, acc) held in VMEM
scratch — the cache is read EXACTLY once at bandwidth roof, the PIM pattern
(bank = chip, MRAM = HBM shard, WRAM = VMEM, tasklets = grid steps).

GQA-aware: scores are computed per kv-head group without repeating K/V
(repeat-to-full-heads costs G x the cache traffic — the difference between
the roofline memory terms of the naive and kernel paths).

The cache length is data-dependent: a per-chunk valid-count array is
blocked into the kernel ((1,1) int32), avoiding scalar prefetch while
keeping masking exact."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BW = 512    # KV chunk (sequence) per grid step


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_s, l_s, acc_s,
                   *, n_chunks: int, scale: float):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                 # (KVH, G, hd)
    k = k_ref[0].astype(jnp.float32)                 # (BW, KVH, hd)
    v = v_ref[0].astype(jnp.float32)                 # (BW, KVH, hd)
    s = jnp.einsum("kgd,wkd->kgw", q, k,
                   preferred_element_type=jnp.float32) * scale

    pos = w * BW + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos < len_ref[0, 0], s, -1e30)

    m_prev, l_prev = m_s[...], l_s[...]              # (KVH, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                # (KVH, G, BW)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc = acc_s[...] * alpha[..., None] + jnp.einsum(
        "kgw,wkd->kgd", p, v, preferred_element_type=jnp.float32)
    m_s[...], l_s[...], acc_s[...] = m_new, l_new, acc

    @pl.when(w == n_chunks - 1)
    def _finish():
        o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)[..., None]) \
            .astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, length, *, interpret: bool = False):
    """q: (B, KVH, G, hd); k, v: (B, W, KVH, hd); length: int32 scalar
    (valid cache slots, same for the batch). Returns (B, KVH, G, hd)."""
    b, kvh, g, hd = q.shape
    w = k.shape[1]
    assert w % BW == 0, (w, BW)
    n_chunks = w // BW
    lens = jnp.full((n_chunks, 1), length, jnp.int32)
    kern = functools.partial(_decode_kernel, n_chunks=n_chunks,
                             scale=1.0 / math.sqrt(hd))
    return pl.pallas_call(
        kern,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, kvh, g, hd), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, BW, kvh, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, BW, kvh, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, kvh, g, hd), lambda i, j: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kvh, g), jnp.float32),
            pltpu.VMEM((kvh, g), jnp.float32),
            pltpu.VMEM((kvh, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lens)
