"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the per-kernel shape/dtype sweep tests assert
against (tests/test_kernels.py). Kept deliberately naive — readability over
speed."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def va(a, b):
    return a + b


def gemv(A, x):
    """A: (M, K); x: (K,) -> (M,). Accumulates in f32."""
    return (A.astype(jnp.float32) @ x.astype(jnp.float32)).astype(A.dtype)


def reduction(x):
    """Full sum, f32 accumulation."""
    return jnp.sum(x.astype(jnp.float32))


def block_scan(x, block: int):
    """(local inclusive scan per block, per-block totals) — the bank-local
    phase of SCAN-SSA."""
    n = x.shape[0]
    xb = x.reshape(n // block, block)
    scans = jnp.cumsum(xb.astype(jnp.float32), axis=1)
    return scans.reshape(n).astype(x.dtype), scans[:, -1].astype(x.dtype)


def scan(x, block: int = 256):
    """Full prefix sum via the SSA structure (oracle = jnp.cumsum)."""
    return jnp.cumsum(x)


def histogram(x, bins: int, shift: int):
    idx = (x.astype(jnp.uint32) * bins) >> shift
    return jnp.zeros((bins,), jnp.int32).at[idx].add(1)


def ts_dists(series, query):
    """Squared euclidean distance of query to every aligned window."""
    m = query.shape[0]
    nwin = series.shape[0] - m + 1
    idx = jnp.arange(nwin)[:, None] + jnp.arange(m)[None, :]
    wins = series[idx].astype(jnp.float32)
    d = wins - query.astype(jnp.float32)[None, :]
    return jnp.sum(d * d, axis=1)


def trns(A):
    return A.T


def decode_attention(q, k, v, length):
    """q: (B,H,hd); k,v: (B,W,KVH,hd); length: #valid cache slots.
    Returns (B,H,hd) attention output, GQA-aware, f32 softmax."""
    b, h, hd = q.shape
    w, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, kf) / math.sqrt(hd)
    mask = jnp.arange(w) < length
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, vf)
    return o.reshape(b, h, hd).astype(q.dtype)


def microbench_stream(x, ops_per_elem: int):
    """Fig-2 microbenchmark: `ops_per_elem` dependent adds per element."""
    y = x
    for i in range(ops_per_elem):
        y = y + jnp.int32(i + 1)
    return y


def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KVH,hd) — plain softmax attention."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
