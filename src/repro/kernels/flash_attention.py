"""Flash-attention (prefill/train forward) Pallas kernel.

The pure-JAX chunked flash in models/layers.py is the dry-run/reference
path; this is the TPU hot-spot version: one (BQ, hd) query tile stays
VMEM-resident while (BK, hd) K/V tiles stream through, with the online
softmax state in VMEM scratch. GQA-grouped (no repeat-to-full-heads),
causal and sliding-window masks supported.

Grid: (batch, kv_head, q_group_member?, q_blocks, kv_blocks) — flattened
to (B*KVH*G, n_q, n_k) with the kv dimension innermost (sequential
revisiting accumulation). Causality skips fully-masked kv tiles via
pl.when (the classic ~2x for causal prefill)."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 256
BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s,
                  *, n_k: int, scale: float, causal: bool, window: int,
                  valid_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -1e30)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_lo = qi * BQ
    k_lo = ki * BK
    # tile-level culling: skip tiles fully above the causal diagonal or
    # fully outside the sliding window (the classic ~2x for causal)
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + BQ - 1
    if window:
        live &= q_lo - (k_lo + BK - 1) < window

    @pl.when(live)
    def _tile():
        q = q_ref[0].astype(jnp.float32)               # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)               # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        mask = k_pos < valid_k          # kv tile padding (static)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]            # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...], l_s[...] = m_new, l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)) \
            .astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        valid_k: int = 0, interpret: bool = False):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd) — heads pre-flattened into the
    leading dim (GQA handled by the ops.py wrapper). Sq % BQ == 0,
    Skv % BK == 0; rows >= valid_k (kv padding) are masked out."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    valid_k = valid_k or skv
    assert sq % BQ == 0 and skv % BK == 0, (sq, skv)
    n_q, n_k = sq // BQ, skv // BK
    kern = functools.partial(
        _flash_kernel, n_k=n_k, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, valid_k=valid_k)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
