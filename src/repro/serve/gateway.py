"""Serving gateway: admission control + SLO-aware scheduling above the
slot loop — continuous batching under real traffic.

`ServeEngine` owns slots; nothing above it scheduled *requests*: its
`serve()` admitted FIFO from `pending[0]` with an unbounded backlog, no
priorities, and no notion of how much decode stall an admission's
prefill injects. `Gateway` is that layer (DESIGN.md §14):

  * **Admission queue** — bounded, priority-classed (`PRIORITIES`:
    interactive < standard < batch). A full queue rejects the arrival
    (policy `"reject"`) or sheds the lowest-priority queued request in
    favor of a strictly higher-priority one (policy `"shed"`); prompts
    the slot cache cannot hold are rejected up front (the engine would
    raise `ValueError`).
  * **Plan cache** — planner products keyed by *batch signature*
    (`dispatch.plan_cache.batch_signature`: live-slot count, bucketed
    KV length, chunk splits, channel-topology shape — plans priced
    under different rank counts never alias). The gateway prices every
    decode step and
    every candidate admission through one `PlanCache`, so planner
    solves amortize as slot composition churns — the gateway bench
    gates >80% hit rate at steady state.
  * **SLO-aware interleaving** — each admission's prefill stalls every
    live slot's next decode token (depth-first prefill), so the gap
    between two decode steps spends a *stall budget*: `max_stall_s`
    when set, else `stall_factor` x the modeled decode-step seconds
    (both sides priced by the plan cache, cf. the replayer's
    priority-ordered device queues). At least one admission per gap
    always proceeds when a slot is free (no starvation), and with no
    live decode there is nothing to stall, so draining is budget-free.

All wall-clock timestamps come from the injected `clock` (seconds;
`time.perf_counter` by default — `ManualClock` makes runs fully
deterministic for tests and replays). `GatewayStats` aggregates
sustained requests/s, p50/p99 TTFT and inter-token latency, and goodput
(requests/s that met their SLOs) — the numbers
`benchmarks/gateway_bench.py` reports under seeded Poisson arrivals.
"""

from __future__ import annotations

import dataclasses
import heapq
import json
import math
import random
import time
from collections import deque
from typing import Callable, Sequence

import jax.numpy as jnp

from ..dispatch import workloads
from ..dispatch.placement import Plan, plan as plan_placement
from ..dispatch.plan_cache import PlanCache, batch_signature
from ..dispatch.schedule import make_schedule
from .dispatch_engine import dims_for_config
from .engine import Request, ServeEngine

#: priority classes, best first: index into this tuple is the `priority`
#: field — lower admits first (FIFO within a class)
PRIORITIES = ("interactive", "standard", "batch")


def percentile(sorted_vals: Sequence[float], pct: float) -> float:
    """Nearest-rank percentile of an ascending sequence (seconds in all
    gateway uses); 0.0 for an empty sequence."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1,
            max(0, math.ceil(pct / 100.0 * len(sorted_vals)) - 1))
    return float(sorted_vals[i])


@dataclasses.dataclass
class GatewayRequest:
    """One gateway-scheduled request: the engine-facing payload (prompt,
    token budget) plus its priority class, arrival time, and the latency
    milestones the gateway records. All timestamps are clock seconds;
    `priority` indexes `PRIORITIES` (lower admits first). `arrival_s` is
    an offset from the run start when built by `poisson_requests` and is
    rebased to absolute clock time by `Gateway.run`."""
    rid: int
    prompt: jnp.ndarray            # (S,) int32
    max_new_tokens: int
    priority: int = 1
    arrival_s: float = 0.0
    state: str = "created"         # created|queued|running|done|rejected
    reject_reason: str | None = None
    admit_s: float | None = None
    finish_s: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    request: Request | None = None  # engine-side twin, set at admission

    @property
    def ttft_s(self) -> float | None:
        """Time to first token in seconds — first sampled token's clock
        time minus arrival (None before the first token)."""
        if not self.token_times:
            return None
        return self.token_times[0] - self.arrival_s

    @property
    def itl_s(self) -> list[float]:
        """Inter-token latencies in seconds between consecutive generated
        tokens (empty for single-token outputs)."""
        return [b - a for a, b in zip(self.token_times,
                                      self.token_times[1:])]

    @property
    def out_tokens(self) -> list[int]:
        """Generated token ids (the engine `Request`'s output; empty
        before admission)."""
        return list(self.request.out_tokens) if self.request else []


class AdmissionQueue:
    """Bounded priority admission queue: pop order is (priority class,
    arrival order) — FIFO within a class. `offer` applies the admission
    policy at capacity: `"reject"` refuses the arrival, `"shed"` evicts
    the worst queued request (lowest class, newest within it) when the
    arrival's class is strictly better."""

    def __init__(self, capacity: int, policy: str = "reject"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy not in ("reject", "shed"):
            raise ValueError(f"policy must be 'reject' or 'shed', "
                             f"got {policy!r}")
        self.capacity = capacity
        self.policy = policy
        self._heap: list[tuple[int, int, GatewayRequest]] = []
        self._seq = 0

    def __len__(self) -> int:
        """Number of queued requests (<= capacity)."""
        return len(self._heap)

    def offer(self, greq: GatewayRequest
              ) -> tuple[bool, GatewayRequest | None]:
        """Try to enqueue `greq`: returns `(accepted, shed)` where `shed`
        is the lower-priority request evicted to make room (policy
        `"shed"` only), else None. Neither the rejected arrival nor the
        shed victim is state-marked here — the gateway records the
        decision."""
        if len(self._heap) < self.capacity:
            self._push(greq)
            return True, None
        if self.policy == "shed":
            worst_i = max(range(len(self._heap)),
                          key=lambda i: self._heap[i][:2])
            if self._heap[worst_i][0] > greq.priority:
                shed = self._heap.pop(worst_i)[2]
                heapq.heapify(self._heap)
                self._push(greq)
                return True, shed
        return False, None

    def _push(self, greq: GatewayRequest) -> None:
        heapq.heappush(self._heap, (greq.priority, self._seq, greq))
        self._seq += 1

    def peek(self) -> GatewayRequest | None:
        """The request `pop` would return next, without removing it."""
        return self._heap[0][2] if self._heap else None

    def pop(self) -> GatewayRequest | None:
        """Remove and return the best queued request (lowest priority
        class, earliest arrival within it), or None when empty."""
        return heapq.heappop(self._heap)[2] if self._heap else None


class ManualClock:
    """Deterministic virtual clock for tests and replayable runs:
    calling it returns the current time in seconds and advances it by
    `tick`, so a run's timestamps are a pure function of the call
    sequence — two seeded-Poisson gateway runs with equal ManualClocks
    produce identical traces. `advance_to` jumps forward over idle
    waits instead of sleeping."""

    def __init__(self, tick: float = 0.0, start: float = 0.0):
        self.t = float(start)
        self.tick = float(tick)

    def __call__(self) -> float:
        """Current time in seconds; each read advances the clock by
        `tick`."""
        now = self.t
        self.t += self.tick
        return now

    def advance_to(self, t: float) -> None:
        """Jump the clock forward to `t` seconds (no-op if already
        past)."""
        self.t = max(self.t, float(t))


@dataclasses.dataclass(frozen=True)
class PricedPlan:
    """One plan-cache entry: the planned operator DAG, the placement the
    planner chose for it, and the modeled pipelined wall-clock in
    SECONDS of executing it — the currency the gateway's stall budget
    and paper-scale projections are denominated in."""
    graph: object                  # dispatch.OpGraph
    plan: Plan
    priced_s: float


@dataclasses.dataclass
class GatewayStats:
    """One gateway run's aggregate serving metrics. All times are
    seconds; rates are requests/s. `sustained_rps` counts completed
    requests over the run duration; `goodput_rps` counts only those that
    met the configured SLOs (equal to `sustained_rps` when no SLO is
    set). TTFT / inter-token percentiles are nearest-rank over completed
    requests; `plan_cache` is the gateway `PlanCache.stats` dict."""
    offered: int
    completed: int
    rejected: int
    shed: int
    tokens: int
    steps: int
    duration_s: float
    sustained_rps: float
    goodput_rps: float
    ttft_p50_s: float
    ttft_p99_s: float
    itl_p50_s: float
    itl_p99_s: float
    plan_cache: dict

    def rows(self) -> list[tuple[str, str]]:
        """(metric, value) rows for report tables — times rendered in
        milliseconds, rates in requests/s."""
        return [
            ("completed / offered",
             f"{self.completed}/{self.offered}"),
            ("rejected (shed)", f"{self.rejected} ({self.shed})"),
            ("tokens", str(self.tokens)),
            ("decode steps", str(self.steps)),
            ("duration", f"{self.duration_s:.3f} s"),
            ("sustained req/s", f"{self.sustained_rps:.2f}"),
            ("goodput req/s", f"{self.goodput_rps:.2f}"),
            ("TTFT p50 / p99",
             f"{self.ttft_p50_s * 1e3:.1f} / {self.ttft_p99_s * 1e3:.1f} ms"),
            ("ITL p50 / p99",
             f"{self.itl_p50_s * 1e3:.1f} / {self.itl_p99_s * 1e3:.1f} ms"),
            ("plan-cache hit rate",
             f"{self.plan_cache['hit_rate']:.2%} "
             f"({self.plan_cache['hits']}/{self.plan_cache['calls']})"),
        ]


class Gateway:
    """Admission-control and scheduling layer above one `ServeEngine`.

    `submit` applies admission control (prompt validation + the bounded
    priority queue), `step` runs one batched decode step and records
    per-request token times, and `run` drives a full arrival-stamped
    workload to completion. Admissions between decode steps are capped
    by the stall budget (see module docstring); every planner price the
    gateway consults flows through its `PlanCache`, keyed by
    `batch_signature`. All times are seconds from the injected `clock`;
    all modeled prices are seconds from the dispatch cost model."""

    def __init__(self, engine: ServeEngine, *, queue_capacity: int = 64,
                 shed_policy: str = "reject", pos_bucket: int = 64,
                 stall_factor: float = 4.0,
                 max_stall_s: float | None = None,
                 slo_ttft_s: float | None = None,
                 slo_itl_s: float | None = None,
                 plan_cache: PlanCache | None = None,
                 devices: tuple = ("xeon", "upmem_2556"),
                 kv_home: str = "upmem_2556",
                 clock: Callable[[], float] = time.perf_counter):
        self.engine = engine
        self.queue = AdmissionQueue(queue_capacity, shed_policy)
        self.plans = plan_cache if plan_cache is not None \
            else PlanCache(maxsize=64)
        self.pos_bucket = pos_bucket
        self.stall_factor = stall_factor
        self.max_stall_s = max_stall_s
        self.slo_ttft_s = slo_ttft_s
        self.slo_itl_s = slo_itl_s
        self.devices = tuple(devices)
        self.kv_home = kv_home
        self.clock = clock
        self._dims = dims_for_config(engine.cfg, engine.n_slots,
                                     engine.max_len)
        self.running: dict[int, GatewayRequest] = {}
        self.finished: list[GatewayRequest] = []
        self.rejected: list[GatewayRequest] = []
        self.submitted = 0
        self.steps = 0
        self.last_decode_price_s = 0.0
        self._t0: float | None = None
        self._t_end: float | None = None

    # ------------------------------------------------------------- #
    # plan-cache pricing
    # ------------------------------------------------------------- #
    def _positions(self) -> list[int]:
        """Python-side position estimate per running request (prompt
        length + tokens generated) — no device sync; feeds the decode
        batch signature."""
        return [int(g.request.prompt.shape[0]) + len(g.request.out_tokens)
                for g in self.running.values()]

    def decode_plan(self) -> PricedPlan:
        """The priced decode plan for the CURRENT batch signature
        (live-slot count + bucketed KV length), planned over the decode
        DAG through the plan cache — one planner solve per signature,
        shared until composition churns out of the bucket."""
        n_live = max(1, self.engine.n_slots - self.engine.n_free)
        key = batch_signature(n_live, self._positions(),
                              pos_bucket=self.pos_bucket,
                              topology=self.devices,
                              window=self._dims.window)
        return self.plans.get_or_plan(
            key, lambda: self._price_decode(n_live, key[2]))

    def _price_decode(self, n_live: int, kv_len: int) -> PricedPlan:
        dims = dataclasses.replace(self._dims, batch=n_live,
                                   seq=min(kv_len, self.engine.max_len))
        dag = workloads.decode_dag(dims, kv_home=self.kv_home)
        p = plan_placement(dag, devices=self.devices)
        sched = make_schedule(dag, p, pipelined=True)
        return PricedPlan(dag, p, float(sched.pipelined_s))

    def decode_price_s(self) -> float:
        """Modeled seconds of one decode step at the current batch
        signature — the denominator of the stall budget."""
        return self.decode_plan().priced_s

    def prefill_price_s(self, plen: int) -> float:
        """Modeled seconds of prefilling a `plen`-token prompt — the
        stall one admission charges against the budget. The chunked
        prefill DAG is keyed by its chunk splits
        (`ServeEngine.prefill_splits`) through the plan cache, so ragged
        prompts sharing a chunk grid share one planner solve."""
        splits = self.engine.prefill_splits(plen)
        key = batch_signature(1, splits=splits, phase="prefill",
                              pos_bucket=self.pos_bucket,
                              topology=self.devices,
                              window=self._dims.window)
        return self.plans.get_or_plan(
            key, lambda: self._price_prefill(splits)).priced_s

    def _price_prefill(self, splits: list[int]) -> PricedPlan:
        dims = dataclasses.replace(self._dims, batch=1)
        dag = workloads.prefill_dag(dims, prefill_len=sum(splits),
                                    chunk=splits[0], batch=1,
                                    kv_home=self.kv_home)
        p = plan_placement(dag, devices=self.devices)
        sched = make_schedule(dag, p, pipelined=True)
        return PricedPlan(dag, p, float(sched.pipelined_s))

    def prewarm(self, prompt_lens: Sequence[int] = ()) -> dict:
        """Price the expected signature envelope out of band, before
        taking traffic: every decode signature the engine can reach
        (live-slot count 1..n_slots x position buckets up to max_len)
        plus the prefill grids of `prompt_lens`. Building and costing a
        DAG dominates a cache miss (~100s of ms at reduced scale), so a
        cold miss inside the serving loop stalls every live slot's next
        token — production gateways warm first. Returns the plan
        cache's `stats` afterwards."""
        for n_live in range(1, self.engine.n_slots + 1):
            for hi in range(self.pos_bucket, self.engine.max_len +
                            self.pos_bucket, self.pos_bucket):
                key = batch_signature(n_live, (hi - 1,),
                                      pos_bucket=self.pos_bucket,
                                      topology=self.devices,
                                      window=self._dims.window)
                self.plans.get_or_plan(
                    key, lambda n=n_live, k=key[2]:
                        self._price_decode(n, k))
        for plen in prompt_lens:
            self.prefill_price_s(int(plen))
        return self.plans.stats

    # ------------------------------------------------------------- #
    # admission control
    # ------------------------------------------------------------- #
    def submit(self, greq: GatewayRequest) -> bool:
        """Admission control for one arrival: validate the payload
        against the engine (too-long prompts and empty budgets are
        rejected here — the engine would raise), then offer it to the
        bounded priority queue under the reject/shed policy. Returns
        True when queued; otherwise the request (or the shed victim)
        ends in state `"rejected"` with `reject_reason` set."""
        self.submitted += 1
        if int(greq.prompt.shape[0]) >= self.engine.max_len:
            self._reject(greq, "prompt-too-long")
            return False
        if greq.max_new_tokens < 1:
            self._reject(greq, "bad-budget")
            return False
        accepted, shed = self.queue.offer(greq)
        if shed is not None:
            self._reject(shed, "shed")
        if not accepted:
            self._reject(greq, "queue-full")
            return False
        greq.state = "queued"
        return True

    def _reject(self, greq: GatewayRequest, reason: str) -> None:
        greq.state = "rejected"
        greq.reject_reason = reason
        self.rejected.append(greq)

    def admit_pending(self) -> int:
        """Drain the queue into free slots in priority order under the
        stall budget; returns the number of admissions made. The budget
        caps the modeled prefill seconds one decode gap may inject:
        `max_stall_s` when set, else `stall_factor` x the modeled
        decode-step price — both sides priced by the plan cache. The
        first admission per gap always proceeds when a slot is free (no
        starvation), and with no live decode there is nothing to stall,
        so the budget only binds while decodes are in flight."""
        n = 0
        spent = 0.0
        while self.engine.n_free > 0 and len(self.queue) > 0:
            live = self.engine.n_slots - self.engine.n_free
            if live == 0:
                budget = math.inf
            elif self.max_stall_s is not None:
                budget = self.max_stall_s
            else:
                budget = self.stall_factor * self.decode_price_s()
            greq = self.queue.peek()
            price = self.prefill_price_s(int(greq.prompt.shape[0]))
            if n > 0 and spent + price > budget:
                break
            greq = self.queue.pop()
            req = Request(greq.rid, greq.prompt, greq.max_new_tokens)
            greq.request = req
            self.engine.admit(req)       # a slot is free: always True
            t = self.clock()
            greq.admit_s = t
            greq.state = "running"
            greq.token_times.append(t)   # first token sampled at admit
            spent += price
            n += 1
            if req.done:                 # budget/EOS met by first token
                self._finish(greq, t)
            else:
                self.running[greq.rid] = greq
        return n

    def _finish(self, greq: GatewayRequest, t: float) -> None:
        greq.state = "done"
        greq.finish_s = t
        self.finished.append(greq)

    # ------------------------------------------------------------- #
    # serving loop
    # ------------------------------------------------------------- #
    def step(self) -> int:
        """One batched decode step through the engine: prices the
        current signature through the plan cache (the per-step planner
        consult the cache amortizes), advances every live slot one
        token, records token times, and finalizes finished requests.
        Returns the number of live slots after the step."""
        if self.running:
            self.last_decode_price_s = self.decode_price_s()
        self.steps += 1
        live = self.engine.step()
        t = self.clock()
        for rid, greq in list(self.running.items()):
            req = greq.request
            if len(req.out_tokens) > len(greq.token_times):
                greq.token_times.append(t)
            if req.done:
                del self.running[rid]
                self._finish(greq, t)
        return live

    def run(self, requests: Sequence[GatewayRequest],
            max_steps: int | None = None) -> GatewayStats:
        """Drive a full arrival-stamped workload: feed each request at
        its `arrival_s` (an offset from the run start, rebased onto the
        clock), admit under the stall budget, decode until everything
        accepted has finished (or `max_steps` decode steps). When idle
        before the next arrival the gateway jumps a `ManualClock`
        forward (`advance_to`) or sleeps the wall clock. Returns the
        run's `GatewayStats`."""
        t0 = self.clock()
        if self._t0 is None:
            self._t0 = t0
        pending = deque(sorted(requests, key=lambda g: g.arrival_s))
        for g in pending:
            g.arrival_s += t0            # rebase offsets to clock time
        while pending or len(self.queue) > 0 or self.running:
            if max_steps is not None and self.steps >= max_steps:
                break
            now = self.clock()
            while pending and pending[0].arrival_s <= now:
                self.submit(pending.popleft())
            if not self.running and len(self.queue) == 0:
                if pending:              # idle until the next arrival
                    self._idle_until(pending[0].arrival_s)
                continue
            self.admit_pending()
            if self.engine.n_slots - self.engine.n_free > 0:
                self.step()
        self._t_end = self.clock()
        return self.stats()

    def _idle_until(self, t: float) -> None:
        if hasattr(self.clock, "advance_to"):
            self.clock.advance_to(t)
        else:
            time.sleep(max(0.0, min(t - self.clock(), 0.05)))

    # ------------------------------------------------------------- #
    # metrics
    # ------------------------------------------------------------- #
    def attach_tracer(self, tracer) -> None:
        """Attach a `dispatch.trace.Trace` to the underlying engine (see
        `ServeEngine.attach_tracer`): admissions record `prefill_step`
        spans and batched steps record `decode_step` spans — under the
        dispatch engine the per-stage compute spans too — the timeline
        `gateway_bench`'s fidelity gate replays. Pass None to detach."""
        self.engine.attach_tracer(tracer)

    def _met_slo(self, greq: GatewayRequest) -> bool:
        if self.slo_ttft_s is not None:
            if greq.ttft_s is None or greq.ttft_s > self.slo_ttft_s:
                return False
        if self.slo_itl_s is not None:
            if any(x > self.slo_itl_s for x in greq.itl_s):
                return False
        return True

    def stats(self) -> GatewayStats:
        """Aggregate `GatewayStats` over everything this gateway has
        finished or rejected so far (all times seconds; percentiles
        nearest-rank over completed requests)."""
        end = self._t_end if self._t_end is not None else self.clock()
        start = self._t0 if self._t0 is not None else end
        dur = max(end - start, 0.0)
        done = self.finished
        ttfts = sorted(g.ttft_s for g in done if g.ttft_s is not None)
        itls = sorted(x for g in done for x in g.itl_s)
        good = [g for g in done if self._met_slo(g)]
        shed = sum(1 for g in self.rejected if g.reject_reason == "shed")
        return GatewayStats(
            offered=self.submitted, completed=len(done),
            rejected=len(self.rejected), shed=shed,
            tokens=sum(len(g.out_tokens) for g in done),
            steps=self.steps, duration_s=dur,
            sustained_rps=(len(done) / dur) if dur > 0 else 0.0,
            goodput_rps=(len(good) / dur) if dur > 0 else 0.0,
            ttft_p50_s=percentile(ttfts, 50),
            ttft_p99_s=percentile(ttfts, 99),
            itl_p50_s=percentile(itls, 50),
            itl_p99_s=percentile(itls, 99),
            plan_cache=self.plans.stats)


def poisson_requests(n: int, rate_rps: float, *, seed: int = 0,
                     vocab: int = 128, prompt_lens: tuple = (4, 12),
                     max_new: tuple = (4, 12),
                     priorities: Sequence[int] = (0, 1, 2),
                     weights: Sequence[float] = (1, 2, 1),
                     start_s: float = 0.0) -> list[GatewayRequest]:
    """Seeded Poisson workload: `n` requests whose inter-arrival gaps are
    exponential with mean `1/rate_rps` seconds, prompt lengths and token
    budgets uniform over the given inclusive ranges, and priority
    classes drawn from `priorities` with `weights` — fully deterministic
    for one seed (`random.Random(seed)`), which is what the determinism
    test and the bench rely on. Arrival timestamps are seconds relative
    to the run start (`Gateway.run` rebases them onto its clock)."""
    rng = random.Random(seed)
    t = float(start_s)
    out = []
    for i in range(n):
        t += rng.expovariate(rate_rps)
        plen = rng.randint(*prompt_lens)
        prompt = jnp.asarray([rng.randrange(vocab) for _ in range(plen)],
                             jnp.int32)
        out.append(GatewayRequest(
            rid=i, prompt=prompt,
            max_new_tokens=rng.randint(*max_new),
            priority=rng.choices(list(priorities), list(weights))[0],
            arrival_s=t))
    return out


def save_arrival_trace(path, requests: Sequence[GatewayRequest]) -> int:
    """Write an arrival trace: one JSON record per line with the
    workload SHAPE of each request — `arrival_s` (seconds from run
    start), `prompt_len`, `max_new`, and the priority `class` name
    (`PRIORITIES`). Prompt token ids are deliberately not recorded: a
    trace captures traffic (what production logs give you), not
    content, and `load_arrival_trace` resynthesizes tokens from a seed.
    Returns the number of records written."""
    with open(path, "w") as f:
        for g in requests:
            f.write(json.dumps({
                "arrival_s": float(g.arrival_s),
                "prompt_len": int(g.prompt.shape[0]),
                "max_new": int(g.max_new_tokens),
                "class": PRIORITIES[g.priority]}) + "\n")
    return len(requests)


def load_arrival_trace(path, *, seed: int = 0,
                       vocab: int = 128) -> list[GatewayRequest]:
    """Load an arrival trace written by `save_arrival_trace` (or by
    hand: JSONL of `{"arrival_s", "prompt_len", "max_new", "class"}`,
    blank lines and `#` comments skipped; `class` is a `PRIORITIES`
    name or an integer index). Prompt tokens are drawn deterministically
    from `random.Random(seed)`, so one (trace, seed) pair replays the
    same workload byte-for-byte — the gateway determinism gate extended
    to file-based traffic. Requests are re-ridded 0..n-1 in file
    order."""
    rng = random.Random(seed)
    out: list[GatewayRequest] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            rec = json.loads(line)
            cls = rec["class"]
            prompt = jnp.asarray(
                [rng.randrange(vocab) for _ in range(int(rec["prompt_len"]))],
                jnp.int32)
            out.append(GatewayRequest(
                rid=len(out), prompt=prompt,
                max_new_tokens=int(rec["max_new"]),
                priority=(PRIORITIES.index(cls) if isinstance(cls, str)
                          else int(cls)),
                arrival_s=float(rec["arrival_s"])))
    return out
