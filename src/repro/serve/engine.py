"""Serving: prefill + decode steps and a batched continuous-batching loop.

The decode step is the paper's workload (§4 of DESIGN.md): a batched GEMV
against bank-resident weights — PIM-suitable by all three takeaways. The
engine keeps the weight layout identical between prefill and decode (no
resharding at the boundary) and a slot-based KV cache so requests of
different lengths share one batch (continuous batching):

  * `Slots` tracks per-slot position/liveness; arrivals fill free slots,
    finished sequences free them. Positions are per-slot (`positions`
    argument of the model forward), so one decode step advances every live
    slot by one token regardless of length skew.
  * Greedy sampling by default; temperature knob for examples.
  * Two backends share the loop: the fused-jit steps (default) and the
    planner-routed hybrid steps (`engine="dispatch"`,
    `serve.dispatch_engine`) — same signatures, same tokens. Under
    dispatch, BOTH phases flow through the offload planner (decode over
    the decode DAG, prefill chunked over the prefill DAG) and execute
    through the unified plan executor's schedule timeline (DESIGN.md
    §9-§11). Dense and routed-MoE decoders both dispatch: MoE layers
    run as the planner's exchange-phase ladder (router -> token
    exchange -> bank-sharded expert FFNs -> combine, DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import ModelConfig, Shardings, forward, init_cache


def make_prefill_step(cfg: ModelConfig, shd: Shardings):
    """(params, cache, batch_inputs) -> (last_logits, cache)."""
    def prefill_step(params, cache, inputs):
        logits, cache, _ = forward(params, cfg, shd, cache=cache, **inputs)
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, shd: Shardings):
    """(params, cache, tokens (B,1)) -> (logits (B,V), cache)."""
    def decode_step(params, cache, tokens):
        logits, cache, _ = forward(params, cfg, shd, tokens=tokens,
                                   cache=cache)
        return logits[:, -1], cache
    return decode_step


def sample(logits, key, temperature: float = 0.0):
    """Greedy argmax (`temperature <= 0`) or temperature sampling over the
    last axis of `logits`; returns int32 token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


# --------------------------------------------------------------------- #
# batched serving engine
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class Request:
    """One serving request: an int32 prompt, a new-token budget, and the
    tokens generated so far (`out_tokens`, filled by the engine)."""
    rid: int
    prompt: jnp.ndarray          # (S,) int32
    max_new_tokens: int
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based batched decoding over a fixed batch of cache slots.

    Single-sequence prefill per arrival (depth-first admission) + batched
    decode for all live slots. CPU-host loop; the steps themselves are
    jitted and mesh-shardable (the decode step is what the dry-run lowers).
    """

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int,
                 max_len: int, shd: Shardings | None = None,
                 temperature: float = 0.0, eos_id: int | None = None,
                 seed: int = 0, engine: str = "jit",
                 dispatch_kwargs: dict | None = None):
        if engine not in ("jit", "dispatch"):
            raise ValueError(f"engine must be 'jit' or 'dispatch', "
                             f"got {engine!r}")
        self.cfg = cfg
        self.shd = shd or Shardings(None)
        self.params = params
        self.n_slots = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.engine = engine

        # per-slot caches live stacked in one batched cache
        self.tracer = None               # dispatch.trace.Trace | None
        self._step_no = 0
        self.cache = init_cache(cfg, batch_slots, max_len, self.shd)
        # the model's cache carries one global index; per-slot positions
        # are maintained here and passed through `positions`
        self.slot_pos = jnp.zeros((batch_slots,), jnp.int32)
        self.slot_live = [False] * batch_slots
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.last_tok = jnp.zeros((batch_slots, 1), jnp.int32)

        if engine == "dispatch":
            # both serving phases route through the offload planner
            # (serve.dispatch_engine): decode over the decode DAG, prefill
            # chunked over the prefill DAG — PIM stages run as BankGrid
            # phases, host stages under per-stage jit. `prefill_*` keys of
            # dispatch_kwargs configure the prefill step; the rest go to
            # both steps.
            from .dispatch_engine import (DispatchDecodeStep,
                                          DispatchPrefillStep)
            dk = dict(dispatch_kwargs or {})
            pk = {"chunk": dk.pop("prefill_chunk", None),
                  "objective": dk.pop("prefill_objective", "overlapped"),
                  "force_assignment":
                      dk.pop("prefill_force_assignment", None)}
            # `prefill_engine="jit"` keeps prefill on the fused path —
            # the dispatch prefill is ulp-close but not bitwise to it
            # (per-stage jit changes XLA fusion), so decode-only bitwise
            # identity gates need fused-prefilled caches
            prefill_engine = dk.pop("prefill_engine", "dispatch")
            if prefill_engine not in ("dispatch", "jit"):
                raise ValueError(f"prefill_engine must be 'dispatch' or "
                                 f"'jit', got {prefill_engine!r}")
            self._decode = DispatchDecodeStep(
                cfg, self.shd, batch_slots=batch_slots, max_len=max_len,
                temperature=temperature, **dk)
            self.dispatch_plan = self._decode.plan
            if prefill_engine == "dispatch":
                self._prefill_step = DispatchPrefillStep(
                    cfg, self.shd, max_len=max_len, grid=self._decode.grid,
                    devices=dk.get("devices", ("xeon", "upmem_2556")),
                    kv_home=dk.get("kv_home", "upmem_2556"), **pk)
                self.prefill_plan = self._prefill_step.plan
                self._prefill_one = self._prefill_step
            else:
                self.prefill_plan = None
                self._prefill_one = jax.jit(self._prefill_one_fn)
        else:
            self._decode = jax.jit(self._decode_step_fn)
            self.dispatch_plan = None
            self.prefill_plan = None
            # retraces once per distinct prompt length (padded buckets
            # in prod)
            self._prefill_one = jax.jit(self._prefill_one_fn)

    # ------------------------------------------------------------- #
    def _decode_step_fn(self, params, cache, tokens, slot_pos, live_mask,
                        key):
        positions = slot_pos[:, None]
        # index drives slot addressing; per-slot validity is the per-row
        # positions array (cache index is the max position across slots)
        logits, new_cache, _ = forward(params, self.cfg, self.shd,
                                       tokens=tokens, cache=cache,
                                       positions=positions)
        nxt = sample(logits[:, -1], key, self.temperature)
        # dead slots keep their last token and don't advance
        nxt = jnp.where(live_mask, nxt, tokens[:, 0])
        new_pos = jnp.where(live_mask, slot_pos + 1, slot_pos)
        return nxt[:, None], new_cache, new_pos

    def _prefill_one_fn(self, params, cache, tokens, slot):
        """Prefill one slot: run the single sequence through, scatter its
        KV rows into the batched cache at `slot`."""
        one = init_cache(self.cfg, 1, self.max_len, self.shd)
        logits, one, _ = forward(params, self.cfg, self.shd,
                                 tokens=tokens[None], cache=one)
        # scatter every per-batch tensor of `one` into row `slot` of cache
        def scatter(c_dst, c_src):
            # leaves have shape (blocks, B, ...) for stacked layers or (B,...)
            def leaf(d, s):
                if d.ndim >= 2 and d.shape[0] == self.cfg.n_blocks \
                        and s.shape[0] == self.cfg.n_blocks:
                    return jax.vmap(
                        lambda dd, ss: jax.lax.dynamic_update_slice_in_dim(
                            dd, ss.astype(dd.dtype), slot, axis=0))(d, s)
                return jax.lax.dynamic_update_slice_in_dim(
                    d, s.astype(d.dtype), slot, axis=0)
            return jax.tree.map(leaf, c_dst, c_src)

        new_layers = scatter(cache["layers"], one["layers"])
        new_cache = dict(cache, layers=new_layers,
                         index=jnp.maximum(cache["index"], one["index"]))
        return logits[0, -1], new_cache

    # ------------------------------------------------------------- #
    def attach_tracer(self, tracer) -> None:
        """Attach a `dispatch.trace.Trace`: the serving loop records one
        `prefill_step` span per admission (with the slot and prompt
        length) and one `decode_step` span per batched step (with the
        live slots and per-slot positions — per-slot latency
        attribution: every live slot advanced one token in that span).
        Under `engine="dispatch"` the tracer also threads through both
        planner-routed steps into `PlanExecutor.run` (per-node compute
        spans, channel occupancy) and the FaceCache (compile vs
        cache-hit). Pass None to detach."""
        self.tracer = tracer
        if self.engine == "dispatch":
            self._decode.tracer = tracer
            if self.prefill_plan is not None:
                self._prefill_step.tracer = tracer

    @property
    def n_free(self) -> int:
        """Number of free (admittable) cache slots right now."""
        return self.slot_live.count(False)

    def prefill_splits(self, plen: int) -> list[int]:
        """Chunk lengths a `plen`-token prompt prefills in: the dispatch
        prefill step's chunk grid when that path is active, one fused
        chunk otherwise. This is the chunk-splits component of the batch
        signature `serve.gateway`'s plan cache keys prefill pricing by."""
        if self.engine == "dispatch" and self.prefill_plan is not None:
            return self._prefill_step.chunk_splits(plen)
        return [int(plen)]

    def admit(self, req: Request) -> bool:
        """Admit a request into a free slot (prefill now). False if full.

        Raises ValueError for prompts the slot cache cannot hold
        (`len(prompt) >= max_len` would overflow the scatter into the
        batched cache — the slot must fit the prompt plus at least one
        generated token) and for non-positive token budgets; admission
        control above the engine (`serve.gateway`) turns both into
        reject/shed decisions. A request whose budget or EOS is already
        satisfied by its FIRST sampled token finishes at admit: it is
        marked done and the slot stays free — it never enters decode."""
        try:
            slot = self.slot_live.index(False)
        except ValueError:
            return False
        plen = int(req.prompt.shape[0])
        if plen >= self.max_len:
            raise ValueError(
                f"prompt of {plen} tokens does not fit max_len="
                f"{self.max_len} (slot cache holds prompt + generated "
                "tokens); reject or shed it upstream")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        t0 = self.tracer.now() if self.tracer is not None else 0.0
        logits, self.cache = self._prefill_one(
            self.params, self.cache, req.prompt, jnp.int32(slot))
        if self.tracer is not None:
            self.tracer.add("prefill_step", f"req{req.rid}", "engine", t0,
                            slot=slot, prompt_len=plen)
        self.key, k = jax.random.split(self.key)
        first = int(sample(logits, k, self.temperature))
        req.out_tokens.append(first)
        # the first token can already exhaust the budget or hit EOS —
        # finish here and leave the slot free instead of decoding (and
        # billing) an extra token
        if (len(req.out_tokens) >= req.max_new_tokens
                or (self.eos_id is not None and first == self.eos_id)):
            req.done = True
            return True
        self.slot_live[slot] = True
        self.slot_req[slot] = req
        self.slot_pos = self.slot_pos.at[slot].set(plen)
        self.last_tok = self.last_tok.at[slot, 0].set(first)
        return True

    def step(self) -> int:
        """One batched decode step for all live slots. Returns #live."""
        live = jnp.asarray(self.slot_live)
        if not any(self.slot_live):
            return 0
        self.key, k = jax.random.split(self.key)
        t0 = self.tracer.now() if self.tracer is not None else 0.0
        self.last_tok, self.cache, self.slot_pos = self._decode(
            self.params, self.cache, self.last_tok, self.slot_pos, live, k)
        # ONE host sync per step: tokens and positions fetched together.
        # (The finish loop's per-slot int(self.slot_pos[slot]) and the
        # tracer's second device_get were each an extra device round-trip.)
        toks, pos = jax.device_get((self.last_tok[:, 0], self.slot_pos))
        if self.tracer is not None:      # device_get synced: span = real
            self._step_no += 1           # step latency, one token per slot
            self.tracer.add(
                "decode_step", f"step{self._step_no}", "engine", t0,
                n_live=sum(self.slot_live),
                slots=[s for s, lv in enumerate(self.slot_live) if lv],
                positions=[int(p) for p, lv in zip(pos, self.slot_live)
                           if lv])
        for slot, req in enumerate(self.slot_req):
            if req is None or not self.slot_live[slot]:
                continue
            t = int(toks[slot])
            req.out_tokens.append(t)
            limit_hit = len(req.out_tokens) >= req.max_new_tokens
            eos_hit = self.eos_id is not None and t == self.eos_id
            if limit_hit or eos_hit or int(pos[slot]) >= self.max_len - 1:
                req.done = True
                self.slot_live[slot] = False
                self.slot_req[slot] = None
        return sum(self.slot_live)

    def serve(self, requests: list[Request]) -> list[Request]:
        """Run a full workload: admit as slots free up, decode until done."""
        pending = list(requests)
        done: list[Request] = []
        inflight: list[Request] = []
        while pending or inflight:
            while pending and self.admit(pending[0]):
                inflight.append(pending.pop(0))
            self.step()
            for r in list(inflight):
                if r.done:
                    inflight.remove(r)
                    done.append(r)
        return done
