"""repro.serve — prefill/decode steps + batched serving engine.

Two decode backends share the continuous-batching loop: the fused-jit
step (`engine="jit"`, default) and the dispatch-backed step
(`engine="dispatch"`) that routes every decode-DAG stage to the device
the offload planner chose (serve.dispatch_engine)."""

from .dispatch_engine import (DispatchDecodeStep, dims_for_config,
                              make_dispatch_decode_step)
from .engine import (Request, ServeEngine, make_decode_step,
                     make_prefill_step, sample)
