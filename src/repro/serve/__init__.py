"""repro.serve — prefill/decode steps + batched serving engine.

Two backends share the continuous-batching loop: the fused-jit steps
(`engine="jit"`, default) and the dispatch-backed steps
(`engine="dispatch"`) that route every operator-DAG stage to the device
the offload planner chose (`serve.dispatch_engine`). Under dispatch BOTH
serving phases flow through the planner — decode over
`dispatch.workloads.decode_dag`, prefill chunked over
`dispatch.workloads.prefill_dag` — and both execute through the unified
plan executor (`dispatch.executor.PlanExecutor`), which walks the
schedule's launch groups in timeline order and pipelines chunked prefill
across chunks (DESIGN.md §9-§11). Device names follow
`dispatch.placement.DEVICES` (`"xeon"`, `"titan_v"`, `"upmem_2556"`,
`"upmem_640"`); all modeled costs are seconds, all payloads bytes.

Above the engine sits the serving gateway (`serve.gateway`,
DESIGN.md §14): a bounded priority admission queue with reject/shed
policies, a plan cache keyed by batch signature so planner solves
amortize as slot composition churns, and SLO-aware interleaving of
prefill admissions with decode steps — the layer that turns the slot
loop into a production-shaped server under Poisson traffic
(`benchmarks/gateway_bench.py`)."""

from .dispatch_engine import (DispatchDecodeStep, DispatchPrefillStep,
                              dims_for_config, make_dispatch_decode_step)
from .engine import (Request, ServeEngine, make_decode_step,
                     make_prefill_step, sample)
from .gateway import (PRIORITIES, AdmissionQueue, Gateway, GatewayRequest,
                      GatewayStats, ManualClock, PricedPlan,
                      load_arrival_trace, percentile, poisson_requests,
                      save_arrival_trace)
