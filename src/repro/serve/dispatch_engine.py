"""Dispatch-backed serving: route prefill AND decode through planner plans.

`DispatchDecodeStep` is a drop-in replacement for `ServeEngine`'s jitted
decode callable (same `(params, cache, tokens, slot_pos, live_mask, key)`
signature) and `DispatchPrefillStep` replaces its jitted prefill-one
callable (`(params, cache, tokens, slot) -> (last_logits, cache)`), both
selected with `ServeEngine(..., engine="dispatch")`. Instead of one fused
jit, each step is decomposed into the stages of its operator DAG
(`dispatch.workloads.decode_dag` / `dispatch.workloads.prefill_dag`) and
handed to the unified plan executor (`dispatch.executor.PlanExecutor`),
which runs the planner's `Schedule` launch groups in timeline order:

  * host stages (`xeon` / `titan_v` in the model) run under per-stage jit,
    one trace per stage *kind* — all layers share it;
  * PIM stages run as BankGrid local phases (decode: batch slots sharded
    over banks — each bank owns its slots' activations and KV rows, the
    continuous-batching-across-banks layout of DESIGN.md §4; prefill: the
    chunk's token rows shard over banks, weights and the KV prefix
    replicate), with boundary tensors staged ahead of each PIM group;
  * the executed group order IS the schedule's group order, so a chunked
    prefill runs *pipelined across chunks* — chunk i+1's qkv ladder is
    issued under chunk i's KV write-back instead of a serial chunk loop
    (DESIGN.md §11).

Neither step owns a stage-execution loop: each contributes only its stage
bodies (`StageDef`s) and a `bind(name, env)` callback mapping DAG node
names to argument tuples — the executor does the walking.

Every stage computes exactly what `models.forward` computes for that slice
of the step (same library calls: `_qkv`, `write_decode`/`write_prefill`,
`cached_attention`, `mlp_forward`, ...). For decode the composed step is
bit-identical to the single-jit engine; for prefill the per-stage
decomposition changes XLA fusion boundaries, so agreement is
ulp-level rather than bitwise (~1e-7 relative at f32) — the serving gates
in `tests/test_serve.py` therefore pin decode token-identity on the
default dtype and the mixed prefill+decode run on the f32 model (the same
precedent as the two-bank decode gate, DESIGN.md §9/§10).

Planning happens once at engine construction: the model config is mapped
to `DecodeDims`, the DAGs are built with the KV cache homed on the PIM
system (bank-resident KV), and `placement.plan` runs the ladder — exact
frontier DP for the decode DAG (width 2) and for prefill up to 2 chunks;
wider chunked prefill falls to bounded branch-and-bound (DESIGN.md §10).
The chosen assignment routes stages by name; `force_assignment` overrides
it for tests and ablations (the executor regroups its timeline around the
override).

Scope: attention decoder LMs with dense OR routed-MoE MLPs (every pattern
position `attn`+`dense`/`attn`+`moe`; no cross-attention/SSM/shared
experts) with an unsharded host mesh — the dispatch layer does its own
distribution through the BankGrid. MoE layers run as the routed ladder
`router{i}` -> token exchange -> `expert{i}` -> combine exchange ->
`combine{i}` (`_MoeStageMixin`): the planner's exchange edges
(`OpGraph.annotate_exchange`) price the host-relayed all-to-all the
dispatch/combine pay on PIM, and the executor performs it as a host
gather/scatter around the expert face, which shards the EXPERT axis over
the grid's banks (DESIGN.md §12).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core.bank_parallel import BankGrid, make_bank_mesh
from ..dispatch import workloads
from ..dispatch.executor import FaceCache, PlanExecutor, StageDef
from ..dispatch.plan_cache import PlanCache
from ..dispatch.placement import Plan, plan as plan_placement
from ..models import ModelConfig, Shardings
from ..models import cache as cache_lib
from ..models import layers as L


def dims_for_config(cfg: ModelConfig, batch_slots: int,
                    max_len: int) -> workloads.DecodeDims:
    """Map a serving config onto the decode DAG's planning dims. The KV
    cache is sized as the engine actually allocates it — GQA head count
    and the config dtype's itemsize — so the migration charge matches the
    bytes a real migration would move. `cfg.quant == "int8"` maps onto
    the KT2-flip planning configuration: 1-byte KV rows and int8-tagged
    expert GEMMs (`DecodeDims.quant`, DESIGN.md §15). `cfg.sliding_window`
    threads through as `DecodeDims.window`: decode dims already price the
    ring width (`seq` IS `cache_width`, so `kv_len == seq` and decode
    planning is unchanged), but prefill DAGs built from these dims go
    banded for prompts longer than the window."""
    q8 = getattr(cfg, "quant", "") == "int8"
    return workloads.DecodeDims(
        d_model=cfg.d_model, n_heads=cfg.n_heads, head_dim=cfg.hd,
        d_ff=cfg.d_ff, seq=cache_lib.cache_width(cfg, max_len),
        vocab=cfg.padded_vocab, n_layers=cfg.n_layers, batch=batch_slots,
        n_kv_heads=cfg.n_kv_heads,
        kv_itemsize=1 if q8 else jnp.dtype(cfg.dtype).itemsize,
        n_experts=cfg.n_experts, top_k=cfg.top_k, moe_d_ff=cfg.moe_d_ff,
        quant="int8" if q8 else "", window=cfg.sliding_window)


def _check_dispatchable(cfg: ModelConfig, shd: Shardings) -> None:
    pattern = cfg.layer_pattern()
    ok = (len(pattern) == 1 and pattern[0].kind == "attn"
          and pattern[0].mlp in ("dense", "moe") and not pattern[0].cross_attn
          and not cfg.encoder_layers)
    if not ok:
        raise ValueError(
            f"engine='dispatch' supports dense attention decoders (dense "
            f"or routed-MoE MLPs); {cfg.name} has pattern {pattern}")
    if pattern[0].mlp == "moe" and cfg.n_shared_experts:
        raise ValueError(
            f"engine='dispatch' MoE support covers routed experts only "
            f"(router -> exchange -> expert FFNs -> combine); {cfg.name} "
            "has shared experts")
    if shd.mesh is not None:
        raise ValueError("engine='dispatch' distributes through the "
                         "BankGrid; pass an unsharded Shardings")


class _MoeStageMixin:
    """Shared MoE stage bodies for the dispatch serving steps: the routed
    ladder `router -> (token exchange) -> expert -> (combine exchange) ->
    combine`, each calling the SAME library slice the fused engine's
    `models.layers.moe_forward` is composed of (`L.moe_dispatch`,
    `L.moe_expert_ffn`, `L.moe_combine`) — code reuse, not a hand-kept
    mirror, so the two paths cannot drift. The router and combine are
    token-side (capacity positions are row-local cumsums, so decode may
    shard slots over banks; prefill replicates them — a chunk's cumsum
    spans the whole chunk); the expert FFN is the bank-parallel face,
    sharded over the EXPERT axis (each bank owns its experts' weights
    and dispatch rows)."""

    def _router_fn(self, x, ln2, router):
        h = L.apply_norm(x, ln2, self.cfg)
        buf, topi, pos, w, _ = L.moe_dispatch(h, router, self.cfg)
        return buf, topi, pos, w

    def _expert_fn(self, buf, wu, wg, wd):
        return L.moe_expert_ffn(buf, {"wu": wu, "wg": wg, "wd": wd},
                                self.cfg, self.shd)

    def _expert_fn_ungated(self, buf, wu, wd):
        return L.moe_expert_ffn(buf, {"wu": wu, "wd": wd}, self.cfg,
                                self.shd)

    def _expert_fn_q8(self, buf, wuq, su, wgq, sg, wdq, sd):
        return L.moe_expert_ffn_q8(buf, wuq, su, wdq, sd, self.cfg,
                                   self.shd, wgq, sg)

    def _expert_fn_q8_ungated(self, buf, wuq, su, wdq, sd):
        return L.moe_expert_ffn_q8(buf, wuq, su, wdq, sd, self.cfg,
                                   self.shd)

    def _q8_stacked(self, mp):
        """Per-layer int8 expert weights for `cfg.quant == "int8"`:
        quantize the scan-STACKED `(L, E, D, F)` weights once (axis 2 is
        each layer's contraction axis — the per-channel amax never crosses
        layers, so the result is bit-identical to per-layer
        `quantize_q8`), slice per layer, and cache keyed on the stacked
        array's identity — serving params are fixed after init, so the
        quantization runs once per engine, not once per step."""
        key = id(mp["wu"])
        cached = getattr(self, "_q8_cache", None)
        if cached is None or cached[0] != key:
            names = (("wu", "wg", "wd") if self.cfg.gated_mlp
                     else ("wu", "wd"))
            qfn = jax.jit(lambda ws: {n: L.quantize_q8(w, axis=2)
                                      for n, w in ws.items()})
            stacked = qfn({n: mp[n] for n in names})
            per_layer = [jax.tree.map(lambda a, i=i: a[i], stacked)
                         for i in range(self.cfg.n_blocks)]
            self._q8_cache = cached = (key, per_layer)
        return cached[1]

    def _combine_fn(self, x, out_buf, topi, pos, w):
        y = L.moe_combine(out_buf, topi, pos, w, x.dtype)
        y = self.shd.act(y, "batch", "seq", None)
        x = x + y
        return self.shd.act(x, "batch", "seq", None)

    def _moe_stage_defs(self, token_axis: int | None):
        """The three MoE StageDefs: `token_axis` is the bank-shard axis of
        token-side tensors (0 for decode's slot sharding; None for
        prefill — a chunk's capacity cumsum spans the whole chunk, so
        router/combine replicate). The expert face always shards the
        expert axis (buf axis 1, weight axis 0) over banks; the int8
        variant's f32 scales carry the expert axis first, so they shard
        axis 0 alongside their weights."""
        ta = token_axis
        if getattr(self.cfg, "quant", "") == "int8":
            if self.cfg.gated_mlp:
                expert = StageDef("expert", self._expert_fn_q8,
                                  (1, 0, 0, 0, 0, 0, 0), (1,))
            else:
                expert = StageDef("expert", self._expert_fn_q8_ungated,
                                  (1, 0, 0, 0, 0), (1,))
        elif self.cfg.gated_mlp:
            expert = StageDef("expert", self._expert_fn, (1, 0, 0, 0), (1,))
        else:
            expert = StageDef("expert", self._expert_fn_ungated,
                              (1, 0, 0), (1,))
        return [
            StageDef("router", self._router_fn, (ta, None, None),
                     (ta, ta, ta, ta)),
            expert,
            StageDef("combine", self._combine_fn, (ta,) * 5, (ta,)),
        ]

    #: expert-parallel shard count (rank-sharded expert faces); decode
    #: overrides per instance, prefill keeps the unsharded default
    expert_shards: int = 1

    def _expert_out(self, env, i, chunk: str):
        """The (B, E, C, D) expert-output buffer the combine gathers from:
        the single expert face's output, or the R rank shards' outputs
        reassembled along the expert axis (exact — experts compute
        independently, so concatenation is the unsharded buffer)."""
        if self.expert_shards == 1:
            return env[f"expert{i}{chunk}"]
        return jnp.concatenate(
            [env[f"expert{i}@r{j}{chunk}"]
             for j in range(self.expert_shards)], axis=1)

    def _bind_moe(self, name, env, lp, chunk: str = ""):
        """Argument tuples for the MoE stages (decode names have no
        `chunk` suffix; prefill passes `"/c{c}"`). Expert-parallel shard
        stages (`"expert{i}@r{j}"`) get their slice of the dispatch
        buffer and the expert-axis weight stacks — shard j computes
        experts `[j*E/R, (j+1)*E/R)`, matching the DAG's per-shard
        cost/exchange split."""
        kind, i, _ = workloads.parse_stage_name(name)
        mp = lp[i]["mlp"]
        if kind == "router":
            return env[f"o{i}{chunk}"], lp[i]["ln2"], mp["router"]
        if kind == "expert":
            buf = env[f"router{i}{chunk}"][0]
            j = workloads.stage_shard(name)
            sl = slice(None)
            if j is not None:
                es = self.cfg.n_experts // self.expert_shards
                sl = slice(j * es, (j + 1) * es)
                buf = buf[:, sl]
            if getattr(self.cfg, "quant", "") == "int8":
                q = self._q8_layers[i]
                wuq, su = (w[sl] for w in q["wu"])
                wdq, sd = (w[sl] for w in q["wd"])
                if self.cfg.gated_mlp:
                    wgq, sg = (w[sl] for w in q["wg"])
                    return buf, wuq, su, wgq, sg, wdq, sd
                return buf, wuq, su, wdq, sd
            return ((buf, mp["wu"][sl], mp["wg"][sl], mp["wd"][sl])
                    if self.cfg.gated_mlp
                    else (buf, mp["wu"][sl], mp["wd"][sl]))
        if kind == "combine":
            _, topi, pos, w = env[f"router{i}{chunk}"]
            return (env[f"o{i}{chunk}"], self._expert_out(env, i, chunk),
                    topi, pos, w)
        raise KeyError(f"unknown MoE stage {name!r}")


def make_dispatch_decode_step(cfg: ModelConfig, shd: Shardings,
                              **kwargs) -> "DispatchDecodeStep":
    """`make_decode_step`'s dispatch twin: plan the decode DAG and compile
    the planner's chosen plan into an executable step (same call signature
    as the engine's jitted `_decode`)."""
    return DispatchDecodeStep(cfg, shd, **kwargs)


class DispatchDecodeStep(_MoeStageMixin):
    """Planner-routed decode step with the jit engine's call signature —
    a thin workload adapter over `dispatch.executor.PlanExecutor`. MoE
    configs route each layer's routed ladder (router -> token exchange ->
    expert -> combine exchange -> combine) through the same executor,
    with expert FFNs sharded over the BankGrid's banks when placed on
    PIM (`_MoeStageMixin`)."""

    def __init__(self, cfg: ModelConfig, shd: Shardings, *,
                 batch_slots: int, max_len: int, temperature: float = 0.0,
                 grid: BankGrid | None = None,
                 devices: tuple[str, ...] = ("xeon", "upmem_2556"),
                 kv_home: str | None = "upmem_2556",
                 objective: str = "serial",
                 expert_shards: int = 1,
                 force_assignment: dict[str, str] | None = None):
        _check_dispatchable(cfg, shd)
        self.cfg, self.shd = cfg, shd
        self.temperature = temperature
        self.grid = grid or BankGrid(make_bank_mesh())
        if batch_slots % self.grid.n_banks:
            raise ValueError(f"batch_slots={batch_slots} must divide over "
                             f"{self.grid.n_banks} bank(s)")
        self.expert_shards = int(expert_shards)
        self.dag = workloads.decode_dag(
            dims_for_config(cfg, batch_slots, max_len), kv_home=kv_home,
            expert_shards=self.expert_shards)
        self.plan: Plan = plan_placement(self.dag, devices=devices,
                                         objective=objective)
        self.assignment = dict(self.plan.assignment)
        if force_assignment:
            self.assignment.update(force_assignment)
        # the executable stage names and the DAG's node names are the
        # routing contract — any drift must fail loudly here, not fall
        # back to host execution (which the token-identity tests could
        # never distinguish from a correctly routed plan)
        self._moe = cfg.n_experts > 0
        expected = {"embed", "head"}
        for i in range(cfg.n_blocks):
            expected |= {f"qkv{i}", f"attn{i}", f"o{i}"}
            if not self._moe:
                expected.add(f"mlp{i}")
            elif self.expert_shards > 1:
                expected |= {f"router{i}", f"combine{i}"}
                expected |= {f"expert{i}@r{j}"
                             for j in range(self.expert_shards)}
            else:
                expected |= {f"router{i}", f"expert{i}", f"combine{i}"}
        missing = expected - set(self.assignment)
        if missing:
            raise ValueError(f"plan is missing stages {sorted(missing)}; "
                             "decode_dag node names drifted from the "
                             "executable stages")

        #: one compiled face per stage kind (host jit / BankGrid phase),
        #: shared by all layers; the executor walks the schedule timeline
        self.faces = FaceCache(self._stage_defs(), self.grid)
        self.executor = PlanExecutor(self.dag, self.assignment, self.faces)
        self._sample = jax.jit(self._sample_fn)
        #: optional `dispatch.trace.Trace`: when set (ServeEngine
        #: attach_tracer), every step records its executed timeline
        self.tracer = None

    # ------------------------------------------------------------- #
    # stage bodies — each mirrors models.forward's decode path exactly
    # ------------------------------------------------------------- #

    def _stage_defs(self):
        """StageDefs for the decode DAG: batch slots shard on axis 0 of
        every flowing tensor, weights replicate. MoE layers swap the
        dense `mlp` for the routed trio — router/combine stay slot-
        sharded (capacity positions are row-local), the expert FFN
        shards the EXPERT axis over banks."""
        mlp_defs = (self._moe_stage_defs(token_axis=0) if self._moe
                    else [StageDef("mlp", self._mlp_fn, (0, None, None),
                                   (0,))])
        return [
            StageDef("embed", self._embed_fn, (None, 0, 0), (0, 0, 0)),
            StageDef("qkv", self._qkv_fn, (0, 0, 0, None, None), (0, 0, 0)),
            StageDef("attn", self._attn_fn, (0,) * 6, (0, 0, 0)),
            StageDef("o", self._o_fn, (0, 0, None), (0,)),
            *mlp_defs,
            StageDef("head", self._head_fn, (0, None, None), (0,)),
        ]

    def _embed_fn(self, table, tokens, slot_pos):
        x = table[tokens].astype(self.cfg.dtype)
        positions = slot_pos[:, None]
        if self.cfg.rope == "none":
            b = tokens.shape[0]
            sin = cos = jnp.zeros((b, 1, self.cfg.hd // 2), jnp.float32)
        else:
            sin, cos = L.rope_sincos(positions, self.cfg)
        return x, sin, cos

    def _qkv_fn(self, x, sin, cos, ln1, attn_p):
        h = L.apply_norm(x, ln1, self.cfg)
        rs = None if self.cfg.rope == "none" else sin
        rc = None if self.cfg.rope == "none" else cos
        return L._qkv(h, attn_p, self.cfg, self.shd, rope_sin=rs,
                      rope_cos=rc, heads_tp=False)

    def _attn_fn(self, q, k, v, k_cache, v_cache, attn_index):
        width = k_cache.shape[1]
        new_kv = cache_lib.write_decode({"k": k_cache, "v": v_cache},
                                        k, v, attn_index, width)
        pos = cache_lib.slot_positions(attn_index + 1, width)
        o = L.cached_attention(q, new_kv["k"], new_kv["v"], pos,
                               attn_index, self.cfg, self.shd)
        return o, new_kv["k"], new_kv["v"]

    def _o_fn(self, x, o, attn_p):
        return x + L.attn_out(o, attn_p, x.dtype, self.shd)

    def _mlp_fn(self, x, ln2, mlp_p):
        h = L.apply_norm(x, ln2, self.cfg)
        x = x + L.mlp_forward(h, mlp_p, self.cfg, self.shd)
        return self.shd.act(x, "batch", "seq", None)

    def _head_fn(self, x, norm_p, wv):
        from ..models.transformer import mask_vocab_padding
        x = L.apply_norm(x, norm_p, self.cfg)
        logits = jnp.einsum("bsd,vd->bsv", x, wv.astype(x.dtype))
        return mask_vocab_padding(logits, self.cfg)

    def _sample_fn(self, logits, tokens, slot_pos, live_mask, key):
        from .engine import sample
        nxt = sample(logits[:, -1], key, self.temperature)
        nxt = jnp.where(live_mask, nxt, tokens[:, 0])
        new_pos = jnp.where(live_mask, slot_pos + 1, slot_pos)
        return nxt[:, None], new_pos

    # ------------------------------------------------------------- #
    def _bind(self, params, cache, tokens, slot_pos, attn_index):
        """The executor's workload surface: map a decode-DAG node name to
        its stage argument tuple, reading prior results from `env`."""
        cfg = self.cfg
        stacked = params["layers"][0]
        kv_stack = cache["layers"][0]
        lp = [jax.tree.map(lambda l, i=i: l[i], stacked)
              for i in range(cfg.n_blocks)]
        if self._moe and getattr(cfg, "quant", "") == "int8":
            self._q8_layers = self._q8_stacked(stacked["mlp"])
        wv = params["embed"] if cfg.tie_embeddings else params["unembed"]
        res_kind = "combine" if self._moe else "mlp"

        def residual(env, i):
            return env[f"{res_kind}{i - 1}"] if i else env["embed"][0]

        def bind(name, env):
            kind, i, _ = workloads.parse_stage_name(name)
            if kind == "embed":
                return params["embed"], tokens, slot_pos
            if kind == "qkv":
                _, sin, cos = env["embed"]
                return (residual(env, i), sin, cos,
                        lp[i]["ln1"], lp[i]["attn"])
            if kind == "attn":
                q, k, v = env[f"qkv{i}"]
                return (q, k, v, kv_stack["k"][i], kv_stack["v"][i],
                        attn_index)
            if kind == "o":
                return residual(env, i), env[f"attn{i}"][0], lp[i]["attn"]
            if kind == "mlp":
                return env[f"o{i}"], lp[i]["ln2"], lp[i]["mlp"]
            if kind in ("router", "expert", "combine"):
                return self._bind_moe(name, env, lp)
            if kind == "head":
                return (env[f"{res_kind}{cfg.n_blocks - 1}"],
                        params["final_norm"], wv)
            raise KeyError(f"unknown decode stage {name!r}")
        return bind

    def __call__(self, params, cache, tokens, slot_pos, live_mask, key):
        cfg = self.cfg
        index = cache["index"]
        attn_index = slot_pos            # per-row positions (cont. batching)
        # keep: outputs read after the run (head, the attn KV updates) and
        # off-graph binds — every layer's qkv reads embed's sin/cos, but
        # the DAG only edges embed->qkv0/o0, so embed must be pinned or
        # the executor frees it after layer 0's group
        env = self.executor.run(
            self._bind(params, cache, tokens, slot_pos, attn_index),
            keep={"head", "embed",
                  *(f"attn{i}" for i in range(cfg.n_blocks))},
            tracer=self.tracer)
        logits = env["head"]
        new_ks = [env[f"attn{i}"][1] for i in range(cfg.n_blocks)]
        new_vs = [env[f"attn{i}"][2] for i in range(cfg.n_blocks)]
        nxt, new_pos = self._sample(logits, tokens, slot_pos, live_mask, key)
        kv_stack = cache["layers"][0]
        new_layer = dict(kv_stack, k=jnp.stack(new_ks), v=jnp.stack(new_vs))
        new_index = jnp.maximum(index + 1,
                                jnp.max(slot_pos) + 1).astype(jnp.int32)
        new_cache = dict(cache, index=new_index, layers=[new_layer])
        return nxt, new_cache, new_pos


# ------------------------------------------------------------------- #
# planner-routed chunked prefill
# ------------------------------------------------------------------- #

class DispatchPrefillStep(_MoeStageMixin):
    """Planner-routed chunked prefill with the engine's prefill-one
    signature: `(params, cache, tokens, slot) -> (last_logits, new_cache)`
    — a thin workload adapter over `dispatch.executor.PlanExecutor`.

    The prompt is processed `chunk` tokens at a time; each chunk's
    per-layer qkv -> attention -> o -> mlp stage ladder runs on the device
    the planner assigned to the matching `workloads.prefill_dag` node
    (`"qkv{layer}/c{chunk}"`, ...). Chunk attention attends each query row
    causally over all K/V rows produced so far — the same math
    `models.transformer._plain_attention` computes, with absolute
    positions passed explicitly so a bank-sharded chunk masks correctly.
    After the last chunk, the assembled K/V rows are written into the
    batched cache at `slot` exactly like the fused engine's prefill
    (`cache.write_prefill` + per-block scatter), and the head runs on the
    final chunk only (the engine samples from the prompt's last position).

    Execution is PIPELINED across chunks: the executor walks the
    schedule's launch groups over the prompt's own (structural) prefill
    DAG, whose topological order interleaves chunks — chunk i+1's qkv
    ladder is issued under chunk i's KV write-back, instead of the old
    strictly serial chunk loop (DESIGN.md §11). One executor is built per
    distinct chunk-split signature and cached; all of them share one
    `FaceCache`, so stage traces are still one per kind.

    Planning happens once, on a canonical DAG of `planned_chunks` chunks
    (prompts with more chunks reuse the last planned chunk's placement —
    the `min(c, planned-1)` clamp; prompts with fewer just use a prefix).
    The cross-chunk KV fan-in widens the DAG frontier to ~2*chunks+1, so
    beyond 2 chunks the ladder's bounded branch-and-bound rung plans it
    (budgets are constructor knobs; DESIGN.md §10). `objective` defaults
    to `"overlapped"` — prefill is where batched chunk transfers have
    compute to hide under.

    PIM-assigned stages run as BankGrid local phases with the chunk's
    token rows sharded over banks (weights and the KV prefix replicate);
    a chunk length not divisible by the bank count falls back to the host
    face for that call (single-bank dev containers always shard).

    Numerics: every stage mirrors `models.forward`'s prefill path
    library-call-for-library-call, but per-stage jit boundaries change
    XLA fusion, so agreement with the fused engine is ulp-level, not
    bitwise (module docstring); prompts at or above the fused path's
    flash-attention threshold (2048 tokens) are out of scope.

    MoE configs run each chunk's routed ladder (router -> exchange ->
    expert -> exchange -> combine) with expert capacity derived from the
    CHUNK length, not the whole prompt — overflow tokens drop per chunk,
    so multi-chunk MoE prefill is deliberately NOT output-equivalent to
    the fused whole-prompt forward (a single chunk covering the prompt
    is). It IS deterministic across bank counts (experts compute
    independently), which is what the multi-bank identity gate pins; the
    fused-vs-dispatch MoE token gates therefore prefill fused or
    single-chunk (tests/test_serve.py)."""

    def __init__(self, cfg: ModelConfig, shd: Shardings, *,
                 max_len: int, grid: BankGrid | None = None,
                 devices: tuple[str, ...] = ("xeon", "upmem_2556"),
                 kv_home: str | None = "upmem_2556",
                 chunk: int | None = None, planned_chunks: int = 4,
                 objective: str = "overlapped",
                 state_budget: int = 200_000, bnb_budget: int = 20_000,
                 force_assignment: dict[str, str] | None = None):
        _check_dispatchable(cfg, shd)
        self.cfg, self.shd = cfg, shd
        self.grid = grid or BankGrid(make_bank_mesh())
        self.max_len = max_len
        self.chunk = int(chunk if chunk is not None else min(512, max_len))
        if self.chunk < 1:
            raise ValueError(f"prefill chunk must be >= 1, got {self.chunk}")
        canonical = min(max_len, planned_chunks * self.chunk)
        canonical_splits = workloads.prefill_chunk_splits(canonical,
                                                          self.chunk)
        self.n_chunks_planned = len(canonical_splits)
        self._dims = dims_for_config(cfg, 1, max_len)
        self._kv_home = kv_home
        self.dag = workloads.prefill_dag(
            self._dims, prefill_len=canonical, chunk=self.chunk, batch=1,
            kv_home=kv_home)
        self.plan: Plan = plan_placement(
            self.dag, devices=devices, objective=objective,
            state_budget=state_budget, bnb_budget=bnb_budget)
        self.assignment = dict(self.plan.assignment)
        if force_assignment:
            self.assignment.update(force_assignment)
        # routing contract: executable stage names == DAG node names
        self._moe = cfg.n_experts > 0
        mlp_kinds = (("router", "expert", "combine") if self._moe
                     else ("mlp",))
        expected = {"head"}
        for c in range(self.n_chunks_planned):
            expected.add(f"embed/c{c}")
            for i in range(cfg.n_blocks):
                expected |= {f"qkv{i}/c{c}", f"attn{i}/c{c}", f"o{i}/c{c}"}
                expected |= {f"{kd}{i}/c{c}" for kd in mlp_kinds}
        missing = expected - set(self.assignment)
        if missing:
            raise ValueError(f"plan is missing stages {sorted(missing)}; "
                             "prefill_dag node names drifted from the "
                             "executable stages")

        self.faces = FaceCache(self._stage_defs(), self.grid)
        #: per chunk-split-signature executors (ragged prompts differ),
        #: all sharing `faces` so stages keep one trace per kind; held in
        #: a `dispatch.PlanCache` (LRU + hit/miss stats) — distinct
        #: prompt lengths are unbounded over an engine's lifetime, and an
        #: evicted executor rebuilds cheaply (structural DAG only, no
        #: re-tracing)
        self.executor_cache = PlanCache(maxsize=16)
        self.executor = self._executor_for(canonical_splits)
        self._scatter = jax.jit(self._scatter_fn)
        #: optional `dispatch.trace.Trace`: when set (ServeEngine
        #: attach_tracer), every prefill records its executed timeline
        self.tracer = None

    # ------------------------------------------------------------- #
    # stage bodies — each mirrors models.forward's prefill path exactly
    # ------------------------------------------------------------- #

    def _stage_defs(self):
        """StageDefs for the prefill DAG: a chunk's token rows shard on
        axis 1 (axis 0 for the 1-D positions array), weights and the KV
        prefix replicate. MoE layers swap the dense `mlp` for the routed
        trio — router/combine replicate (a chunk's capacity cumsum spans
        the whole chunk, so token-sharding would change which tokens
        overflow), the expert FFN shards the EXPERT axis over banks."""
        mlp_defs = (self._moe_stage_defs(token_axis=None) if self._moe
                    else [StageDef("mlp", self._mlp_fn, (1, None, None),
                                   (1,))])
        return [
            StageDef("embed", self._embed_fn, (None, 1, 1), (1, 1, 1)),
            StageDef("qkv", self._qkv_fn, (1, 1, 1, None, None), (1, 1, 1)),
            StageDef("attn", self._attn_fn, (1, None, None, 0, None), (1,)),
            StageDef("o", self._o_fn, (1, 1, None), (1,)),
            *mlp_defs,
            StageDef("head", self._head_fn, (1, None, None), (1,)),
        ]

    def _embed_fn(self, table, tokens, positions):
        x = table[tokens].astype(self.cfg.dtype)
        if self.cfg.rope == "none":
            b, t = tokens.shape
            sin = cos = jnp.zeros((b, t, self.cfg.hd // 2), jnp.float32)
        else:
            sin, cos = L.rope_sincos(positions, self.cfg)
        return x, sin, cos

    def _qkv_fn(self, x, sin, cos, ln1, attn_p):
        h = L.apply_norm(x, ln1, self.cfg)
        rs = None if self.cfg.rope == "none" else sin
        rc = None if self.cfg.rope == "none" else cos
        return L._qkv(h, attn_p, self.cfg, self.shd, rope_sin=rs,
                      rope_cos=rc, heads_tp=True)

    def _attn_fn(self, q, kp, vp, q_pos, k_pos):
        # _plain_attention with absolute q AND k positions passed
        # explicitly (bank-sharded chunks must not rebuild them from a
        # local arange). Key positions must come from the caller: a slot
        # index only equals its absolute position in a full cache, and a
        # banded prefix doesn't even start at 0 — an in-stage
        # `arange(skv)` would silently mis-mask both (the ISSUE-10
        # ring-cache position bug).
        b, sq, h, hd = q.shape
        skv, kvh = kp.shape[1], kp.shape[2]
        if skv != k_pos.shape[0]:
            raise ValueError(
                f"attn stage got {skv} KV rows but {k_pos.shape[0]} key "
                "positions — slot index != absolute position here (ring "
                "cache or banded prefix?); refusing to mis-mask")
        if kvh != h:
            kp = jnp.repeat(kp, h // kvh, axis=2)
            vp = jnp.repeat(vp, h // kvh, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kp,
                       preferred_element_type=jnp.float32) / math.sqrt(hd)
        mask = q_pos[:, None] >= k_pos[None, :]
        if self.cfg.sliding_window:
            mask &= q_pos[:, None] - k_pos[None, :] < self.cfg.sliding_window
        s = jnp.where(mask, s, -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", a, vp)

    def _o_fn(self, x, o, attn_p):
        return x + L.attn_out(o, attn_p, x.dtype, self.shd)

    def _mlp_fn(self, x, ln2, mlp_p):
        h = L.apply_norm(x, ln2, self.cfg)
        x = x + L.mlp_forward(h, mlp_p, self.cfg, self.shd)
        return self.shd.act(x, "batch", "seq", None)

    def _head_fn(self, x, norm_p, wv):
        from ..models.transformer import mask_vocab_padding
        x = L.apply_norm(x, norm_p, self.cfg)
        logits = jnp.einsum("bsd,vd->bsv", x, wv.astype(x.dtype))
        return mask_vocab_padding(logits, self.cfg)

    def _scatter_fn(self, cache, k_full, v_full, slot):
        # mirror ServeEngine._prefill_one_fn: write the prompt's rows into
        # a fresh zeroed slot-cache (ring semantics via write_prefill),
        # then scatter that row into the batched cache at `slot`
        kv_stack = cache["layers"][0]
        s = k_full.shape[2]

        def per_block(dst_k, dst_v, kf, vf):
            one = {"k": jnp.zeros_like(dst_k[:1]),
                   "v": jnp.zeros_like(dst_v[:1])}
            one = cache_lib.write_prefill(one, kf, vf)
            k = jax.lax.dynamic_update_slice_in_dim(
                dst_k, one["k"].astype(dst_k.dtype), slot, axis=0)
            v = jax.lax.dynamic_update_slice_in_dim(
                dst_v, one["v"].astype(dst_v.dtype), slot, axis=0)
            return k, v

        new_k, new_v = jax.vmap(per_block)(kv_stack["k"], kv_stack["v"],
                                           k_full, v_full)
        new_layer = dict(kv_stack, k=new_k, v=new_v)
        new_index = jnp.maximum(cache["index"], jnp.int32(s))
        return dict(cache, index=new_index, layers=[new_layer])

    # ------------------------------------------------------------- #
    def _clamped(self, name: str) -> str:
        """The planned stage a (possibly beyond-horizon) execution stage
        routes as: chunks past the planned DAG reuse the last planned
        chunk's placement (the `min(c, planned-1)` clamp)."""
        kind, layer, c = workloads.parse_stage_name(name)
        if c is None:
            return name
        return (f"{kind}{'' if layer is None else layer}"
                f"/c{min(c, self.n_chunks_planned - 1)}")

    def _executor_for(self, splits: list[int]) -> PlanExecutor:
        """The executor for one chunk-split signature, reused through
        `executor_cache` (a `dispatch.PlanCache` keyed by the splits
        tuple): a structural (uncosted) prefill DAG of the actual chunks
        supplies the node names / edges / timeline order; the planned
        assignment routes it, with chunks beyond the planned horizon
        clamped onto the last planned chunk's placement."""
        def build() -> PlanExecutor:
            skeleton = workloads.prefill_dag(
                self._dims, prefill_len=sum(splits), chunk=self.chunk,
                batch=1, kv_home=self._kv_home, costed=False)
            assignment = {name: self.assignment[self._clamped(name)]
                          for name in skeleton.nodes}
            return PlanExecutor(skeleton, assignment, self.faces)
        return self.executor_cache.get_or_plan(tuple(splits), build)

    def devices_for(self, s_len: int) -> dict[str, str]:
        """Stage name -> device for a prompt of `s_len` tokens (the
        clamped planned assignment the executor routes) — derived from
        the structural DAG (the node-name source of truth), without
        touching the executor cache."""
        skeleton = workloads.prefill_dag(
            self._dims, prefill_len=s_len, chunk=self.chunk, batch=1,
            kv_home=self._kv_home, costed=False)
        return {name: self.assignment[self._clamped(name)]
                for name in skeleton.nodes}

    def chunk_splits(self, s_len: int) -> list[int]:
        """Chunk lengths a prompt of `s_len` tokens is processed in (all
        `self.chunk` long except a possibly ragged tail) — the same
        split the planned DAG uses (`workloads.prefill_chunk_splits`)."""
        return workloads.prefill_chunk_splits(s_len, self.chunk)

    # ------------------------------------------------------------- #
    def _bind(self, params, toks, splits):
        """The executor's workload surface for one prompt: map a prefill
        node name (`"{kind}{layer}/c{chunk}"`) to its argument tuple.
        Cross-chunk attention concatenates every LIVE prior chunk's K/V
        from the environment — the executable twin of the DAG's fan-in
        edges, banded by the same `workloads.prefill_live_from` bound
        the builder drops dead edges with (a sliding window narrower
        than the prompt makes old chunks' KV unreadable; concatenating
        them anyway would feed the stage keys the plan never priced).
        The banded prefix starts at absolute position
        `offs[live_from[c]]`, so the true key positions thread through
        to the attn stage explicitly."""
        cfg = self.cfg
        stacked = params["layers"][0]
        lp = [jax.tree.map(lambda l, i=i: l[i], stacked)
              for i in range(cfg.n_blocks)]
        if self._moe and getattr(cfg, "quant", "") == "int8":
            self._q8_layers = self._q8_stacked(stacked["mlp"])
        wv = params["embed"] if cfg.tie_embeddings else params["unembed"]
        offs = [0]
        for t in splits:
            offs.append(offs[-1] + t)
        live_from = workloads.prefill_live_from(splits, cfg.sliding_window)

        def kv_prefix(env, i, c, idx):
            parts = [env[f"qkv{i}/c{j}"][idx]
                     for j in range(live_from[c], c + 1)]
            return parts[0] if len(parts) == 1 \
                else jnp.concatenate(parts, axis=1)

        res_kind = "combine" if self._moe else "mlp"

        def bind(name, env):
            kind, i, c = workloads.parse_stage_name(name)
            if kind == "head":
                return (env[f"{res_kind}{cfg.n_blocks - 1}"
                            f"/c{len(splits) - 1}"],
                        params["final_norm"], wv)
            c0, t = offs[c], splits[c]
            if kind == "embed":
                q_pos = jnp.arange(c0, c0 + t, dtype=jnp.int32)
                return (params["embed"], toks[:, c0:c0 + t],
                        jnp.broadcast_to(q_pos[None, :], (1, t)))
            if kind == "qkv":
                x = (env[f"{res_kind}{i - 1}/c{c}"] if i
                     else env[f"embed/c{c}"][0])
                _, sin, cos = env[f"embed/c{c}"]
                return x, sin, cos, lp[i]["ln1"], lp[i]["attn"]
            if kind == "attn":
                q = env[f"qkv{i}/c{c}"][0]
                q_pos = jnp.arange(c0, c0 + t, dtype=jnp.int32)
                k_pos = jnp.arange(offs[live_from[c]], c0 + t,
                                   dtype=jnp.int32)
                return (q, kv_prefix(env, i, c, 1),
                        kv_prefix(env, i, c, 2), q_pos, k_pos)
            if kind == "o":
                x = (env[f"{res_kind}{i - 1}/c{c}"] if i
                     else env[f"embed/c{c}"][0])
                return x, env[f"attn{i}/c{c}"], lp[i]["attn"]
            if kind == "mlp":
                return env[f"o{i}/c{c}"], lp[i]["ln2"], lp[i]["mlp"]
            if kind in ("router", "expert", "combine"):
                return self._bind_moe(name, env, lp, chunk=f"/c{c}")
            raise KeyError(f"unknown prefill stage {name!r}")
        return bind

    def __call__(self, params, cache, tokens, slot):
        cfg = self.cfg
        toks = tokens[None]              # (1, S) like the fused prefill
        s_len = int(toks.shape[1])
        splits = self.chunk_splits(s_len)
        n = cfg.n_blocks
        executor = self._executor_for(splits)
        # keep: the K/V assembly reads every chunk's qkv after the run,
        # and every layer's qkv binds its chunk's embed output (sin/cos)
        # although the DAG only edges embed/c -> qkv0/c, o0/c
        env = executor.run(
            self._bind(params, toks, splits),
            keep={"head", *(f"embed/c{c}" for c in range(len(splits))),
                  *(f"qkv{i}/c{c}" for i in range(n)
                    for c in range(len(splits)))},
            tracer=self.tracer)
        logits = env["head"]
        k_full = jnp.stack([
            jnp.concatenate([env[f"qkv{i}/c{c}"][1]
                             for c in range(len(splits))], axis=1)
            if len(splits) > 1 else env[f"qkv{i}/c0"][1]
            for i in range(n)])
        v_full = jnp.stack([
            jnp.concatenate([env[f"qkv{i}/c{c}"][2]
                             for c in range(len(splits))], axis=1)
            if len(splits) > 1 else env[f"qkv{i}/c0"][2]
            for i in range(n)])
        new_cache = self._scatter(cache, k_full, v_full, slot)
        return logits[0, -1], new_cache
