"""Dispatch-backed serving: route prefill AND decode through planner plans.

`DispatchDecodeStep` is a drop-in replacement for `ServeEngine`'s jitted
decode callable (same `(params, cache, tokens, slot_pos, live_mask, key)`
signature) and `DispatchPrefillStep` replaces its jitted prefill-one
callable (`(params, cache, tokens, slot) -> (last_logits, cache)`), both
selected with `ServeEngine(..., engine="dispatch")`. Instead of one fused
jit, each step is decomposed into the stages of its operator DAG
(`dispatch.workloads.decode_dag` / `dispatch.workloads.prefill_dag`) and
each stage runs on the device the offload planner chose for it:

  * host stages (`xeon` / `titan_v` in the model) run under per-stage jit,
    one trace per stage *kind* — all layers share it;
  * PIM stages run through `dispatch.runtime.bank_face` (decode: batch
    slots sharded over banks — each bank owns its slots' activations and
    KV rows, the continuous-batching-across-banks layout of DESIGN.md §4)
    or a sequence-sharded face (prefill: the chunk's token rows shard over
    banks, weights and the KV prefix replicate); the body stays a pure
    bank-local phase.

Every stage computes exactly what `models.forward` computes for that slice
of the step (same library calls: `_qkv`, `write_decode`/`write_prefill`,
`cached_attention`, `mlp_forward`, ...). For decode the composed step is
bit-identical to the single-jit engine; for prefill the per-stage
decomposition changes XLA fusion boundaries, so agreement is
ulp-level rather than bitwise (~1e-7 relative at f32) — the serving gates
in `tests/test_serve.py` therefore pin decode token-identity on the
default dtype and the mixed prefill+decode run on the f32 model (the same
precedent as the two-bank decode gate, DESIGN.md §9/§10).

Planning happens once at engine construction: the model config is mapped
to `DecodeDims`, the DAGs are built with the KV cache homed on the PIM
system (bank-resident KV), and `placement.plan` runs the ladder — exact
frontier DP for the decode DAG (width 2) and for prefill up to 2 chunks;
wider chunked prefill falls to bounded branch-and-bound (DESIGN.md §10).
The chosen assignment routes stages by name; `force_assignment` overrides
it for tests and ablations.

Scope: dense attention decoder LMs (every pattern position `attn`+`dense`,
no cross-attention/MoE/SSM) with an unsharded host mesh — the dispatch
layer does its own distribution through the BankGrid.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid, make_bank_mesh
from ..dispatch import workloads
from ..dispatch.placement import Plan, plan as plan_placement
from ..dispatch.runtime import bank_face
from ..models import ModelConfig, Shardings
from ..models import cache as cache_lib
from ..models import layers as L


def dims_for_config(cfg: ModelConfig, batch_slots: int,
                    max_len: int) -> workloads.DecodeDims:
    """Map a serving config onto the decode DAG's planning dims. The KV
    cache is sized as the engine actually allocates it — GQA head count
    and the config dtype's itemsize — so the migration charge matches the
    bytes a real migration would move."""
    return workloads.DecodeDims(
        d_model=cfg.d_model, n_heads=cfg.n_heads, head_dim=cfg.hd,
        d_ff=cfg.d_ff, seq=cache_lib.cache_width(cfg, max_len),
        vocab=cfg.padded_vocab, n_layers=cfg.n_layers, batch=batch_slots,
        n_kv_heads=cfg.n_kv_heads,
        kv_itemsize=jnp.dtype(cfg.dtype).itemsize)


def _check_dispatchable(cfg: ModelConfig, shd: Shardings) -> None:
    pattern = cfg.layer_pattern()
    ok = (len(pattern) == 1 and pattern[0].kind == "attn"
          and pattern[0].mlp == "dense" and not pattern[0].cross_attn
          and not cfg.encoder_layers)
    if not ok:
        raise ValueError(
            f"engine='dispatch' supports dense attention decoders; "
            f"{cfg.name} has pattern {pattern}")
    if shd.mesh is not None:
        raise ValueError("engine='dispatch' distributes through the "
                         "BankGrid; pass an unsharded Shardings")


def make_dispatch_decode_step(cfg: ModelConfig, shd: Shardings,
                              **kwargs) -> "DispatchDecodeStep":
    """`make_decode_step`'s dispatch twin: plan the decode DAG and compile
    the planner's chosen plan into an executable step (same call signature
    as the engine's jitted `_decode`)."""
    return DispatchDecodeStep(cfg, shd, **kwargs)


class DispatchDecodeStep:
    """Planner-routed decode step with the jit engine's call signature."""

    def __init__(self, cfg: ModelConfig, shd: Shardings, *,
                 batch_slots: int, max_len: int, temperature: float = 0.0,
                 grid: BankGrid | None = None,
                 devices: tuple[str, ...] = ("xeon", "upmem_2556"),
                 kv_home: str | None = "upmem_2556",
                 objective: str = "serial",
                 force_assignment: dict[str, str] | None = None):
        _check_dispatchable(cfg, shd)
        self.cfg, self.shd = cfg, shd
        self.temperature = temperature
        self.grid = grid or BankGrid(make_bank_mesh())
        if batch_slots % self.grid.n_banks:
            raise ValueError(f"batch_slots={batch_slots} must divide over "
                             f"{self.grid.n_banks} bank(s)")
        self.dag = workloads.decode_dag(
            dims_for_config(cfg, batch_slots, max_len), kv_home=kv_home)
        self.plan: Plan = plan_placement(self.dag, devices=devices,
                                         objective=objective)
        self.assignment = dict(self.plan.assignment)
        if force_assignment:
            self.assignment.update(force_assignment)
        # the executable stage names and the DAG's node names are the
        # routing contract — any drift must fail loudly here, not fall
        # back to host execution (which the token-identity tests could
        # never distinguish from a correctly routed plan)
        expected = {"embed", "head"}
        for i in range(cfg.n_blocks):
            expected |= {f"qkv{i}", f"attn{i}", f"o{i}", f"mlp{i}"}
        missing = expected - set(self.assignment)
        if missing:
            raise ValueError(f"plan is missing stages {sorted(missing)}; "
                             "decode_dag node names drifted from the "
                             "executable stages")

        #: host faces: one jit per stage kind, shared by all layers
        self._host = {kind: jax.jit(fn) for kind, fn, _, _ in self._stages()}
        self._pim: dict[str, Any] = {}   # built lazily (grid lowering)
        self._sample = jax.jit(self._sample_fn)

    # ------------------------------------------------------------- #
    # stage bodies — each mirrors models.forward's decode path exactly
    # ------------------------------------------------------------- #

    def _stages(self):
        """(kind, host_fn, batched-arg flags, n_outputs) for every stage."""
        return [
            ("embed", self._embed_fn, (False, True, True), 3),
            ("qkv", self._qkv_fn, (True, True, True, False, False), 3),
            ("attn", self._attn_fn, (True,) * 6, 3),
            ("o", self._o_fn, (True, True, False), 1),
            ("mlp", self._mlp_fn, (True, False, False), 1),
            ("head", self._head_fn, (True, False, False), 1),
        ]

    def _embed_fn(self, table, tokens, slot_pos):
        x = table[tokens].astype(self.cfg.dtype)
        positions = slot_pos[:, None]
        if self.cfg.rope == "none":
            b = tokens.shape[0]
            sin = cos = jnp.zeros((b, 1, self.cfg.hd // 2), jnp.float32)
        else:
            sin, cos = L.rope_sincos(positions, self.cfg)
        return x, sin, cos

    def _qkv_fn(self, x, sin, cos, ln1, attn_p):
        h = L.apply_norm(x, ln1, self.cfg)
        rs = None if self.cfg.rope == "none" else sin
        rc = None if self.cfg.rope == "none" else cos
        return L._qkv(h, attn_p, self.cfg, self.shd, rope_sin=rs,
                      rope_cos=rc, heads_tp=False)

    def _attn_fn(self, q, k, v, k_cache, v_cache, attn_index):
        width = k_cache.shape[1]
        new_kv = cache_lib.write_decode({"k": k_cache, "v": v_cache},
                                        k, v, attn_index, width)
        pos = cache_lib.slot_positions(attn_index + 1, width)
        o = L.cached_attention(q, new_kv["k"], new_kv["v"], pos,
                               attn_index, self.cfg, self.shd)
        return o, new_kv["k"], new_kv["v"]

    def _o_fn(self, x, o, attn_p):
        return x + L.attn_out(o, attn_p, x.dtype, self.shd)

    def _mlp_fn(self, x, ln2, mlp_p):
        h = L.apply_norm(x, ln2, self.cfg)
        x = x + L.mlp_forward(h, mlp_p, self.cfg, self.shd)
        return self.shd.act(x, "batch", "seq", None)

    def _head_fn(self, x, norm_p, wv):
        from ..models.transformer import mask_vocab_padding
        x = L.apply_norm(x, norm_p, self.cfg)
        logits = jnp.einsum("bsd,vd->bsv", x, wv.astype(x.dtype))
        return mask_vocab_padding(logits, self.cfg)

    def _sample_fn(self, logits, tokens, slot_pos, live_mask, key):
        from .engine import sample
        nxt = sample(logits[:, -1], key, self.temperature)
        nxt = jnp.where(live_mask, nxt, tokens[:, 0])
        new_pos = jnp.where(live_mask, slot_pos + 1, slot_pos)
        return nxt[:, None], new_pos

    # ------------------------------------------------------------- #
    def _run(self, name: str, kind: str, *args):
        device = self.assignment[name]   # KeyError = name-contract break
        if device.startswith("upmem"):
            if kind not in self._pim:
                _, fn, batched, n_out = next(
                    s for s in self._stages() if s[0] == kind)
                self._pim[kind] = jax.jit(
                    bank_face(self.grid, fn, batched, n_out))
            return self._pim[kind](*args)
        return self._host[kind](*args)

    def devices_used(self) -> dict[str, str]:
        """Stage name -> device name the step actually routes through."""
        return dict(self.assignment)

    def __call__(self, params, cache, tokens, slot_pos, live_mask, key):
        cfg = self.cfg
        index = cache["index"]
        attn_index = slot_pos            # per-row positions (cont. batching)
        x, sin, cos = self._run("embed", "embed",
                                params["embed"], tokens, slot_pos)
        stacked = params["layers"][0]
        kv_stack = cache["layers"][0]
        new_ks, new_vs = [], []
        for i in range(cfg.n_blocks):
            lp = jax.tree.map(lambda l: l[i], stacked)
            q, k, v = self._run(f"qkv{i}", "qkv", x, sin, cos,
                                lp["ln1"], lp["attn"])
            o, nk, nv = self._run(f"attn{i}", "attn", q, k, v,
                                  kv_stack["k"][i], kv_stack["v"][i],
                                  attn_index)
            x = self._run(f"o{i}", "o", x, o, lp["attn"])
            x = self._run(f"mlp{i}", "mlp", x, lp["ln2"], lp["mlp"])
            new_ks.append(nk)
            new_vs.append(nv)
        wv = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = self._run("head", "head", x, params["final_norm"], wv)
        nxt, new_pos = self._sample(logits, tokens, slot_pos, live_mask, key)
        new_layer = dict(kv_stack, k=jnp.stack(new_ks), v=jnp.stack(new_vs))
        new_index = jnp.maximum(index + 1,
                                jnp.max(slot_pos) + 1).astype(jnp.int32)
        new_cache = dict(cache, index=new_index, layers=[new_layer])
        return nxt, new_cache, new_pos


# ------------------------------------------------------------------- #
# planner-routed chunked prefill
# ------------------------------------------------------------------- #

class DispatchPrefillStep:
    """Planner-routed chunked prefill with the engine's prefill-one
    signature: `(params, cache, tokens, slot) -> (last_logits, new_cache)`.

    The prompt is processed `chunk` tokens at a time; each chunk runs the
    per-layer qkv -> attention -> o -> mlp stage ladder on the device the
    planner assigned to the matching `workloads.prefill_dag` node
    (`"qkv{layer}/c{chunk}"`, ...). Chunk attention attends each query row
    causally over all K/V rows produced so far — the same math
    `models.transformer._plain_attention` computes, with absolute
    positions passed explicitly so a bank-sharded chunk masks correctly.
    After the last chunk, the assembled K/V rows are written into the
    batched cache at `slot` exactly like the fused engine's prefill
    (`cache.write_prefill` + per-block scatter), and the head runs on the
    final chunk only (the engine samples from the prompt's last position).

    Planning happens once, on a canonical DAG of `planned_chunks` chunks
    (prompts with more chunks reuse the last planned chunk's placement —
    the `min(c, planned-1)` clamp; prompts with fewer just use a prefix).
    The cross-chunk KV fan-in widens the DAG frontier to ~2*chunks+1, so
    beyond 2 chunks the ladder's bounded branch-and-bound rung plans it
    (budgets are constructor knobs; DESIGN.md §10). `objective` defaults
    to `"overlapped"` — prefill is where batched chunk transfers have
    compute to hide under.

    PIM-assigned stages run as BankGrid local phases with the chunk's
    token rows sharded over banks (weights and the KV prefix replicate);
    a chunk length not divisible by the bank count falls back to the host
    face for that call (single-bank dev containers always shard).

    Numerics: every stage mirrors `models.forward`'s prefill path
    library-call-for-library-call, but per-stage jit boundaries change
    XLA fusion, so agreement with the fused engine is ulp-level, not
    bitwise (module docstring); prompts at or above the fused path's
    flash-attention threshold (2048 tokens) are out of scope."""

    def __init__(self, cfg: ModelConfig, shd: Shardings, *,
                 max_len: int, grid: BankGrid | None = None,
                 devices: tuple[str, ...] = ("xeon", "upmem_2556"),
                 kv_home: str | None = "upmem_2556",
                 chunk: int | None = None, planned_chunks: int = 4,
                 objective: str = "overlapped",
                 state_budget: int = 200_000, bnb_budget: int = 20_000,
                 force_assignment: dict[str, str] | None = None):
        _check_dispatchable(cfg, shd)
        self.cfg, self.shd = cfg, shd
        self.grid = grid or BankGrid(make_bank_mesh())
        self.max_len = max_len
        self.chunk = int(chunk if chunk is not None else min(512, max_len))
        if self.chunk < 1:
            raise ValueError(f"prefill chunk must be >= 1, got {self.chunk}")
        canonical = min(max_len, planned_chunks * self.chunk)
        self.n_chunks_planned = len(
            workloads.prefill_chunk_splits(canonical, self.chunk))
        dims = dims_for_config(cfg, 1, max_len)
        self.dag = workloads.prefill_dag(
            dims, prefill_len=canonical, chunk=self.chunk, batch=1,
            kv_home=kv_home)
        self.plan: Plan = plan_placement(
            self.dag, devices=devices, objective=objective,
            state_budget=state_budget, bnb_budget=bnb_budget)
        self.assignment = dict(self.plan.assignment)
        if force_assignment:
            self.assignment.update(force_assignment)
        # routing contract: executable stage names == DAG node names
        expected = {"head"}
        for c in range(self.n_chunks_planned):
            expected.add(f"embed/c{c}")
            for i in range(cfg.n_blocks):
                expected |= {f"qkv{i}/c{c}", f"attn{i}/c{c}",
                             f"o{i}/c{c}", f"mlp{i}/c{c}"}
        missing = expected - set(self.assignment)
        if missing:
            raise ValueError(f"plan is missing stages {sorted(missing)}; "
                             "prefill_dag node names drifted from the "
                             "executable stages")

        self._host = {kind: jax.jit(fn)
                      for kind, fn, _, _ in self._stages()}
        self._pim: dict[str, Any] = {}   # built lazily (grid lowering)
        self._scatter = jax.jit(self._scatter_fn)

    # ------------------------------------------------------------- #
    # stage bodies — each mirrors models.forward's prefill path exactly
    # ------------------------------------------------------------- #

    def _stages(self):
        """(kind, host_fn, per-arg seq-shard axis or None, n_outputs):
        axis 1 shards a chunk's token rows over banks, axis 0 shards a
        1-D positions array, None replicates (weights, the KV prefix)."""
        return [
            ("embed", self._embed_fn, (None, 1, 1), 3),
            ("qkv", self._qkv_fn, (1, 1, 1, None, None), 3),
            ("attn", self._attn_fn, (1, None, None, 0), 1),
            ("o", self._o_fn, (1, 1, None), 1),
            ("mlp", self._mlp_fn, (1, None, None), 1),
            ("head", self._head_fn, (1, None, None), 1),
        ]

    def _embed_fn(self, table, tokens, positions):
        x = table[tokens].astype(self.cfg.dtype)
        if self.cfg.rope == "none":
            b, t = tokens.shape
            sin = cos = jnp.zeros((b, t, self.cfg.hd // 2), jnp.float32)
        else:
            sin, cos = L.rope_sincos(positions, self.cfg)
        return x, sin, cos

    def _qkv_fn(self, x, sin, cos, ln1, attn_p):
        h = L.apply_norm(x, ln1, self.cfg)
        rs = None if self.cfg.rope == "none" else sin
        rc = None if self.cfg.rope == "none" else cos
        return L._qkv(h, attn_p, self.cfg, self.shd, rope_sin=rs,
                      rope_cos=rc, heads_tp=True)

    def _attn_fn(self, q, kp, vp, q_pos):
        # _plain_attention with absolute q positions passed explicitly
        # (bank-sharded chunks must not rebuild them from a local arange)
        b, sq, h, hd = q.shape
        skv, kvh = kp.shape[1], kp.shape[2]
        if kvh != h:
            kp = jnp.repeat(kp, h // kvh, axis=2)
            vp = jnp.repeat(vp, h // kvh, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kp,
                       preferred_element_type=jnp.float32) / math.sqrt(hd)
        k_pos = jnp.arange(skv)
        mask = q_pos[:, None] >= k_pos[None, :]
        if self.cfg.sliding_window:
            mask &= q_pos[:, None] - k_pos[None, :] < self.cfg.sliding_window
        s = jnp.where(mask, s, -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", a, vp)

    def _o_fn(self, x, o, attn_p):
        return x + L.attn_out(o, attn_p, x.dtype, self.shd)

    def _mlp_fn(self, x, ln2, mlp_p):
        h = L.apply_norm(x, ln2, self.cfg)
        x = x + L.mlp_forward(h, mlp_p, self.cfg, self.shd)
        return self.shd.act(x, "batch", "seq", None)

    def _head_fn(self, x, norm_p, wv):
        from ..models.transformer import mask_vocab_padding
        x = L.apply_norm(x, norm_p, self.cfg)
        logits = jnp.einsum("bsd,vd->bsv", x, wv.astype(x.dtype))
        return mask_vocab_padding(logits, self.cfg)

    def _scatter_fn(self, cache, k_full, v_full, slot):
        # mirror ServeEngine._prefill_one_fn: write the prompt's rows into
        # a fresh zeroed slot-cache (ring semantics via write_prefill),
        # then scatter that row into the batched cache at `slot`
        kv_stack = cache["layers"][0]
        s = k_full.shape[2]

        def per_block(dst_k, dst_v, kf, vf):
            one = {"k": jnp.zeros_like(dst_k[:1]),
                   "v": jnp.zeros_like(dst_v[:1])}
            one = cache_lib.write_prefill(one, kf, vf)
            k = jax.lax.dynamic_update_slice_in_dim(
                dst_k, one["k"].astype(dst_k.dtype), slot, axis=0)
            v = jax.lax.dynamic_update_slice_in_dim(
                dst_v, one["v"].astype(dst_v.dtype), slot, axis=0)
            return k, v

        new_k, new_v = jax.vmap(per_block)(kv_stack["k"], kv_stack["v"],
                                           k_full, v_full)
        new_layer = dict(kv_stack, k=new_k, v=new_v)
        new_index = jnp.maximum(cache["index"], jnp.int32(s))
        return dict(cache, index=new_index, layers=[new_layer])

    # ------------------------------------------------------------- #
    def _run(self, name: str, kind: str, t: int, *args):
        device = self.assignment[name]   # KeyError = name-contract break
        if device.startswith("upmem") and t % self.grid.n_banks == 0:
            if kind not in self._pim:
                _, fn, axes, n_out = next(
                    s for s in self._stages() if s[0] == kind)
                in_specs = tuple(
                    P() if ax is None
                    else (P(self.grid.axis) if ax == 0
                          else P(None, self.grid.axis))
                    for ax in axes)
                out = (tuple(P(None, self.grid.axis)
                             for _ in range(n_out))
                       if n_out > 1 else P(None, self.grid.axis))
                self._pim[kind] = jax.jit(self.grid.local(
                    fn, in_specs=in_specs, out_specs=out))
            return self._pim[kind](*args)
        return self._host[kind](*args)

    def devices_used(self) -> dict[str, str]:
        """Stage name -> device name the step actually routes through."""
        return dict(self.assignment)

    def chunk_splits(self, s_len: int) -> list[int]:
        """Chunk lengths a prompt of `s_len` tokens is processed in (all
        `self.chunk` long except a possibly ragged tail) — the same
        split the planned DAG uses (`workloads.prefill_chunk_splits`)."""
        return workloads.prefill_chunk_splits(s_len, self.chunk)

    def __call__(self, params, cache, tokens, slot):
        cfg = self.cfg
        toks = tokens[None]              # (1, S) like the fused prefill
        s_len = int(toks.shape[1])
        stacked = params["layers"][0]
        n = cfg.n_blocks
        ks: list[list] = [[] for _ in range(n)]
        vs: list[list] = [[] for _ in range(n)]
        x = None
        c0 = 0
        for c, t in enumerate(self.chunk_splits(s_len)):
            cc = min(c, self.n_chunks_planned - 1)
            q_pos = jnp.arange(c0, c0 + t, dtype=jnp.int32)
            positions = jnp.broadcast_to(q_pos[None, :], (1, t))
            x, sin, cos = self._run(f"embed/c{cc}", "embed", t,
                                    params["embed"], toks[:, c0:c0 + t],
                                    positions)
            for i in range(n):
                lp = jax.tree.map(lambda l: l[i], stacked)
                q, k, v = self._run(f"qkv{i}/c{cc}", "qkv", t, x, sin, cos,
                                    lp["ln1"], lp["attn"])
                ks[i].append(k)
                vs[i].append(v)
                kp = (ks[i][0] if len(ks[i]) == 1
                      else jnp.concatenate(ks[i], axis=1))
                vp = (vs[i][0] if len(vs[i]) == 1
                      else jnp.concatenate(vs[i], axis=1))
                o = self._run(f"attn{i}/c{cc}", "attn", t, q, kp, vp, q_pos)
                x = self._run(f"o{i}/c{cc}", "o", t, x, o, lp["attn"])
                x = self._run(f"mlp{i}/c{cc}", "mlp", t, x, lp["ln2"],
                              lp["mlp"])
            c0 += t
        wv = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = self._run("head", "head", x.shape[1], x,
                           params["final_norm"], wv)
        k_full = jnp.stack([jnp.concatenate(ks[i], axis=1)
                            if len(ks[i]) > 1 else ks[i][0]
                            for i in range(n)])
        v_full = jnp.stack([jnp.concatenate(vs[i], axis=1)
                            if len(vs[i]) > 1 else vs[i][0]
                            for i in range(n)])
        new_cache = self._scatter(cache, k_full, v_full, slot)
        return logits[0, -1], new_cache
