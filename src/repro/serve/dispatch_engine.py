"""Dispatch-backed decode: route the serving engine through planner plans.

`DispatchDecodeStep` is a drop-in replacement for `ServeEngine`'s jitted
decode callable (same `(params, cache, tokens, slot_pos, live_mask, key)`
signature), selected with `ServeEngine(..., engine="dispatch")`. Instead of
one fused jit, the decode step is decomposed into the stages of the decode
DAG (`dispatch.workloads.decode_dag`) and each stage runs on the device the
offload planner chose for it:

  * host stages (`xeon` / `titan_v` in the model) run under per-stage jit,
    one trace per stage *kind* — all layers share it;
  * PIM stages run through `dispatch.runtime.bank_face`: batch slots are
    sharded over banks (each bank owns its slots' activations and KV rows,
    the continuous-batching-across-banks layout of DESIGN.md §4), weights
    replicate, and the body is a pure bank-local phase.

Every stage computes exactly what `models.forward`'s decode path computes
for that slice of the step (same library calls: `_qkv`, `write_decode`,
`cached_attention`, `mlp_forward`, ...), so the composed step is
numerically equivalent to the single-jit engine — `tests/test_serve.py`
pins token-for-token identity over a continuous-batching run.

Planning happens once at engine construction: the model config is mapped
to `DecodeDims`, the decode DAG is built with the KV cache homed on the
PIM system (bank-resident KV), and `placement.plan` runs the exact ladder
(the DAG's frontier width is 2, so the frontier DP is exact). The chosen
assignment routes stages by name; `force_assignment` overrides it for
tests and ablations.

Scope: dense attention decoder LMs (every pattern position `attn`+`dense`,
no cross-attention/MoE/SSM) with an unsharded host mesh — the dispatch
layer does its own distribution through the BankGrid.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core.bank_parallel import BankGrid, make_bank_mesh
from ..dispatch import workloads
from ..dispatch.placement import Plan, plan as plan_placement
from ..dispatch.runtime import bank_face
from ..models import ModelConfig, Shardings
from ..models import cache as cache_lib
from ..models import layers as L


def dims_for_config(cfg: ModelConfig, batch_slots: int,
                    max_len: int) -> workloads.DecodeDims:
    """Map a serving config onto the decode DAG's planning dims. The KV
    cache is sized as the engine actually allocates it — GQA head count
    and the config dtype's itemsize — so the migration charge matches the
    bytes a real migration would move."""
    return workloads.DecodeDims(
        d_model=cfg.d_model, n_heads=cfg.n_heads, head_dim=cfg.hd,
        d_ff=cfg.d_ff, seq=cache_lib.cache_width(cfg, max_len),
        vocab=cfg.padded_vocab, n_layers=cfg.n_layers, batch=batch_slots,
        n_kv_heads=cfg.n_kv_heads,
        kv_itemsize=jnp.dtype(cfg.dtype).itemsize)


def _check_dispatchable(cfg: ModelConfig, shd: Shardings) -> None:
    pattern = cfg.layer_pattern()
    ok = (len(pattern) == 1 and pattern[0].kind == "attn"
          and pattern[0].mlp == "dense" and not pattern[0].cross_attn
          and not cfg.encoder_layers)
    if not ok:
        raise ValueError(
            f"engine='dispatch' supports dense attention decoders; "
            f"{cfg.name} has pattern {pattern}")
    if shd.mesh is not None:
        raise ValueError("engine='dispatch' distributes through the "
                         "BankGrid; pass an unsharded Shardings")


def make_dispatch_decode_step(cfg: ModelConfig, shd: Shardings,
                              **kwargs) -> "DispatchDecodeStep":
    """`make_decode_step`'s dispatch twin: plan the decode DAG and compile
    the planner's chosen plan into an executable step (same call signature
    as the engine's jitted `_decode`)."""
    return DispatchDecodeStep(cfg, shd, **kwargs)


class DispatchDecodeStep:
    """Planner-routed decode step with the jit engine's call signature."""

    def __init__(self, cfg: ModelConfig, shd: Shardings, *,
                 batch_slots: int, max_len: int, temperature: float = 0.0,
                 grid: BankGrid | None = None,
                 devices: tuple[str, ...] = ("xeon", "upmem_2556"),
                 kv_home: str | None = "upmem_2556",
                 force_assignment: dict[str, str] | None = None):
        _check_dispatchable(cfg, shd)
        self.cfg, self.shd = cfg, shd
        self.temperature = temperature
        self.grid = grid or BankGrid(make_bank_mesh())
        if batch_slots % self.grid.n_banks:
            raise ValueError(f"batch_slots={batch_slots} must divide over "
                             f"{self.grid.n_banks} bank(s)")
        self.dag = workloads.decode_dag(
            dims_for_config(cfg, batch_slots, max_len), kv_home=kv_home)
        self.plan: Plan = plan_placement(self.dag, devices=devices)
        self.assignment = dict(self.plan.assignment)
        if force_assignment:
            self.assignment.update(force_assignment)
        # the executable stage names and the DAG's node names are the
        # routing contract — any drift must fail loudly here, not fall
        # back to host execution (which the token-identity tests could
        # never distinguish from a correctly routed plan)
        expected = {"embed", "head"}
        for i in range(cfg.n_blocks):
            expected |= {f"qkv{i}", f"attn{i}", f"o{i}", f"mlp{i}"}
        missing = expected - set(self.assignment)
        if missing:
            raise ValueError(f"plan is missing stages {sorted(missing)}; "
                             "decode_dag node names drifted from the "
                             "executable stages")

        #: host faces: one jit per stage kind, shared by all layers
        self._host = {kind: jax.jit(fn) for kind, fn, _, _ in self._stages()}
        self._pim: dict[str, Any] = {}   # built lazily (grid lowering)
        self._sample = jax.jit(self._sample_fn)

    # ------------------------------------------------------------- #
    # stage bodies — each mirrors models.forward's decode path exactly
    # ------------------------------------------------------------- #

    def _stages(self):
        """(kind, host_fn, batched-arg flags, n_outputs) for every stage."""
        return [
            ("embed", self._embed_fn, (False, True, True), 3),
            ("qkv", self._qkv_fn, (True, True, True, False, False), 3),
            ("attn", self._attn_fn, (True,) * 6, 3),
            ("o", self._o_fn, (True, True, False), 1),
            ("mlp", self._mlp_fn, (True, False, False), 1),
            ("head", self._head_fn, (True, False, False), 1),
        ]

    def _embed_fn(self, table, tokens, slot_pos):
        x = table[tokens].astype(self.cfg.dtype)
        positions = slot_pos[:, None]
        if self.cfg.rope == "none":
            b = tokens.shape[0]
            sin = cos = jnp.zeros((b, 1, self.cfg.hd // 2), jnp.float32)
        else:
            sin, cos = L.rope_sincos(positions, self.cfg)
        return x, sin, cos

    def _qkv_fn(self, x, sin, cos, ln1, attn_p):
        h = L.apply_norm(x, ln1, self.cfg)
        rs = None if self.cfg.rope == "none" else sin
        rc = None if self.cfg.rope == "none" else cos
        return L._qkv(h, attn_p, self.cfg, self.shd, rope_sin=rs,
                      rope_cos=rc, heads_tp=False)

    def _attn_fn(self, q, k, v, k_cache, v_cache, attn_index):
        width = k_cache.shape[1]
        new_kv = cache_lib.write_decode({"k": k_cache, "v": v_cache},
                                        k, v, attn_index, width)
        pos = cache_lib.slot_positions(attn_index + 1, width)
        o = L.cached_attention(q, new_kv["k"], new_kv["v"], pos,
                               attn_index, self.cfg, self.shd)
        return o, new_kv["k"], new_kv["v"]

    def _o_fn(self, x, o, attn_p):
        return x + L.attn_out(o, attn_p, x.dtype, self.shd)

    def _mlp_fn(self, x, ln2, mlp_p):
        h = L.apply_norm(x, ln2, self.cfg)
        x = x + L.mlp_forward(h, mlp_p, self.cfg, self.shd)
        return self.shd.act(x, "batch", "seq", None)

    def _head_fn(self, x, norm_p, wv):
        from ..models.transformer import mask_vocab_padding
        x = L.apply_norm(x, norm_p, self.cfg)
        logits = jnp.einsum("bsd,vd->bsv", x, wv.astype(x.dtype))
        return mask_vocab_padding(logits, self.cfg)

    def _sample_fn(self, logits, tokens, slot_pos, live_mask, key):
        from .engine import sample
        nxt = sample(logits[:, -1], key, self.temperature)
        nxt = jnp.where(live_mask, nxt, tokens[:, 0])
        new_pos = jnp.where(live_mask, slot_pos + 1, slot_pos)
        return nxt[:, None], new_pos

    # ------------------------------------------------------------- #
    def _run(self, name: str, kind: str, *args):
        device = self.assignment[name]   # KeyError = name-contract break
        if device.startswith("upmem"):
            if kind not in self._pim:
                _, fn, batched, n_out = next(
                    s for s in self._stages() if s[0] == kind)
                self._pim[kind] = jax.jit(
                    bank_face(self.grid, fn, batched, n_out))
            return self._pim[kind](*args)
        return self._host[kind](*args)

    def devices_used(self) -> dict[str, str]:
        return dict(self.assignment)

    def __call__(self, params, cache, tokens, slot_pos, live_mask, key):
        cfg = self.cfg
        index = cache["index"]
        attn_index = slot_pos            # per-row positions (cont. batching)
        x, sin, cos = self._run("embed", "embed",
                                params["embed"], tokens, slot_pos)
        stacked = params["layers"][0]
        kv_stack = cache["layers"][0]
        new_ks, new_vs = [], []
        for i in range(cfg.n_blocks):
            lp = jax.tree.map(lambda l: l[i], stacked)
            q, k, v = self._run(f"qkv{i}", "qkv", x, sin, cos,
                                lp["ln1"], lp["attn"])
            o, nk, nv = self._run(f"attn{i}", "attn", q, k, v,
                                  kv_stack["k"][i], kv_stack["v"][i],
                                  attn_index)
            x = self._run(f"o{i}", "o", x, o, lp["attn"])
            x = self._run(f"mlp{i}", "mlp", x, lp["ln2"], lp["mlp"])
            new_ks.append(nk)
            new_vs.append(nv)
        wv = params["embed"] if cfg.tie_embeddings else params["unembed"]
        logits = self._run("head", "head", x, params["final_norm"], wv)
        nxt, new_pos = self._sample(logits, tokens, slot_pos, live_mask, key)
        new_layer = dict(kv_stack, k=jnp.stack(new_ks), v=jnp.stack(new_vs))
        new_index = jnp.maximum(index + 1,
                                jnp.max(slot_pos) + 1).astype(jnp.int32)
        new_cache = dict(cache, index=new_index, layers=[new_layer])
        return nxt, new_cache, new_pos
