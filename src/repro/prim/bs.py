"""BS — binary search (data analytics, int64). Table I: sequential +
random access, compare only, no sync. Queries are sharded across banks;
the sorted array is replicated to each bank's MRAM (the PrIM layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**21      # 2M queries into a 16 MB sorted array


def make_inputs(n: int, key):
    """n queries against a sorted array of n elements."""
    ka, kq = jax.random.split(key)
    arr = jnp.sort(jax.random.randint(ka, (n,), 0, 1 << 30, jnp.int64))
    queries = jax.random.randint(kq, (n,), 0, 1 << 30, jnp.int64)
    return {"arr": arr, "queries": queries}


def ref(arr, queries):
    return jnp.searchsorted(arr, queries).astype(jnp.int32)


def run_pim(grid: BankGrid, arr, queries):
    def local(a, q):
        return jnp.searchsorted(a, q).astype(jnp.int32)
    return grid.local(local, in_specs=(P(), P(grid.axis)),
                      out_specs=P(grid.axis))(arr, queries)


def counts(n: int) -> WorkloadCounts:
    import math
    steps = max(math.log2(n), 1.0)
    return WorkloadCounts(
        name="BS",
        ops={("compare", "int64"): float(n * steps)},
        bytes_streamed=8.0 * (n * steps + n),   # random probes + queries
        interbank_bytes=0.0,
        flops_equiv=float(n * steps),
        pim_suitable=SUITABLE,
        # CPU probes are dependent 64B-line misses once below the cached
        # tree top (~half the levels); GPU fetches 32B sectors
        bytes_cpu=8.0 * n + 32.0 * n * steps,
        bytes_gpu=8.0 * n + 16.0 * n * steps,
    )
