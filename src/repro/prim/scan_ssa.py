"""SCAN-SSA — prefix sum, scan-scan-add variant (int64). Table I:
sequential, add, handshake+barrier, inter-DPU communication.

Phases (the PrIM SSA structure):
  1. bank-local inclusive scan of the bank's block
  2. exchange: exclusive scan of the per-bank totals (through the host)
  3. bank-local add of the incoming offset"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**27


def make_inputs(n: int, key):
    return {"x": jax.random.randint(key, (n,), -100, 100, jnp.int64)}


def ref(x):
    return jnp.cumsum(x)


def run_pim(grid: BankGrid, x):
    # phase 1: local inclusive scan (+ the bank total)
    def local_scan(xb):
        s = jnp.cumsum(xb)
        return s, s[-1:]
    scanned, totals = grid.local(
        local_scan, in_specs=P(grid.axis),
        out_specs=(P(grid.axis), P(grid.axis)))(x)
    # phase 2: exclusive scan of bank totals (host)
    offsets = grid.exchange_scan_sums(totals)
    # phase 3: local add
    def local_add(sb, ob):
        return sb + ob[0]
    return grid.local(local_add, in_specs=(P(grid.axis), P(grid.axis)),
                      out_specs=P(grid.axis))(scanned, offsets)


def counts(n: int) -> WorkloadCounts:
    return WorkloadCounts(
        name="SCAN-SSA",
        ops={("add", "int64"): 2.0 * n},    # scan + offset add
        bytes_streamed=8.0 * 3 * n,          # read, write scan, rewrite add
        interbank_bytes=8.0 * 64,
        flops_equiv=2.0 * n,
        pim_suitable=SUITABLE,
    )
