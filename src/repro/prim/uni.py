"""UNI — database unique (remove consecutive duplicates, int64). Table I:
sequential, add+compare, handshake+barrier, inter-DPU communication.

Like SEL plus one extra exchange: bank i needs bank i-1's LAST element to
decide whether its own first element is a duplicate (neighbor handshake)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts
from .common import assemble_compact, local_compact

SUITABLE = True
REF_N = 2**27


def make_inputs(n: int, key):
    # runs of duplicates: sorted small-alphabet values
    x = jnp.sort(jax.random.randint(key, (n,), 0, max(n // 4, 4), jnp.int64))
    return {"x": x}


def ref(x):
    keep = jnp.concatenate([jnp.ones((1,), bool), x[1:] != x[:-1]])
    return x[keep]


def run_pim(grid: BankGrid, x):
    # phase 1 (exchange): neighbor handshake — last element of bank i-1
    def last_elem(xb):
        return xb[-1:]
    lasts = grid.local(last_elem, in_specs=P(grid.axis),
                       out_specs=P(grid.axis))(x)
    prev_last = grid.exchange_shift(lasts, offset=1)

    # phase 2: bank-local predicate + compaction
    def local(xb, prevb, bank_first_mask):
        prev = jnp.concatenate([prevb, xb[:-1]])
        keep = xb != prev
        # bank 0's first element is always kept (no predecessor)
        keep = keep | bank_first_mask
        comp, cnt = local_compact(xb, keep)
        return comp, cnt[None]

    b = grid.n_banks
    per = x.shape[0] // b
    first_mask = jnp.zeros((x.shape[0],), bool).at[0].set(True)
    parts, cnts = grid.local(
        local, in_specs=(P(grid.axis), P(grid.axis), P(grid.axis)),
        out_specs=(P(grid.axis), P(grid.axis)))(x, prev_last, first_mask)

    # phase 3: host-side assembly
    parts = parts.reshape(b, -1)
    total = int(jnp.sum(cnts))
    return assemble_compact(parts, cnts, total)[:total]


def counts(n: int) -> WorkloadCounts:
    kept = n / 4
    return WorkloadCounts(
        name="UNI",
        ops={("compare", "int64"): float(n), ("add", "int64"): float(n)},
        bytes_streamed=8.0 * (n + kept),
        interbank_bytes=8.0 * 64,   # neighbor handshake + counts scan
        flops_equiv=float(n),
        pim_suitable=SUITABLE,
    )
