"""SCAN-RSS — prefix sum, reduce-scan-scan variant (int64). Table I:
sequential, add, handshake+barrier, inter-DPU communication.

Phases (RSS trades a second streaming pass for not re-writing the scan):
  1. bank-local reduce (totals only)
  2. exchange: exclusive scan of per-bank totals (host)
  3. bank-local full scan + offset in one pass"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**27


def make_inputs(n: int, key):
    return {"x": jax.random.randint(key, (n,), -100, 100, jnp.int64)}


def ref(x):
    return jnp.cumsum(x)


def run_pim(grid: BankGrid, x):
    # phase 1: local reduce
    totals = grid.local(lambda xb: jnp.sum(xb)[None],
                        in_specs=P(grid.axis), out_specs=P(grid.axis))(x)
    # phase 2: exclusive scan of totals (host)
    offsets = grid.exchange_scan_sums(totals)
    # phase 3: local scan + add in a single pass
    def local_scan_add(xb, ob):
        return jnp.cumsum(xb) + ob[0]
    return grid.local(local_scan_add,
                      in_specs=(P(grid.axis), P(grid.axis)),
                      out_specs=P(grid.axis))(x, offsets)


def counts(n: int) -> WorkloadCounts:
    return WorkloadCounts(
        name="SCAN-RSS",
        ops={("add", "int64"): 2.0 * n},    # reduce + scan
        bytes_streamed=8.0 * 3 * n,          # reduce pass + scan pass + write
        interbank_bytes=8.0 * 64,
        flops_equiv=2.0 * n,
        pim_suitable=SUITABLE,
    )
