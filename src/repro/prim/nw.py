"""NW — Needleman-Wunsch global sequence alignment (bioinformatics, int32).
Table I: sequential + strided, add/sub/compare, barrier, inter-DPU
communication. The paper's canonical BAD-fit workload: every wavefront step
moves block boundaries between DPUs through the host.

Bank-parallel block-wavefront (the PrIM 2-D blocking):
  * columns are partitioned across banks (w = n/B each); rows are processed
    in blocks of height h (R = n/h row-blocks),
  * at wavefront step t, bank b computes row-block r = t - b: a (h, w) DP
    block, given its own previous top row (bank-local carry) and the left
    boundary column received from bank b-1 (exchange_shift per step),
  * the within-row dependence H[i][j] = max(c[j], H[i][j-1] - gap) is
    solved with the max-plus cummax transform
        H[i][p] = cummax(c[p] + gap*p) - gap*p
    so a whole row is one vectorized pass (the 8-tasklet inner loop of the
    UPMEM version becomes a VPU-wide scan).

Scoring: match +1, mismatch -1, linear gap -2 (vs the numpy oracle)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = False   # inter-DPU per wavefront step (Takeaway 3)
REF_N = 2**12      # 4096 x 4096 DP matrix

MATCH, MISMATCH, GAP = 1, -1, 2


def make_inputs(n: int, key):
    ka, kb = jax.random.split(key)
    return {"a": jax.random.randint(ka, (n,), 0, 4, jnp.int32),
            "b": jax.random.randint(kb, (n,), 0, 4, jnp.int32)}


def ref(a, b):
    """Full numpy DP; returns the last row H[n][1..n]."""
    a, b = np.asarray(a), np.asarray(b)
    n, m = len(a), len(b)
    H = np.zeros((n + 1, m + 1), np.int32)
    H[0, :] = -GAP * np.arange(m + 1)
    H[:, 0] = -GAP * np.arange(n + 1)
    for i in range(1, n + 1):
        s = np.where(b == a[i - 1], MATCH, MISMATCH)
        for j in range(1, m + 1):
            H[i, j] = max(H[i - 1, j - 1] + s[j - 1],
                          H[i - 1, j] - GAP, H[i, j - 1] - GAP)
    return jnp.asarray(H[n, 1:])


def _block(a_rows, b_local, top, left_col, corner):
    """Solve one (h, w) DP block. Returns (new_top, right_col)."""
    w = b_local.shape[0]
    gaps = GAP * jnp.arange(w + 1, dtype=jnp.int32)

    def row_fn(carry, inp):
        prev_row, prev_left = carry          # H[i-1][cols], H[i-1][c0]
        a_i, left_val = inp                  # row char, H[i][c0]
        diag = jnp.concatenate([prev_left[None], prev_row[:-1]])
        s = jnp.where(b_local == a_i, MATCH, MISMATCH)
        c = jnp.maximum(diag + s, prev_row - GAP)
        e = jnp.concatenate([left_val[None], c]) + gaps
        h_row = (jax.lax.cummax(e) - gaps)[1:]
        return (h_row, left_val), h_row[-1]

    (new_top, _), right_col = jax.lax.scan(
        row_fn, (top, corner), (a_rows, left_col))
    return new_top, right_col


def run_pim(grid: BankGrid, a, b, block_rows: int | None = None):
    """Returns the final DP row H[n][1..n] (bank-sharded concatenation)."""
    n = int(a.shape[0])
    nb = grid.n_banks
    w = n // nb
    h = block_rows or max(w, 1)
    assert n % nb == 0 and n % h == 0, (n, nb, h)
    r_blocks = n // h

    top = -GAP * (jnp.arange(n, dtype=jnp.int32) + 1)   # H[0][1..n]
    msg = jnp.zeros((nb, h + 1), jnp.int32)             # right_col + corner

    def step_fn(t, a_all, b_loc, top_loc, msg_in):
        bank = jax.lax.axis_index(grid.axis)
        r_idx = t - bank
        active = (r_idx >= 0) & (r_idx < r_blocks)
        r_safe = jnp.clip(r_idx, 0, r_blocks - 1)
        row0 = r_safe * h
        # left boundary: bank 0 uses the DP edge, others the neighbor msg
        bound_left = -GAP * (row0 + 1 + jnp.arange(h, dtype=jnp.int32))
        bound_corner = (-GAP * row0).astype(jnp.int32)
        left_col = jnp.where(bank == 0, bound_left, msg_in[0, :h])
        corner = jnp.where(bank == 0, bound_corner, msg_in[0, h])
        a_rows = jax.lax.dynamic_slice_in_dim(a_all, row0, h)
        send_corner = top_loc[-1]            # H[row0][my last col]
        new_top, right_col = _block(a_rows, b_loc, top_loc, left_col, corner)
        top_out = jnp.where(active, new_top, top_loc)
        msg_out = jnp.concatenate([right_col, send_corner[None]])[None]
        return top_out, msg_out

    for t in range(nb + r_blocks - 1):
        msg_in = grid.exchange_shift(msg, offset=1)   # host handshake
        top, msg = grid.local(
            functools.partial(step_fn, t),
            in_specs=(P(), P(grid.axis), P(grid.axis), P(grid.axis)),
            out_specs=(P(grid.axis), P(grid.axis)))(a, b, top, msg_in)
    return top


def counts(n: int) -> WorkloadCounts:
    cells = float(n * n)
    return WorkloadCounts(
        name="NW",
        ops={("add", "int32"): 2 * cells, ("sub", "int32"): 2 * cells,
             ("compare", "int32"): 3 * cells},
        bytes_streamed=4.0 * 2 * cells,
        interbank_bytes=8.0 * 64 * n,   # block boundaries, every wavefront
        flops_equiv=4.0 * cells,
        pim_suitable=SUITABLE,
    )
