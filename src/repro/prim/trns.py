"""TRNS — matrix transposition (int64). Table I: sequential + random,
add/sub/mul (index arithmetic), mutex, NO inter-DPU column.

The PrIM algorithm: the host performs the coarse (tile-granular) transpose
as part of the scatter to MRAM banks; each DPU then transposes its own
tiles in-place (step 2/3 of the paper's algorithm). Mapped here: an
all-to-all exchange moves tile ROWS to the owning bank (the host-side
coarse step), then a bank-local fine transpose."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**13      # 8192 x 8192 int64


def make_inputs(n: int, key):
    """(n, n) int64 matrix."""
    return {"A": jax.random.randint(key, (n, n), -1000, 1000, jnp.int64)}


def ref(A):
    return A.T


def run_pim(grid: BankGrid, A):
    b = grid.n_banks
    m, n = A.shape

    def local(Ab):
        # Ab: (m/b, n). split columns into b tiles, all-to-all so bank j
        # receives every bank's j-th column tile (the host coarse step),
        # then transpose each received tile locally (the DPU fine step).
        rows = Ab.shape[0]
        tiles = Ab.reshape(rows, b, n // b)           # (r, b, n/b)
        tiles = jnp.transpose(tiles, (1, 0, 2))       # (b, r, n/b)
        recv = jax.lax.all_to_all(tiles, grid.axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        # recv: (b*r, n/b) = all row blocks of my column tile
        recv = recv.reshape(b, rows, n // b)
        out = jnp.transpose(recv, (2, 0, 1)).reshape(n // b, m)
        return out

    return grid.local(local, in_specs=P(grid.axis),
                      out_specs=P(grid.axis))(A)


def counts(n: int) -> WorkloadCounts:
    elems = float(n * n)
    return WorkloadCounts(
        name="TRNS",
        ops={("add", "int64"): elems / 8, ("sub", "int64"): elems / 16,
             ("mul", "int64"): elems / 16},   # amortized index arithmetic
        bytes_streamed=8.0 * 2 * elems,
        interbank_bytes=0.0,    # coarse step rides the initial host scatter
        flops_equiv=elems / 4,
        pim_suitable=SUITABLE,
        bytes_cpu=(8.0 + 64.0) * elems,   # strided writes: line per element
        # GPU tiles through shared memory: no penalty
    )
