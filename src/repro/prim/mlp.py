"""MLP — multilayer perceptron inference (neural networks, int32).
Table I: sequential, add+mul+compare (ReLU), no intra-DPU sync, but each
layer boundary is an inter-DPU exchange: the layer output must be gathered
and re-broadcast because the next layer's GEMV needs the WHOLE vector on
every bank (weights are row-partitioned, Takeaway 3's cost made visible)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = False   # multiplication (Takeaway 2)
REF_N = 2**12      # 3 square layers of width 4096

N_LAYERS = 3


def make_inputs(n: int, key):
    """n = layer width; N_LAYERS square layers."""
    keys = jax.random.split(key, N_LAYERS + 1)
    ws = [jax.random.randint(keys[i], (n, n), -4, 5, jnp.int32)
          for i in range(N_LAYERS)]
    x = jax.random.randint(keys[-1], (n,), -4, 5, jnp.int32)
    return {"ws": ws, "x": x}


def ref(ws, x):
    h = x
    for w in ws:
        h = jnp.maximum(w.astype(jnp.int64) @ h.astype(jnp.int64), 0) \
            .astype(jnp.int32)
    return h


def run_pim(grid: BankGrid, ws, x):
    def layer(wb, hb):
        y = wb.astype(jnp.int64) @ hb.astype(jnp.int64)
        return jnp.maximum(y, 0).astype(jnp.int32)
    local_gemv = grid.local(layer, in_specs=(P(grid.axis), P()),
                            out_specs=P(grid.axis))
    h = x
    for w in ws:
        part = local_gemv(w, h)       # bank-local GEMV on the row block
        h = grid.exchange_gather(part)  # layer boundary: through the host
    return h


def counts(n: int) -> WorkloadCounts:
    ops_mm = float(N_LAYERS * n * n)
    return WorkloadCounts(
        name="MLP",
        ops={("mul", "int32"): ops_mm, ("add", "int32"): ops_mm,
             ("compare", "int32"): float(N_LAYERS * n)},
        bytes_streamed=4.0 * (N_LAYERS * n * n + 2 * N_LAYERS * n),
        interbank_bytes=4.0 * N_LAYERS * n,   # gather+rebroadcast per layer
        flops_equiv=2.0 * ops_mm,
        pim_suitable=SUITABLE,
    )
