"""GEMV — matrix-vector multiply (dense linear algebra). Table I:
sequential, add+mul, uint32. Row-block partitioning: each bank owns M/B
rows of A and the whole x (the UPMEM layout); y is produced bank-locally
and retrieved by the host. No inter-DPU communication.

This is the decode-GEMV of the LM serving path (DESIGN.md §4): the
weight-stationary pattern the paper's technique maps onto."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = False   # uses multiplication (Takeaway 2)
REF_N = 2**13      # 8192 x 2048


def make_inputs(n: int, key):
    """n = M; N fixed at n//4 for a 4:1 aspect (paper uses 8192x1024)."""
    m, k = n, max(n // 4, 8)
    ka, kx = jax.random.split(key)
    return {"A": jax.random.randint(ka, (m, k), 0, 64, jnp.uint32),
            "x": jax.random.randint(kx, (k,), 0, 64, jnp.uint32)}


def ref(A, x):
    return (A.astype(jnp.uint64) @ x.astype(jnp.uint64)).astype(jnp.uint32)


def run_pim(grid: BankGrid, A, x):
    def local(Ab, xb):
        return (Ab.astype(jnp.uint64) @ xb.astype(jnp.uint64)).astype(jnp.uint32)
    return grid.local(local, in_specs=(P(grid.axis), P()),
                      out_specs=P(grid.axis))(A, x)


def counts(n: int) -> WorkloadCounts:
    m, k = n, max(n // 4, 8)
    return WorkloadCounts(
        name="GEMV",
        ops={("mul", "int32"): float(m * k), ("add", "int32"): float(m * k)},
        bytes_streamed=4.0 * (m * k + k + m),
        interbank_bytes=0.0,
        flops_equiv=2.0 * m * k,
        pim_suitable=SUITABLE,
    )
