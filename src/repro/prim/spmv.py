"""SpMV — sparse matrix-vector multiply (float32, CSR in the paper).
Table I: sequential + random access, add+mul float. Row-block partition;
x is replicated per bank (the paper copies it to every DPU's MRAM).

JAX adaptation: rows are padded to a fixed nnz/row (ELL layout) — ragged
CSR does not map to fixed-shape arrays; the access pattern (random gathers
into x) and the op mix (float mul/add) are what the paper characterizes,
and both are preserved."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = False   # floating point (Takeaway 2)
REF_N = 2**22      # 67M nnz

NNZ_PER_ROW = 16


def make_inputs(n: int, key):
    """n rows, NNZ_PER_ROW nonzeros each, n columns."""
    kc, kv, kx = jax.random.split(key, 3)
    cols = jax.random.randint(kc, (n, NNZ_PER_ROW), 0, n, jnp.int32)
    vals = jax.random.normal(kv, (n, NNZ_PER_ROW), jnp.float32)
    x = jax.random.normal(kx, (n,), jnp.float32)
    return {"cols": cols, "vals": vals, "x": x}


def ref(cols, vals, x):
    return jnp.sum(vals * x[cols], axis=1)


def run_pim(grid: BankGrid, cols, vals, x):
    def local(c, v, xb):
        return jnp.sum(v * xb[c], axis=1)   # random gather into local x copy
    return grid.local(local, in_specs=(P(grid.axis), P(grid.axis), P()),
                      out_specs=P(grid.axis))(cols, vals, x)


def counts(n: int) -> WorkloadCounts:
    nnz = n * NNZ_PER_ROW
    return WorkloadCounts(
        name="SpMV",
        ops={("mul", "float"): float(nnz), ("add", "float"): float(nnz)},
        bytes_streamed=8.0 * nnz + 4.0 * 2 * n,   # val+col per nnz, x + y
        interbank_bytes=0.0,
        flops_equiv=2.0 * nnz,
        pim_suitable=SUITABLE,
        bytes_cpu=8.0 * nnz + 64.0 * nnz + 4.0 * 2 * n,  # line per gather
        bytes_gpu=8.0 * nnz + 16.0 * nnz + 4.0 * 2 * n,  # sector per gather
    )
