"""TS — time series analysis (int32). Table I: sequential, add/sub/mul/div.

PrIM's TS computes a matrix-profile-style z-normalized distance of a query
subsequence against every window of a long series. The series is sharded
across banks with an (m-1)-element halo from the RIGHT neighbor so every
window is computable bank-locally; the final min-distance/argmin is a tiny
cross-bank reduction."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = False    # mul/div heavy (Takeaway 2)
REF_N = 2**26

M = 8  # query length


def make_inputs(n: int, key):
    ks, kq = jax.random.split(key)
    series = jax.random.randint(ks, (n,), -100, 100, jnp.int32)
    query = jax.random.randint(kq, (M,), -100, 100, jnp.int32)
    return {"series": series, "query": query}


def _dists(seg, query):
    """Squared euclidean distance of query to every window in seg."""
    m = query.shape[0]
    nwin = seg.shape[0] - m + 1
    idx = jnp.arange(nwin)[:, None] + jnp.arange(m)[None, :]
    wins = seg[idx].astype(jnp.int64)
    d = wins - query.astype(jnp.int64)[None, :]
    return jnp.sum(d * d, axis=1)


def ref(series, query):
    d = _dists(series, query)
    return jnp.min(d), jnp.argmin(d).astype(jnp.int32)


def run_pim(grid: BankGrid, series, query):
    b = grid.n_banks
    per = series.shape[0] // b

    # phase 1 (exchange): halo — first m-1 elements of the RIGHT neighbor
    def head(xb):
        return xb[:M - 1]
    heads = grid.local(head, in_specs=P(grid.axis),
                       out_specs=P(grid.axis))(series)
    halo = grid.exchange_shift(heads, offset=-1)  # bank i gets bank i+1's head

    # phase 2: bank-local windows (+ halo), local min/argmin
    def local(xb, hb, qb):
        bank = jax.lax.axis_index(grid.axis)
        seg = jnp.concatenate([xb, hb])
        d = _dists(seg, qb)
        # windows starting in the halo belong to the next bank
        d = jnp.where(jnp.arange(d.shape[0]) < per, d, jnp.iinfo(d.dtype).max)
        loc = jnp.argmin(d)
        return d[loc][None], (bank * per + loc).astype(jnp.int32)[None]
    dmin, amin = grid.local(
        local, in_specs=(P(grid.axis), P(grid.axis), P()),
        out_specs=(P(grid.axis), P(grid.axis)))(series, halo, query)

    # phase 3 (exchange): global min + owner  (host-side tiny reduce)
    best = int(jnp.argmin(dmin))
    return dmin[best], amin[best]


def counts(n: int) -> WorkloadCounts:
    return WorkloadCounts(
        name="TS",
        ops={("sub", "int32"): float(n * M), ("mul", "int32"): float(n * M),
             ("add", "int32"): float(n * M), ("div", "int32"): float(n)},
        bytes_streamed=4.0 * n * M,
        interbank_bytes=0.0,
        flops_equiv=3.0 * n * M,
        pim_suitable=SUITABLE,
    )
