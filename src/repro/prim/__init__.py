"""PrIM — the 16-workload benchmark suite of the paper (Table I), written
against the bank-parallel execution model (core.bank_parallel).

Registry: WORKLOADS maps short name -> module; HST-L shares the hst module
with a different bin count (the paper's S/L distinction is bins-per-WRAM).
"""

from . import bfs, bs, gemv, hst, mlp, nw, red, scan_rss, scan_ssa, sel, \
    spmv, trns, ts, uni, va

WORKLOADS = {
    "VA": va, "GEMV": gemv, "SpMV": spmv, "SEL": sel, "UNI": uni,
    "BS": bs, "TS": ts, "BFS": bfs, "MLP": mlp, "NW": nw,
    "HST-S": hst, "HST-L": hst, "RED": red, "SCAN-SSA": scan_ssa,
    "SCAN-RSS": scan_rss, "TRNS": trns,
}

#: paper Fig. 4 grouping (group 1 = "more suitable")
SUITABLE_SET = {n for n, m in WORKLOADS.items() if m.SUITABLE}


def all_counts(n: int):
    """WorkloadCounts for all 16 at a common scale n (perf model input)."""
    out = []
    for name, mod in WORKLOADS.items():
        if name == "HST-L":
            out.append(mod.counts_l(n))
        else:
            out.append(mod.counts(n))
    return out


def all_ref_counts():
    """WorkloadCounts at each workload's paper-scale reference size
    (module REF_N) — what the Fig-4 comparison validates against."""
    out = []
    for name, mod in WORKLOADS.items():
        if name == "HST-L":
            out.append(mod.counts_l(mod.REF_N))
        else:
            out.append(mod.counts(mod.REF_N))
    return out
