"""SEL — database select / stream compaction (int64). Table I: sequential,
add+compare, handshake+barrier intra-DPU, inter-DPU communication.

Phases (exactly the PrIM structure):
  1. bank-local: predicate + local compaction + local count
  2. exchange:   exclusive scan of per-bank counts (through the host)
  3. host:       assembly of the compacted output at the scanned offsets
                 (the serial retrieve the paper identifies as the
                 scaling cost of SEL/UNI)"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts
from .common import assemble_compact, local_compact

SUITABLE = True
REF_N = 2**27

PRED_MOD = 2   # keep odd values (paper keeps !pred elements)


def make_inputs(n: int, key):
    return {"x": jax.random.randint(key, (n,), 0, 1 << 30, jnp.int64)}


def ref(x):
    return x[x % PRED_MOD == 1]


def run_pim(grid: BankGrid, x):
    # phase 1: bank-local compaction
    def local(xb):
        comp, cnt = local_compact(xb, xb % PRED_MOD == 1)
        return comp, cnt[None]
    parts, cnts = grid.local(local, in_specs=P(grid.axis),
                             out_specs=(P(grid.axis), P(grid.axis)))(x)
    # phase 2+3: host gathers counts + parts and assembles (serial retrieve)
    b = grid.n_banks
    parts = parts.reshape(b, -1)
    total = int(jnp.sum(cnts))
    return assemble_compact(parts, cnts, total)[:total]


def counts(n: int) -> WorkloadCounts:
    kept = n / 2
    return WorkloadCounts(
        name="SEL",
        ops={("compare", "int64"): float(n), ("add", "int64"): float(n)},
        bytes_streamed=8.0 * (n + kept),
        # inter-DPU traffic is only the counts scan; the compacted result
        # rides the (parallel) final retrieve like every benchmark's output
        interbank_bytes=8.0 * 64,
        flops_equiv=float(n),
        pim_suitable=SUITABLE,
    )
