"""Shared helpers for the PrIM workloads.

Every workload module exposes the same surface:

    make_inputs(n, key)      -> dict of jnp arrays (sized for n)
    ref(**inputs)            -> oracle result (pure jnp/numpy, host-style)
    run_pim(grid, **inputs)  -> same result, bank-parallel phase structure
    counts(n)                -> WorkloadCounts for the Fig-4 perf model
    SUITABLE                 -> paper Fig-4 grouping (True = group 1)

`run_pim` keeps the exact UPMEM phase structure (bank-local programs +
host-mediated exchanges, Table I's communication column); tests assert both
correctness vs `ref` and phase discipline (no collectives inside local
phases) via core.bank_parallel.assert_local.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.bank_parallel import BankGrid


def pad_to_banks(x, n_banks: int, axis: int = 0, fill=0):
    """Pad dim `axis` so it divides n_banks. Returns (padded, orig_len)."""
    n = x.shape[axis]
    rem = (-n) % n_banks
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=fill), n


def local_compact(vals, keep):
    """Stable compaction of `vals` where keep is True; returns
    (compacted_padded, count). Padded slots hold the last kept value
    (callers slice by count). Pure bank-local (sort by ~keep)."""
    idx = jnp.argsort(~keep, stable=True)
    comp = vals[idx]
    count = jnp.sum(keep.astype(jnp.int32))
    return comp, count


def assemble_compact(parts, counts, total_len: int):
    """Host-side assembly of per-bank compacted parts (B, L) + counts (B,)
    into one dense array — the serial DPU->host retrieve of the paper."""
    b, l = parts.shape
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    out = jnp.zeros((total_len,), parts.dtype)
    # scatter each bank's first counts[i] values at offs[i]
    pos_in_bank = jnp.arange(l)[None, :]                      # (1, L)
    dest = offs[:, None] + pos_in_bank                        # (B, L)
    valid = pos_in_bank < counts[:, None]
    dest = jnp.where(valid, dest, total_len)                  # drop pads
    out = out.at[dest.reshape(-1)].set(parts.reshape(-1), mode="drop")
    return out


def zipf_ints(key, n: int, vocab: int, dtype=jnp.int32):
    u = jax.random.uniform(key, (n,), jnp.float32, 1e-6, 1.0)
    ids = jnp.floor(jnp.power(u, -1.0 / 0.9)).astype(jnp.int32)
    return jnp.clip(ids, 0, vocab - 1).astype(dtype)
