"""RED — reduction (parallel primitives, int64). Table I: sequential +
strided, add, barrier, inter-DPU communication (the cross-bank tree).

Phases: bank-local sum -> cross-bank tree reduction (through the host)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**27


def make_inputs(n: int, key):
    return {"x": jax.random.randint(key, (n,), -1000, 1000, jnp.int64)}


def ref(x):
    return jnp.sum(x)


def run_pim(grid: BankGrid, x):
    # phase 1: bank-local reduce
    local = grid.local(lambda xb: jnp.sum(xb)[None], in_specs=P(grid.axis),
                       out_specs=P(grid.axis))(x)
    # phase 2: cross-bank tree (psum exchange)
    total = grid.exchange_reduce(local, op="add")
    return total[0]


def counts(n: int) -> WorkloadCounts:
    return WorkloadCounts(
        name="RED",
        ops={("add", "int64"): float(n)},
        bytes_streamed=8.0 * n,
        interbank_bytes=8.0 * 64,          # one scalar per bank, tiny
        flops_equiv=float(n),
        pim_suitable=SUITABLE,
    )
