"""HST-S / HST-L — image histogram (uint32). Table I: sequential + random,
add, barrier (+mutex for L), inter-DPU communication (final merge).

HST-S: few bins — each UPMEM tasklet keeps a private WRAM histogram, merged
per DPU then across DPUs. HST-L: many bins — one shared per-DPU histogram
behind a mutex. The JAX bank-local scatter-add models both; the variants
differ in bin count and in the merge volume `counts()` charges (the paper's
distinction that matters at system level)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**26

BINS_S = 256
BINS_L = 4096


def make_inputs(n: int, key, bins: int = BINS_S):
    return {"x": jax.random.randint(key, (n,), 0, 1 << 12, jnp.uint32),
            "bins": bins}


def ref(x, bins: int):
    idx = (x.astype(jnp.uint32) * bins) >> 12
    return jnp.zeros((bins,), jnp.uint32).at[idx].add(1)


def run_pim(grid: BankGrid, x, bins: int):
    # phase 1: bank-local histogram (tasklet-private -> per-bank merge)
    def local(xb):
        idx = (xb.astype(jnp.uint32) * bins) >> 12
        return jnp.zeros((bins,), jnp.uint32).at[idx].add(1)[None]
    parts = grid.local(local, in_specs=P(grid.axis),
                       out_specs=P(grid.axis))(x)
    # phase 2: cross-bank merge (through the host)
    merged = grid.exchange_reduce(parts, op="add")
    return merged[0]


def _counts(n: int, bins: int, name: str) -> WorkloadCounts:
    # HST-L's shared per-DPU histogram is mutex-guarded: ~2 extra
    # bookkeeping instructions per update (the paper's S/L gap)
    mutex_ops = 2.0 * n if bins > 2048 else 0.0
    return WorkloadCounts(
        name=name,
        ops={("add", "int32"): float(n) + mutex_ops,
             ("bitwise", "int32"): float(n)},
        bytes_streamed=4.0 * (n + bins),
        interbank_bytes=4.0 * bins * 8,       # tree-merged per rank
        flops_equiv=float(n),
        pim_suitable=SUITABLE,
        # GPU histogram atomics serialize hot bins: ~half effective bw
        bytes_gpu=2.0 * 4.0 * n,
    )


def counts(n: int) -> WorkloadCounts:
    return _counts(n, BINS_S, "HST-S")


def counts_l(n: int) -> WorkloadCounts:
    return _counts(n, BINS_L, "HST-L")
