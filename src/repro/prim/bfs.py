"""BFS — breadth-first search (graph processing, uint64 bitmaps). Table I:
sequential + random, bitwise logic, barrier+mutex, inter-DPU communication.

Level-synchronous frontier BFS: vertices (and their out-edges) are sharded
across banks; each level is one bank-local expand (bitwise OR into a
next-frontier bitmap) followed by a cross-bank OR exchange of the bitmap —
the paper's worst-case inter-DPU pattern (the whole frontier crosses the
host every level)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = False   # inter-DPU heavy (Takeaway 3)
REF_N = 2**18      # paper-scale graphs (loc-gowalla etc are ~200K vertices)

MAX_DEG = 8


def make_inputs(n: int, key):
    """Random graph: n vertices, MAX_DEG out-edges each (self-loops ok)."""
    adj = jax.random.randint(key, (n, MAX_DEG), 0, n, jnp.int32)
    return {"adj": adj, "src": jnp.zeros((), jnp.int32)}


def ref(adj, src):
    n = adj.shape[0]
    dist = jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32).at[src].set(0)
    frontier = jnp.zeros((n,), bool).at[src].set(True)
    visited = frontier
    level = 0
    while bool(jnp.any(frontier)):
        level += 1
        nxt = jnp.zeros((n,), bool)
        nxt = nxt.at[adj[frontier].reshape(-1)].set(True)
        nxt = nxt & ~visited
        dist = jnp.where(nxt, level, dist)
        visited = visited | nxt
        frontier = nxt
    return dist


def run_pim(grid: BankGrid, adj, src):
    n = adj.shape[0]
    dist = jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32).at[src].set(0)
    frontier = jnp.zeros((n,), bool).at[src].set(True)
    visited = frontier
    level = 0

    # bank-local expand over the bank's adjacency rows
    def expand(adj_b, frontier_all):
        bank = jax.lax.axis_index(grid.axis)
        per = adj_b.shape[0]
        mine = jax.lax.dynamic_slice_in_dim(frontier_all, bank * per, per)
        targets = jnp.where(mine[:, None], adj_b, n)  # n = out of range
        nxt = jnp.zeros((n,), bool).at[targets.reshape(-1)].set(
            True, mode="drop")
        return nxt.astype(jnp.uint32)

    local_expand = grid.local(expand, in_specs=(P(grid.axis), P()),
                              out_specs=P(grid.axis))

    while bool(jnp.any(frontier)):
        level += 1
        partial = local_expand(adj, frontier)           # (B, n) per-bank
        # exchange: cross-bank OR of the frontier bitmap (through the host)
        nxt = jnp.any(partial.reshape(grid.n_banks, n).astype(bool), axis=0)
        nxt = nxt & ~visited
        dist = jnp.where(nxt, level, dist)
        visited = visited | nxt
        frontier = nxt
    return dist


def counts(n: int) -> WorkloadCounts:
    e = n * MAX_DEG
    levels = 4.0   # random MAX_DEG-regular graphs have tiny diameter
    return WorkloadCounts(
        name="BFS",
        ops={("bitwise", "int64"): float(e + 2 * n * levels)},
        bytes_streamed=4.0 * e + (n / 8) * levels * 4,
        interbank_bytes=(n / 8) * levels * 64,   # bitmap x banks per level
        flops_equiv=float(e),
        pim_suitable=SUITABLE,
        bytes_cpu=64.0 * e,      # random vertex touch: line per edge
        bytes_gpu=32.0 * e / 4,  # sectors + warp coalescing over frontier
    )
