"""VA — vector addition (dense linear algebra). Table I: sequential, add,
int32, no intra/inter-DPU sync. The canonical PIM-suitable workload."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.bank_parallel import BankGrid
from ..core.perf_model import WorkloadCounts

SUITABLE = True
REF_N = 2**27          # ~2 GB working set (paper-scale strong-scaling input)


def make_inputs(n: int, key):
    ka, kb = jax.random.split(key)
    return {"a": jax.random.randint(ka, (n,), -1000, 1000, jnp.int32),
            "b": jax.random.randint(kb, (n,), -1000, 1000, jnp.int32)}


def ref(a, b):
    return a + b


def run_pim(grid: BankGrid, a, b):
    # one bank-local phase, no exchange
    return grid.bank_map(lambda x, y: x + y)(a, b)


def counts(n: int) -> WorkloadCounts:
    return WorkloadCounts(
        name="VA",
        ops={("add", "int32"): float(n)},
        bytes_streamed=3.0 * 4 * n,        # read a, b; write c
        interbank_bytes=0.0,
        flops_equiv=float(n),
        pim_suitable=SUITABLE,
    )
