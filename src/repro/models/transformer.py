"""Model assembly: params, forward (train / prefill / decode), loss.

The decoder stack scans over repeats of the config's layer pattern (blocks);
heterogeneous stacks (jamba) unroll the pattern inside the scan body. Each
block is rematerialized. Cache tensors ride the scan as xs/ys so decode
state stays stacked and shardable.

Forward modes:
  * cache=None, S tokens      -> training / eval forward
  * cache given, S>1          -> prefill (writes KV, returns logits+cache)
  * cache given, S==1         -> decode step
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import cache as cache_lib
from . import layers as L
from .config import LayerSpec, ModelConfig
from .mamba import mamba_defs, mamba_forward
from .rwkv import (rwkv_channel_mix, rwkv_defs, rwkv_time_mix)
from .sharding import (ParamDef, Shardings, is_def, stack_defs, tree_specs,
                       tree_shape_structs)


# --------------------------------------------------------------------- #
# parameter definitions
# --------------------------------------------------------------------- #

def layer_defs(cfg: ModelConfig, spec: LayerSpec, name: str) -> dict:
    d: dict[str, Any] = {"ln1": L.norm_defs(cfg, f"{name}.ln1")}
    if spec.kind == "attn":
        d["attn"] = L.attn_defs(cfg, f"{name}.attn")
        if spec.cross_attn:
            d["ln_cross"] = L.norm_defs(cfg, f"{name}.ln_cross")
            d["cross"] = L.attn_defs(cfg, f"{name}.cross")
    elif spec.kind == "mamba":
        d["mamba"] = mamba_defs(cfg, f"{name}.mamba")
    elif spec.kind == "rwkv":
        d["rwkv"] = rwkv_defs(cfg, f"{name}.rwkv")
        d["ln2"] = L.norm_defs(cfg, f"{name}.ln2")
        return d
    if spec.mlp != "none":
        d["ln2"] = L.norm_defs(cfg, f"{name}.ln2")
        d["mlp"] = (L.moe_defs(cfg, f"{name}.moe") if spec.mlp == "moe"
                    else L.mlp_defs(cfg, f"{name}.mlp"))
    return d


def param_defs(cfg: ModelConfig) -> dict:
    v, dm = cfg.padded_vocab, cfg.d_model
    defs: dict[str, Any] = {
        # embedding: D sharded over tp so lookup is a local gather
        "embed": ParamDef((v, dm), (None, "tp"), "embed", "normal"),
        "final_norm": L.norm_defs(cfg, "final_norm"),
    }
    if not cfg.tie_embeddings:
        # unembedding: vocab-parallel logits
        defs["unembed"] = ParamDef((v, dm), ("vocab", "fsdp"), "unembed")
    pattern = cfg.layer_pattern()
    defs["layers"] = [
        stack_defs(layer_defs(cfg, spec, f"l{i}"), cfg.n_blocks)
        for i, spec in enumerate(pattern)]
    if cfg.encoder_layers:
        enc_spec = LayerSpec("attn", "dense", cross_attn=False)
        defs["encoder"] = {
            "layers": stack_defs(layer_defs(cfg, enc_spec, "enc"),
                                 cfg.encoder_layers),
            "final_norm": L.norm_defs(cfg, "enc.final_norm"),
        }
    return defs


def init_params(rng, cfg: ModelConfig, shd: Shardings | None = None):
    defs = param_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))

    def mk(d: ParamDef, key):
        dt = jnp.dtype(d.dtype or cfg.dtype)
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dt)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 0.02 if d.init == "small" else 1.0 / math.sqrt(fan_in)
            arr = (jax.random.normal(key, d.shape, jnp.float32)
                   * scale).astype(dt)
        if shd is not None and shd.mesh is not None:
            arr = jax.device_put(arr, shd.named(d.shape, d.kinds, d.name))
        return arr

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def param_shape_structs(cfg: ModelConfig):
    return tree_shape_structs(param_defs(cfg), cfg.dtype)


def param_specs(cfg: ModelConfig, shd: Shardings):
    return tree_specs(shd, param_defs(cfg))


# --------------------------------------------------------------------- #
# attention sub-layer with all cache modes
# --------------------------------------------------------------------- #

def _attention(x, p, cfg: ModelConfig, shd: Shardings, rope, kv_cache,
               index, width):
    """Returns (attn_out, new_kv_cache)."""
    b, s, _ = x.shape
    sin, cos = rope
    decoding = kv_cache is not None and s == 1
    q, k, v = L._qkv(x, p, cfg, shd, rope_sin=sin, rope_cos=cos,
                     heads_tp=not decoding)

    if kv_cache is None:  # training: full self-attention over s
        if s >= 2048 and s % cfg.q_chunk == 0 and s % cfg.kv_chunk == 0:
            o = L.flash_attention(q, k, v, cfg, shd, causal=True)
        else:
            o = _plain_attention(q, k, v, cfg, causal=True)
        return L.attn_out(o, p, x.dtype, shd), None

    if s > 1:  # prefill (ring caches keep the trailing window)
        new_kv = cache_lib.write_prefill(kv_cache, k, v)
        if s >= 2048 and s % cfg.q_chunk == 0 and s % cfg.kv_chunk == 0:
            o = L.flash_attention(q, k, v, cfg, shd, causal=True)
        else:
            o = _plain_attention(q, k, v, cfg, causal=True)
        return L.attn_out(o, p, x.dtype, shd), new_kv

    # decode
    new_kv = cache_lib.write_decode(kv_cache, k, v, index, width)
    positions = cache_lib.slot_positions(index + 1, width)
    o = L.cached_attention(q, new_kv["k"], new_kv["v"], positions, index,
                           cfg, shd)
    return L.attn_out(o, p, x.dtype, shd), new_kv


def _cross_attention(x, p, cfg: ModelConfig, shd: Shardings, cross_cache,
                     encoder_out):
    """Whisper-style cross attention. Prefill computes and caches encoder
    K/V; decode reuses them."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    if encoder_out is not None:
        k = jnp.einsum("bsd,dhk->bshk", encoder_out, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", encoder_out, p["wv"].astype(x.dtype))
        if "bk" in p:
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
        new_cache = (None if cross_cache is None
                     else cache_lib.write_prefill(cross_cache, k, v))
    else:
        assert cross_cache is not None
        k, v = cross_cache["k"].astype(x.dtype), cross_cache["v"].astype(x.dtype)
        new_cache = cross_cache
    o = _plain_attention(q, k, v, cfg, causal=False)
    return L.attn_out(o, p, x.dtype, shd), new_cache


def _plain_attention(q, k, v, cfg: ModelConfig, causal: bool,
                     q_offset: int = 0):
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        k_pos = jnp.arange(skv)
        mask = q_pos[:, None] >= k_pos[None, :]
        if cfg.sliding_window:
            mask &= q_pos[:, None] - k_pos[None, :] < cfg.sliding_window
        s = jnp.where(mask, s, -1e30)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", a, v)


# --------------------------------------------------------------------- #
# block and stack
# --------------------------------------------------------------------- #

def block_forward(x, spec: LayerSpec, p, cfg: ModelConfig, shd: Shardings,
                  rope, cache_slice, index, width, encoder_out):
    """One pattern position. Returns (x, new_cache_slice, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache_slice
    h = L.apply_norm(x, p["ln1"], cfg)
    if spec.kind == "attn":
        kv = None if cache_slice is None else {
            "k": cache_slice["k"], "v": cache_slice["v"]}
        o, new_kv = _attention(h, p["attn"], cfg, shd, rope, kv, index, width)
        x = x + o
        if cache_slice is not None:
            new_cache = dict(cache_slice, **new_kv)
        if spec.cross_attn:
            h = L.apply_norm(x, p["ln_cross"], cfg)
            cc = None if cache_slice is None else cache_slice.get("cross")
            o, new_cc = _cross_attention(h, p["cross"], cfg, shd, cc,
                                         encoder_out)
            x = x + o
            if cache_slice is not None and new_cc is not None:
                new_cache = dict(new_cache, cross=new_cc)
    elif spec.kind == "mamba":
        state = cache_slice if cache_slice is not None else None
        o, new_state = mamba_forward(h, p["mamba"], cfg, shd, state)
        x = x + o
        if cache_slice is not None:
            new_cache = new_state
    elif spec.kind == "rwkv":
        state = cache_slice if cache_slice is not None else {
            "wkv": jnp.zeros((x.shape[0], cfg.n_rwkv_heads,
                              cfg.rwkv_head_size, cfg.rwkv_head_size),
                             jnp.float32),
            "shift_tm": jnp.zeros((x.shape[0], 1, cfg.d_model), x.dtype),
            "shift_cm": jnp.zeros((x.shape[0], 1, cfg.d_model), x.dtype),
        }
        o, tm_state = rwkv_time_mix(h, p["rwkv"], cfg, shd, state)
        x = x + o
        h2 = L.apply_norm(x, p["ln2"], cfg)
        o2, cm_state = rwkv_channel_mix(h2, p["rwkv"], cfg, shd, state)
        x = x + o2
        if cache_slice is not None:
            new_cache = dict(state, **tm_state, **cm_state)
        return x, new_cache, aux

    if spec.mlp != "none":
        h = L.apply_norm(x, p["ln2"], cfg)
        if spec.mlp == "moe":
            o, aux = L.moe_forward(h, p["mlp"], cfg, shd)
        else:
            o = L.mlp_forward(h, p["mlp"], cfg, shd)
        x = x + o
    x = shd.act(x, "batch", "seq", None)
    return x, new_cache, aux


def stack_forward(x, params, cfg: ModelConfig, shd: Shardings, rope,
                  cache_layers, index, width, encoder_out):
    """Scan over groups of `remat_group` blocks; the pattern (and the
    group) is unrolled inside the rematerialized body, so activations are
    saved only at group boundaries (n_blocks/remat_group stacked residuals
    instead of n_blocks — the §Perf memory-term lever)."""
    pattern = cfg.layer_pattern()
    have_cache = cache_layers is not None
    g = max(cfg.remat_group, 1)
    if cfg.n_blocks % g != 0:
        g = 1
    n_steps = cfg.n_blocks // g

    def regroup(leaf):
        return leaf.reshape((n_steps, g) + leaf.shape[1:])

    def ungroup(leaf):
        return leaf.reshape((cfg.n_blocks,) + leaf.shape[2:])

    def body(carry, xs):
        xc, aux_acc = carry
        layer_ps, cache_slices = xs
        new_groups = []
        for j in range(g):
            lp = (jax.tree.map(lambda l: l[j], layer_ps) if g > 1
                  else layer_ps)
            cs = (jax.tree.map(lambda l: l[j], cache_slices)
                  if have_cache and g > 1 else cache_slices)
            new_slices = []
            for i, spec in enumerate(pattern):
                sl = cs[i] if have_cache else None
                xc, new_sl, aux = block_forward(
                    xc, spec, lp[i], cfg, shd, rope, sl, index, width,
                    encoder_out)
                aux_acc = aux_acc + aux
                new_slices.append(new_sl if have_cache else 0)
            new_groups.append(tuple(new_slices) if have_cache else 0)
        if have_cache and g > 1:
            ys = jax.tree.map(lambda *ls: jnp.stack(ls), *new_groups)
        else:
            ys = new_groups[-1]
        return (xc, aux_acc), ys

    if cfg.remat:
        body = jax.checkpoint(body)

    layer_xs = (jax.tree.map(regroup, params["layers"]) if g > 1
                else params["layers"])
    if have_cache:
        cache_xs = (jax.tree.map(regroup, tuple(cache_layers)) if g > 1
                    else tuple(cache_layers))
    else:
        cache_xs = _zeros_xs(cfg, n_steps)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (layer_xs, cache_xs))
    if have_cache and g > 1:
        new_cache = jax.tree.map(ungroup, new_cache)
    return x, (list(new_cache) if have_cache else None), aux


def _zeros_xs(cfg: ModelConfig, n_steps: int | None = None):
    # placeholder xs so scan signature stays stable without a cache
    return jnp.zeros((n_steps or cfg.n_blocks,), jnp.float32)


# --------------------------------------------------------------------- #
# encoder (whisper backbone; frame embeddings come from the stub frontend)
# --------------------------------------------------------------------- #

def encoder_forward(embeds, params, cfg: ModelConfig, shd: Shardings):
    x = embeds + _sinusoid(cfg.encoder_seq, cfg.d_model).astype(embeds.dtype)
    x = shd.act(x, "batch", None, None)
    spec = LayerSpec("attn", "dense")
    no_rope = (None, None)

    def body(xc, p):
        h = L.apply_norm(xc, p["ln1"], cfg)
        q, k, v = L._qkv(h, p["attn"], cfg, shd, want_rope=False)
        o = _plain_attention(q, k, v, cfg, causal=False)
        xc = xc + L.attn_out(o, p["attn"], xc.dtype, shd)
        h = L.apply_norm(xc, p["ln2"], cfg)
        xc = xc + L.mlp_forward(h, p["mlp"], cfg, shd)
        return shd.act(xc, "batch", None, None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(x, params["final_norm"], cfg)


def _sinusoid(s, d):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], -1)[None]


# --------------------------------------------------------------------- #
# full forward
# --------------------------------------------------------------------- #

def mask_vocab_padding(logits, cfg: ModelConfig):
    """Mask Megatron-style vocab padding out of the softmax. Shared by
    `forward` and the dispatch decode step (serve.dispatch_engine), whose
    correctness contract is exact numerical agreement with forward."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))


def forward(params, cfg: ModelConfig, shd: Shardings, *,
            tokens=None, embeds=None, positions=None, mrope_positions=None,
            cache=None, encoder_embeds=None):
    """Returns (logits, new_cache, aux)."""
    if embeds is not None:
        x = embeds.astype(cfg.dtype)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = params["embed"][tokens].astype(cfg.dtype)
    x = shd.act(x, "batch", None, None)

    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    if positions is None:
        positions = index + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    if cfg.rope == "mrope":
        if mrope_positions is None:
            mrope_positions = jnp.broadcast_to(positions[None], (3, b, s))
        rope = L.rope_sincos(mrope_positions, cfg)
    elif cfg.rope == "none":
        rope = (None, None)
    else:
        rope = L.rope_sincos(positions, cfg)

    encoder_out = None
    if cfg.encoder_layers:
        if encoder_embeds is not None:
            encoder_out = encoder_forward(encoder_embeds.astype(cfg.dtype),
                                          params["encoder"], cfg, shd)
        # else: decode step, cross-KV comes from the cache

    width = 0
    cache_layers = None
    attn_index = index
    if cache is not None:
        cache_layers = cache["layers"]
        width = _cache_seq_width(cache_layers)
        if s == 1:
            # per-row index (continuous batching: slots at skewed positions)
            attn_index = positions[:, -1]

    x, new_layers, aux = stack_forward(
        x, params, cfg, shd, rope, cache_layers, attn_index, width,
        encoder_out)

    x = L.apply_norm(x, params["final_norm"], cfg)
    wv = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", x, wv.astype(x.dtype))
    logits = shd.act(logits, "batch", None, "vocab")
    logits = mask_vocab_padding(logits, cfg)

    new_cache = None
    if cache is not None:
        new_index = index + s
        if s == 1:
            # global index tracks the furthest-advanced slot
            new_index = jnp.maximum(new_index,
                                    jnp.max(positions[:, -1]) + 1).astype(jnp.int32)
        new_cache = dict(cache, index=new_index, layers=new_layers)
    return logits, new_cache, aux


def _cache_seq_width(cache_layers) -> int:
    for sl in cache_layers:
        if "k" in sl:
            return sl["k"].shape[2]  # (blocks, B, W, KVH, hd)
    return 0


# --------------------------------------------------------------------- #
# loss
# --------------------------------------------------------------------- #

def lm_loss(logits, labels, aux=0.0, aux_weight: float = 0.01):
    """Mean token cross-entropy; vocab-sharded-safe (one-hot contraction)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(lf * onehot, axis=-1)
    return jnp.mean(lse - ll) + aux_weight * aux
