"""Decode caches: full / sliding-window-ring KV, SSM states, cross-attn KV.

Slot->position math is derived from a single scalar `index` (tokens written
so far), so no positions array is stored or checkpointed:

  full cache (W == max_len):  slot s holds position s, valid iff s < index
  ring cache (W == window):   slot s holds p = (index-1) - ((index-1 - s) % W),
                              valid iff p >= 0

KV tensors are sequence-sharded over the model axis by default
(flash-decoding; the bank-parallel layout of DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .config import LayerSpec, ModelConfig
from .mamba import mamba_state_defs
from .rwkv import rwkv_state_defs
from .sharding import ParamDef, Shardings, stack_defs


def kv_defs(cfg: ModelConfig, batch: int, width: int, name: str) -> dict:
    kvh, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": ParamDef((batch, width, kvh, hd),
                      ("batch", "cache_seq", None, None), f"{name}.k", "zeros"),
        "v": ParamDef((batch, width, kvh, hd),
                      ("batch", "cache_seq", None, None), f"{name}.v", "zeros"),
    }


def cross_kv_defs(cfg: ModelConfig, batch: int, name: str) -> dict:
    kvh, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": ParamDef((batch, cfg.encoder_seq, kvh, hd),
                      ("batch", "cache_seq", None, None), f"{name}.ck", "zeros"),
        "v": ParamDef((batch, cfg.encoder_seq, kvh, hd),
                      ("batch", "cache_seq", None, None), f"{name}.cv", "zeros"),
    }


def cache_width(cfg: ModelConfig, max_len: int) -> int:
    """Ring-buffer width: the window if it is smaller than the context."""
    if cfg.sliding_window and cfg.sliding_window < max_len:
        return cfg.sliding_window
    return max_len


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ParamDef tree for the whole decode cache (stacked over blocks)."""
    width = cache_width(cfg, max_len)
    per_pos = []
    for i, spec in enumerate(cfg.layer_pattern()):
        name = f"cache.l{i}"
        if spec.kind == "attn":
            d = kv_defs(cfg, batch, width, name)
            if spec.cross_attn:
                d.update(cross=cross_kv_defs(cfg, batch, name))
        elif spec.kind == "mamba":
            d = mamba_state_defs(cfg, batch, name)
        elif spec.kind == "rwkv":
            d = rwkv_state_defs(cfg, batch, name)
        else:
            d = {}
        per_pos.append(d)
    layers = [stack_defs(d, cfg.n_blocks) for d in per_pos]
    return {
        "index": ParamDef((), (), "cache.index", "zeros", "int32"),
        "layers": layers,
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               shd: Shardings | None = None) -> dict:
    """Zero-initialized cache (concrete arrays, optionally sharded)."""
    from .sharding import tree_specs, is_def
    defs = cache_defs(cfg, batch, max_len)

    def mk(d: ParamDef):
        dt = jnp.dtype(d.dtype or ("float32" if "wkv" in d.name
                                   or d.name.endswith(".h") else cfg.dtype))
        arr = jnp.zeros(d.shape, dt)
        if shd is not None and shd.mesh is not None:
            arr = jax.device_put(arr, shd.named(d.shape, d.kinds, d.name))
        return arr
    return jax.tree.map(mk, defs, is_leaf=is_def)


def slot_positions(count, width: int):
    """True position held by each slot given `count` tokens written.

    count: scalar -> (W,); per-row (B,) -> (B, W). -1 marks empty slots.
    Per-row counts support continuous batching (length-skewed slots share
    one batched cache — serve/engine.py)."""
    s = jnp.arange(width, dtype=jnp.int32)
    idx1 = jnp.asarray(count, jnp.int32) - 1
    if jnp.ndim(idx1):
        idx1 = idx1[:, None]
    pos = idx1 - jnp.mod(idx1 - s, width)
    return jnp.where(pos >= 0, pos, -1)


def write_decode(kv: dict, k_new, v_new, index, width: int) -> dict:
    """Insert one token's k/v at slot index % width. k_new: (B,1,KVH,hd).
    index: scalar (synchronized batch) or (B,) per-row positions."""
    slot = jnp.mod(jnp.asarray(index, jnp.int32), width)
    if jnp.ndim(slot) == 0:
        k = jax.lax.dynamic_update_slice_in_dim(
            kv["k"], k_new.astype(kv["k"].dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            kv["v"], v_new.astype(kv["v"].dtype), slot, axis=1)
    else:
        upd = jax.vmap(lambda dst, src, sl:
                       jax.lax.dynamic_update_slice_in_dim(dst, src, sl, axis=0))
        k = upd(kv["k"], k_new.astype(kv["k"].dtype), slot)
        v = upd(kv["v"], v_new.astype(kv["v"].dtype), slot)
    return dict(kv, k=k, v=v)


def write_prefill(kv: dict, k_full, v_full) -> dict:
    """Write a prefill's k/v. If the prefill is longer than the (ring)
    cache, keep the last `width` tokens at their p % width slots."""
    s, width = k_full.shape[1], kv["k"].shape[1]
    if s > width:
        k_full = jnp.roll(k_full[:, s - width:], s % width, axis=1)
        v_full = jnp.roll(v_full[:, s - width:], s % width, axis=1)
        s = width
    k = jax.lax.dynamic_update_slice_in_dim(
        kv["k"], k_full.astype(kv["k"].dtype), 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        kv["v"], v_full.astype(kv["v"].dtype), 0, axis=1)
    return dict(kv, k=k, v=v)
