"""RWKV-6 (Finch) block: data-dependent-decay linear attention + channel mix.

The wkv state is (B, H, hs, hs) per layer, updated per token:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (faaaa * (k_t^T v_t) + S_t)
Training/prefill runs a `lax.scan` over time (sequence-chunked at the
caller's discretion); decode is one step. Attention-free: O(1) state makes
this the strongest fit for the paper's bank-parallel decode mapping (pure
weight/state streaming, no inter-bank traffic).

Time-mix projections stay head-aligned: (D, D) weights are sharded on the
*input* dim (contracting) so outputs keep whole heads per chip regardless
of H % tp (H=40 does not divide a 16-way model axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ParamDef, Shardings

_MIX = ("w", "k", "v", "r", "g")

#: chunk length for the parallel wkv formulation. 8 * max |log w| (= 8 per
#: the decay clamp) keeps every pairwise exponent within f32 range.
WKV_CHUNK = 8


def _wkv_chunked(rh, kh, vh, wh, u, S0, chunk: int):
    """Chunked-parallel wkv: solve S_{t+1} = diag(w_t) S_t + k_t^T v_t and
    o_t = r_t (u ⊙ k_t^T v_t + S_t) with the state carried once per chunk.

    Within a chunk (log-space, c_t = sum_{i<t} log w_i from chunk start):
        o_t  = (r_t e^{c_t}) S0             (inter-chunk, one matmul)
             + sum_{j<t} [r_t·k_j e^{c_t - c_{j+1}}] v_j   (intra, masked
               (C,C) attention-like matmul pair on the MXU)
             + (r_t·(u ⊙ k_t)) v_t          (diagonal bonus term)
        S'   = diag(e^{c_C}) S0 + sum_j diag(e^{c_C - c_{j+1}}) k_j^T v_j
    All exponents are differences of same-chunk cumulative sums, bounded by
    chunk * max|log w| <= 64 < 88.7 (f32 exp range) via the decay clamp.

    rh/kh/vh/wh: (B,S,H,hs) f32; S0: (B,H,hs,hs) f32.
    Returns (S_final, o (B,S,H,hs))."""
    b, s, h, hs = rh.shape
    n = s // chunk
    resh = lambda x: x.reshape(b, n, chunk, h, hs).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = resh(rh), resh(kh), resh(vh), resh(wh)

    tril = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def chunk_step(S, inp):
        r, k, v, w = inp                       # (B,C,H,hs)
        lw = jnp.log(w)
        cum = jnp.cumsum(lw, axis=1)           # c_{t+1}: sum_{i<=t}
        c_ex = cum - lw                        # c_t: sum_{i<t}
        q = r * jnp.exp(c_ex)                  # (B,C,H,hs)
        o_inter = jnp.einsum("bchk,bhkv->bchv", q, S)
        kd = k * jnp.exp(-cum)                 # e^{-c_{j+1}} k_j
        A = jnp.einsum("bthk,bjhk->bhtj", q, kd)
        A = jnp.where(tril[None, None], A, 0.0)
        o_intra = jnp.einsum("bhtj,bjhv->bthv", A, v)
        coef = jnp.einsum("bthk,hk->bth", r * k, u)
        o_diag = coef[..., None] * v
        wC = jnp.exp(cum[:, -1])               # (B,H,hs): e^{c_C}
        ks = k * jnp.exp(cum[:, -1:] - cum)    # e^{c_C - c_{j+1}} k_j
        S_new = wC[..., None] * S + jnp.einsum("bjhk,bjhv->bhkv", ks, v)
        return S_new, o_inter + o_intra + o_diag

    S_final, outs = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    # (n, B, C, H, hs) -> (B, S, H, hs)
    o = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hs)
    return S_final, o


def rwkv_defs(cfg: ModelConfig, name: str) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    lw, lm = cfg.rwkv_decay_lora, cfg.rwkv_mix_lora
    defs = {
        # token-shift mixing coefficients + LoRA
        "maa_x": ParamDef((d,), (None,), f"{name}.maa_x", "small"),
        "maa": ParamDef((5, d), (None, None), f"{name}.maa", "small"),
        "maa_w1": ParamDef((d, 5 * lm), (None, None), f"{name}.maa_w1", "small"),
        "maa_w2": ParamDef((5, lm, d), (None, None, None), f"{name}.maa_w2", "small"),
        # data-dependent decay
        "decay": ParamDef((d,), (None,), f"{name}.decay", "small"),
        "decay_w1": ParamDef((d, lw), (None, None), f"{name}.decay_w1", "small"),
        "decay_w2": ParamDef((lw, d), (None, None), f"{name}.decay_w2", "small"),
        "faaaa": ParamDef((cfg.n_rwkv_heads, cfg.rwkv_head_size),
                          (None, None), f"{name}.faaaa", "small"),
        # projections: input-dim sharded (see module docstring)
        "wr": ParamDef((d, d), ("tp", None), f"{name}.wr"),
        "wk": ParamDef((d, d), ("tp", None), f"{name}.wk"),
        "wv": ParamDef((d, d), ("tp", None), f"{name}.wv"),
        "wg": ParamDef((d, d), ("tp", None), f"{name}.wg"),
        "wo": ParamDef((d, d), (None, "tp"), f"{name}.wo"),
        "ln_x": ParamDef((d,), (None,), f"{name}.ln_x", "ones"),
        # channel mix
        "cm_maa_k": ParamDef((d,), (None,), f"{name}.cm_maa_k", "small"),
        "cm_maa_r": ParamDef((d,), (None,), f"{name}.cm_maa_r", "small"),
        "cm_wk": ParamDef((d, f), ("fsdp", "tp"), f"{name}.cm_wk"),
        "cm_wv": ParamDef((f, d), ("tp", "fsdp"), f"{name}.cm_wv"),
        "cm_wr": ParamDef((d, d), ("tp", None), f"{name}.cm_wr"),
    }
    return defs


def _token_shift(x, shift_state):
    """x: (B,S,D); shift_state: (B,1,D) last token of previous segment."""
    prev = jnp.concatenate([shift_state.astype(x.dtype), x[:, :-1]], axis=1)
    return prev


def rwkv_time_mix(x, p, cfg: ModelConfig, shd: Shardings, state):
    b, s, d = x.shape
    h, hs = cfg.n_rwkv_heads, cfg.rwkv_head_size
    lm = cfg.rwkv_mix_lora

    prev = _token_shift(x, state["shift_tm"])
    xx = prev - x
    xxx = x + xx * p["maa_x"].astype(x.dtype)
    # (B,S,5*lm) -> (5,B,S,lm) -> lora -> (5,B,S,D)
    lora = jnp.tanh(jnp.einsum("bsd,dl->bsl", xxx, p["maa_w1"].astype(x.dtype)))
    lora = lora.reshape(b, s, 5, lm).transpose(2, 0, 1, 3)
    mix = jnp.einsum("fbsl,fld->fbsd", lora, p["maa_w2"].astype(x.dtype))
    mix = mix + p["maa"].astype(x.dtype)[:, None, None, :]
    xw, xk, xv, xr, xg = [x + xx * mix[i] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))

    dec = p["decay"].astype(jnp.float32) + jnp.einsum(
        "bsl,ld->bsd",
        jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["decay_w1"].astype(x.dtype))
                 ).astype(jnp.float32),
        p["decay_w2"].astype(jnp.float32))
    # per-token decay clamped to >= e^-8 (state halving every 0.09 tokens
    # is never useful) — makes the chunked log-space formulation below
    # overflow-safe (pairwise exponents bounded by 8*chunk < 88.7 = f32
    # exp range). Applied in BOTH the chunked and the per-token (decode)
    # paths, so decode == full forward stays exact.
    w = jnp.exp(-jnp.minimum(jnp.exp(dec), 8.0))   # (B,S,D) in [e^-8, 1)

    rh = r.reshape(b, s, h, hs).astype(jnp.float32)
    kh = k.reshape(b, s, h, hs).astype(jnp.float32)
    vh = v.reshape(b, s, h, hs).astype(jnp.float32)
    wh = w.reshape(b, s, h, hs)
    u = p["faaaa"].astype(jnp.float32)             # (H,hs)

    if s > 1 and s % WKV_CHUNK == 0:
        # chunked parallel formulation: state touched once per CHUNK and
        # the per-token outer products become (C x C x hs) MXU matmuls —
        # the TPU adaptation of the paper's "put compute where the
        # bandwidth is" (§Perf rwkv iteration; state traffic / WKV_CHUNK)
        S_final, o = _wkv_chunked(rh, kh, vh, wh, u, state["wkv"],
                                  WKV_CHUNK)
        o = o.reshape(b, s, d)
    else:
        def step(S, inp):
            r_t, k_t, v_t, w_t = inp               # (B,H,hs) each
            kv = k_t[..., None] * v_t[..., None, :]  # (B,H,hs,hs)
            o_t = jnp.einsum("bhk,bhkv->bhv", r_t,
                             u[None, :, :, None] * kv + S)
            S_new = w_t[..., None] * S + kv
            return S_new, o_t

        xs = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
              vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
        S_final, outs = jax.lax.scan(step, state["wkv"], xs)
        o = outs.transpose(1, 0, 2, 3).reshape(b, s, d)  # (B,S,D) f32

    # group norm over heads (ln_x), then gate and output projection
    o = o.reshape(b, s, h, hs)
    mu = jnp.mean(o, -1, keepdims=True)
    var = jnp.var(o, -1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 64e-5)
    o = o.reshape(b, s, d) * p["ln_x"].astype(jnp.float32)
    o = o.astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", o, p["wo"].astype(x.dtype))
    out = shd.act(out, "batch", "seq", None)
    new_state = {"wkv": S_final, "shift_tm": x[:, -1:]}
    return out, new_state


def rwkv_channel_mix(x, p, cfg: ModelConfig, shd: Shardings, state):
    prev = _token_shift(x, state["shift_cm"])
    xx = prev - x
    xk = x + xx * p["cm_maa_k"].astype(x.dtype)
    xr = x + xx * p["cm_maa_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["cm_wk"].astype(x.dtype))))
    kv = jnp.einsum("bsf,fd->bsd", k, p["cm_wv"].astype(x.dtype))
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cm_wr"].astype(x.dtype)))
    out = shd.act(r * kv, "batch", "seq", None)
    return out, {"shift_cm": x[:, -1:]}


def rwkv_state_defs(cfg: ModelConfig, batch: int, name: str) -> dict:
    h, hs, d = cfg.n_rwkv_heads, cfg.rwkv_head_size, cfg.d_model
    return {
        "wkv": ParamDef((batch, h, hs, hs), ("batch", None, None, None),
                        f"{name}.wkv", "zeros"),
        "shift_tm": ParamDef((batch, 1, d), ("batch", None, None),
                             f"{name}.shift_tm", "zeros"),
        "shift_cm": ParamDef((batch, 1, d), ("batch", None, None),
                             f"{name}.shift_cm", "zeros"),
    }
