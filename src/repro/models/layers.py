"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention (full,
sliding-window, cross), chunked-flash attention for long prefill, capacity-
based MoE.

All weights are declared as `ParamDef` (shape + logical sharding kinds) so
one table in `sharding.py` controls distribution. Attention q/k/v weights
are kept 3-D (d_model, heads, head_dim) so head-aligned TP never requires a
resharding reshape.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ParamDef, Shardings


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #

def norm_defs(cfg: ModelConfig, name: str) -> dict:
    d = {"scale": ParamDef((cfg.d_model,), (None,), f"{name}.scale", "ones")}
    if _is_layernorm(cfg):
        d["bias"] = ParamDef((cfg.d_model,), (None,), f"{name}.bias", "zeros")
    return d


def _is_layernorm(cfg: ModelConfig) -> bool:
    return cfg.name.startswith(("starcoder", "whisper"))


def apply_norm(x, p, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if _is_layernorm(cfg):
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# RoPE / M-RoPE
# --------------------------------------------------------------------- #

def rope_freqs(cfg: ModelConfig) -> jnp.ndarray:
    hd = cfg.hd
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def rope_sincos(positions, cfg: ModelConfig):
    """positions: (..., S) int32 -> sin/cos (..., S, hd/2) f32.

    For M-RoPE (qwen2-vl), positions is (3, B, S) — temporal/height/width —
    and the head dim is split into 3 sections rotated by their own stream
    (text tokens use t==h==w so this reduces to 1-D RoPE; the machinery is
    the faithful part, the visual grid comes from the stub frontend).
    """
    freqs = rope_freqs(cfg)
    if cfg.rope == "mrope":
        t = positions.astype(jnp.float32)[..., None] * freqs  # (3,B,S,hd/2)
        hd2 = freqs.shape[0]
        s1, s2 = hd2 // 3, 2 * (hd2 // 3)
        sel = jnp.concatenate([
            t[0, ..., :s1], t[1, ..., s1:s2], t[2, ..., s2:]], axis=-1)
        return jnp.sin(sel), jnp.cos(sel)
    t = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(t), jnp.cos(t)


def apply_rope(x, sin, cos):
    """x: (B,S,H,hd); sin/cos: (B,S,hd/2) or (S,hd/2)."""
    if sin.ndim == 2:
        sin, cos = sin[None], cos[None]
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------- #

def attn_defs(cfg: ModelConfig, name: str, cross: bool = False) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "wq": ParamDef((d, h, hd), ("fsdp", "tp", None), f"{name}.wq"),
        "wk": ParamDef((d, kvh, hd), ("fsdp", "tp", None), f"{name}.wk"),
        "wv": ParamDef((d, kvh, hd), ("fsdp", "tp", None), f"{name}.wv"),
        "wo": ParamDef((h, hd, d), ("tp", None, "fsdp"), f"{name}.wo"),
    }
    if cfg.attn_bias:
        defs["bq"] = ParamDef((h, hd), ("tp", None), f"{name}.bq", "zeros")
        defs["bk"] = ParamDef((kvh, hd), ("tp", None), f"{name}.bk", "zeros")
        defs["bv"] = ParamDef((kvh, hd), ("tp", None), f"{name}.bv", "zeros")
    return defs


def _qkv(x, p, cfg: ModelConfig, shd: Shardings, *, rope_sin=None,
         rope_cos=None, want_rope=True, heads_tp=True):
    """heads_tp: shard q heads over tp (train/prefill). Decode uses the
    flash-decoding layout instead: heads replicated, cache seq sharded."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if want_rope and cfg.rope != "none" and rope_sin is not None:
        q = apply_rope(q, rope_sin, rope_cos)
        k = apply_rope(k, rope_sin, rope_cos)
    # NOTE (§Perf, refuted attempt): for archs whose head count doesn't
    # divide the model axis (deepseek 56H, starcoder2 36H on 16-way tp)
    # a constraint-only "shard q over SEQ instead" fallback was measured
    # a no-op — GSPMD re-gathers q around the dynamically-sliced flash
    # loop. The working fix is a shard_map-structured flash (future work,
    # EXPERIMENTS.md §Perf).
    q = shd.act(q, "batch", None, "tp" if heads_tp else None, None)
    k = shd.act(k, "batch", None, None, None)
    v = shd.act(v, "batch", None, None, None)
    return q, k, v


def _grouped(q, kvh):
    b, s, h, hd = q.shape
    return q.reshape(b, s, kvh, h // kvh, hd)


def flash_attention(q, k, v, cfg: ModelConfig, shd: Shardings, *,
                    causal: bool = True, q_offset: int = 0):
    """Chunked online-softmax attention (pure-JAX flash): never materializes
    the (S, S) score matrix. q: (B,Sq,H,hd); k,v: (B,Skv,KVH,hd).
    The Pallas TPU kernel in repro/kernels/flash_attention is the hardware
    hot-spot version; this is the reference / dry-run path.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qc = min(cfg.q_chunk, sq)
    kc = min(cfg.kv_chunk, skv)
    n_q, n_k = sq // qc, skv // kc
    assert sq % qc == 0 and skv % kc == 0, (sq, qc, skv, kc)

    # repeat KV to full heads so every attention tensor shards cleanly on
    # the head dim over tp (GQA group splits don't propagate through GSPMD)
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    k = shd.act(k, "batch", None, "tp", None)
    v = shd.act(v, "batch", None, "tp", None)
    window = cfg.sliding_window

    def q_step(_, qi):
        qchunk = jax.lax.dynamic_slice_in_dim(q, qi * qc, qc, axis=1)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            kchunk = jax.lax.dynamic_slice_in_dim(k, ki * kc, kc, axis=1)
            vchunk = jax.lax.dynamic_slice_in_dim(v, ki * kc, kc, axis=1)
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqhd,bkhd->bhqk", qchunk, kchunk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p_, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_.astype(vchunk.dtype), vchunk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(n_k))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        o = jnp.transpose(o, (0, 2, 1, 3))        # (B,qc,H,hd)
        return None, o.astype(q.dtype)

    _, chunks = jax.lax.scan(q_step, None, jnp.arange(n_q))
    # (n_q, B, qc, H, hd) -> (B, Sq, H, hd)
    o = jnp.transpose(chunks, (1, 0, 2, 3, 4)).reshape(b, sq, h, hd)
    return o


def cached_attention(q, k_cache, v_cache, cache_positions, index,
                     cfg: ModelConfig, shd: Shardings):
    """Decode-step attention against a (possibly ring) KV cache.

    q: (B,1,H,hd); caches: (B,W,KVH,hd) sequence-sharded (flash-decoding:
    every chip scans its context slice, then a small cross-chip reduce —
    the bank-parallel pattern). cache_positions: (W,) or per-row (B,W) true
    position of each slot, -1 for empty; index: current position, scalar or
    per-row (B,) for continuous batching.
    """
    b, _, h, hd = q.shape
    kvh = k_cache.shape[2]
    qg = _grouped(q, kvh)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = index[:, None] if jnp.ndim(index) else index
    valid = cache_positions >= 0
    valid &= cache_positions <= idx
    if cfg.sliding_window:
        valid &= cache_positions > idx - cfg.sliding_window
    if valid.ndim == 1:
        valid = valid[None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskd->bkgqd", (p / l).astype(q.dtype), v_cache)
    o = jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(b, 1, h, hd)
    return o


def attn_out(o, p, x_dtype, shd: Shardings):
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x_dtype))
    # seq-sharded output under SP: GSPMD turns the tp-partial sum into a
    # reduce-scatter (Megatron sequence parallelism); no-op otherwise
    return shd.act(out, "batch", "seq", None)


# --------------------------------------------------------------------- #
# dense MLP
# --------------------------------------------------------------------- #

def mlp_defs(cfg: ModelConfig, name: str, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    defs = {
        "wu": ParamDef((d, f), ("fsdp", "tp"), f"{name}.wu"),
        "wd": ParamDef((f, d), ("tp", "fsdp"), f"{name}.wd"),
    }
    if cfg.gated_mlp:
        defs["wg"] = ParamDef((d, f), ("fsdp", "tp"), f"{name}.wg")
    return defs


def _act_fn(cfg: ModelConfig):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[cfg.mlp_act]


def mlp_forward(x, p, cfg: ModelConfig, shd: Shardings):
    act = _act_fn(cfg)
    up = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    if cfg.gated_mlp:
        gate = act(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype)))
        up = gate * up
    else:
        up = act(up)
    out = jnp.einsum("bsf,fd->bsd", up, p["wd"].astype(x.dtype))
    return shd.act(out, "batch", "seq", None)


# --------------------------------------------------------------------- #
# MoE (capacity-based dispatch, GShard-style, row-local positions)
# --------------------------------------------------------------------- #

CAPACITY_FACTOR = 1.25


def moe_defs(cfg: ModelConfig, name: str) -> dict:
    d = cfg.d_model
    e, fe = cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    defs = {
        "router": ParamDef((d, e), (None, None), f"{name}.router", "small"),
        "wu": ParamDef((e, d, fe), ("experts", "fsdp", "tp"), f"{name}.e_wu"),
        "wd": ParamDef((e, fe, d), ("experts", "tp", "fsdp"), f"{name}.e_wd"),
    }
    if cfg.gated_mlp:
        defs["wg"] = ParamDef((e, d, fe), ("experts", "fsdp", "tp"),
                              f"{name}.e_wg")
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff or cfg.n_shared_experts * fe
        defs["shared"] = mlp_defs(cfg, f"{name}.shared", fs)
        defs["shared_gate"] = ParamDef((d, 1), (None, None),
                                       f"{name}.shared_gate", "small")
    return defs


def moe_dispatch(x, router, cfg: ModelConfig):
    """Router + top-k gate + capacity scatter: the token-side half of the
    MoE dispatch. Returns `(buf, topi, pos, w, gates)` — the (B, E, C, D)
    dispatch buffer (the tensor an expert-parallel layout re-distributes
    across devices/banks), each token's expert ids / capacity positions /
    normalized kept-gate weights (what the combine needs back), and the
    raw gate softmax (for the aux loss). Positions are ROW-LOCAL cumsums,
    so no cross-device prefix is needed and batch rows may shard freely;
    overflow tokens beyond `CAPACITY_FACTOR` drop (standard semantics).
    Shared by the fused `moe_forward` and the dispatch serving stages
    (`serve.dispatch_engine._MoeStageMixin`) so the two paths cannot
    drift."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(CAPACITY_FACTOR * k * s / e), 1)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)          # (B,S,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # row-local position of each (token, slot) inside its expert
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)      # (B,S,k,E)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                     # inclusive-1
    pos = jnp.sum(pos.reshape(b, s, k, e) * onehot, axis=-1)  # (B,S,k)
    keep = pos < cap
    w = topw * keep.astype(topw.dtype)

    # scatter tokens into (B, E, C, D)
    buf = jnp.zeros((b, e, cap, d), x.dtype)
    bidx = jnp.arange(b)[:, None, None]
    buf = buf.at[bidx, topi, jnp.where(keep, pos, cap - 1)].add(
        jnp.where(keep[..., None], x[:, :, None, :], 0).astype(x.dtype),
        mode="drop")
    return buf, topi, pos, w, gates


def quantize_q8(w, axis: int = 1):
    """Symmetric per-channel int8 weight quantization: one f32 scale per
    output channel, reduced over the contraction `axis` (kept as a size-1
    dim so `q * scale` broadcasts back to `w`'s shape). Deterministic
    elementwise + max-reduce ops, so quantizing inside the fused jit and
    once-ahead for the dispatch stages yields bit-identical `(q, scale)`
    — the property the exact-integer identity gate rests on
    (DESIGN.md §15)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    # reciprocal-multiply, NOT `amax / 127.0`: XLA rewrites division by a
    # constant into a reciprocal multiply under jit but not eagerly, and
    # the identity gate needs both compilations to emit the same scale
    scale = jnp.where(amax > 0, amax * (1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _quantize_rows(x):
    """Per-row (per-token) symmetric int8 activation quantization over the
    trailing feature axis; returns `(q, scale)` with scale keepdims."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax * (1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8), scale


def moe_expert_ffn_q8(buf, wuq, su, wdq, sd, cfg: ModelConfig,
                      shd: Shardings, wgq=None, sg=None):
    """`moe_expert_ffn` on PRE-quantized int8 expert weights: int8 x int8
    einsums accumulating in int32 (`preferred_element_type`), dequantized
    to f32 by the product of the row activation scale and the per-channel
    weight scale, with the gate nonlinearity applied in f32 and the rows
    re-quantized before the down projection. Taking the quantized weights
    as ARGUMENTS (not quantizing in-body) is load-bearing twice over: the
    dispatch stage's compiled HLO prices int8 params, int8-operand dots,
    and 4x-smaller weight bytes (what flips the planner, KT2), and the
    fused path's in-jit `quantize_q8` of the same f32 weights produces
    bit-identical integers — so dispatch-vs-fused identity is exact on
    the int32 accumulators, not approximate (DESIGN.md §15)."""
    act = _act_fn(cfg)
    xq, sx = _quantize_rows(buf.astype(jnp.float32))
    up = jnp.einsum("becd,edf->becf", xq, wuq,
                    preferred_element_type=jnp.int32)
    up = up.astype(jnp.float32) * sx * su[None, :, 0, None, :]
    up = shd.act(up, "batch", None, None, "tp")
    if cfg.gated_mlp:
        gate = jnp.einsum("becd,edf->becf", xq, wgq,
                          preferred_element_type=jnp.int32)
        gate = act(gate.astype(jnp.float32) * sx * sg[None, :, 0, None, :])
        gate = shd.act(gate, "batch", None, None, "tp")
        up = gate * up
    else:
        up = act(up)
    uq, sup = _quantize_rows(up)
    out_buf = jnp.einsum("becf,efd->becd", uq, wdq,
                         preferred_element_type=jnp.int32)
    out_buf = out_buf.astype(jnp.float32) * sup * sd[None, :, 0, None, :]
    return shd.act(out_buf.astype(buf.dtype), "batch", None, None, None)


def moe_expert_ffn(buf, p, cfg: ModelConfig, shd: Shardings):
    """The per-expert (gated) FFN over the (B, E, C, D) dispatch buffer —
    embarrassingly parallel over the expert axis, which is exactly what
    an expert-parallel layout shards. Shared by `moe_forward` and the
    dispatch serving stages. With `cfg.quant == "int8"` the weights are
    quantized in-jit (`quantize_q8`) and the arithmetic runs through
    `moe_expert_ffn_q8` — identical integers to the dispatch stages'
    quantize-once-ahead path.

    Sharding note: constrain the expert einsum OUTPUTS to tp-sharded
    tiles — left to itself GSPMD all-reduced full-F f32 partials
    (18.8 GB/layer); with the constraint the d-contraction partial-sum
    reduces tp-sharded bf16 tiles instead (§Perf, mixtral collective
    iteration — the explicit weight-gather variant was REFUTED: it
    replicated the contraction)."""
    if getattr(cfg, "quant", "") == "int8":
        wuq, su = quantize_q8(p["wu"])
        wdq, sd = quantize_q8(p["wd"])
        wgq = sg = None
        if cfg.gated_mlp:
            wgq, sg = quantize_q8(p["wg"])
        return moe_expert_ffn_q8(buf, wuq, su, wdq, sd, cfg, shd, wgq, sg)
    act = _act_fn(cfg)
    up = jnp.einsum("becd,edf->becf", buf, p["wu"].astype(buf.dtype))
    up = shd.act(up, "batch", None, None, "tp")
    if cfg.gated_mlp:
        gate = act(jnp.einsum("becd,edf->becf", buf,
                              p["wg"].astype(buf.dtype)))
        gate = shd.act(gate, "batch", None, None, "tp")
        up = gate * up
    else:
        up = act(up)
    out_buf = jnp.einsum("becf,efd->becd", up, p["wd"].astype(buf.dtype))
    return shd.act(out_buf, "batch", None, None, None)


def moe_combine(out_buf, topi, pos, w, dtype):
    """Gather each token's expert outputs back from the (B, E, C, D)
    buffer and combine with the normalized gate weights (the token-side
    tail of the MoE layer; dropped tokens gather a clamped slot whose
    weight is zero). Shared by `moe_forward` and the dispatch serving
    stages."""
    bidx = jnp.arange(out_buf.shape[0])[:, None, None]
    gathered = out_buf[bidx, topi, pos]                    # (B,S,k,D)
    return jnp.sum(gathered * w[..., None].astype(dtype), axis=2)


def moe_forward(x, p, cfg: ModelConfig, shd: Shardings):
    """Top-k expert MLP with per-sequence capacity dispatch.

    Tokens are dispatched into an (E, C) buffer per batch row via scatter
    (`moe_dispatch`), crunched by the per-expert FFN (`moe_expert_ffn`),
    and gathered back (`moe_combine`) — the three slices the dispatch
    serving engine runs as separate planner stages around its token/
    combine exchanges. Overflow tokens are dropped (standard
    capacity-factor semantics); an aux load-balancing loss is returned.
    """
    e, k = cfg.n_experts, cfg.top_k
    buf, topi, pos, w, gates = moe_dispatch(x, p["router"], cfg)

    # aux load-balance loss (Switch-style)
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    out_buf = moe_expert_ffn(buf, p, cfg, shd)
    y = moe_combine(out_buf, topi, pos, w, x.dtype)

    if cfg.n_shared_experts:
        sh = mlp_forward(x, p["shared"], cfg, shd)
        sg = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x.astype(jnp.float32),
                       p["shared_gate"]))
        y = y + (sh * sg.astype(x.dtype) if cfg.name.startswith("qwen2-moe")
                 else sh)
    return shd.act(y, "batch", "seq", None), aux
