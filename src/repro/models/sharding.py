"""Logical-axis sharding: one table maps model dims onto mesh axes.

Production mesh axes (launch/mesh.py): ("pod",) "data", "model".

Train policy (2-D FSDP x TP, MaxText-style):
  * batch            -> ("pod", "data")
  * weight in-dim    -> "data"   (FSDP: all-gathered per layer)
  * weight out-dim / heads / ffn / vocab -> "model" (tensor parallel)
  * KV-cache seq     -> "model"  (flash-decoding / bank-parallel layout)

Decode reuses the same weight layout (no resharding at checkpoint load) —
each chip streams only its weight shard per token, the PIM pattern of the
paper (bank-local streaming + small activations exchange).

Divisibility: a dim is only sharded if the axis size divides it; otherwise
the rule is dropped for that tensor and recorded in `ShardingPlan.dropped`
(e.g. deepseek's 56 q-heads on a 16-way model axis stay unsharded unless
`pad_heads=True` lets GSPMD pad).

Activation constraint points (`Shardings.act`) are mandatory: GSPMD loses
the batch sharding of scan-carried residuals without them (measured: the
405B prototype kept activations replicated over the 16-way data axis,
499 GB/device temp -> see EXPERIMENTS.md §Perf baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Policy:
    batch: tuple[str, ...] = ("pod", "data")
    fsdp: tuple[str, ...] = ("data",)
    tp: tuple[str, ...] = ("model",)
    # KV cache layout: "sequence" (flash-decoding) | "heads" | "batch"
    kv_layout: str = "sequence"
    # shard vocab dim of embedding / lm head over tp
    shard_vocab: bool = True
    # allow GSPMD padding when heads don't divide the tp axis
    pad_uneven_heads: bool = False
    # sequence-parallel activations between layers (Megatron SP: sub-layer
    # outputs reduce-scatter to seq-sharded; saved remat boundaries shrink
    # by the tp size — §Perf iteration 3, on by default for training)
    seq_parallel_acts: bool = True
    # experts dim over tp instead of per-expert ffn TP (EP hillclimb knob)
    expert_parallel: bool = False


TRAIN_POLICY = Policy()
DECODE_POLICY = Policy(kv_layout="sequence", seq_parallel_acts=False)


class Shardings:
    """Resolves logical dims against a concrete mesh; None mesh = no-op
    (single-device smoke tests)."""

    def __init__(self, mesh: Mesh | None, policy: Policy = TRAIN_POLICY):
        self.mesh = mesh
        self.policy = policy
        self.dropped: list[str] = []
        if mesh is not None:
            self._axis_size = {a: mesh.shape[a] for a in mesh.axis_names}
        else:
            self._axis_size = {}

    # -------------------------------------------------------------- #
    def _present(self, axes: tuple[str, ...]) -> tuple[str, ...]:
        return tuple(a for a in axes if a in self._axis_size)

    def _axes_size(self, axes: tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self._axis_size[a]
        return n

    def logical(self, kind: str) -> tuple[str, ...]:
        pol = self.policy
        table = {
            "batch": pol.batch,
            "fsdp": pol.fsdp,
            "tp": pol.tp,
            "vocab": pol.tp if pol.shard_vocab else (),
            "experts": pol.tp if pol.expert_parallel else (),
            "cache_seq": pol.tp if pol.kv_layout == "sequence" else (),
            "cache_heads": pol.tp if pol.kv_layout == "heads" else (),
            "seq": pol.tp if pol.seq_parallel_acts else (),
            # unconditional seq-over-tp (uneven-head attention fallback)
            "force_seq": pol.tp,
            "none": (),
        }
        return self._present(table[kind])

    def spec(self, dims: tuple[int, ...], kinds: tuple[str | None, ...],
             name: str = "?") -> P:
        """Build a PartitionSpec for a tensor, dropping non-dividing rules."""
        if self.mesh is None:
            return P()
        assert len(dims) == len(kinds), (name, dims, kinds)
        entries: list[Any] = []
        for dim, kind in zip(dims, kinds):
            if kind is None:
                entries.append(None)
                continue
            axes = self.logical(kind)
            if not axes:
                entries.append(None)
                continue
            size = self._axes_size(axes)
            if dim % size != 0:
                if kind in ("tp", "cache_heads") and self.policy.pad_uneven_heads:
                    entries.append(axes if len(axes) > 1 else axes[0])
                    continue
                self.dropped.append(f"{name}[{dim}]%{size}!=0 ({kind})")
                entries.append(None)
                continue
            entries.append(axes if len(axes) > 1 else axes[0])
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def named(self, dims, kinds, name="?") -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(dims, kinds, name))

    # -------------------------------------------------------------- #
    def act(self, x, *kinds: str | None):
        """Constrain an activation's sharding (no-op without a mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(tuple(x.shape), kinds, "act")
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def batch_spec(self, shape) -> P:
        """Batch-sharded on dim0, replicated elsewhere (tokens, labels).
        Falls back to replicated when the batch doesn't divide the axis
        (e.g. long_500k's global_batch=1)."""
        if self.mesh is None:
            return P()
        kinds = ("batch",) + (None,) * (len(tuple(shape)) - 1)
        return self.spec(tuple(shape), kinds, "batch")


def tree_specs(shd: Shardings, defs) -> Any:
    """Map a tree of ParamDef -> tree of PartitionSpec."""
    return jax.tree.map(
        lambda d: shd.spec(d.shape, d.kinds, d.name), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def tree_shape_structs(defs, default_dtype) -> Any:
    """Map a tree of ParamDef -> tree of jax.ShapeDtypeStruct (dry-run)."""
    import jax.numpy as jnp  # local to avoid cycles

    def f(d: "ParamDef"):
        dt = jnp.dtype(d.dtype or default_dtype)
        return jax.ShapeDtypeStruct(d.shape, dt)
    return jax.tree.map(f, defs, is_leaf=is_def)


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Shape + logical kinds + initializer for one parameter/state tensor."""
    shape: tuple[int, ...]
    kinds: tuple[str | None, ...]
    name: str = "?"
    init: str = "normal"        # normal | zeros | ones | small
    dtype: str | None = None    # None -> model dtype


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(defs, n: int):
    """Add a leading (scan/blocks) dim of size n to every ParamDef."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.kinds, d.name,
                           d.init, d.dtype),
        defs, is_leaf=is_def)
