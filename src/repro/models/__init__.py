"""repro.models — the architecture zoo (dense/MoE/hybrid/SSM/enc-dec/VLM)."""

from .config import LayerSpec, ModelConfig
from .sharding import (ParamDef, Policy, Shardings, stack_defs, tree_specs,
                       tree_shape_structs, TRAIN_POLICY, DECODE_POLICY)
from .transformer import (forward, init_params, lm_loss, param_defs,
                          param_shape_structs, param_specs)
from .cache import cache_defs, init_cache, cache_width
