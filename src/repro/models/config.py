"""Model configuration covering all assigned architecture families.

One `ModelConfig` describes any of: dense decoder LMs (llama-style),
MoE decoders (mixtral / qwen2-moe), hybrid attention+Mamba (jamba),
attention-free SSM (rwkv6), encoder-decoder audio (whisper backbone), and
VLM backbones (qwen2-vl). Heterogeneous stacks (jamba's 1:7 attn:mamba
interleave with MoE every other layer) are expressed as a repeating
*layer pattern*; the decoder scans over pattern repeats (blocks).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

LayerKind = Literal["attn", "mamba", "rwkv"]
MlpKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: LayerKind
    mlp: MlpKind
    cross_attn: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention
    rope: str = "rope"              # rope | mrope | none
    rope_theta: float = 1e6
    sliding_window: int = 0         # 0 = full attention
    attn_bias: bool = False         # qwen2 / starcoder2 use qkv bias
    attn_layer_period: int = 1      # jamba: attention every 8th layer
    attn_layer_offset: int = 0
    mlp_act: str = "silu"
    gated_mlp: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert ffn width (0 -> d_ff)
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    moe_layer_period: int = 1
    moe_layer_offset: int = 0
    router_aux_loss: float = 0.01

    # SSM (mamba / rwkv6)
    ssm_type: str = ""              # "" | mamba | rwkv6
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0            # 0 -> ceil(d_model/16)
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # encoder-decoder (whisper backbone)
    encoder_layers: int = 0
    encoder_seq: int = 0            # precomputed frame embeddings length

    # modality frontend stub: "tokens" (LM) or "embeds" (vlm/audio encoder)
    input_mode: str = "tokens"

    # numerics / distribution
    dtype: str = "bfloat16"
    # "" (full precision) | "int8": symmetric per-channel int8 expert
    # weights + int8 KV storage — the KT2-flip configuration
    # (models.layers.moe_expert_ffn_q8, DESIGN.md §15)
    quant: str = ""
    norm_eps: float = 1e-5
    # pad embedding/unembedding vocab dim to a multiple (Megatron-style) so
    # vocab-parallel sharding divides; pad logits are masked in forward.
    vocab_pad_multiple: int = 128
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat: bool = True
    # scan over groups of `remat_group` pattern-repeats: boundaries are
    # saved every remat_group blocks (K-fewer stacked residuals; backward
    # recomputes the group). Must divide n_blocks.
    remat_group: int = 1
    opt_moment_dtype: str = "float32"
    # attention chunking for long sequences (pure-JAX flash)
    q_chunk: int = 512
    kv_chunk: int = 1024
    # ssm sequence chunk
    ssm_chunk: int = 64

    # ----------------------------------------------------------------- #
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        if m <= 1 or self.vocab_size % m == 0:
            return self.vocab_size
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def layer_pattern(self) -> list[LayerSpec]:
        """The repeating block the decoder scans over."""
        if self.ssm_type == "rwkv6":
            return [LayerSpec("rwkv", "none")]
        period = 1
        if self.attn_layer_period > 1:
            period = self.attn_layer_period
        if self.n_experts and self.moe_layer_period > 1:
            period = _lcm(period, self.moe_layer_period)
        out = []
        for i in range(period):
            if self.attn_layer_period > 1:
                kind: LayerKind = ("attn" if i % self.attn_layer_period ==
                                   self.attn_layer_offset else "mamba")
            else:
                kind = "attn"
            if self.n_experts:
                is_moe = (i % self.moe_layer_period) == self.moe_layer_offset
                mlp: MlpKind = "moe" if is_moe else "dense"
            else:
                mlp = "dense"
            out.append(LayerSpec(kind, mlp, cross_attn=bool(self.encoder_layers)))
        assert self.n_layers % len(out) == 0, (self.name, self.n_layers, len(out))
        return out

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.layer_pattern())

    # ------------------------- parameter counting ---------------------- #
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        n = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.attn_bias:
            n += (self.n_heads + 2 * self.n_kv_heads) * hd
        return n

    def _dense_mlp_params(self, ff: int | None = None) -> int:
        f = ff or self.d_ff
        return (3 if self.gated_mlp else 2) * self.d_model * f

    def _moe_params(self, active_only: bool) -> int:
        fe = self.moe_d_ff or self.d_ff
        n_e = self.top_k if active_only else self.n_experts
        n = n_e * (3 if self.gated_mlp else 2) * self.d_model * fe
        n += self.d_model * self.n_experts  # router
        if self.n_shared_experts:
            n += self._dense_mlp_params(self.shared_d_ff or
                                        self.n_shared_experts * fe)
        return n

    def _mamba_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_d_state
        return (d * 2 * di + self.ssm_d_conv * di
                + di * (self.dt_rank + 2 * ds) + self.dt_rank * di
                + di * ds + di + di * d)

    def _rwkv_params(self) -> int:
        d, f = self.d_model, self.d_ff
        att = 4 * d * d + d * d  # r,k,v,g,o projections
        att += 2 * self.rwkv_decay_lora * d + 5 * 2 * self.rwkv_mix_lora * d
        att += self.d_model  # time_faaaa
        cmix = d * f + f * d + d * d
        return att + cmix

    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, used for MODEL_FLOPS."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        per_pattern = 0
        for spec in self.layer_pattern():
            if spec.kind == "attn":
                per_pattern += self._attn_params()
                if spec.cross_attn:
                    per_pattern += self._attn_params()
            elif spec.kind == "mamba":
                per_pattern += self._mamba_params()
            elif spec.kind == "rwkv":
                per_pattern += self._rwkv_params()
            if spec.mlp == "dense":
                per_pattern += self._dense_mlp_params()
            elif spec.mlp == "moe":
                per_pattern += self._moe_params(active_only)
            per_pattern += 2 * self.d_model  # norms
        n += self.n_blocks * per_pattern
        n += self.d_model  # final norm
        if self.encoder_layers:
            n += self.encoder_layers * (self._attn_params()
                                        + self._dense_mlp_params()
                                        + 2 * self.d_model)
        return n

    def model_flops(self, *, tokens: int, train: bool) -> float:
        """The spec's MODEL_FLOPS: 6*N*D (train) or 2*N*D (inference),
        with N = active params for MoE."""
        n_active = self.param_count(active_only=True)
        return (6.0 if train else 2.0) * n_active * tokens


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
