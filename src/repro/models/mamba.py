"""Mamba (selective SSM) block for the jamba hybrid architecture.

Training/prefill uses a chunked parallel scan: the sequence is split into
`cfg.ssm_chunk`-sized chunks; within a chunk the linear recurrence
``h_t = dA_t * h_{t-1} + dB_t x_t`` is solved with an associative scan
(so the (B, chunk, d_inner, d_state) intermediate stays VMEM-sized per
chip), and an outer `lax.scan` carries the state across chunks. Decode is
the single-step recurrence. The depthwise causal conv (k=4) is expressed
as a sum of shifts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ParamDef, Shardings


def mamba_defs(cfg: ModelConfig, name: str) -> dict:
    d, di, ds, r = cfg.d_model, cfg.d_inner, cfg.ssm_d_state, cfg.dt_rank
    k = cfg.ssm_d_conv
    return {
        "in_proj": ParamDef((d, 2 * di), ("fsdp", "tp"), f"{name}.in_proj"),
        "conv_w": ParamDef((k, di), (None, "tp"), f"{name}.conv_w", "small"),
        "conv_b": ParamDef((di,), ("tp",), f"{name}.conv_b", "zeros"),
        "x_proj": ParamDef((di, r + 2 * ds), ("tp", None), f"{name}.x_proj"),
        "dt_proj": ParamDef((r, di), (None, "tp"), f"{name}.dt_proj"),
        "dt_bias": ParamDef((di,), ("tp",), f"{name}.dt_bias", "zeros"),
        "A_log": ParamDef((di, ds), ("tp", None), f"{name}.A_log", "ones"),
        "D": ParamDef((di,), ("tp",), f"{name}.D", "ones"),
        "out_proj": ParamDef((di, d), ("tp", "fsdp"), f"{name}.out_proj"),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv over S as a sum of shifts.
    x: (B,S,di); w: (k,di); conv_state: (B,k-1,di) history or None."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+k-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return out + b, new_state


def _ssm_scan_chunked(dA, dBx, C, h0, chunk: int):
    """Solve h_t = dA_t h_{t-1} + dBx_t and contract y_t = h_t · C_t
    INSIDE the chunk scan, so the (B,S,di,ds) state sequence is never
    materialized — only one (B,chunk,di,ds) transient lives at a time
    (§Perf jamba iteration: 4.3 GB/layer -> 67 MB/layer).

    dA, dBx: (B,S,di,ds) f32; C: (B,S,ds) f32.
    Returns y (B,S,di) and final h (B,di,ds)."""
    b, s, di, ds = dA.shape
    n = s // chunk
    assert s % chunk == 0, (s, chunk)
    resh = lambda x: x.reshape((b, n, chunk) + x.shape[2:]) \
        .swapaxes(0, 1)
    dA_c, dBx_c, C_c = resh(dA), resh(dBx), resh(C)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inputs):
        a, bx, c = inputs                  # (B,chunk,di,ds), (B,chunk,ds)
        aa, bb = jax.lax.associative_scan(combine, (a, bx), axis=1)
        hs = aa * h[:, None] + bb          # (B,chunk,di,ds) transient
        y = jnp.einsum("bcds,bcs->bcd", hs, c)
        return hs[:, -1], y

    h_final, ys = jax.lax.scan(chunk_step, h0, (dA_c, dBx_c, C_c))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    return y, h_final


def mamba_forward(x, p, cfg: ModelConfig, shd: Shardings, state=None):
    """x: (B,S,D). state: None (train) or {"h": (B,di,ds) f32,
    "conv": (B,k-1,di)} for prefill-out / decode. Returns (y, new_state)."""
    b, s, d = x.shape
    di, ds, r = cfg.d_inner, cfg.ssm_d_state, cfg.dt_rank
    decoding = state is not None and s == 1

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shd.act(xin, "batch", None, "tp")

    conv_state = state["conv"] if state is not None else None
    xin, new_conv = _causal_conv(xin, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xin = jax.nn.silu(xin)

    dbc = jnp.einsum("bse,ef->bsf", xin, p["x_proj"].astype(x.dtype))
    dt, B_, C_ = jnp.split(dbc, [r, r + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt, p["dt_proj"].astype(x.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, ds)
    xin_f = xin.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)                        # (B,S,di,ds)
    dBx = (dt * xin_f)[..., None] * B_.astype(jnp.float32)[:, :, None, :]
    # keep the (B,S,di,ds) intermediates sharded on di over tp — GSPMD
    # loses it through the chunk reshapes otherwise (measured 761 GiB/dev
    # temp on jamba train before this constraint)
    dA = shd.act(dA, "batch", None, "tp", None)
    dBx = shd.act(dBx, "batch", None, "tp", None)

    h0 = (state["h"] if state is not None
          else jnp.zeros((b, di, ds), jnp.float32))
    Cf = C_.astype(jnp.float32)
    if decoding:
        h = dA[:, 0] * h0 + dBx[:, 0]
        h_final = h
        y = jnp.einsum("bds,bs->bd", h, Cf[:, 0])[:, None]
    else:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:  # identity steps: h = 1*h + 0 (sliced off below)
            dA_p = jnp.concatenate(
                [dA, jnp.ones((b, pad, di, ds), dA.dtype)], axis=1)
            dBx_p = jnp.concatenate(
                [dBx, jnp.zeros((b, pad, di, ds), dBx.dtype)], axis=1)
            C_p = jnp.concatenate(
                [Cf, jnp.zeros((b, pad, ds), Cf.dtype)], axis=1)
            y, h_final = _ssm_scan_chunked(dA_p, dBx_p, C_p, h0, chunk)
            y = y[:, :s]
        else:
            y, h_final = _ssm_scan_chunked(dA, dBx, Cf, h0, chunk)

    y = shd.act(y, "batch", None, "tp")
    y = y + xin_f * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    out = shd.act(out, "batch", "seq", None)
    new_state = {"h": h_final, "conv": new_conv}
    return out, new_state


def mamba_state_defs(cfg: ModelConfig, batch: int, name: str) -> dict:
    k = cfg.ssm_d_conv
    return {
        "h": ParamDef((batch, cfg.d_inner, cfg.ssm_d_state),
                      ("batch", "tp", None), f"{name}.h", "zeros"),
        "conv": ParamDef((batch, k - 1, cfg.d_inner),
                         ("batch", None, "tp"), f"{name}.conv", "zeros"),
    }
