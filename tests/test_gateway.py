"""Gateway layer (DESIGN.md §14): admission control, plan-cache
amortization, SLO-aware interleaving. Unit tests for the queue/cache
primitives plus ManualClock-driven integration runs — the virtual clock
makes every integration run fully deterministic, which the
seeded-Poisson determinism gate pins."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.dispatch import PlanCache, batch_signature
from repro.dispatch import trace as dtrace
from repro.models import Shardings, init_params
from repro.serve import (AdmissionQueue, Gateway, GatewayRequest,
                         ManualClock, ServeEngine, percentile,
                         poisson_requests)

SHD = Shardings(None)


@pytest.fixture(scope="module")
def setup():
    cfg = REDUCED["granite-3-8b"]
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    return cfg, params


def _greq(rid, plen=4, budget=4, priority=1, arrival=0.0, vocab=64):
    prompt = jnp.asarray([(rid * 7 + i) % vocab for i in range(plen)],
                         jnp.int32)
    return GatewayRequest(rid=rid, prompt=prompt, max_new_tokens=budget,
                          priority=priority, arrival_s=arrival)


# ------------------------------------------------------------------ #
# admission queue
# ------------------------------------------------------------------ #

def test_queue_pops_priority_then_fifo():
    q = AdmissionQueue(capacity=8)
    for rid, prio in [(0, 2), (1, 0), (2, 1), (3, 0), (4, 2)]:
        ok, shed = q.offer(_greq(rid, priority=prio))
        assert ok and shed is None
    order = [q.pop().rid for _ in range(len(q))]
    assert order == [1, 3, 2, 0, 4]     # class asc, FIFO within class
    assert q.pop() is None and q.peek() is None


def test_queue_rejects_when_full():
    q = AdmissionQueue(capacity=2, policy="reject")
    assert q.offer(_greq(0))[0] and q.offer(_greq(1))[0]
    ok, shed = q.offer(_greq(2, priority=0))
    assert not ok and shed is None and len(q) == 2


def test_queue_shed_evicts_lowest_priority_for_strictly_better():
    q = AdmissionQueue(capacity=2, policy="shed")
    q.offer(_greq(0, priority=1))
    q.offer(_greq(1, priority=2))
    # equal-to-worst priority does NOT shed
    ok, shed = q.offer(_greq(2, priority=2))
    assert not ok and shed is None
    # strictly better sheds the worst (class 2), newest within the class
    ok, shed = q.offer(_greq(3, priority=0))
    assert ok and shed is not None and shed.rid == 1
    assert sorted(g.rid for _, _, g in q._heap) == [0, 3]


def test_queue_validates_args():
    with pytest.raises(ValueError):
        AdmissionQueue(capacity=0)
    with pytest.raises(ValueError):
        AdmissionQueue(capacity=2, policy="drop-newest")


# ------------------------------------------------------------------ #
# batch signature + plan cache
# ------------------------------------------------------------------ #

def test_batch_signature_buckets_positions():
    a = batch_signature(2, (5, 9), pos_bucket=16)
    b = batch_signature(2, (3, 15), pos_bucket=16)
    assert a == b == ("decode", 2, 16, (), ())
    # crossing a bucket boundary changes the key; so do live count
    # and chunk splits
    assert batch_signature(2, (16,), pos_bucket=16)[2] == 32
    assert batch_signature(3, (5,), pos_bucket=16) != a
    assert batch_signature(2, (5,), pos_bucket=16,
                           splits=(4, 4)) != a
    assert batch_signature(1, splits=(4, 2), phase="prefill") == \
        ("prefill", 1, 64, (4, 2), ())
    with pytest.raises(ValueError):
        batch_signature(1, (), pos_bucket=0)


def test_batch_signature_keys_on_topology():
    """ISSUE-9 regression: plans priced under different channel
    topologies must never alias in the plan cache — same batch shape,
    different rank count, different key."""
    from repro.dispatch.placement import Topology
    t1, t4 = Topology(n_ranks=1), Topology(n_ranks=4)
    a1 = batch_signature(2, (5,), pos_bucket=16, topology=t1)
    a4 = batch_signature(2, (5,), pos_bucket=16, topology=t4)
    assert a1 != a4
    assert a1[-1] == ("upmem_2556", 1) and a4[-1] == ("upmem_2556", 4)
    # a raw signature tuple keys identically to the Topology it came from
    assert batch_signature(2, (5,), pos_bucket=16,
                           topology=t4.signature) == a4
    # stable across equal topologies (frozen dataclass, pure shape key)
    assert batch_signature(2, (5,), pos_bucket=16,
                           topology=Topology(n_ranks=4)) == a4


def test_plan_cache_hits_misses_evictions():
    cache = PlanCache(maxsize=2)
    builds = []

    def builder(tag):
        def build():
            builds.append(tag)
            return tag
        return build

    assert cache.get_or_plan("a", builder("a")) == "a"
    assert cache.get_or_plan("a", builder("a")) == "a"   # hit, no build
    assert builds == ["a"] and "a" in cache
    cache.get_or_plan("b", builder("b"))
    cache.get_or_plan("c", builder("c"))                 # evicts "a" (LRU)
    assert "a" not in cache and len(cache) == 2
    s = cache.stats
    assert s["calls"] == 4 and s["hits"] == 1 and s["misses"] == 3
    assert s["evictions"] == 1 and s["hit_rate"] == 0.25
    with pytest.raises(ValueError):
        PlanCache(maxsize=0)


def test_percentile_nearest_rank():
    assert percentile([], 99) == 0.0
    vals = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vals, 50) == 2.0
    assert percentile(vals, 99) == 4.0


# ------------------------------------------------------------------ #
# gateway integration (ManualClock: fully deterministic)
# ------------------------------------------------------------------ #

def _gateway(cfg, params, *, slots=3, max_len=48, tick=1e-3, **kw):
    eng = ServeEngine(cfg, params, batch_slots=slots, max_len=max_len,
                      shd=SHD)
    return Gateway(eng, clock=ManualClock(tick=tick), pos_bucket=16,
                   **kw)


def test_gateway_completes_all_under_capacity(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, queue_capacity=16)
    reqs = poisson_requests(8, 100.0, seed=3, vocab=cfg.vocab_size,
                            prompt_lens=(3, 8), max_new=(2, 6))
    stats = gw.run(reqs)
    assert stats.completed == 8 and stats.rejected == 0
    assert stats.offered == 8
    assert stats.tokens == sum(len(g.out_tokens) for g in gw.finished)
    for g in gw.finished:
        assert g.state == "done"
        assert len(g.out_tokens) == g.request.max_new_tokens \
            or g.request.done
        assert g.ttft_s is not None and g.ttft_s >= 0.0
        assert len(g.token_times) == len(g.out_tokens)
    assert stats.sustained_rps > 0 and stats.duration_s > 0


def test_gateway_rejects_under_overload(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, slots=1, queue_capacity=2)
    # a simultaneous burst: everything arrives before the first admit
    reqs = [_greq(i, arrival=0.0, vocab=cfg.vocab_size)
            for i in range(8)]
    stats = gw.run(reqs)
    assert stats.rejected > 0
    assert stats.completed + stats.rejected == stats.offered == 8
    assert all(g.reject_reason == "queue-full" for g in gw.rejected)
    assert all(g.state == "rejected" for g in gw.rejected)


def test_gateway_shed_policy_prefers_interactive(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, slots=1, queue_capacity=2,
                  shed_policy="shed")
    reqs = [_greq(i, priority=2, vocab=cfg.vocab_size) for i in range(4)]
    reqs += [_greq(10 + i, priority=0, vocab=cfg.vocab_size)
             for i in range(2)]
    stats = gw.run(reqs)
    # the late interactive arrivals shed queued batch requests
    assert stats.shed > 0
    assert all(g.priority == 2 for g in gw.rejected)
    assert all(g.priority == 0 for g in gw.finished
               if g.rid >= 10) and any(g.rid >= 10 for g in gw.finished)


def test_gateway_admits_in_priority_order(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, slots=1, queue_capacity=16)
    reqs = [_greq(0, priority=2), _greq(1, priority=0),
            _greq(2, priority=1), _greq(3, priority=0)]
    gw.run(reqs)
    admitted = sorted(gw.finished, key=lambda g: g.admit_s)
    assert [g.rid for g in admitted] == [1, 3, 2, 0]


def test_gateway_rejects_invalid_payloads(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, max_len=48)
    long = _greq(0, plen=48)
    bad = _greq(1, budget=0)
    assert not gw.submit(long) and long.reject_reason == "prompt-too-long"
    assert not gw.submit(bad) and bad.reject_reason == "bad-budget"
    assert gw.stats().rejected == 2


def test_gateway_budget_one_finishes_at_admit(setup):
    cfg, params = setup
    gw = _gateway(cfg, params)
    greq = _greq(0, budget=1, vocab=cfg.vocab_size)
    stats = gw.run([greq])
    assert stats.completed == 1
    assert greq.state == "done" and len(greq.out_tokens) == 1
    assert len(greq.token_times) == 1 and gw.engine.n_free == 3


def test_stall_budget_caps_admissions_per_gap(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, slots=3, queue_capacity=8,
                  max_stall_s=0.0)
    for i in range(3):
        assert gw.submit(_greq(i, budget=6, vocab=cfg.vocab_size))
    # zero budget: one admission per decode gap (never starves), even
    # with three slots free — prefill stall is interleaved with decode
    assert gw.admit_pending() == 1
    assert gw.admit_pending() == 1
    # an unconstrained gateway drains the queue into all free slots
    gw2 = _gateway(cfg, params, slots=3, queue_capacity=8,
                   max_stall_s=1e9)
    for i in range(3):
        assert gw2.submit(_greq(i, budget=6, vocab=cfg.vocab_size))
    assert gw2.admit_pending() == 3


def test_gateway_seeded_poisson_deterministic(setup):
    cfg, params = setup

    def one_run():
        gw = _gateway(cfg, params, queue_capacity=16)
        reqs = poisson_requests(6, 80.0, seed=21, vocab=cfg.vocab_size,
                                prompt_lens=(3, 8), max_new=(2, 5))
        stats = gw.run(reqs)
        return ({g.rid: g.out_tokens for g in gw.finished},
                {g.rid: (g.arrival_s, g.admit_s, tuple(g.token_times))
                 for g in gw.finished},
                (stats.completed, stats.steps, stats.tokens))

    assert one_run() == one_run()
    other = poisson_requests(6, 80.0, seed=22, vocab=cfg.vocab_size)
    base = poisson_requests(6, 80.0, seed=21, vocab=cfg.vocab_size)
    assert [g.arrival_s for g in other] != [g.arrival_s for g in base]


def test_arrival_trace_round_trip(setup, tmp_path):
    """ISSUE-9 satellite: a saved arrival trace (timestamp, prompt_len,
    max_new, class — no token content) round-trips through the file and
    drives a gateway run deterministically: same (trace, seed) pair,
    same completed tokens and timestamps."""
    cfg, params = setup
    from repro.serve import load_arrival_trace, save_arrival_trace
    path = tmp_path / "arrivals.jsonl"
    reqs = poisson_requests(6, 80.0, seed=21, vocab=cfg.vocab_size,
                            prompt_lens=(3, 8), max_new=(2, 5))
    assert save_arrival_trace(path, reqs) == 6
    loaded = load_arrival_trace(path, seed=9, vocab=cfg.vocab_size)
    # the workload shape survives the file byte-for-byte
    assert [g.arrival_s for g in loaded] == [g.arrival_s for g in reqs]
    assert [int(g.prompt.shape[0]) for g in loaded] == \
        [int(g.prompt.shape[0]) for g in reqs]
    assert [g.max_new_tokens for g in loaded] == \
        [g.max_new_tokens for g in reqs]
    assert [g.priority for g in loaded] == [g.priority for g in reqs]

    def one_run():
        gw = _gateway(cfg, params, queue_capacity=16)
        stats = gw.run(load_arrival_trace(path, seed=9,
                                          vocab=cfg.vocab_size))
        return ({g.rid: g.out_tokens for g in gw.finished},
                {g.rid: (g.arrival_s, tuple(g.token_times))
                 for g in gw.finished}, stats.completed)

    a = one_run()
    assert a == one_run() and a[2] == 6
    # a different token seed replays the same traffic, different content
    alt = load_arrival_trace(path, seed=10, vocab=cfg.vocab_size)
    assert [g.arrival_s for g in alt] == [g.arrival_s for g in reqs]
    assert any(g.prompt.tolist() != h.prompt.tolist()
               for g, h in zip(alt, loaded))
    # hand-written traces: comments, blanks, integer class indices
    path2 = tmp_path / "hand.jsonl"
    path2.write_text(
        "# fleet replay\n\n"
        '{"arrival_s": 0.5, "prompt_len": 4, "max_new": 2, "class": 0}\n'
        '{"arrival_s": 1.0, "prompt_len": 3, "max_new": 3,'
        ' "class": "batch"}\n')
    hand = load_arrival_trace(path2, vocab=cfg.vocab_size)
    assert [(g.rid, g.arrival_s, g.priority) for g in hand] == \
        [(0, 0.5, 0), (1, 1.0, 2)]


def test_gateway_plan_cache_hit_rate_across_churn(setup):
    """The tentpole's amortization claim at test scale: a run whose
    admissions/evictions churn the batch signature still serves >80% of
    planner consults from cache (the gateway bench gates the same
    number on its longer sweep)."""
    cfg, params = setup
    gw = _gateway(cfg, params, slots=3, queue_capacity=32, tick=1e-4)
    gw.pos_bucket = 8
    reqs = poisson_requests(20, 150.0, seed=5, vocab=cfg.vocab_size,
                            prompt_lens=(3, 10), max_new=(2, 8))
    stats = gw.run(reqs)
    assert stats.completed == 20
    pc = stats.plan_cache
    assert pc["hit_rate"] > 0.80, pc
    # distinct signatures each solved exactly once (no double builds)
    assert pc["misses"] == pc["size"] + pc["evictions"]


def test_gateway_prewarm_primes_the_cache(setup):
    cfg, params = setup
    gw = _gateway(cfg, params, slots=2, max_len=32)
    warm = gw.prewarm(prompt_lens=(4, 5, 6))
    assert warm["misses"] > 0 and warm["hits"] == 0
    # a warmed gateway's run adds no new decode/prefill solves for
    # covered signatures
    reqs = poisson_requests(4, 100.0, seed=9, vocab=cfg.vocab_size,
                            prompt_lens=(4, 6), max_new=(2, 4))
    stats = gw.run(reqs)
    assert stats.plan_cache["misses"] == warm["misses"]


# ------------------------------------------------------------------ #
# dispatch engine: gateway-driven timeline through the fidelity gate
# ------------------------------------------------------------------ #

def test_gateway_dispatch_fidelity_replay(setup):
    """The gateway drives the planner-routed engine with a tracer
    attached; the planner-fidelity gate must hold on the GATEWAY-driven
    decode timeline (predicted pipelined_s within 10% of the replayed
    measured trace), and the prefill executor cache reports its reuse."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                      engine="dispatch",
                      dispatch_kwargs={"prefill_chunk": 4})
    tracer = dtrace.Trace("gateway-test")
    gw = Gateway(eng, queue_capacity=8, pos_bucket=16,
                 clock=ManualClock(tick=1e-3))
    gw.attach_tracer(tracer)
    # two possible prompt lengths over four requests: the executor
    # cache must get reuse (at most 2 distinct chunk-split signatures)
    reqs = poisson_requests(4, 100.0, seed=5, vocab=cfg.vocab_size,
                            prompt_lens=(4, 5), max_new=(3, 5))
    stats = gw.run(reqs)
    assert stats.completed == 4
    assert len(tracer.by_kind("decode_step")) == stats.steps
    assert len(tracer.by_kind("prefill_step")) == 4
    rep = dtrace.fidelity(eng._decode.dag, eng._decode.plan,
                          trace=tracer)
    assert rep.ok, rep.render()
    ec = eng._prefill_step.executor_cache.stats
    assert ec["calls"] >= 4 and ec["hits"] >= 1
