"""Serving engine: continuous batching must be transparent — a request's
greedy output is identical whether it runs alone or batched with others at
skewed positions (exercises the per-row cache-index path)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.models import Shardings, init_params
from repro.serve import Request, ServeEngine

SHD = Shardings(None)


@pytest.fixture(scope="module")
def setup():
    cfg = REDUCED["granite-3-8b"]
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    return cfg, params


def _prompts(cfg, n, key):
    out = []
    for i in range(n):
        key, k = jax.random.split(key)
        plen = 3 + int(jax.random.randint(k, (), 0, 8))
        out.append(jax.random.randint(k, (plen,), 0, cfg.vocab_size,
                                      dtype=jnp.int32))
    return out


def test_batched_equals_solo(setup):
    cfg, params = setup
    prompts = _prompts(cfg, 5, jax.random.PRNGKey(5))

    solo_outputs = []
    for i, p in enumerate(prompts):
        eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, shd=SHD)
        done = eng.serve([Request(i, p, 6)])
        solo_outputs.append(done[0].out_tokens)

    eng = ServeEngine(cfg, params, batch_slots=3, max_len=64, shd=SHD)
    done = eng.serve([Request(i, p, 6) for i, p in enumerate(prompts)])
    batched = {r.rid: r.out_tokens for r in done}

    for i in range(len(prompts)):
        assert batched[i] == solo_outputs[i], \
            f"req {i}: batched {batched[i]} != solo {solo_outputs[i]}"


def test_all_requests_complete(setup):
    cfg, params = setup
    prompts = _prompts(cfg, 7, jax.random.PRNGKey(9))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    reqs = [Request(i, p, 4 + i % 3) for i, p in enumerate(prompts)]
    done = eng.serve(reqs)
    assert len(done) == 7
    for r in done:
        assert r.done and len(r.out_tokens) == r.max_new_tokens


# ------------------------------------------------------------------ #
# dispatch-backed decode (ISSUE-2): planner-routed == fused jit
# ------------------------------------------------------------------ #

def _run_16_steps(eng, prompts):
    """A fixed 16-step continuous-batching schedule with arrivals (admit
    whenever a slot is free) and evictions (finished requests leave and
    new ones take their slot mid-run). Returns {rid: tokens} including
    still-inflight requests, so the trace is step-exact."""
    reqs = [Request(i, p, 3 + i % 4) for i, p in enumerate(prompts)]
    pending = list(reqs)
    for _ in range(16):
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        eng.step()
    return {r.rid: (list(r.out_tokens), r.done) for r in reqs}


def test_dispatch_decode_token_identical_to_jit(setup):
    """The PR-2 tentpole gate: routing decode through the offload
    planner's plan (per-stage jit + BankGrid faces) must be a pure
    execution-layer change — token-for-token identical to the fused-jit
    engine over a continuous-batching run with arrivals and evictions.
    Prefill stays fused here (`prefill_engine="jit"`): decode-only
    *bitwise* identity at the default bf16 is only observable when both
    engines decode from bitwise-identical prefilled caches; the dispatch
    prefill path has its own gate below, on the f32 model."""
    cfg, params = setup
    prompts = _prompts(cfg, 8, jax.random.PRNGKey(11))
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_engine": "jit"})
    assert dis_eng.dispatch_plan is not None
    assert dis_eng.dispatch_plan.method == "dag-dp"
    assert dis_eng.prefill_plan is None
    jit_trace = _run_16_steps(jit_eng, prompts)
    dis_trace = _run_16_steps(dis_eng, prompts)
    assert jit_trace == dis_trace


def test_dispatch_decode_forced_hybrid_token_identical(setup, bank_grid):
    """Force the attention stages onto the PIM face (BankGrid local
    phases) regardless of what the planner picks at reduced scale — the
    hybrid execution must still be token-identical."""
    cfg, params = setup
    prompts = _prompts(cfg, 6, jax.random.PRNGKey(13))
    forced = {f"attn{i}": "upmem_2556" for i in range(cfg.n_blocks)}
    forced["embed"] = "upmem_2556"
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, shd=SHD, engine="dispatch",
        dispatch_kwargs={"grid": bank_grid, "force_assignment": forced,
                         "prefill_engine": "jit"})
    assert dis_eng._decode.assignment["attn0"] == "upmem_2556"
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


# ------------------------------------------------------------------ #
# dispatch-backed prefill (ISSUE-3): chunked planner-routed prefill
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def setup_f32():
    """The f32 model for prefill gates: the per-stage prefill is ulp-close
    but not bitwise to the fused forward (stage boundaries change XLA
    fusion), so the token gates run at f32 where the residual is ~1e-7 —
    the same precedent as the two-bank decode gate (DESIGN.md §9)."""
    import dataclasses
    cfg = dataclasses.replace(REDUCED["granite-3-8b"], dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    return cfg, params


def test_dispatch_prefill_decode_token_identical(setup_f32):
    """The ISSUE-3 tentpole gate, extended over the ISSUE-4 PIPELINED
    path: with BOTH phases planner-routed — chunked prefill over the
    prefill DAG (prompts span 1-3 chunks with ragged tails at chunk=4)
    and decode over the decode DAG — the engine matches the fused-jit
    engine token-for-token over a 16-step continuous-batching run with
    mid-run arrivals and evictions. The multi-chunk prompts here execute
    the executor's interleaved timeline (chunk i+1's qkv issued under
    chunk i's ladder), not a serial chunk loop — asserted below."""
    cfg, params = setup_f32
    prompts = _prompts(cfg, 8, jax.random.PRNGKey(11))
    assert max(int(p.shape[0]) for p in prompts) > 4   # multi-chunk runs
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_chunk": 4})
    assert dis_eng.prefill_plan is not None
    assert dis_eng.prefill_plan.objective == "overlapped"
    assert dis_eng._prefill_step.n_chunks_planned == 4
    # the gated path is pipelined: a 2-chunk prompt's executed node order
    # interleaves chunks (qkv0/c1 before this layer's ladder finishes on
    # chunk 0), unlike the old chunk-major loop
    two_chunk = dis_eng._prefill_step._executor_for([4, 4])
    flat = [n for _, nodes in two_chunk.executed_order() for n in nodes]
    assert flat.index("qkv0/c1") < flat.index("mlp0/c0")
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_dispatch_prefill_forced_pim_token_identical(setup_f32, bank_grid):
    """Force every prefill chunk's embed + attention onto the PIM face
    (sequence-sharded BankGrid local phases) regardless of the planner's
    pick — the hybrid chunked prefill must stay token-identical."""
    cfg, params = setup_f32
    prompts = _prompts(cfg, 6, jax.random.PRNGKey(13))
    forced = {}
    for c in range(4):
        forced[f"embed/c{c}"] = "upmem_2556"
        for i in range(cfg.n_blocks):
            forced[f"attn{i}/c{c}"] = "upmem_2556"
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, shd=SHD, engine="dispatch",
        dispatch_kwargs={"grid": bank_grid, "prefill_chunk": 4,
                         "prefill_force_assignment": forced})
    assert dis_eng._prefill_step.assignment["attn0/c0"] == "upmem_2556"
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_dispatch_prefill_plan_routes_chunks(setup_f32):
    """The prefill plan covers every planned chunk's stage ladder, longer
    prompts clamp onto the last planned chunk, and the ragged tail reuses
    the chunk grid."""
    cfg, params = setup_f32
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                      engine="dispatch",
                      dispatch_kwargs={"prefill_chunk": 4})
    step = eng._prefill_step
    for c in range(step.n_chunks_planned):
        for i in range(cfg.n_blocks):
            for stage in ("qkv", "attn", "o", "mlp"):
                assert f"{stage}{i}/c{c}" in step.assignment
    assert "head" in step.assignment
    assert step.chunk_splits(11) == [4, 4, 3]
    assert step.chunk_splits(4) == [4]


def test_steps_route_through_unified_executor(setup_f32):
    """The ISSUE-4 acceptance gate: neither dispatch step owns a private
    stage-execution loop — both are adapters over
    `dispatch.executor.PlanExecutor`, and the executed launch-group order
    is exactly the planner schedule's group order."""
    from repro.dispatch.executor import PlanExecutor
    from repro.serve.dispatch_engine import (DispatchDecodeStep,
                                             DispatchPrefillStep)
    for cls in (DispatchDecodeStep, DispatchPrefillStep):
        for legacy in ("_run", "_stages"):
            assert not hasattr(cls, legacy), \
                f"{cls.__name__}.{legacy}: private stage machinery is back"
    cfg, params = setup_f32
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                      engine="dispatch",
                      dispatch_kwargs={"prefill_chunk": 4})
    for step in (eng._decode, eng._prefill_step):
        # instance-level check too: per-step face caches must not come
        # back beside the shared FaceCache/PlanExecutor path
        for legacy in ("_run", "_stages", "_host", "_pim"):
            assert legacy not in vars(step), \
                f"{type(step).__name__}.{legacy}: private stage machinery"
        assert isinstance(step.executor, PlanExecutor)
        order = step.executor.executed_order()
        # groups are maximal same-device runs of the DAG's topo order
        flat = [n for _, nodes in order for n in nodes]
        assert flat == step.executor.graph.topo_order()
        for dev, nodes in order:
            assert all(step.executor.assignment[n] == dev for n in nodes)
        for a, b in zip(order, order[1:]):
            assert a[0] != b[0], "adjacent groups on one device"
    # ragged/over-horizon prompts clamp onto the planned placement
    pre = eng._prefill_step
    devs = pre.devices_for(4 * pre.n_chunks_planned + 6)   # 2 extra chunks
    last = pre.n_chunks_planned - 1
    for i in range(cfg.n_blocks):
        assert devs[f"qkv{i}/c{last + 2}"] == \
            pre.assignment[f"qkv{i}/c{last}"]


def test_dispatch_three_layer_hybrid_token_identical():
    """Regression (executor env freeing): every layer's qkv re-reads
    embed's sin/cos although the DAG only edges embed->qkv0/o0 — with
    >= 3 layers and attention forced onto PIM (multiple launch groups),
    a freeing contract that follows graph edges alone would drop embed
    after layer 0 and KeyError at qkv2. Both phases must stay
    token-identical to the fused engine at depth 3."""
    import dataclasses
    cfg = dataclasses.replace(REDUCED["granite-3-8b"], n_layers=3,
                              dtype="float32")
    params = init_params_for(cfg)
    prompts = _prompts(cfg, 5, jax.random.PRNGKey(17))
    forced = {f"attn{i}": "upmem_2556" for i in range(cfg.n_blocks)}
    pforced = {}
    for c in range(4):
        for i in range(cfg.n_blocks):
            pforced[f"attn{i}/c{c}"] = "upmem_2556"
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, shd=SHD, engine="dispatch",
        dispatch_kwargs={"force_assignment": forced, "prefill_chunk": 4,
                         "prefill_force_assignment": pforced})
    assert len(dis_eng._decode.executor.executed_order()) > 3
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


# ------------------------------------------------------------------ #
# dispatch-backed MoE serving (ISSUE-5): routed experts as an exchange
# phase — token-identity gates for the planner-routed MoE ladder
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def setup_moe():
    """The f32 mixtral-reduced model: routed MoE (4 experts top-2,
    sliding-window attention), no shared experts — the dispatch engine's
    MoE scope. f32 for the same reason as the prefill gates (per-stage
    jit changes XLA fusion; DESIGN.md §9)."""
    import dataclasses
    from repro.configs import REDUCED
    cfg = dataclasses.replace(REDUCED["mixtral-8x7b"], dtype="float32")
    return cfg, init_params_for(cfg)


def test_dispatch_moe_decode_token_identical_to_jit(setup_moe):
    """The ISSUE-5 e2e gate, mirroring the dense decode gate: routing MoE
    decode through the planner's plan (router -> token exchange -> expert
    FFNs -> combine exchange per layer) must be token-for-token identical
    to the fused-jit engine over a 16-step continuous-batching run with
    arrivals and evictions, on the f32 model. Prefill stays fused here
    (`prefill_engine="jit"`), the dense gate's precedent — chunked MoE
    prefill has per-chunk capacity semantics (gates below)."""
    cfg, params = setup_moe
    prompts = _prompts(cfg, 8, jax.random.PRNGKey(11))
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_engine": "jit"})
    assert dis_eng.dispatch_plan is not None
    assert dis_eng.dispatch_plan.method == "dag-dp"
    # the decode DAG carries the routed ladder and its exchange edges
    dag = dis_eng._decode.dag
    assert "router0" in dag.nodes and "expert0" in dag.nodes
    assert ("router0", "expert0") in dag.exchange_edges
    assert ("expert0", "combine0") in dag.exchange_edges
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_dispatch_moe_forced_expert_pim_token_identical(setup_moe,
                                                        bank_grid):
    """Force every layer's router + expert (and attention) onto the PIM
    face regardless of the planner's pick: the router->expert edge
    becomes an intra-PIM exchange the executor must relay through the
    host (gather/scatter), with the expert FFN sharded over the grid's
    expert axis — still token-identical to the fused engine."""
    cfg, params = setup_moe
    prompts = _prompts(cfg, 6, jax.random.PRNGKey(13))
    forced = {}
    for i in range(cfg.n_blocks):
        forced[f"attn{i}"] = "upmem_2556"
        forced[f"router{i}"] = "upmem_2556"
        forced[f"expert{i}"] = "upmem_2556"
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, shd=SHD, engine="dispatch",
        dispatch_kwargs={"grid": bank_grid, "force_assignment": forced,
                         "prefill_engine": "jit"})
    # the intra-PIM exchange is registered for the executor's host relay
    assert sorted(dis_eng._decode.executor._exchange_in) == \
        sorted(f"expert{i}" for i in range(cfg.n_blocks))
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_dispatch_moe_expert_sharded_decode_token_identical(setup_moe):
    """ISSUE-9 rank-sharded expert faces: decode with `expert_shards=2`
    builds the expert-parallel DAG (shard nodes `expert{i}@r{j}`, each
    owning E/R experts), pins shard j on rank j's device, and must stay
    token-for-token identical to the fused engine — the combine
    reassembles the rank shards' outputs along the expert axis, which is
    exact because experts compute independently. The forced per-rank
    placement makes the executor stage each shard's boundary transfers
    per rank device, the executable twin of the schedule's per-rank
    channels."""
    cfg, params = setup_moe
    prompts = _prompts(cfg, 6, jax.random.PRNGKey(17))
    forced = {}
    for i in range(cfg.n_blocks):
        forced[f"expert{i}@r0"] = "upmem_2556"
        forced[f"expert{i}@r1"] = "upmem_2556:1"
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, shd=SHD, engine="dispatch",
        dispatch_kwargs={"expert_shards": 2,
                         "devices": ("xeon", "upmem_2556", "upmem_2556:1"),
                         "force_assignment": forced,
                         "prefill_engine": "jit"})
    dag = dis_eng._decode.dag
    # the sharded ladder: per-shard exchange edges, no fused expert node
    assert "expert0@r0" in dag.nodes and "expert0@r1" in dag.nodes
    assert "expert0" not in dag.nodes
    assert ("router0", "expert0@r1") in dag.exchange_edges
    assert ("expert0@r1", "combine0") in dag.exchange_edges
    assert dis_eng._decode.assignment["expert0@r1"] == "upmem_2556:1"
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_dispatch_moe_single_chunk_prefill_token_identical(setup_moe):
    """Dispatch MoE prefill in ONE chunk covers the whole prompt, so the
    per-chunk expert capacity equals the fused whole-prompt capacity and
    the full dispatch path (prefill AND decode planner-routed) matches
    the fused engine token-for-token. Multi-chunk MoE prefill drops
    overflow per chunk by design and is gated for bank-count identity
    instead (the slow multibank gate)."""
    cfg, params = setup_moe
    prompts = _prompts(cfg, 8, jax.random.PRNGKey(11))
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_chunk": 48})
    assert dis_eng.prefill_plan is not None
    pre_dag = dis_eng._prefill_step.dag
    assert any(n.startswith("router") for n in pre_dag.nodes)
    assert pre_dag.exchange_edges
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


@pytest.mark.slow
def test_dispatch_moe_multibank_matches_single_bank():
    """ISSUE-5 satellite: full MoE dispatch serving (planner-routed
    chunked prefill AND decode, experts forced onto the PIM face) with
    the EXPERT axis sharded over TWO banks must be token-identical to the
    single-bank run — each bank owns its experts' weights and dispatch
    rows, and the host gather/scatter exchange is what re-distributes
    tokens between the slot/chunk sharding and the expert sharding.
    Subprocess per the dry-run isolation rule; f32 model."""
    import os
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    code = (
        "import dataclasses, jax, jax.numpy as jnp\n"
        "from repro.configs import REDUCED\n"
        "from repro.core.bank_parallel import BankGrid, make_bank_mesh\n"
        "from repro.models import Shardings, init_params\n"
        "from repro.serve import Request, ServeEngine\n"
        "shd = Shardings(None)\n"
        "cfg = dataclasses.replace(REDUCED['mixtral-8x7b'],\n"
        "                          dtype='float32')\n"
        "params = init_params(jax.random.PRNGKey(0), cfg, shd)\n"
        "key = jax.random.PRNGKey(5)\n"
        "prompts = []\n"
        "for _ in range(6):\n"
        "    key, k = jax.random.split(key)\n"
        "    plen = 4 + int(jax.random.randint(k, (), 0, 8))\n"
        "    prompts.append(jax.random.randint(k, (plen,), 0,\n"
        "                   cfg.vocab_size, dtype=jnp.int32))\n"
        "forced, pforced = {}, {}\n"
        "for i in range(cfg.n_blocks):\n"
        "    forced[f'attn{i}'] = 'upmem_2556'\n"
        "    forced[f'router{i}'] = 'upmem_2556'\n"
        "    forced[f'expert{i}'] = 'upmem_2556'\n"
        "    for c in range(4):\n"
        "        pforced[f'expert{i}/c{c}'] = 'upmem_2556'\n"
        "outs = {}\n"
        "for n_banks in (1, 2):\n"
        "    grid = BankGrid(make_bank_mesh(n_banks))\n"
        "    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,\n"
        "        shd=shd, engine='dispatch', dispatch_kwargs={\n"
        "        'grid': grid, 'force_assignment': forced,\n"
        "        'prefill_chunk': 4,\n"
        "        'prefill_force_assignment': pforced})\n"
        "    assert eng._decode.executor._exchange_in, 'no exchanges'\n"
        "    done = eng.serve([Request(i, p, 5)\n"
        "                      for i, p in enumerate(prompts)])\n"
        "    outs[n_banks] = {r.rid: r.out_tokens for r in done}\n"
        "assert outs[1] == outs[2], outs\n"
        "print('MOE_MULTIBANK_OK')\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=f"{root / 'src'}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MOE_MULTIBANK_OK" in out.stdout


@pytest.mark.slow
def test_dispatch_serving_multibank_matches_single_bank():
    """ISSUE-4 satellite: full dispatch serving (planner-routed prefill
    AND decode) with batch slots sharded over TWO banks must be
    token-identical to the single-bank run — the executor's PIM faces
    shard slots (decode, axis 0) and chunk token rows (prefill, axis 1)
    over however many banks the grid has. Subprocess per the dry-run
    isolation rule; f32 model (bf16 can flip a near-tie argmax across
    bank-shard tilings, DESIGN.md §9)."""
    import os
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    code = (
        "import dataclasses, jax, jax.numpy as jnp\n"
        "from repro.configs import REDUCED\n"
        "from repro.core.bank_parallel import BankGrid, make_bank_mesh\n"
        "from repro.models import Shardings, init_params\n"
        "from repro.serve import Request, ServeEngine\n"
        "shd = Shardings(None)\n"
        "cfg = dataclasses.replace(REDUCED['granite-3-8b'], dtype='float32')\n"
        "params = init_params(jax.random.PRNGKey(0), cfg, shd)\n"
        "key = jax.random.PRNGKey(5)\n"
        "prompts = []\n"
        "for _ in range(6):\n"
        "    key, k = jax.random.split(key)\n"
        "    plen = 4 + int(jax.random.randint(k, (), 0, 8))\n"
        "    prompts.append(jax.random.randint(k, (plen,), 0,\n"
        "                   cfg.vocab_size, dtype=jnp.int32))\n"
        "forced = {f'attn{i}': 'upmem_2556' for i in range(cfg.n_blocks)}\n"
        "forced['embed'] = 'upmem_2556'\n"
        "pforced = {}\n"
        "for c in range(4):\n"
        "    pforced[f'embed/c{c}'] = 'upmem_2556'\n"
        "    for i in range(cfg.n_blocks):\n"
        "        pforced[f'attn{i}/c{c}'] = 'upmem_2556'\n"
        "outs = {}\n"
        "for n_banks in (1, 2):\n"
        "    grid = BankGrid(make_bank_mesh(n_banks))\n"
        "    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,\n"
        "        shd=shd, engine='dispatch', dispatch_kwargs={\n"
        "        'grid': grid, 'force_assignment': forced,\n"
        "        'prefill_chunk': 4,\n"
        "        'prefill_force_assignment': pforced})\n"
        "    pim_groups = [d for d, _ in\n"
        "                  eng._decode.executor.executed_order()\n"
        "                  if d.startswith('upmem')]\n"
        "    assert pim_groups, 'no PIM launch groups to shard'\n"
        "    done = eng.serve([Request(i, p, 5)\n"
        "                      for i, p in enumerate(prompts)])\n"
        "    outs[n_banks] = {r.rid: r.out_tokens for r in done}\n"
        "assert outs[1] == outs[2], outs\n"
        "print('MULTIBANK_SERVE_OK')\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=f"{root / 'src'}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIBANK_SERVE_OK" in out.stdout


@pytest.mark.slow
def test_dispatch_decode_two_banks_token_identical():
    """Real multi-bank sharding (subprocess, dry-run isolation rule):
    slots sharded 2-ways over banks, attention forced onto the BankGrid
    face, f32 model — token-identical to the fused-jit engine. (bf16 can
    flip a near-tie argmax across bank-shard tilings — an XLA rounding
    artifact, so the cross-bank gate runs the f32 model.)"""
    import os
    import pathlib
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    code = (
        "import dataclasses, jax, jax.numpy as jnp\n"
        "from repro.configs import REDUCED\n"
        "from repro.core.bank_parallel import BankGrid, make_bank_mesh\n"
        "from repro.models import Shardings, init_params\n"
        "from repro.serve import Request, ServeEngine\n"
        "shd = Shardings(None)\n"
        "cfg = dataclasses.replace(REDUCED['granite-3-8b'], dtype='float32')\n"
        "params = init_params(jax.random.PRNGKey(0), cfg, shd)\n"
        "grid = BankGrid(make_bank_mesh())\n"
        "assert grid.n_banks == 2\n"
        "key = jax.random.PRNGKey(3)\n"
        "prompts = []\n"
        "for _ in range(6):\n"
        "    key, k = jax.random.split(key)\n"
        "    plen = 3 + int(jax.random.randint(k, (), 0, 6))\n"
        "    prompts.append(jax.random.randint(k, (plen,), 0,\n"
        "                   cfg.vocab_size, dtype=jnp.int32))\n"
        "forced = {f'attn{i}': 'upmem_2556' for i in range(cfg.n_blocks)}\n"
        "forced['embed'] = 'upmem_2556'\n"
        "outs = {}\n"
        "for name, kw in (('jit', {}), ('dispatch', dict(\n"
        "        engine='dispatch', dispatch_kwargs={'grid': grid,\n"
        "        'force_assignment': forced,\n"
        "        'prefill_engine': 'jit'}))):\n"
        "    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,\n"
        "                      shd=shd, **kw)\n"
        "    done = eng.serve([Request(i, p, 5)\n"
        "                      for i, p in enumerate(prompts)])\n"
        "    outs[name] = {r.rid: r.out_tokens for r in done}\n"
        "assert outs['jit'] == outs['dispatch'], outs\n"
        "print('OK')\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=f"{root / 'src'}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_dispatch_engine_rejects_unsupported_configs(setup):
    cfg, params = setup
    from repro.configs import REDUCED
    rwkv = REDUCED["rwkv6-3b"]
    with pytest.raises(ValueError, match="decoders"):
        ServeEngine(rwkv, init_params_for(rwkv), batch_slots=1, max_len=16,
                    shd=SHD, engine="dispatch")
    # routed MoE is supported (mixtral); shared-expert MoE is not
    shared = REDUCED["qwen2-moe-a2.7b"]
    with pytest.raises(ValueError, match="shared experts"):
        ServeEngine(shared, init_params_for(shared), batch_slots=1,
                    max_len=16, shd=SHD, engine="dispatch")
    with pytest.raises(ValueError, match="engine must be"):
        ServeEngine(cfg, params, batch_slots=1, max_len=16, shd=SHD,
                    engine="nope")


def init_params_for(cfg):
    from repro.models import init_params
    return init_params(jax.random.PRNGKey(0), cfg, SHD)


def test_decode_step_shapes(setup):
    cfg, params = setup
    from repro.models import init_cache
    from repro.serve import make_decode_step, make_prefill_step
    b, w = 2, 32
    cache = init_cache(cfg, b, w, SHD)
    prefill = make_prefill_step(cfg, SHD)
    decode = make_decode_step(cfg, SHD)
    toks = jnp.ones((b, 8), jnp.int32)
    last, cache = prefill(params, cache, {"tokens": toks})
    assert last.shape == (b, cfg.padded_vocab)
    lg, cache = decode(params, cache, jnp.ones((b, 1), jnp.int32))
    assert lg.shape == (b, cfg.padded_vocab)
    assert int(cache["index"]) == 9


# ------------------------------------------------------------------ #
# ISSUE-7 serve-loop bugfix regressions
# ------------------------------------------------------------------ #

def test_budget_one_yields_exactly_one_token_fused(setup):
    """Before the fix, admit() never checked max_new_tokens on the first
    sampled token: a budget-1 request entered decode and generated a
    second token. Now it finishes at admit and the slot stays free."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    req = Request(0, jnp.asarray([3, 1, 4], jnp.int32), max_new_tokens=1)
    assert eng.admit(req)
    assert req.done and len(req.out_tokens) == 1
    assert eng.n_free == 2 and eng.step() == 0
    # and through the serve loop, mixed with multi-token requests
    reqs = [Request(1, jnp.asarray([2, 7], jnp.int32), 1),
            Request(2, jnp.asarray([1, 8, 2], jnp.int32), 4)]
    done = eng.serve(reqs)
    assert sorted(len(r.out_tokens) for r in done) == [1, 4]


def test_budget_one_yields_exactly_one_token_dispatch(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                      engine="dispatch",
                      dispatch_kwargs={"prefill_chunk": 4})
    req = Request(0, jnp.asarray([3, 1, 4, 1, 5], jnp.int32),
                  max_new_tokens=1)
    assert eng.admit(req)
    assert req.done and len(req.out_tokens) == 1
    assert eng.n_free == 2


def test_eos_on_first_token_finishes_at_admit(setup):
    """EOS can land on the FIRST sampled token; before the fix the done
    check only ran inside step(), so the request decoded one token past
    its EOS. Greedy sampling makes the first token reproducible: observe
    it, then replay the same prompt with eos_id set to it."""
    cfg, params = setup
    prompt = jnp.asarray([5, 9, 2, 6], jnp.int32)
    probe = Request(0, prompt, max_new_tokens=1)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, shd=SHD)
    assert eng.admit(probe)
    first = probe.out_tokens[0]

    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, shd=SHD,
                      eos_id=first)
    req = Request(1, prompt, max_new_tokens=8)
    assert eng.admit(req)
    assert req.done and req.out_tokens == [first]
    assert eng.n_free == 1


def test_admit_validates_prompt_and_budget(setup):
    """admit() used to silently accept prompts with len >= max_len,
    overflowing the scatter into the batched cache."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=16, shd=SHD)
    with pytest.raises(ValueError, match="does not fit max_len"):
        eng.admit(Request(0, jnp.ones((16,), jnp.int32), 4))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.admit(Request(1, jnp.ones((4,), jnp.int32), 0))
    assert eng.n_free == 1        # neither invalid request held a slot
    # a prompt of max_len - 1 still fits (one generated token)
    ok = Request(2, jnp.ones((15,), jnp.int32), 4)
    assert eng.admit(ok) and not eng.slot_req[0] is None


def test_step_syncs_device_once(setup, monkeypatch):
    """step() used to do a per-slot int(slot_pos[slot]) sync in the
    finish loop plus a second device_get in the tracer branch; both now
    reuse ONE hoisted device_get per step — with or without a tracer."""
    from repro.dispatch import trace as dtrace
    cfg, params = setup
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    eng.admit(Request(0, jnp.asarray([1, 2, 3], jnp.int32), 6))
    eng.admit(Request(1, jnp.asarray([4, 5], jnp.int32), 6))
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (calls.append(1), real(x))[1])
    eng.step()
    assert len(calls) == 1
    calls.clear()
    eng.attach_tracer(dtrace.Trace("sync-count"))
    eng.step()
    assert len(calls) == 1


def test_engine_prefill_splits_hook(setup):
    """The gateway keys prefill pricing by the engine's chunk grid: one
    fused chunk on the jit path, the dispatch prefill step's splits on
    the dispatch path."""
    cfg, params = setup
    jit_eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, shd=SHD)
    assert jit_eng.prefill_splits(11) == [11]
    dis_eng = ServeEngine(cfg, params, batch_slots=1, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_chunk": 4})
    assert dis_eng.prefill_splits(11) == [4, 4, 3]
    assert dis_eng.prefill_splits(4) == [4]


# ------------------------------------------------------------------ #
# windowed serving (ISSUE-10): ring-cache decode + banded prefill
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def setup_swa():
    """A mistral-style sliding-window config at f32: starcoder2-reduced
    (dense, window 16, attention bias) — at max_len 32 the engine's KV
    cache is a RING of width 16, so decode slots wrap and slot index !=
    absolute position (the ISSUE-10 bug surface)."""
    import dataclasses
    cfg = dataclasses.replace(REDUCED["starcoder2-7b"], dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    return cfg, params


def _run_16_steps_wrapping(eng, prompts):
    """The 16-step continuous-batching schedule with budgets big enough
    that positions cross the ring width mid-decode."""
    reqs = [Request(i, p, 8) for i, p in enumerate(prompts)]
    pending = list(reqs)
    for _ in range(16):
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        eng.step()
    return {r.rid: (list(r.out_tokens), r.done) for r in reqs}


def test_windowed_dispatch_decode_token_identical(setup_swa):
    """The ISSUE-10 serving gate: windowed dispatch decode against the
    ring cache is token-identical to the fused engine over a 16-step
    continuous-batching run whose positions wrap the ring (prompts of
    12-14 tokens + 8 generated cross width 16)."""
    from repro.models import cache as cache_lib
    cfg, params = setup_swa
    assert cache_lib.cache_width(cfg, 32) == 16    # ring, not full
    key = jax.random.PRNGKey(17)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (12 + i % 3,), 0, cfg.vocab_size,
                                  dtype=jnp.int32) for i in range(4)]
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_engine": "jit"})
    from repro.serve.dispatch_engine import dims_for_config
    assert dims_for_config(cfg, 2, 32).window == cfg.sliding_window
    jit_trace = _run_16_steps_wrapping(jit_eng, prompts)
    assert any(len(p) + len(toks) > 16
               for p, (toks, _) in zip(prompts, jit_trace.values()))
    assert jit_trace == _run_16_steps_wrapping(dis_eng, prompts)


def test_windowed_banded_prefill_token_identical(setup_swa):
    """Banded dispatch prefill: prompts LONGER than the window execute
    the banded KV prefix (chunk 5 of a 22-token prompt drops chunk 0,
    matching the DAG's dropped edges) and stay token-identical to the
    fused engine — fully-masked keys contribute exactly zero at f32, so
    dropping them is exact, not approximate."""
    from repro.dispatch import workloads
    cfg, params = setup_swa
    key = jax.random.PRNGKey(23)
    plens = [22, 20, 9, 18]
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (plens[i],),
                                  0, cfg.vocab_size, dtype=jnp.int32)
               for i in range(4)]
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_chunk": 4})
    step = dis_eng._prefill_step
    lf = workloads.prefill_live_from(step.chunk_splits(22),
                                     cfg.sliding_window)
    assert lf[-1] == 1                     # banding actually engages
    reqs = [Request(i, p, 3) for i, p in enumerate(prompts)]

    def run(eng):
        rs = [Request(r.rid, prompts[r.rid], 3) for r in reqs]
        pending = list(rs)
        for _ in range(12):
            while pending and eng.admit(pending[0]):
                pending.pop(0)
            eng.step()
        return {r.rid: (list(r.out_tokens), r.done) for r in rs}

    assert run(jit_eng) == run(dis_eng)
