"""Serving engine: continuous batching must be transparent — a request's
greedy output is identical whether it runs alone or batched with others at
skewed positions (exercises the per-row cache-index path)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.models import Shardings, init_params
from repro.serve import Request, ServeEngine

SHD = Shardings(None)


@pytest.fixture(scope="module")
def setup():
    cfg = REDUCED["granite-3-8b"]
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    return cfg, params


def _prompts(cfg, n, key):
    out = []
    for i in range(n):
        key, k = jax.random.split(key)
        plen = 3 + int(jax.random.randint(k, (), 0, 8))
        out.append(jax.random.randint(k, (plen,), 0, cfg.vocab_size,
                                      dtype=jnp.int32))
    return out


def test_batched_equals_solo(setup):
    cfg, params = setup
    prompts = _prompts(cfg, 5, jax.random.PRNGKey(5))

    solo_outputs = []
    for i, p in enumerate(prompts):
        eng = ServeEngine(cfg, params, batch_slots=1, max_len=64, shd=SHD)
        done = eng.serve([Request(i, p, 6)])
        solo_outputs.append(done[0].out_tokens)

    eng = ServeEngine(cfg, params, batch_slots=3, max_len=64, shd=SHD)
    done = eng.serve([Request(i, p, 6) for i, p in enumerate(prompts)])
    batched = {r.rid: r.out_tokens for r in done}

    for i in range(len(prompts)):
        assert batched[i] == solo_outputs[i], \
            f"req {i}: batched {batched[i]} != solo {solo_outputs[i]}"


def test_all_requests_complete(setup):
    cfg, params = setup
    prompts = _prompts(cfg, 7, jax.random.PRNGKey(9))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    reqs = [Request(i, p, 4 + i % 3) for i, p in enumerate(prompts)]
    done = eng.serve(reqs)
    assert len(done) == 7
    for r in done:
        assert r.done and len(r.out_tokens) == r.max_new_tokens


def test_decode_step_shapes(setup):
    cfg, params = setup
    from repro.models import init_cache
    from repro.serve import make_decode_step, make_prefill_step
    b, w = 2, 32
    cache = init_cache(cfg, b, w, SHD)
    prefill = make_prefill_step(cfg, SHD)
    decode = make_decode_step(cfg, SHD)
    toks = jnp.ones((b, 8), jnp.int32)
    last, cache = prefill(params, cache, {"tokens": toks})
    assert last.shape == (b, cfg.padded_vocab)
    lg, cache = decode(params, cache, jnp.ones((b, 1), jnp.int32))
    assert lg.shape == (b, cfg.padded_vocab)
    assert int(cache["index"]) == 9
