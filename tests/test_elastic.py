"""Elastic scaling: a checkpoint taken on one mesh must resume on a
different mesh with the same training trajectory (runs launch/elastic.py
in an 8-device subprocess)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_elastic_mesh_restart():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.elastic",
         "--arch", "granite-3-8b", "--ckpt", "/tmp/repro_elastic_test"],
        env=env, capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "elastic restart OK" in r.stdout
