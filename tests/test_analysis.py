"""hlo_analysis / roofline / suitability validation against analytic
ground truth on known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import analyze_hlo, op_mix, parse_shapes
from repro.core.pim_model import TPU_V5E, UPMEM_2556
from repro.core.roofline import roofline_from_analysis
from repro.core.suitability import score


def _analyze(fn, *args, trips=1):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text(), trip_count_fallback=trips)


def test_shape_parsing():
    shapes = parse_shapes("(f32[128,256]{1,0}, bf16[8]{0})")
    assert shapes[0].bytes == 128 * 256 * 4
    assert shapes[1].bytes == 16


def test_matmul_flops_exact():
    m, k, n = 256, 512, 128
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    an = _analyze(lambda x, y: x @ y, a, b)
    want = 2 * m * k * n
    assert an.dot_flops == want, (an.dot_flops, want)
    # bytes: read a, b; write out (within 2x for fusion variance)
    io = (m * k + k * n + m * n) * 4
    assert io <= an.hbm_bytes <= 3 * io


def test_scan_trip_count_correction():
    """cost_analysis counts while bodies once; ours multiplies by trips."""
    t = 17
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ c * 0.5, ()
        out, _ = jax.lax.scan(body, x, None, length=t)
        return out

    an = _analyze(f, a)
    per_iter = 2 * 64 * 64 * 64
    assert an.dot_flops == t * per_iter, (an.dot_flops, t * per_iter)
    assert t in an.trip_counts.values()


def test_roofline_terms_and_dominance():
    m = 4096
    a = jnp.zeros((m, m), jnp.bfloat16)
    an = _analyze(lambda x, y: x @ y, a, a)
    rep = roofline_from_analysis(an, name="mm", n_chips=1,
                                 model_flops=2 * m ** 3)
    # one 4096^3 bf16 matmul on v5e: compute-bound
    assert rep.dominant == "compute"
    # convert fusions add ~0.04% elementwise flops on top of the dot
    assert rep.compute_s == pytest.approx(2 * m ** 3 / 197e12, rel=1e-2)
    assert 0.9 < rep.useful_compute_ratio <= 1.1


def test_streaming_is_memory_bound():
    x = jnp.zeros((1 << 22,), jnp.float32)
    an = _analyze(lambda v: v + 1.0, x)
    rep = roofline_from_analysis(an, name="va", n_chips=1,
                                 model_flops=float(x.size))
    assert rep.dominant == "memory"


def test_suitability_kt1_kt2_kt3():
    # VA-like: int add stream -> suitable on UPMEM
    x = jnp.zeros((1 << 20,), jnp.int32)
    an = _analyze(lambda a, b: a + b, x, x)
    rep = score(an, name="va", machine="upmem_2556")
    assert rep.memory_bound and rep.simple_ops and rep.low_comm
    assert rep.pim_suitable

    # matmul: operational intensity >> balance -> NOT memory-bound
    a = jnp.zeros((2048, 2048), jnp.float32)
    an2 = _analyze(lambda p, q: p @ q, a, a)
    rep2 = score(an2, name="mm", machine="tpu_v5e")
    assert not rep2.memory_bound
    assert not rep2.pim_suitable

    # float divide stream -> complex-op heavy (KT2)
    an3 = _analyze(lambda p, q: p / (q + 2.0), x.astype(jnp.float32),
                   x.astype(jnp.float32))
    rep3 = score(an3, name="div", machine="upmem_2556")
    assert rep3.complex_frac > 0.3
    assert not rep3.pim_suitable


def test_machine_balance_inversion():
    """DESIGN.md §2: the DPU is compute-bound where the TPU is memory-bound
    — the machine balance points sit on opposite sides of 1 op/byte."""
    dpu = UPMEM_2556.as_machine()
    assert dpu.balance < 1.0 < TPU_V5E.balance


def test_op_mix_census():
    x = jnp.zeros((1 << 16,), jnp.float32)
    an = _analyze(lambda a: jnp.tanh(a) * a, x)
    mix = op_mix(an)
    assert mix["complex_frac"] > 0.3     # tanh + multiply
    assert mix["total_arith_ops"] > 0
