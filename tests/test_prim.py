"""PrIM workload correctness vs oracles (single-bank mesh; the 8-bank
cross-bank semantics run in test_prim_multibank.py's subprocess)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import prim

KEY = jax.random.PRNGKey(3)

SIZES = {"NW": 64, "MLP": 128, "BFS": 128, "GEMV": 256}


def _inputs(name, mod):
    n = SIZES.get(name, 1024)
    if name == "HST-L":
        return mod.make_inputs(n, KEY, bins=mod.BINS_L)
    return mod.make_inputs(n, KEY)


@pytest.mark.parametrize("name", sorted(prim.WORKLOADS))
def test_workload_matches_oracle(name, bank_grid):
    mod = prim.WORKLOADS[name]
    inputs = _inputs(name, mod)
    got = mod.run_pim(bank_grid, **inputs)
    want = mod.ref(**inputs)
    got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("name", sorted(prim.WORKLOADS))
def test_counts_well_formed(name):
    mod = prim.WORKLOADS[name]
    c = mod.counts_l(1 << 16) if name == "HST-L" else mod.counts(1 << 16)
    assert c.bytes_streamed > 0
    assert c.flops_equiv > 0
    assert c.interbank_bytes >= 0
    assert all(v >= 0 for v in c.ops.values())
    assert c.pim_suitable == mod.SUITABLE


def test_fig4_grouping():
    """10 of 16 benchmarks are in the paper's 'more suitable' group."""
    assert len(prim.SUITABLE_SET) == 10
    assert {"VA", "SEL", "UNI", "BS", "RED", "SCAN-SSA", "SCAN-RSS",
            "TRNS", "HST-S", "HST-L"} == prim.SUITABLE_SET
