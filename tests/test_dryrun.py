"""The multi-pod dry-run machinery itself, smoke-tested in a subprocess
(it needs the 512-device env var set before jax init)."""

import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cell_compiles_and_reports(tmp_path):
    out = tmp_path / "rec.json"
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-tiny", "--shape", "decode_32k,train_4k",
         "--mesh", "both", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    recs = json.load(open(out))
    assert len(recs) == 4                      # 2 shapes x 2 meshes
    for rec in recs:
        assert rec["status"] == "ok", rec
        rf = rec["roofline"]
        # three terms present and positive where expected
        assert rf["memory_s"] > 0
        assert rf["compute_s"] >= 0
        assert rf["dominant"] in ("compute", "memory", "collective")
        assert 0 <= rf["roofline_fraction"] <= 1
        # the multi-pod record really used 512 chips
    chips = {rec["n_chips"] for rec in recs}
    assert chips == {256, 512}
    # decode must be memory-dominant (the paper's regime)
    dec = [rec for rec in recs if rec["shape"] == "decode_32k"]
    assert all(rec["roofline"]["dominant"] == "memory" for rec in dec)
