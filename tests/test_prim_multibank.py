"""Cross-bank semantics: the full 16-workload check on an 8-bank mesh in a
subprocess (xla_force_host_platform_device_count must be set before jax
init, so it cannot run in this process)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core.bank_parallel import BankGrid, make_bank_mesh
from repro import prim

grid = BankGrid(make_bank_mesh(8))
key = jax.random.PRNGKey(42)
sizes = {"NW": 128, "MLP": 256, "BFS": 256, "GEMV": 512}
bad = []
for name, mod in prim.WORKLOADS.items():
    n = sizes.get(name, 1024)
    k = jax.random.fold_in(key, abs(hash(name)) % 1000)
    inputs = mod.make_inputs(n, k, bins=mod.BINS_L) if name == "HST-L" \
        else mod.make_inputs(n, k)
    got = mod.run_pim(grid, **inputs)
    want = mod.ref(**inputs)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        if not np.array_equal(np.asarray(g), np.asarray(w)):
            bad.append(name)
            break
assert not bad, f"multibank mismatches: {bad}"
print("MULTIBANK_OK")
"""


@pytest.mark.slow
def test_all_workloads_on_8_banks():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MULTIBANK_OK" in r.stdout


@pytest.mark.slow
def test_phase_discipline_assert_local():
    """assert_local flags a collective inside a 'bank-local' phase."""
    script = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.bank_parallel import BankGrid, make_bank_mesh, assert_local

grid = BankGrid(make_bank_mesh(8))
x = jnp.arange(64, dtype=jnp.float32)

legal = grid.local(lambda v: v * 2, in_specs=P(grid.axis),
                   out_specs=P(grid.axis))
assert_local(legal, x)      # must pass

illegal = grid.local(lambda v: jax.lax.psum(v, grid.axis),
                     in_specs=P(grid.axis), out_specs=P(grid.axis))
try:
    assert_local(illegal, x)
    raise SystemExit("assert_local failed to catch a collective")
except AssertionError:
    print("DISCIPLINE_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "DISCIPLINE_OK" in r.stdout
