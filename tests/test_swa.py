"""Sliding-window attention (ISSUE-10): mask consistency across the three
window implementations, ring-cache round-trip properties, the banded
prefill DAG's structure, windowed plan-cache signatures, and the
dispatch attn-stage key-position threading regression.

The three implementations that must agree on which keys a query sees:

  1. prefill flash mask       `q_pos - k_pos < window`   models/layers.py
  2. decode cache validity    `pos > idx - window`       models/layers.py
  3. Pallas block liveness    `q_lo - (k_lo+BK-1) < window`
                                                kernels/flash_attention.py

all checked against one dense oracle (`kernels.ref.flash_attention`) on a
grid of (seq, window, chunk) shapes including window-boundary off-by-ones.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.dispatch import workloads
from repro.dispatch.placement import plan
from repro.dispatch.plan_cache import batch_signature
from repro.dispatch.trace import fidelity
from repro.kernels import ops, ref
from repro.models import Shardings
from repro.models import cache as cache_lib
from repro.models import layers as L

SHD = Shardings(None)
KEY = jax.random.PRNGKey(10)


def k(i):
    return jax.random.fold_in(KEY, i)


def _qkv_arrays(seq, h=4, kvh=2, hd=16):
    q = jax.random.normal(k(0), (1, seq, h, hd), jnp.float32) / 4
    kk = jax.random.normal(k(1), (1, seq, kvh, hd), jnp.float32) / 4
    v = jax.random.normal(k(2), (1, seq, kvh, hd), jnp.float32) / 4
    return q, kk, v


def _window_cfg(window, qc=8, kc=8):
    return dataclasses.replace(REDUCED["granite-3-8b"], dtype="float32",
                               sliding_window=window, q_chunk=qc,
                               kv_chunk=kc)


# ------------------------------------------------------------------ #
# 1. mask-consistency battery across the three implementations
# ------------------------------------------------------------------ #

# seq=32 with windows straddling the chunk boundary (7/8/9), mid-size,
# and the seq-1 / seq edge where the window stops binding entirely
@pytest.mark.parametrize("window", [7, 8, 9, 16, 31, 32])
@pytest.mark.parametrize("qc,kc", [(8, 8), (16, 8)])
def test_prefill_flash_mask_matches_oracle(window, qc, kc):
    """Implementation 1: the pure-JAX chunked flash prefill
    (models.layers.flash_attention) against the dense oracle."""
    seq = 32
    q, kk, v = _qkv_arrays(seq)
    cfg = _window_cfg(window, qc, kc)
    got = L.flash_attention(q, kk, v, cfg, SHD)
    want = ref.flash_attention(q, kk, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [7, 8, 9, 16, 31, 32])
def test_decode_ring_validity_matches_oracle(window):
    """Implementation 2: decoding token-by-token against the ring cache
    (`write_decode` slots + `slot_positions` + `cached_attention`
    validity) reproduces the oracle's row for every position, including
    every post-wrap position of the ring."""
    seq = 32
    q, kk, v = _qkv_arrays(seq)
    cfg = _window_cfg(window)
    width = window if window < seq else seq     # cache_width semantics
    kv = {"k": jnp.zeros((1, width, 2, 16)), "v": jnp.zeros((1, width, 2, 16))}
    want = np.asarray(ref.flash_attention(q, kk, v, causal=True,
                                          window=window))
    for t in range(seq):
        kv = cache_lib.write_decode(kv, kk[:, t:t + 1], v[:, t:t + 1],
                                    t, width)
        pos = cache_lib.slot_positions(t + 1, width)
        o = L.cached_attention(q[:, t:t + 1], kv["k"], kv["v"], pos,
                               jnp.int32(t), cfg, SHD)
        np.testing.assert_allclose(np.asarray(o)[0, 0], want[0, t],
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"decode position {t}")


@pytest.mark.parametrize("window", [7, 8, 9, 16, 31, 32])
def test_pallas_block_liveness_matches_oracle(window):
    """Implementation 3: the Pallas flash kernel's tile-culling bound
    (`q_lo - (k_lo + BK - 1) < window` plus the element mask) against
    the same oracle — run via the shape-normalizing ops wrapper
    (interpret mode on CPU)."""
    seq = 32
    q, kk, v = _qkv_arrays(seq)
    got = ops.flash_attention(q, kk, v, causal=True, window=window)
    want = ref.flash_attention(q, kk, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ #
# 2. ring-cache round-trip properties
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("width", [4, 8])
@pytest.mark.parametrize("count", [0, 1, 3, 4, 5, 7, 8, 9, 16, 17])
def test_slot_positions_bijection(width, count):
    """`slot_positions(count, W)` maps the occupied slots bijectively
    onto the last `min(count, W)` positions, each at its `p % W` slot,
    with exactly the unoccupied remainder marked -1."""
    pos = np.asarray(cache_lib.slot_positions(count, width))
    held = sorted(int(p) for p in pos if p >= 0)
    assert held == list(range(max(0, count - width), count))
    for s, p in enumerate(pos):
        if p >= 0:
            assert p % width == s, f"slot {s} holds position {p}"
    assert int((pos < 0).sum()) == width - len(held)


def test_slot_positions_per_row_matches_scalar():
    """Per-row counts (continuous batching) row-wise equal the scalar
    map — length-skewed slots share one batched ring."""
    counts = jnp.array([0, 3, 8, 13], jnp.int32)
    batched = np.asarray(cache_lib.slot_positions(counts, 8))
    for r, c in enumerate([0, 3, 8, 13]):
        np.testing.assert_array_equal(
            batched[r], np.asarray(cache_lib.slot_positions(c, 8)))


@pytest.mark.parametrize("s", [5, 8, 11, 16, 21])
def test_write_prefill_ring_roundtrip(s):
    """`write_prefill` + a decode read against the ring agree with the
    full-cache reference truncated to the window: every occupied slot
    holds the row of its `slot_positions` position, and the next decode
    step's attention output matches attending the full untruncated cache
    under the same window."""
    width, kvh, hd = 8, 2, 16
    kf = jax.random.normal(k(3), (1, s, kvh, hd), jnp.float32) / 4
    vf = jax.random.normal(k(4), (1, s, kvh, hd), jnp.float32) / 4
    ring = {"k": jnp.zeros((1, width, kvh, hd)),
            "v": jnp.zeros((1, width, kvh, hd))}
    ring = cache_lib.write_prefill(ring, kf, vf)
    pos = np.asarray(cache_lib.slot_positions(s, width))
    for slot, p in enumerate(pos):
        if p >= 0:
            np.testing.assert_array_equal(
                np.asarray(ring["k"])[0, slot], np.asarray(kf)[0, p],
                err_msg=f"slot {slot} != position {p}")

    # decode step at index s: ring read == full-cache read (the window
    # validity truncates the full cache to the same key set)
    cfg = _window_cfg(width)
    kn = jax.random.normal(k(5), (1, 1, kvh, hd), jnp.float32) / 4
    vn = jax.random.normal(k(6), (1, 1, kvh, hd), jnp.float32) / 4
    q = jax.random.normal(k(7), (1, 1, 4, hd), jnp.float32) / 4
    ring = cache_lib.write_decode(ring, kn, vn, s, width)
    o_ring = L.cached_attention(
        q, ring["k"], ring["v"], cache_lib.slot_positions(s + 1, width),
        jnp.int32(s), cfg, SHD)
    full = {"k": jnp.concatenate([kf, kn], axis=1),
            "v": jnp.concatenate([vf, vn], axis=1)}
    o_full = L.cached_attention(
        q, full["k"], full["v"], jnp.arange(s + 1, dtype=jnp.int32),
        jnp.int32(s), cfg, SHD)
    np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# 3. plan-cache signatures key on the window
# ------------------------------------------------------------------ #

def test_batch_signature_window_default_byte_identical():
    """The zero default appends nothing: pre-window callers' signatures
    (and any persisted keys) are unchanged."""
    sig = batch_signature(2, [5, 70], splits=(4, 4), phase="prefill")
    assert sig == ("prefill", 2, 128, (4, 4), ())


def test_batch_signature_window_collision_regression():
    """The ISSUE-10 collision: a windowed and a full-attention batch
    with identical (n_live, positions, splits) price different graphs
    and must never serve each other's plan."""
    base = dict(positions=[5, 70], splits=(4, 4), phase="prefill")
    full = batch_signature(2, **base)
    windowed = batch_signature(2, **base, window=16)
    assert windowed != full
    assert windowed == full + (16,)
    assert batch_signature(2, **base, window=8) != windowed


# ------------------------------------------------------------------ #
# 4. banded prefill DAG + windowed decode dims
# ------------------------------------------------------------------ #

def test_prefill_live_from_boundaries():
    """Band bound off-by-ones: the window that just reaches a chunk's
    last key keeps it live; one less drops it."""
    assert workloads.prefill_live_from([4, 4, 4, 4], 0) == [0, 0, 0, 0]
    assert workloads.prefill_live_from([4, 4, 4, 4], 8) == [0, 0, 0, 1]
    assert workloads.prefill_live_from([4, 4, 4, 4], 9) == [0, 0, 0, 1]
    # window 10: chunk 3's oldest readable key is 12-10+1=3 == chunk 0's
    # last key -> live; window 9 puts it at 4 -> dead
    assert workloads.prefill_live_from([4, 4, 4, 4], 10) == [0, 0, 0, 0]
    assert workloads.prefill_live_from([4, 4, 2], 4) == [0, 0, 1]
    assert workloads.prefill_live_from([8192] * 4, 4096) == [0, 0, 1, 2]


def test_banded_prefill_dag_drops_dead_edges():
    """The banded DAG's structure: dead chunks lose their KV fan-in
    edge, their write-back wait, and their residency charge; live
    partial chunks keep all three."""
    d = workloads.SWA_REDUCED_DIMS          # window 8
    g = workloads.prefill_dag(d, prefill_len=16, chunk=4)
    assert g.name == "lm-prefill-dag-swa8"
    # chunk 3 (queries 12..15) can reach back to position 5 -> chunk 0
    # (keys 0..3) is dead, chunk 1 partially live
    assert sorted(g.preds["attn0/c3"]) == ["qkv0/c1", "qkv0/c2", "qkv0/c3"]
    assert sorted(g.preds["attn0/c2"]) == ["qkv0/c0", "qkv0/c1", "qkv0/c2"]
    a3 = g.nodes["attn0/c3"]
    assert a3.meta["kv_writers"] == ["attn0/c1", "attn0/c2"]
    row = 2.0 * 1 * d.kv_heads * d.head_dim * d.kv_itemsize
    assert a3.meta["kv_bytes"] == row * 8          # live prior rows only
    assert a3.meta["kv_write_bytes"] == row * 4    # min(t, window) rows


def test_unbinding_window_builds_identical_dag():
    """A window the prompt never exceeds builds the byte-identical full
    DAG — names, edges, and node costs (the window=0 golden-stability
    guarantee)."""
    d_w = dataclasses.replace(workloads.REDUCED_DIMS, window=32)
    g_w = workloads.prefill_dag(d_w, prefill_len=8, chunk=4)
    g_0 = workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=8,
                                chunk=4)
    assert g_w.name == g_0.name
    assert g_w.topo_order() == g_0.topo_order()
    for n in g_0.nodes:
        assert sorted(g_w.preds[n]) == sorted(g_0.preds[n])
        nw, n0 = g_w.nodes[n], g_0.nodes[n]
        assert (nw.flops, nw.hbm_bytes, nw.out_bytes, nw.ops) == \
            (n0.flops, n0.hbm_bytes, n0.out_bytes, n0.ops)


def test_windowed_decode_dims_price_the_ring():
    """Decode attention + KV residency/migration charges shrink to the
    ring width: the swa decode DAG's attn nodes carry `kv_len`-row
    charges (4x smaller at window 8 over seq 32), and the graph name
    carries the window."""
    d = workloads.SWA_REDUCED_DIMS
    assert d.kv_len == 8 and workloads.REDUCED_DIMS.kv_len == 32
    g_w = workloads.decode_dag(d)
    g_0 = workloads.decode_dag(workloads.REDUCED_DIMS)
    assert g_w.name == "lm-decode-dag-swa8"
    kvb_w = g_w.nodes["attn0"].meta["kv_bytes"]
    kvb_0 = g_0.nodes["attn0"].meta["kv_bytes"]
    assert kvb_w == kvb_0 / 4
    # the costing proxy attends 8 rows, not 32: strictly less work
    assert g_w.nodes["attn0"].flops < g_0.nodes["attn0"].flops
    # a window as wide as the context changes nothing
    d_nb = dataclasses.replace(workloads.REDUCED_DIMS, window=32)
    assert workloads.decode_dag(d_nb).name == "lm-decode-dag"


def test_swa_presets_in_shipped_registry():
    """The long-context graphs ship through the same registry the golden
    pins and the fidelity gate iterate."""
    names = set(workloads.shipped_graphs())
    assert {"lm-decode-dag-swa4096", "lm-decode-dag-swa8-reduced",
            "lm-moe-decode-dag-int8-swa4096",
            "lm-moe-decode-dag-int8-swa8-reduced",
            "lm-prefill-dag-swa4096-32k",
            "lm-prefill-dag-swa8-reduced"} <= names


# ------------------------------------------------------------------ #
# 5. dispatch attn stage: true key positions, not slot indices
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def prefill_step():
    from repro.serve.dispatch_engine import DispatchPrefillStep
    cfg = dataclasses.replace(REDUCED["starcoder2-7b"], dtype="float32")
    return cfg, DispatchPrefillStep(cfg, SHD, max_len=32, chunk=4)


def test_attn_stage_threads_true_key_positions(prefill_step):
    """The ISSUE-10 ring-cache position bug, as a stage-level regression:
    the prefill attn stage must mask by the CALLER's key positions. A
    permuted key tensor with matching permuted positions yields the
    identical output — the old in-stage `arange(skv)` (slot index ==
    absolute position) masks the wrong keys under any permutation, and a
    banded prefix doesn't even start at 0."""
    cfg, step = prefill_step
    h, hd = cfg.n_heads, cfg.hd
    q = jax.random.normal(k(8), (1, 4, h, hd), jnp.float32) / 4
    kp = jax.random.normal(k(9), (1, 8, cfg.n_kv_heads, hd), jnp.float32)
    vp = jax.random.normal(k(10), (1, 8, cfg.n_kv_heads, hd), jnp.float32)
    q_pos = jnp.arange(9, 13)           # banded prefix: keys start at 5
    k_pos = jnp.arange(5, 13)
    base = step._attn_fn(q, kp, vp, q_pos, k_pos)
    perm = jnp.array([3, 0, 6, 1, 7, 2, 5, 4])
    permuted = step._attn_fn(q, kp[:, perm], vp[:, perm], q_pos,
                             k_pos[perm])
    np.testing.assert_allclose(np.asarray(permuted), np.asarray(base),
                               rtol=1e-6, atol=1e-6)
    # and the window actually binds here (position 12 cannot see key 5
    # under window 16 -- it can; shrink to check the mask is live):
    # q 9..12 with window 16 sees all of 5..12, so widen the gap
    far = step._attn_fn(q, kp, vp, q_pos + 20, k_pos)
    assert not np.allclose(np.asarray(far), np.asarray(base))


def test_attn_stage_rejects_mismatched_positions(prefill_step):
    """Clear error instead of silent mis-masking: a KV prefix whose row
    count disagrees with the threaded positions refuses to run."""
    cfg, step = prefill_step
    h, hd = cfg.n_heads, cfg.hd
    q = jax.random.normal(k(11), (1, 4, h, hd), jnp.float32)
    kp = jax.random.normal(k(12), (1, 8, cfg.n_kv_heads, hd), jnp.float32)
    with pytest.raises(ValueError, match="refusing to mis-mask"):
        step._attn_fn(q, kp, kp, jnp.arange(4), jnp.arange(7))


def test_dispatch_banded_bind_matches_live_from(prefill_step):
    """The executable banded KV prefix agrees with the DAG's dropped
    edges: a 22-token prompt under window 16 drops chunk 0 from chunk
    5's fan-in in BOTH the skeleton DAG and the executor bind."""
    cfg, step = prefill_step
    splits = step.chunk_splits(22)
    assert splits == [4, 4, 4, 4, 4, 2]
    lf = workloads.prefill_live_from(splits, cfg.sliding_window)
    assert lf == [0, 0, 0, 0, 0, 1]
    ex = step._executor_for(splits)
    preds = sorted(ex.graph.preds["attn0/c5"])
    assert preds == [f"qkv0/c{j}" for j in range(1, 6)]


# ------------------------------------------------------------------ #
# 6. the new graphs replay through the planner-fidelity gate
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("name", ["lm-decode-dag-swa8-reduced",
                                  "lm-moe-decode-dag-int8-swa8-reduced",
                                  "lm-prefill-dag-swa8-reduced"])
def test_swa_planner_fidelity_replay(name):
    """The reduced windowed graphs' serial plans replay their modeled
    traces within the fidelity band (the paper-scale entries run under
    tests/test_trace.py's full shipped-graph sweep)."""
    builder, devices = workloads.shipped_graphs()[name]
    g = builder()
    p = plan(g, devices=devices)
    rep = fidelity(g, p)
    assert rep.ok, rep.render()
