"""Execution tracing, replay, calibration, and the planner-fidelity gate
(`repro.dispatch.trace`, DESIGN.md §13).

Four contracts are pinned here:

  1. **Schema + golden trace** — the versioned JSON/Chrome serialization
     round-trips, and `tests/golden_trace.json` pins the MODELED event
     stream (kinds, names, resources, groups exactly; times approx) of
     two shipped reduced graphs whose plans together exercise every
     channel event kind. Like the golden plans, the file is a reviewed
     artifact: regenerate with

         REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_trace.py

     and read the diff like any other code change.
  2. **Ordering invariants** — on any modeled trace, channel events are
     mutually exclusive (ONE shared transfer channel), per-device spans
     are serial (each device is one queue), and every compute span
     starts at or after all its producers' spans end (reader-after-
     writer through the OpGraph).
  3. **The planner-fidelity gate** — for EVERY `workloads.shipped_graphs()`
     entry, the serial plan's predicted `Schedule.pipelined_s` replays
     its own recorded trace to within `FIDELITY_BAND` relative error
     (drift = the replayer and the simulation disagree). On failure the
     offending trace + report are written to `$TRACE_ARTIFACT_DIR` for
     the CI upload step. The measured leg (a REAL dispatch-backed
     serving trace) gates the executor against the planner the same way.
  4. **Calibration round trip** — `calibrate.fit_trace` recovers the
     `placement.cost_constants()` anchors from a synthetic trace priced
     exactly at those anchors (`anchor_trace`), and the tracer costs
     <5% of untraced executor wall-clock (the ISSUE-6 overhead budget).
"""

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import pytest

from repro.dispatch import trace as dtrace
from repro.dispatch import workloads
from repro.dispatch.placement import cost_constants, plan, pure_plan
from repro.dispatch.schedule import make_schedule
from repro.dispatch.trace import (EVENT_KINDS, FIDELITY_BAND,
                                  TRACE_SCHEMA_VERSION, Trace, anchor_trace,
                                  executed_order, fidelity, fit_trace,
                                  measured_node_times, modeled_trace, replay,
                                  what_if)

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_trace.json"
REGEN = bool(os.environ.get("REGEN_GOLDEN"))
EPS = 1e-9


# ------------------------------------------------------------------ #
# fixtures: rich modeled traces + the golden-trace case registry
# ------------------------------------------------------------------ #

def _golden_cases() -> dict:
    """name -> (graph, plan): the two pinned reduced graphs. Together
    their modeled traces cover every channel event kind — the MoE decode
    DAG on pure PIM pays launch/exchange/transfer_out, the dense prefill
    DAG on pure CPU (KV home on PIM) pays per-chunk KV write-backs."""
    moe = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
    pre = workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=8,
                                chunk=4)
    return {
        "lm-moe-decode-dag-reduced:pure_pim":
            (moe, pure_plan(moe, "upmem_2556")),
        "lm-prefill-dag-reduced:pure_cpu":
            (pre, pure_plan(pre, "xeon")),
    }


@pytest.fixture(scope="module")
def rich_traces():
    """The golden cases' modeled traces, keyed like the golden file."""
    return {name: (g, p, modeled_trace(g, p))
            for name, (g, p) in _golden_cases().items()}


@pytest.fixture(scope="module")
def golden():
    """The pinned golden-trace document (skip when absent, unless
    regenerating from scratch)."""
    if not GOLDEN_PATH.exists():
        if REGEN:
            return {}
        pytest.skip("golden_trace.json missing — run with REGEN_GOLDEN=1")
    return json.loads(GOLDEN_PATH.read_text())


@pytest.fixture(scope="session", autouse=True)
def _write_regenerated(request):
    """After a REGEN_GOLDEN run, write the regenerated golden file."""
    yield
    regen = getattr(request.config, "_regen_golden_trace", None)
    if regen is not None:
        GOLDEN_PATH.write_text(json.dumps(regen, indent=1, sort_keys=True)
                               + "\n")


# ------------------------------------------------------------------ #
# 1. event schema + serialization round trips
# ------------------------------------------------------------------ #

def test_trace_records_and_serializes(tmp_path):
    """JSON round trip preserves name, meta, and every event field; the
    loader rejects unknown schema versions."""
    t = Trace("unit", meta={"graph": "g"})
    t.add("compute", "a", "xeon", 0.0, 1.5, group=2, flops=3.0)
    t.instant("cache_hit", "mlp", "host")
    assert t.events[0].dur_s == 1.5 and t.events[1].dur_s == 0.0
    path = tmp_path / "t.json"
    t.save(path)
    back = Trace.load(path)
    assert back.name == "unit" and back.meta == {"graph": "g"}
    assert [e.to_dict() for e in back.events] == \
        [e.to_dict() for e in t.events]
    doc = t.to_json()
    assert doc["schema"] == TRACE_SCHEMA_VERSION
    doc["schema"] = 999
    with pytest.raises(ValueError, match="schema"):
        Trace.from_json(doc)


def test_chrome_export(tmp_path, rich_traces):
    """The Chrome trace_event export names one pseudo-thread per
    resource, emits spans as complete events (µs timestamps) and
    zero-duration events as instants."""
    _, _, t = rich_traces["lm-moe-decode-dag-reduced:pure_pim"]
    doc = t.to_chrome()
    evs = doc["traceEvents"]
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert names == set(t.resources())
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == sum(1 for e in t.events if e.t1 > e.t0)
    ref = next(e for e in t.events if e.t1 > e.t0)
    chrome_ref = next(e for e in spans
                      if e["name"] == f"{ref.kind}:{ref.name}")
    assert chrome_ref["ts"] == pytest.approx(ref.t0 * 1e6)
    assert chrome_ref["dur"] == pytest.approx(ref.dur_s * 1e6)
    t.save_chrome(tmp_path / "t.chrome.json")
    assert json.loads((tmp_path / "t.chrome.json").read_text())[
        "traceEvents"]


def test_modeled_kinds_are_known(rich_traces):
    """Every kind a modeled trace emits is in the EVENT_KINDS registry,
    and the two golden cases together cover all modeled kinds."""
    seen = set()
    for _, _, t in rich_traces.values():
        kinds = {e.kind for e in t.events}
        assert kinds <= set(EVENT_KINDS), kinds - set(EVENT_KINDS)
        seen |= kinds
    assert {"compute", "launch", "stage_in", "exchange", "writeback",
            "transfer_out"} <= seen


def test_trace_helpers():
    """`executed_order` preserves dispatch order across repeats;
    `measured_node_times` keeps the LAST span per node (post-warmup)."""
    t = Trace("synthetic")
    t.add("compute", "a", "xeon", 0.0, 1.0)
    t.add("compute", "b", "xeon", 1.0, 1.5)
    t.add("compute", "a", "xeon", 2.0, 2.25)
    assert executed_order(t) == ["a", "b", "a"]
    assert measured_node_times(t) == pytest.approx({"a": 0.25, "b": 0.5})


# ------------------------------------------------------------------ #
# 2. ordering invariants of the modeled event stream
# ------------------------------------------------------------------ #

def test_channel_events_are_mutually_exclusive(rich_traces):
    """One transfer channel PER RANK: within any `channel*` resource
    (the shared single-rank `"channel"` or a rank's `"channel:r"`), no
    two spans overlap."""
    for name, (_, _, t) in rich_traces.items():
        chans = [r for r in t.resources() if r.startswith("channel")]
        assert chans, name
        for res in chans:
            chan = sorted((e for e in t.events if e.resource == res),
                          key=lambda e: (e.t0, e.t1))
            for a, b in zip(chan, chan[1:]):
                assert b.t0 >= a.t1 - EPS, \
                    f"{name}/{res}: {a.kind}:{a.name} overlaps " \
                    f"{b.kind}:{b.name}"


def test_per_rank_channels_exclusive_on_multi_rank_plan():
    """ISSUE-9: a 2-rank expert-parallel placement stages each rank's
    traffic on its own channel resource. Every rank channel is itself a
    serial queue (per-rank exclusivity), BOTH rank channels carry
    spans (the rank-parallel transfers the speedup comes from), and the
    per-rank replay round trip reproduces the prediction exactly."""
    from repro.dispatch.placement import Topology, evaluate
    topo = Topology(n_ranks=2)
    g = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS_INT8,
                                 expert_shards=2)
    assignment = dict(pure_plan(g, "upmem_2556").assignment)
    for n in g.nodes:
        j = workloads.stage_shard(n)
        if j is not None:
            assignment[n] = topo.rank_device(j % topo.n_ranks)
    p = evaluate(g, assignment, topo.dpu, method="expert-parallel")
    t = modeled_trace(g, p)
    chans = sorted(r for r in t.resources() if r.startswith("channel"))
    assert chans == ["channel", "channel:1"]
    for res in chans:
        evs = sorted((e for e in t.events if e.resource == res),
                     key=lambda e: (e.t0, e.t1))
        assert evs, res
        for a, b in zip(evs, evs[1:]):
            assert b.t0 >= a.t1 - EPS, \
                f"{res}: {a.kind}:{a.name} overlaps {b.kind}:{b.name}"
    fid = fidelity(g, p)
    assert fid.ok
    assert fid.replayed_s == pytest.approx(fid.predicted_s, rel=1e-12)


def test_per_device_spans_are_serial(rich_traces):
    """Each device is a serial queue: its compute/launch spans never
    overlap each other."""
    for name, (_, _, t) in rich_traces.items():
        for res in t.resources():
            if res.startswith("channel"):
                continue
            evs = sorted((e for e in t.events if e.resource == res),
                         key=lambda e: (e.t0, e.t1))
            for a, b in zip(evs, evs[1:]):
                assert b.t0 >= a.t1 - EPS, f"{name}/{res}"


def test_reader_after_writer(rich_traces):
    """Every node's compute span starts at or after each of its graph
    producers' spans end — dependencies are respected in the timeline."""
    for name, (g, _, t) in rich_traces.items():
        start, end = {}, {}
        for e in t.events:
            if e.kind == "compute":
                start[e.name], end[e.name] = e.t0, e.t1
        assert set(start) == set(g.nodes), name
        for n in g.nodes:
            for p in g.preds.get(n, ()):
                assert end[p] <= start[n] + EPS, f"{name}: {p} -> {n}"


# ------------------------------------------------------------------ #
# 3. golden trace
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("name", sorted(_golden_cases()))
def test_modeled_trace_matches_golden(name, golden, rich_traces, request):
    """The modeled event stream is pinned: kind/name/resource/group of
    every event exactly and in order, timestamps to 1e-6 relative. Event
    drift means the pipelined discipline changed — regenerate and review
    like a golden-plan change."""
    _, _, t = rich_traces[name]
    got = t.to_json()
    if REGEN:
        regen = getattr(request.config, "_regen_golden_trace", dict(golden))
        regen[name] = got
        request.config._regen_golden_trace = regen
        return
    assert name in golden, f"no golden trace for {name} (REGEN_GOLDEN=1)"
    want = golden[name]
    assert want["schema"] == TRACE_SCHEMA_VERSION
    shape = [(e["kind"], e["name"], e["resource"], e["group"])
             for e in got["events"]]
    want_shape = [(e["kind"], e["name"], e["resource"], e["group"])
                  for e in want["events"]]
    assert shape == want_shape
    for ge, we in zip(got["events"], want["events"]):
        assert ge["t0"] == pytest.approx(we["t0"], rel=1e-6, abs=1e-12)
        assert ge["t1"] == pytest.approx(we["t1"], rel=1e-6, abs=1e-12)
        assert set(ge["attrs"]) == set(we["attrs"])
        for k, v in we["attrs"].items():
            if isinstance(v, float):
                assert ge["attrs"][k] == pytest.approx(v, rel=1e-6)
            else:
                assert ge["attrs"][k] == v


# ------------------------------------------------------------------ #
# 4. replay + the planner-fidelity gate
# ------------------------------------------------------------------ #

def test_replay_round_trip_is_exact(rich_traces):
    """Replaying a plan's own modeled trace reproduces `pipelined_s`
    exactly — the replayer and the event simulation are the same
    discipline, not two approximations of each other."""
    for name, (g, p, t) in rich_traces.items():
        predicted = make_schedule(g, p, pipelined=True).pipelined_s
        rep = replay(t, g)          # assignment from trace.meta
        assert rep.total_s == pytest.approx(predicted, rel=1e-12), name
        assert rep.order == [n for n in rep.order]  # a list, replayable


def test_replay_requires_an_assignment():
    """A trace without a recorded assignment (and none passed) is a
    loud error, not a silent planner fallback."""
    g = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
    with pytest.raises(ValueError, match="assignment"):
        replay(Trace("empty"), g)


def test_replay_multi_step_trace_takes_last_step(rich_traces):
    """A serving trace repeats every node once per decode step; replay
    prices the LAST repetition (steady state), and measured node times
    can stand in for the cost model."""
    g, p, t = rich_traces["lm-moe-decode-dag-reduced:pure_pim"]
    multi = Trace("multi", meta={"assignment": dict(p.assignment)})
    for step in range(3):
        for e in t.events:
            if e.kind == "compute":
                multi.add("compute", e.name, e.resource,
                          step + e.t0, step + e.t1)
    rep = replay(multi, g)
    assert len(rep.order) == len(g.nodes)
    assert rep.total_s == pytest.approx(replay(t, g).total_s, rel=1e-12)
    timed = replay(multi, g, use_measured_times=True)
    assert timed.total_s > 0


def test_what_if_replay():
    """`what_if` builds override DPU models, and replaying a recorded
    timeline on a faster transfer channel never prices slower."""
    hw = what_if(n_dpus=1234, mram_bw=1.0, launch_overhead_s=0.5)
    assert (hw.n_dpus, hw.mram_bw, hw.launch_overhead_s) == (1234, 1.0, 0.5)
    g = workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=8,
                              chunk=4)
    p = pure_plan(g, "upmem_2556")
    t = modeled_trace(g, p)
    base = replay(t, g).total_s
    fast = replay(t, g, dpu=what_if(channel_scale=4.0)).total_s
    assert fast <= base + EPS


@pytest.mark.parametrize("name", sorted(workloads.shipped_graphs()))
def test_planner_fidelity_gate(name):
    """THE gate: every shipped golden graph's serial plan must replay
    its own execution trace to within FIDELITY_BAND relative error. On
    failure the trace and report land in $TRACE_ARTIFACT_DIR so the CI
    step can upload them for diagnosis."""
    builder, devices = workloads.shipped_graphs()[name]
    g = builder()
    p = plan(g, devices=devices)
    rep = fidelity(g, p)
    assert rep.band == FIDELITY_BAND
    if not rep.ok:
        art = os.environ.get("TRACE_ARTIFACT_DIR")
        if art:
            d = pathlib.Path(art)
            d.mkdir(parents=True, exist_ok=True)
            stem = name.replace("/", "_")
            modeled_trace(g, p).save(d / f"{stem}.trace.json")
            (d / f"{stem}.fidelity.json").write_text(json.dumps(
                {"graph": rep.graph_name, "predicted_s": rep.predicted_s,
                 "replayed_s": rep.replayed_s, "rel_err": rep.rel_err,
                 "band": rep.band}, indent=1) + "\n")
    assert rep.ok, rep.render()
    assert "PASS" in rep.render()


# ------------------------------------------------------------------ #
# 5. calibration
# ------------------------------------------------------------------ #

def test_cost_constants_registry():
    """The Fig.-4 anchor registry: every fittable constant is present,
    positive, and the PIM time scale anchors at exactly 1.0."""
    cc = cost_constants()
    for k in ("xeon.hbm_bw", "xeon.peak_flops", "titan_v.hbm_bw",
              "pcie.bw", "dpu.host_to_dpu_bw", "dpu.dpu_to_host_bw",
              "dpu.mram_bw", "dpu.launch_overhead_s", "dpu.time_scale",
              "dpu.int8_time_scale", "channel.setup_s",
              "exchange.roundtrip_bw"):
        assert k in cc, k
    assert all(v > 0 for v in cc.values()), cc
    assert cc["dpu.time_scale"] == 1.0
    assert cc["dpu.int8_time_scale"] == 1.0


def test_calibration_round_trip_recovers_anchors():
    """Fitting a synthetic trace priced EXACTLY at the anchors must
    recover them: every `ConstantFit.drift` is ~0. (A measured trace
    then reports honest drift against the same anchors.)"""
    g = workloads.decode_dag(workloads.DecodeDims())
    p = plan(g)                       # hybrid: host + PIM nodes
    devs = set(p.assignment.values())
    assert "xeon" in devs and any(d.startswith("upmem") for d in devs)
    t = anchor_trace(g, p.assignment)
    rep = fit_trace(t, g, p.assignment)
    assert rep.fits, "nothing fitted"
    fitted = {f.name for f in rep.fits}
    assert "dpu.time_scale" in fitted
    for f in rep.fits:
        assert f.n_events > 0
        assert abs(f.drift) < 1e-6, (f.name, f.fitted, f.anchor)
    out = rep.render()
    assert "drift" in out and rep.fitted_constants()


def test_calibration_fits_int8_scale_from_quantized_trace():
    """ISSUE-8: a trace over the QUANTIZED MoE decode DAG (int8 experts
    on PIM) has int8-dominant compute spans, so `dpu.int8_time_scale`
    is fittable — and from an anchor-priced trace it round-trips to 1.0
    like every other constant. The f32 DAG's trace must NOT fit it
    (calibration never invents data)."""
    g = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS_INT8)
    p = pure_plan(g, "upmem_2556")
    t = anchor_trace(g, p.assignment)
    rep = fit_trace(t, g, p.assignment)
    names = {f.name: f for f in rep.fits}
    assert "dpu.int8_time_scale" in names, sorted(names)
    assert abs(names["dpu.int8_time_scale"].drift) < 1e-6
    # the pooled scale still fits, from the non-int8 spans only
    assert "dpu.time_scale" in names
    assert abs(names["dpu.time_scale"].drift) < 1e-6

    g32 = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
    p32 = pure_plan(g32, "upmem_2556")
    rep32 = fit_trace(anchor_trace(g32, p32.assignment), g32,
                      p32.assignment)
    assert "dpu.int8_time_scale" not in {f.name for f in rep32.fits}


def test_calibration_on_exchange_trace():
    """Exchange round-trip bandwidth is fittable from a trace whose
    graph pays MoE all-to-alls (the pure-PIM reduced MoE decode)."""
    g = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
    p = pure_plan(g, "upmem_2556")
    t = anchor_trace(g, p.assignment)
    rep = fit_trace(t, g, p.assignment)
    names = {f.name: f for f in rep.fits}
    assert "exchange.roundtrip_bw" in names
    assert abs(names["exchange.roundtrip_bw"].drift) < 1e-6


# ------------------------------------------------------------------ #
# 6. utilization satellite (Schedule.busy_s / render occupancy)
# ------------------------------------------------------------------ #

def test_schedule_utilization_and_occupancy_line():
    """`Schedule.busy_s` books per-resource busy seconds, `utilization`
    normalizes by the modeled wall, and the rendered timeline (what
    `--show-schedule` prints) carries the occupancy line."""
    g = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
    s = make_schedule(g, pure_plan(g, "upmem_2556"), pipelined=True)
    assert "upmem_2556" in s.busy_s and "channel" in s.busy_s
    util = s.utilization()
    assert util and all(0.0 <= v <= 1.0 + EPS for v in util.values())
    # the pipelined wall is the default basis; an explicit wall rescales
    assert s.utilization(wall_s=s.pipelined_s * 2)["upmem_2556"] == \
        pytest.approx(util["upmem_2556"] / 2)
    out = str(s)
    assert "occupancy of pipelined wall" in out and "% busy" in out


# ------------------------------------------------------------------ #
# 7. the measured serving leg (real executor, real FaceCache)
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def serve_rig():
    """A reduced dispatch-backed ServeEngine with both slots admitted
    and the decode step warmed (every stage compiled once)."""
    from repro.configs import REDUCED
    from repro.models import Shardings, init_params
    from repro.serve import Request, ServeEngine
    cfg = REDUCED["granite-3-8b"]
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=512, shd=shd,
                      engine="dispatch",
                      dispatch_kwargs={"prefill_engine": "jit"})
    for i in range(2):
        eng.admit(Request(i, jnp.arange(5, dtype=jnp.int32) + 2, 10_000))
    for _ in range(3):
        eng.step()
    return eng


def test_facecache_stats_steady_state(serve_rig):
    """After warm-up the FaceCache serves every decode-step call from
    cache: calls and hits grow, compiles stay frozen (the PR-5
    recompile regression, asserted through the public counters)."""
    st0 = serve_rig._decode.executor.faces.stats
    assert st0["compiles"] > 0 and st0["calls"] >= st0["compiles"]
    for _ in range(3):
        serve_rig.step()
    st1 = serve_rig._decode.executor.faces.stats
    assert st1["compiles"] == st0["compiles"], (st0, st1)
    assert st1["calls"] > st0["calls"]
    assert st1["hits"] - st0["hits"] == st1["calls"] - st0["calls"]
    for k, v in st1["by_kind"].items():
        assert v["compiles"] >= 1 and v["calls"] >= v["compiles"], (k, v)


def test_measured_serving_trace_and_fidelity(serve_rig):
    """The measured leg of the gate: a traced run records decode-step
    spans, per-node compute spans, channel occupancy, and cache-hit
    instants; the planner's prediction stays within the band of the
    replayed measured linearization; calibration runs on it."""
    tracer = Trace("serve:test", meta={
        "assignment": dict(serve_rig._decode.executor.assignment)})
    serve_rig.attach_tracer(tracer)
    try:
        for _ in range(4):
            serve_rig.step()
    finally:
        serve_rig.attach_tracer(None)
    kinds = {e.kind for e in tracer.events}
    assert {"decode_step", "compute", "cache_hit"} <= kinds, kinds
    steps = tracer.by_kind("decode_step")
    assert len(steps) == 4
    for e in steps:
        assert e.dur_s > 0 and e.attrs["n_live"] == 2
        assert e.attrs["slots"] == [0, 1]
    n_nodes = len(serve_rig._decode.dag.nodes)
    assert len(tracer.by_kind("compute")) == 4 * n_nodes
    # warmed steps never compile
    assert not tracer.by_kind("compile")
    rep = fidelity(serve_rig._decode.dag, serve_rig._decode.plan,
                   trace=tracer)
    assert rep.ok, rep.render()
    cal = fit_trace(tracer, serve_rig._decode.dag,
                    serve_rig._decode.executor.assignment)
    assert cal.fits and all(f.n_events > 0 for f in cal.fits)


def test_tracing_overhead_under_budget(serve_rig):
    """The ISSUE-6 overhead budget: a tracer attached to the serving
    hot loop costs <5% of untraced wall-clock (best-of-5 trials to keep
    scheduler noise out of the comparison)."""
    import gc

    def loop(n):
        t0 = time.perf_counter()
        for _ in range(n):
            serve_rig.step()
        return time.perf_counter() - t0

    def measure(steps=15, trials=3):
        # interleave the trials so machine-load drift lands evenly on
        # both sides; min-of-trials drops scheduler noise; GC paused so
        # a collection pause doesn't land inside one timed batch
        tracer = Trace("overhead")
        untraced_ts, traced_ts = [], []
        gc.disable()
        try:
            for _ in range(trials):
                untraced_ts.append(loop(steps))
                serve_rig.attach_tracer(tracer)
                try:
                    traced_ts.append(loop(steps))
                finally:
                    serve_rig.attach_tracer(None)
        finally:
            gc.enable()
        return min(traced_ts) / min(untraced_ts) - 1.0

    # a genuine regression (say, a per-event device sync) fails EVERY
    # attempt; a noisy container fails at most a couple, so gate on the
    # best of three measurements
    overhead = min(measure() for _ in range(3))
    assert overhead < 0.05, \
        f"tracing overhead {overhead:.1%} blows the <5% budget"


def test_jit_engine_records_serving_spans():
    """The tracer works on the fused-jit engine too: prefill_step and
    decode_step spans only (no executor underneath to trace)."""
    from repro.configs import REDUCED
    from repro.models import Shardings, init_params
    from repro.serve import Request, ServeEngine
    cfg = REDUCED["granite-3-8b"]
    shd = Shardings(None)
    params = init_params(jax.random.PRNGKey(0), cfg, shd)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, shd=shd)
    tracer = Trace("serve:jit")
    eng.attach_tracer(tracer)
    eng.serve([Request(0, jnp.arange(4, dtype=jnp.int32), 3),
               Request(1, jnp.arange(6, dtype=jnp.int32), 3)])
    pre = tracer.by_kind("prefill_step")
    assert [e.name for e in pre] == ["req0", "req1"]
    assert [e.attrs["prompt_len"] for e in pre] == [4, 6]
    assert tracer.by_kind("decode_step")
    assert not tracer.by_kind("compute")
