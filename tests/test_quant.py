"""ISSUE-8 quantization battery: the gates behind the KT2 flip.

KT2 (the source paper's key takeaway 2) bins multiplication-heavy float
workloads as the DPU's WORST case — the 32-slot integer software ladder.
The extended characterization (arXiv:2105.03814) measures INT8 multiply
at the add-band throughput on the same hardware (the DPU's native 8x8
multiplier), so symmetric int8 expert FFNs + int8 KV storage flip the
MoE serving workload from host-bound to PIM-suitable. These tests pin
every layer of that flip:

  * numerics — quantized dispatch MoE decode is EXACT-INTEGER identical
    to the quantized fused engine (both paths run the same
    `models.layers.moe_expert_ffn_q8` int32 accumulators on bit-identical
    `quantize_q8` weights, so the gate is `==` on tokens, not approx);
  * accuracy — quantized logits stay within a measured bound of the f32
    model's (~0.0033 absolute at reduced-mixtral scale; gated at 15x);
  * planner — int8 graphs classify/cost correctly (`_dtype_class`,
    `workloads.moe_exchange_bytes`), and at paper scale the planner
    moves all expert FFNs onto the PIM system and strictly beats the
    f32 hybrid (the flip itself, asserted on the golden placement AND
    re-planned live);
  * sharding — two-bank int8 expert serving == one-bank (slow,
    subprocess per the dry-run isolation rule).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.dispatch import workloads
from repro.dispatch.graph import _dtype_class
from repro.dispatch.placement import plan
from repro.models import Shardings, init_cache, init_params
from repro.serve import Request, ServeEngine, make_prefill_step

SHD = Shardings(None)
GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_plans.json"


@pytest.fixture(scope="module")
def setup_q8():
    """The int8 mixtral-reduced model: the f32 MoE gate model of
    tests/test_serve.py with `quant="int8"` — same params (quantization
    happens at run time from the f32 weights, so both engines quantize
    the same tensors)."""
    cfg = dataclasses.replace(REDUCED["mixtral-8x7b"], dtype="float32",
                              quant="int8")
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    return cfg, params


def _prompts(cfg, n, key):
    out = []
    for _ in range(n):
        key, k = jax.random.split(key)
        plen = 3 + int(jax.random.randint(k, (), 0, 8))
        out.append(jax.random.randint(k, (plen,), 0, cfg.vocab_size,
                                      dtype=jnp.int32))
    return out


def _run_16_steps(eng, prompts):
    """The PR-5 identity-gate schedule: 16 continuous-batching steps with
    arrivals and evictions; returns {rid: (tokens, done)}."""
    reqs = [Request(i, p, 3 + i % 4) for i, p in enumerate(prompts)]
    pending = list(reqs)
    for _ in range(16):
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        eng.step()
    return {r.rid: (list(r.out_tokens), r.done) for r in reqs}


# ------------------------------------------------------------------ #
# exact integer identity: quantized dispatch == quantized fused
# ------------------------------------------------------------------ #

def test_quant_dispatch_decode_token_identical_to_jit(setup_q8):
    """The tentpole numerics gate: routing QUANTIZED MoE decode through
    the planner's plan must be token-for-token identical to the quantized
    fused-jit engine over the 16-step continuous-batching run. Identity
    is exact-integer, not float-approximate: both paths multiply the same
    `quantize_q8` int8 weights into int32 accumulators
    (`moe_expert_ffn_q8`), and `quantize_q8`'s reciprocal-multiply scale
    makes in-jit and ahead-of-time quantization bit-identical."""
    cfg, params = setup_q8
    prompts = _prompts(cfg, 8, jax.random.PRNGKey(11))
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_engine": "jit"})
    # the engine planned the QUANTIZED decode DAG (int8 KV + int8 experts)
    assert dis_eng._decode.dag.name == "lm-moe-decode-dag-int8"
    assert dis_eng.dispatch_plan.method == "dag-dp"
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_quant_dispatch_expert_sharded_decode_token_identical(setup_q8):
    """ISSUE-9 under int8: expert-sharded decode (`expert_shards=2`, rank
    shards forced onto per-rank devices) slices the quantized expert
    weight STACKS (int8 weights + scales) per shard and must stay
    exact-integer identical to the quantized fused engine — shard
    slicing cannot change the int32 accumulation order within an
    expert."""
    cfg, params = setup_q8
    prompts = _prompts(cfg, 6, jax.random.PRNGKey(17))
    forced = {}
    for i in range(cfg.n_blocks):
        forced[f"expert{i}@r0"] = "upmem_2556"
        forced[f"expert{i}@r1"] = "upmem_2556:1"
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(
        cfg, params, batch_slots=2, max_len=48, shd=SHD, engine="dispatch",
        dispatch_kwargs={"expert_shards": 2,
                         "devices": ("xeon", "upmem_2556", "upmem_2556:1"),
                         "force_assignment": forced,
                         "prefill_engine": "jit"})
    assert dis_eng._decode.dag.name == "lm-moe-decode-dag-int8-ep2"
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


def test_quant_dispatch_single_chunk_prefill_token_identical(setup_q8):
    """Quantized dispatch prefill in one chunk (capacity == fused
    whole-prompt capacity) + quantized dispatch decode, against the fully
    fused quantized engine — the full dispatch path under int8."""
    cfg, params = setup_q8
    prompts = _prompts(cfg, 6, jax.random.PRNGKey(13))
    jit_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD)
    dis_eng = ServeEngine(cfg, params, batch_slots=2, max_len=48, shd=SHD,
                          engine="dispatch",
                          dispatch_kwargs={"prefill_chunk": 48})
    # mixtral-reduced is a sliding-window config (window 16 < the 48-token
    # chunk), so its prefill DAG carries the -swa suffix since ISSUE-10
    assert dis_eng._prefill_step.dag.name == "lm-moe-prefill-dag-int8-swa16"
    assert _run_16_steps(jit_eng, prompts) == _run_16_steps(dis_eng, prompts)


# ------------------------------------------------------------------ #
# bounded error vs the f32 reference
# ------------------------------------------------------------------ #

def test_quant_logits_bounded_error_vs_f32(setup_q8):
    """Quantization must change the numbers (else the int8 path is dead
    code) but stay within a measured bound of the f32 reference: max abs
    logit error ~0.0033 at this scale, gated with 15x headroom. Both
    models share the same f32 params — `quant` only changes the compute
    path."""
    cfg8, params = setup_q8
    cfg32 = dataclasses.replace(cfg8, quant="")
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 0,
                              cfg32.vocab_size, dtype=jnp.int32)
    outs = {}
    for name, cfg in (("f32", cfg32), ("q8", cfg8)):
        cache = init_cache(cfg, 2, 32, SHD)
        prefill = make_prefill_step(cfg, SHD)
        last, _ = prefill(params, cache, {"tokens": toks})
        outs[name] = last
    err = float(jnp.max(jnp.abs(outs["f32"] - outs["q8"])))
    assert err > 0.0, "quant='int8' did not change the compute path"
    assert err < 0.05, f"quantization error {err} exceeds the gate"


# ------------------------------------------------------------------ #
# classification + cost-model units
# ------------------------------------------------------------------ #

def test_dtype_class_edge_cases():
    """`graph._dtype_class` over the full HLO dtype vocabulary: f8/bf16
    variants are float (they ride the float software routines), s8/u8 and
    pred are the native 1-byte multiplier band, 64-bit integers are the
    wide ladder, complex follow their component width."""
    assert _dtype_class("f64") == "double"
    assert _dtype_class("c128") == "double"
    for dt in ("f16", "f32", "bf16", "f8e4m3fn", "f8e5m2", "c64"):
        assert _dtype_class(dt) == "float", dt
    for dt in ("s8", "u8", "pred"):
        assert _dtype_class(dt) == "int8", dt
    for dt in ("s64", "u64"):
        assert _dtype_class(dt) == "int64", dt
    for dt in ("s32", "u32", "s16", "u16"):
        assert _dtype_class(dt) == "int32", dt


def test_moe_exchange_bytes_itemsize():
    """Exchange volume scales linearly in itemsize, and the int8 KV
    configuration's ACTIVATION exchanges stay at itemsize 4 — tokens
    ship f32 through the host relay; only weights/KV storage shrink."""
    base = workloads.moe_exchange_bytes(64, 128, 2)
    assert workloads.moe_exchange_bytes(64, 128, 2, itemsize=1) * 4 == base
    d = workloads.MOE_REDUCED_DIMS_INT8
    assert d.kv_itemsize == 1 and d.quant == "int8"
    g8 = workloads.moe_decode_dag(d)
    g32 = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)
    assert g8.exchange_edges == g32.exchange_edges


def test_int8_expert_ops_carry_int8_mul_band():
    """The quantized expert node's dot multiplies must land in the int8
    band (the 8x8-multiplier pass `_dot_mul_class` resolves through
    XLA's widening-convert plumbing) with int32 accumulator adds — if
    this regresses to ('mul', 'int32'), the planner silently re-prices
    experts at the 32-slot software ladder and the KT2 flip dies."""
    g = workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS_INT8)
    ops = g.nodes["expert0"].ops
    assert ops.get(("mul", "int8"), 0) > 0, ops
    assert ops.get(("mul", "int32"), 0) == 0, ops
    assert ops.get(("add", "int32"), 0) > 0, ops
    # the f32 expert has no integer GEMM bands at all
    f32_ops = workloads.moe_decode_dag(
        workloads.MOE_REDUCED_DIMS).nodes["expert0"].ops
    assert not any(dt == "int8" and kind == "mul"
                   for kind, dt in f32_ops), f32_ops


# ------------------------------------------------------------------ #
# the flip: planner placement + strict win at paper scale
# ------------------------------------------------------------------ #

def test_golden_places_quantized_experts_on_pim():
    """The acceptance criterion, asserted on the reviewed golden
    artifact: the paper-scale quantized MoE decode plan places EVERY
    expert FFN on the PIM system, under both objectives."""
    golden = json.loads(GOLDEN_PATH.read_text())
    for case in ("lm-moe-decode-dag-int8", "lm-moe-decode-dag-int8@overlapped"):
        placement = dict(golden[case]["placement"])
        experts = {n: d for n, d in placement.items()
                   if n.startswith("expert")}
        assert len(experts) == 32, case
        assert all(d.startswith("upmem") for d in experts.values()), \
            (case, experts)


@pytest.mark.slow
def test_quantized_hybrid_strictly_beats_f32_hybrid():
    """The KT2 flip, re-planned live at paper scale: the quantized
    hybrid's modeled total must place all experts on PIM and be strictly
    cheaper than the f32 hybrid (which leaves experts on the host).
    Slow: two 194-node paper-scale DAG builds + plans."""
    g8 = workloads.moe_decode_dag(workloads.MOE_PAPER_DIMS_INT8)
    g32 = workloads.moe_decode_dag(workloads.MOE_PAPER_DIMS)
    p8 = plan(g8, devices=("xeon", "upmem_2556"))
    p32 = plan(g32, devices=("xeon", "upmem_2556"))
    experts8 = {n: d for n, d in p8.assignment.items()
                if n.startswith("expert")}
    assert all(d.startswith("upmem") for d in experts8.values()), experts8
    assert p8.total_s < p32.total_s, (p8.total_s, p32.total_s)


# ------------------------------------------------------------------ #
# multi-bank identity (slow, subprocess)
# ------------------------------------------------------------------ #

@pytest.mark.slow
def test_quant_dispatch_multibank_matches_single_bank():
    """Quantized MoE dispatch serving with the EXPERT axis (int8 weights
    AND their f32 scales) sharded over TWO banks must be token-identical
    to the single-bank run — integer accumulators make this exact, and
    the scale arrays must shard alongside their weights or dequant reads
    the wrong expert's scale. Subprocess per the dry-run isolation
    rule."""
    import os
    import subprocess
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    code = (
        "import dataclasses, jax, jax.numpy as jnp\n"
        "from repro.configs import REDUCED\n"
        "from repro.core.bank_parallel import BankGrid, make_bank_mesh\n"
        "from repro.models import Shardings, init_params\n"
        "from repro.serve import Request, ServeEngine\n"
        "shd = Shardings(None)\n"
        "cfg = dataclasses.replace(REDUCED['mixtral-8x7b'],\n"
        "                          dtype='float32', quant='int8')\n"
        "params = init_params(jax.random.PRNGKey(0), cfg, shd)\n"
        "key = jax.random.PRNGKey(5)\n"
        "prompts = []\n"
        "for _ in range(6):\n"
        "    key, k = jax.random.split(key)\n"
        "    plen = 4 + int(jax.random.randint(k, (), 0, 8))\n"
        "    prompts.append(jax.random.randint(k, (plen,), 0,\n"
        "                   cfg.vocab_size, dtype=jnp.int32))\n"
        "forced, pforced = {}, {}\n"
        "for i in range(cfg.n_blocks):\n"
        "    forced[f'attn{i}'] = 'upmem_2556'\n"
        "    forced[f'router{i}'] = 'upmem_2556'\n"
        "    forced[f'expert{i}'] = 'upmem_2556'\n"
        "    for c in range(4):\n"
        "        pforced[f'expert{i}/c{c}'] = 'upmem_2556'\n"
        "outs = {}\n"
        "for n_banks in (1, 2):\n"
        "    grid = BankGrid(make_bank_mesh(n_banks))\n"
        "    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48,\n"
        "        shd=shd, engine='dispatch', dispatch_kwargs={\n"
        "        'grid': grid, 'force_assignment': forced,\n"
        "        'prefill_chunk': 4,\n"
        "        'prefill_force_assignment': pforced})\n"
        "    assert eng._decode.dag.name == 'lm-moe-decode-dag-int8'\n"
        "    assert eng._decode.executor._exchange_in, 'no exchanges'\n"
        "    done = eng.serve([Request(i, p, 5)\n"
        "                      for i, p in enumerate(prompts)])\n"
        "    outs[n_banks] = {r.rid: r.out_tokens for r in done}\n"
        "assert outs[1] == outs[2], outs\n"
        "print('Q8_MULTIBANK_OK')\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=f"{root / 'src'}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "Q8_MULTIBANK_OK" in out.stdout
