"""Per-architecture smoke tests (reduced configs): one forward + one train
step + one prefill/decode on CPU, asserting shapes and finiteness. The FULL
configs are exercised only via the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import pytest

# compile-bound: the whole arch zoo retraces here; tier-1 skips by default
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, REDUCED
from repro.configs.shapes import ShapeConfig
from repro.models import Shardings, forward, init_cache, init_params
from repro.train import DataConfig, HParams, adamw_init, make_batch, \
    make_train_step

SHD = Shardings(None)
B, S = 2, 16


def _inputs(cfg, key):
    kw = {}
    if cfg.input_mode == "embeds":
        kw["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.float32)
    else:
        kw["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.encoder_layers:
        kw["encoder_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return kw


@pytest.mark.parametrize("name", sorted(REDUCED))
def test_forward_shapes_finite(name, rng):
    cfg = REDUCED[name]
    params = init_params(rng, cfg, SHD)
    logits, _, aux = forward(params, cfg, SHD, **_inputs(cfg, rng))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", sorted(REDUCED))
def test_train_step(name, rng):
    cfg = REDUCED[name]
    shape = ShapeConfig("t", S, B, "train")
    params = init_params(rng, cfg, SHD)
    opt = adamw_init(params, cfg)
    step = jax.jit(make_train_step(cfg, SHD, HParams(warmup_steps=2,
                                                     total_steps=10)))
    batch = make_batch(cfg, shape, 0, DataConfig())
    p2, o2, m = step(params, opt, batch)
    assert jnp.isfinite(m["loss"]) and float(m["loss"]) > 0
    assert jnp.isfinite(m["grad_norm"])
    assert int(o2["step"]) == 1
    # params actually changed somewhere (bf16 weight-decay-only deltas on
    # grad-less leaves round away, so check the whole tree, not leaf 0)
    changed = any(
        not bool(jnp.array_equal(a, b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("name", sorted(REDUCED))
def test_prefill_decode(name, rng):
    cfg = REDUCED[name]
    params = init_params(rng, cfg, SHD)
    cache = init_cache(cfg, B, 32, SHD)
    logits, cache, _ = forward(params, cfg, SHD, cache=cache,
                               **_inputs(cfg, rng))
    assert int(cache["index"]) == S
    tok = jnp.ones((B, 1), jnp.int32)
    logits2, cache2, _ = forward(params, cfg, SHD, tokens=tok, cache=cache)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert int(cache2["index"]) == S + 1
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    a = ARCHS
    c = a["qwen2-vl-72b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    c = a["mixtral-8x7b"]
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (32, 4096, 8, 2)
    c = a["qwen2-moe-a2.7b"]
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k,
            c.n_shared_experts) == (24, 2048, 60, 4, 4)
    c = a["jamba-1.5-large-398b"]
    assert (c.n_layers, c.d_model, c.n_experts, c.attn_layer_period) == \
        (72, 8192, 16, 8)
    c = a["rwkv6-3b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 2560, 8960, 65536)
    c = a["deepseek-coder-33b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (62, 7168, 56, 8, 19200, 32256)
    c = a["starcoder2-7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4608, 36, 4, 18432, 49152)
    c = a["granite-3-8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 8, 12800, 49155)
    c = a["llama3-405b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    c = a["whisper-tiny"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.encoder_layers) == (4, 384, 6, 1536, 51865, 4)


def test_param_counts_plausible():
    """6N sanity: param_count lands near the nameplate sizes."""
    def bn(name):
        return ARCHS[name].param_count() / 1e9
    assert 44 < bn("mixtral-8x7b") < 50          # 46.7B total
    assert 390 < bn("llama3-405b") < 420
    assert 30 < bn("deepseek-coder-33b") < 36
    assert 6.5 < bn("starcoder2-7b") < 8.5
    assert 2.5 < bn("rwkv6-3b") < 3.5
    assert 350 < bn("jamba-1.5-large-398b") < 420
    assert 0.02 < bn("whisper-tiny") < 0.08
    # MoE active < total
    assert ARCHS["mixtral-8x7b"].param_count(active_only=True) < \
        ARCHS["mixtral-8x7b"].param_count() / 2.5
