"""Planner optimality properties (ISSUE-2 battery).

Nothing in the suite previously *proved* the planners optimal — these
tests pin it against brute-force enumeration over every placement:

  * chain DP == brute force on random chains (<=6 nodes x 3 devices);
  * the exact DAG planner (frontier DP) == brute force on random DAGs
    (<=8 nodes), and never worse than greedy;
  * branch-and-bound with an ample budget == brute force; with a starved
    budget it still returns its greedy-or-better incumbent;
  * greedy stays within an asserted bound of exact (the construction
    bounds per-node cost ratios, so the bound is structural, not luck);
  * the chain overlapped-objective DP (`method="dp-overlap"`) == brute
    force over every assignment's `Schedule.overlapped_s`, and never
    worse than the coordinate descent it replaced — also asserted on
    every SHIPPED chain graph (ISSUE-4 satellite).

The generators emit nodes with KV-residency annotations too — both the
read side (`kv_bytes`/`kv_home`, decode attention) and the write-back
side (`kv_write_bytes`/`kv_write_home`, prefill chunk attention) — so
the full migration term is exercised through every rung. The
exchange-annotated variants (`OpGraph.annotate_exchange`, ISSUE-5: MoE
token routing) additionally mark random edges as host-relayed bank
exchanges and re-run the same brute-force equalities through every rung,
plus the overlapped-objective guarantee (never worse than scheduling the
serial-ladder seed) on exchange DAGs. The multi-rank variants (ISSUE-9)
re-run the exchange batteries over RANK-QUALIFIED device sets
(`("xeon", "upmem_2556", "upmem_2556:1")`): topology-priced transfers —
per-rank channels, cross-rank pim->pim host relays — must stay exact
through every rung and keep the scheduling invariants. A deterministic
seeded sweep always runs; when `hypothesis` is installed the same
properties are additionally fuzzed over its search space.
"""

from __future__ import annotations

import itertools
import random

import pytest

from repro.dispatch.graph import OpGraph, OpNode
from repro.dispatch.placement import (_plan_dag_bnb, _refine_overlapped,
                                      _resolve, evaluate, greedy_plan, plan)
from repro.dispatch.schedule import make_schedule

DEVICES = ("xeon", "titan_v", "upmem_2556")
#: structural bound for the greedy sweep on the sampled distribution —
#: generous against the observed worst case (~1.2x), tight enough that a
#: planner regression (e.g. dropping the transfer term) trips it
GREEDY_BOUND = 25.0
_REL = 1e-9


#: the DPU_OP_COST bands the dtype-tagged generator samples from — the
#: int8 band (ISSUE-8) must flow through every rung like the others
_DTYPE_BANDS = ("int8", "int32", "int64", "float", "double")


def _rand_node(rng: random.Random, name: str, *,
               dtype_tagged: bool = False) -> OpNode:
    if dtype_tagged:
        ops = {}
        for _ in range(rng.randint(1, 3)):
            op = rng.choice(("add", "mul", "div", "compare"))
            ops[(op, rng.choice(_DTYPE_BANDS))] = rng.uniform(0, 1e9)
    else:
        ops = {("add", "int32"): rng.uniform(0, 1e9)}
        if rng.random() < 0.5:
            ops[("mul", "float")] = rng.uniform(0, 1e8)
    node = OpNode(name, "x", flops=rng.uniform(1e6, 1e10),
                  hbm_bytes=rng.uniform(1e6, 1e9),
                  out_bytes=rng.uniform(0, 1e8), ops=ops,
                  exchange_bytes=rng.uniform(0, 1e7))
    if rng.random() < 0.3:
        node.meta.update(kv_bytes=rng.uniform(1e6, 1e8),
                         kv_home=rng.choice(DEVICES))
    if rng.random() < 0.3:
        node.meta.update(kv_write_bytes=rng.uniform(1e6, 1e8),
                         kv_write_home=rng.choice(DEVICES))
    return node


def make_chain(rng: random.Random, max_nodes: int = 6, *,
               dtype_tagged: bool = False) -> OpGraph:
    g = OpGraph("chain", input_bytes=rng.uniform(0, 1e8))
    prev = None
    for i in range(rng.randint(1, max_nodes)):
        g.add(_rand_node(rng, f"n{i}", dtype_tagged=dtype_tagged),
              *([prev] if prev else []))
        prev = f"n{i}"
    return g


def make_dag(rng: random.Random, max_nodes: int = 8, *,
             dtype_tagged: bool = False) -> OpGraph:
    g = OpGraph("dag", input_bytes=rng.uniform(0, 1e8))
    names: list[str] = []
    for i in range(rng.randint(2, max_nodes)):
        preds = [p for p in names if rng.random() < 0.4]
        g.add(_rand_node(rng, f"n{i}", dtype_tagged=dtype_tagged), *preds)
        names.append(f"n{i}")
    return g


def annotate_exchanges(g: OpGraph, rng: random.Random,
                       p: float = 0.5) -> OpGraph:
    """Mark a random subset of edges as bank exchanges (ISSUE-5): the
    host-relayed re-distribution charge must flow through every rung
    exactly like the other cost terms."""
    for u, v in g.edges:
        if rng.random() < p:
            g.annotate_exchange(u, v, rng.uniform(1e6, 1e8))
    return g


def brute_force_cost(g: OpGraph, device_set=DEVICES) -> float:
    devices, dpu = _resolve(device_set)
    names = list(g.nodes)
    return min(
        evaluate(g, dict(zip(names, combo)), dpu).total_s
        for combo in itertools.product(devices, repeat=len(names)))


def _check_chain(g: OpGraph, device_set=DEVICES):
    best = brute_force_cost(g, device_set)
    p = plan(g, devices=device_set)
    assert p.method == "dp"
    assert p.total_s == pytest.approx(best, rel=_REL)


def _check_dag(g: OpGraph, device_set=DEVICES):
    best = brute_force_cost(g, device_set)
    exact = plan(g, devices=device_set)
    greedy = greedy_plan(g, devices=device_set)
    if not g.is_chain:
        assert exact.method == "dag-dp"
    assert exact.total_s == pytest.approx(best, rel=_REL)
    assert exact.total_s <= greedy.total_s * (1 + _REL)
    assert greedy.total_s <= GREEDY_BOUND * exact.total_s


def brute_force_overlapped_cost(g: OpGraph, device_set=DEVICES) -> float:
    devices, dpu = _resolve(device_set)
    names = list(g.nodes)
    return min(
        make_schedule(g, evaluate(g, dict(zip(names, combo)), dpu),
                      dpu).overlapped_s
        for combo in itertools.product(devices, repeat=len(names)))


def _check_chain_overlapped(g: OpGraph, device_set=DEVICES):
    """ISSUE-4 satellite: for chains, `objective="overlapped"` is planned
    exactly by the group-aggregate DP — equal to brute force over every
    assignment's `Schedule.overlapped_s`, never worse than the coordinate
    descent general DAGs use, and self-consistent with the scheduler."""
    best = brute_force_overlapped_cost(g, device_set)
    p = plan(g, devices=device_set, objective="overlapped")
    assert p.method == "dp-overlap"
    assert p.objective == "overlapped"
    assert p.overlapped_s == pytest.approx(best, rel=_REL)
    devices, dpu = _resolve(device_set)
    assert p.overlapped_s == pytest.approx(
        make_schedule(g, p, dpu).overlapped_s, rel=_REL)
    cd = _refine_overlapped(g, plan(g, devices=device_set).assignment,
                            devices, dpu, "xeon", "xeon", "dp")
    assert p.overlapped_s <= cd.overlapped_s * (1 + _REL)


def _check_bnb(g: OpGraph, device_set=DEVICES):
    devices, dpu = _resolve(device_set)
    best = brute_force_cost(g, device_set)
    ample = evaluate(g, _plan_dag_bnb(g, devices, dpu, "xeon", "xeon",
                                      10 ** 6), dpu)
    assert ample.total_s == pytest.approx(best, rel=_REL)
    starved = evaluate(g, _plan_dag_bnb(g, devices, dpu, "xeon", "xeon", 1),
                       dpu)
    assert starved.total_s <= greedy_plan(g, devices=device_set).total_s \
        * (1 + _REL)


# ------------------------------------------------------------------ #
# deterministic sweep (always runs, no optional deps)
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("seed", range(25))
def test_chain_dp_equals_brute_force(seed):
    _check_chain(make_chain(random.Random(1000 + seed)))


@pytest.mark.parametrize("seed", range(25))
def test_dag_exact_equals_brute_force_and_bounds_greedy(seed):
    _check_dag(make_dag(random.Random(2000 + seed)))


@pytest.mark.parametrize("seed", range(10))
def test_bnb_exact_when_budgeted_and_bounded_when_starved(seed):
    _check_bnb(make_dag(random.Random(3000 + seed, ), max_nodes=6))


@pytest.mark.parametrize("seed", range(15))
def test_chain_overlapped_dp_equals_brute_force(seed):
    _check_chain_overlapped(make_chain(random.Random(4000 + seed),
                                       max_nodes=5))


@pytest.mark.parametrize("seed", range(15))
def test_exchange_chain_dp_equals_brute_force(seed):
    """ISSUE-5 satellite: exchange-annotated chains stay exact under the
    chain DP (the host-relay charge is part of the transition cost)."""
    _check_chain(annotate_exchanges(make_chain(random.Random(5000 + seed)),
                                    random.Random(5000 + seed)))


@pytest.mark.parametrize("seed", range(15))
def test_exchange_dag_exact_equals_brute_force_and_bounds_greedy(seed):
    """ISSUE-5 satellite: exchange-annotated DAGs through the frontier-DP
    rung — still equal to brute force, still never worse than greedy."""
    _check_dag(annotate_exchanges(make_dag(random.Random(6000 + seed)),
                                  random.Random(6000 + seed)))


@pytest.mark.parametrize("seed", range(8))
def test_exchange_bnb_exact_when_budgeted(seed):
    """ISSUE-5 satellite: the branch-and-bound rung on exchange DAGs
    (ample budget == brute force; starved stays greedy-or-better)."""
    _check_bnb(annotate_exchanges(make_dag(random.Random(7000 + seed),
                                           max_nodes=6),
                                  random.Random(7000 + seed)))


@pytest.mark.parametrize("seed", range(10))
def test_exchange_chain_overlapped_dp_equals_brute_force(seed):
    """ISSUE-5 satellite: the exact overlapped chain DP books intra-group
    exchanges as channel occupancy exactly like `make_schedule` — equal
    to brute force over every assignment's `Schedule.overlapped_s`."""
    _check_chain_overlapped(
        annotate_exchanges(make_chain(random.Random(8000 + seed),
                                      max_nodes=5),
                           random.Random(8000 + seed)))


@pytest.mark.parametrize("seed", range(10))
def test_exchange_dag_overlapped_never_worse_than_serial_seed(seed):
    """ISSUE-5 satellite: on exchange DAGs the overlapped objective never
    schedules worse than the serial-ladder seed (the seed is always in
    the candidate set), and the pipelined event sim never loses to the
    serialized groups."""
    rng = random.Random(9000 + seed)
    g = annotate_exchanges(make_dag(rng), rng)
    devices, dpu = _resolve(DEVICES)
    serial = plan(g, devices=DEVICES)
    over = plan(g, devices=DEVICES, objective="overlapped")
    assert over.overlapped_s <= \
        make_schedule(g, serial, dpu).overlapped_s * (1 + _REL) + 1e-15
    sched = make_schedule(g, over, dpu, pipelined=True)
    assert sched.pipelined_s <= sched.overlapped_s + 1e-15


@pytest.mark.parametrize("seed", range(10))
def test_int8_node_cost_never_exceeds_f32_on_pim(seed):
    """ISSUE-8: the int8 band is never pricier than the float band for
    the same op mix on any PIM device — the monotonicity the KT2 flip
    rests on (int8 muls ride the 8x8 HW multiplier; float muls the
    32-slot software routine)."""
    from repro.dispatch.placement import node_time
    rng = random.Random(10_000 + seed)
    counts = {op: rng.uniform(1e3, 1e9)
              for op in ("add", "mul", "div", "compare")}
    n8 = OpNode("n8", "x", flops=1e9, hbm_bytes=1e6, out_bytes=0,
                ops={(op, "int8"): c for op, c in counts.items()})
    nf = OpNode("nf", "x", flops=1e9, hbm_bytes=1e6, out_bytes=0,
                ops={(op, "float"): c for op, c in counts.items()})
    for dev in ("upmem_2556", "upmem_640"):
        assert node_time(n8, dev) <= node_time(nf, dev) * (1 + _REL), dev


@pytest.mark.parametrize("seed", range(15))
def test_dtype_tagged_chain_dp_equals_brute_force(seed):
    """ISSUE-8: chains whose nodes carry random dtype bands (including
    int8) stay exact under the chain DP — dtype-aware costing is plain
    node cost, no special-cased rung."""
    _check_chain(make_chain(random.Random(11_000 + seed),
                            dtype_tagged=True))


@pytest.mark.parametrize("seed", range(15))
def test_dtype_tagged_dag_exact_equals_brute_force(seed):
    """ISSUE-8: randomly dtype-tagged DAGs through the frontier-DP rung —
    equal to brute force, never worse than greedy."""
    _check_dag(make_dag(random.Random(12_000 + seed), dtype_tagged=True))


@pytest.mark.parametrize("seed", range(8))
def test_dtype_tagged_bnb_exact_when_budgeted(seed):
    """ISSUE-8: the branch-and-bound rung on dtype-tagged DAGs (ample
    budget == brute force; starved stays greedy-or-better)."""
    _check_bnb(make_dag(random.Random(13_000 + seed), max_nodes=6,
                        dtype_tagged=True))


@pytest.mark.parametrize("seed", range(10))
def test_dtype_tagged_chain_overlapped_dp_equals_brute_force(seed):
    """ISSUE-8: the exact overlapped chain DP on dtype-tagged chains."""
    _check_chain_overlapped(make_chain(random.Random(14_000 + seed),
                                       max_nodes=5, dtype_tagged=True))


@pytest.mark.parametrize("seed", range(10))
def test_dtype_tagged_dag_pipelined_never_worse_than_overlapped(seed):
    """ISSUE-8: on dtype-tagged exchange DAGs the overlapped objective
    never loses to the serial seed, and the pipelined event sim never
    loses to the serialized groups — the scheduling invariants survive
    dtype-aware costing."""
    rng = random.Random(15_000 + seed)
    g = annotate_exchanges(make_dag(rng, dtype_tagged=True), rng)
    devices, dpu = _resolve(DEVICES)
    serial = plan(g, devices=DEVICES)
    over = plan(g, devices=DEVICES, objective="overlapped")
    assert over.overlapped_s <= \
        make_schedule(g, serial, dpu).overlapped_s * (1 + _REL) + 1e-15
    sched = make_schedule(g, over, dpu, pipelined=True)
    assert sched.pipelined_s <= sched.overlapped_s + 1e-15


# ------------------------------------------------------------------ #
# multi-rank topologies (ISSUE-9): rank-qualified devices through
# every rung — transfers and exchanges priced per rank channel
# ------------------------------------------------------------------ #

#: two ranks of one UPMEM base behind a host: rank 0 is the bare name,
#: rank 1 its `:1`-qualified twin (`placement.Topology` naming). The
#: generators' kv homes still sample `DEVICES`, so placements on rank 1
#: exercise cross-rank pim->pim crossings (retrieve + push, host relay)
RANKED_DEVICES = ("xeon", "upmem_2556", "upmem_2556:1")


@pytest.mark.parametrize("seed", range(15))
def test_ranked_chain_dp_equals_brute_force(seed):
    """ISSUE-9: exchange-annotated chains over rank-qualified devices
    stay exact under the chain DP — topology-priced transfers (per-rank
    channels, cross-rank host relays) are part of the transition cost
    like any other term."""
    rng = random.Random(16_000 + seed)
    _check_chain(annotate_exchanges(make_chain(rng), rng),
                 device_set=RANKED_DEVICES)


@pytest.mark.parametrize("seed", range(15))
def test_ranked_dag_exact_equals_brute_force_and_bounds_greedy(seed):
    """ISSUE-9: exchange-annotated DAGs through the frontier-DP rung
    with a 2-rank device set — equal to brute force over every (device,
    rank) placement, never worse than greedy."""
    rng = random.Random(17_000 + seed)
    _check_dag(annotate_exchanges(make_dag(rng), rng),
               device_set=RANKED_DEVICES)


@pytest.mark.parametrize("seed", range(8))
def test_ranked_bnb_exact_when_budgeted(seed):
    """ISSUE-9: the branch-and-bound rung over rank-qualified devices
    (ample budget == brute force; starved stays greedy-or-better)."""
    rng = random.Random(18_000 + seed)
    _check_bnb(annotate_exchanges(make_dag(rng, max_nodes=6), rng),
               device_set=RANKED_DEVICES)


@pytest.mark.parametrize("seed", range(10))
def test_ranked_chain_overlapped_dp_equals_brute_force(seed):
    """ISSUE-9: the exact overlapped chain DP with ranks in the device
    set — equal to brute force over every assignment's
    `Schedule.overlapped_s`, self-consistent with the scheduler's
    per-rank channel accounting."""
    rng = random.Random(19_000 + seed)
    _check_chain_overlapped(
        annotate_exchanges(make_chain(rng, max_nodes=5), rng),
        device_set=RANKED_DEVICES)


@pytest.mark.parametrize("seed", range(10))
def test_ranked_dag_pipelined_never_worse_than_overlapped(seed):
    """ISSUE-9: on ranked exchange DAGs the overlapped objective never
    loses to the serial seed, and the pipelined event sim (one transfer
    channel PER RANK) never loses to the serialized groups — the
    scheduling invariants survive multi-rank topologies."""
    rng = random.Random(20_000 + seed)
    g = annotate_exchanges(make_dag(rng, dtype_tagged=True), rng)
    devices, dpu = _resolve(RANKED_DEVICES)
    serial = plan(g, devices=RANKED_DEVICES)
    over = plan(g, devices=RANKED_DEVICES, objective="overlapped")
    assert over.overlapped_s <= \
        make_schedule(g, serial, dpu).overlapped_s * (1 + _REL) + 1e-15
    sched = make_schedule(g, over, dpu, pipelined=True)
    assert sched.pipelined_s <= sched.overlapped_s + 1e-15


def test_chain_overlapped_dp_beats_descent_on_shipped_chains():
    """The ISSUE-4 satellite acceptance on every SHIPPED chain graph: the
    exact group-aggregate DP never scores worse than the coordinate
    descent that used to plan chains under the overlapped objective."""
    from repro import prim
    from repro.dispatch import workloads
    chains = {"prim-mixed": (workloads.mixed_pipeline(
                  m=1024, concrete=False).graph(), ("xeon", "upmem_2556")),
              "lm-decode-chain": (workloads.decode_pipeline(
                  concrete=False).graph(), ("xeon", "upmem_2556"))}
    for c in prim.all_ref_counts():
        chains[f"prim/{c.name}"] = (workloads.prim_graph(c), DEVICES)
    for name, (g, devs) in chains.items():
        assert g.is_chain, name
        exact = plan(g, devices=devs, objective="overlapped")
        assert exact.method == "dp-overlap", name
        devices, dpu = _resolve(devs)
        cd = _refine_overlapped(g, plan(g, devices=devs).assignment,
                                devices, dpu, "xeon", "xeon", "dp")
        assert exact.overlapped_s <= cd.overlapped_s * (1 + _REL), name


# ------------------------------------------------------------------ #
# hypothesis fuzzing (when the dev extra is installed)
# ------------------------------------------------------------------ #

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _cases = settings(max_examples=25, deadline=None,
                      suppress_health_check=[hypothesis.HealthCheck.too_slow])

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_chain_dp_equals_brute_force(seed):
        _check_chain(make_chain(random.Random(seed)))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_dag_exact_equals_brute_force(seed):
        _check_dag(make_dag(random.Random(seed)))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_chain_overlapped_dp_equals_brute_force(seed):
        _check_chain_overlapped(make_chain(random.Random(seed),
                                           max_nodes=4))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_exchange_dag_exact_equals_brute_force(seed):
        _check_dag(annotate_exchanges(make_dag(random.Random(seed)),
                                      random.Random(seed)))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_exchange_chain_overlapped_dp_equals_brute_force(seed):
        _check_chain_overlapped(
            annotate_exchanges(make_chain(random.Random(seed), max_nodes=4),
                               random.Random(seed)))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_dtype_tagged_dag_exact_equals_brute_force(seed):
        _check_dag(make_dag(random.Random(seed), dtype_tagged=True))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_dtype_tagged_chain_overlapped_dp_equals_brute_force(seed):
        _check_chain_overlapped(make_chain(random.Random(seed), max_nodes=4,
                                           dtype_tagged=True))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_ranked_dag_exact_equals_brute_force(seed):
        _check_dag(annotate_exchanges(make_dag(random.Random(seed)),
                                      random.Random(seed)),
                   device_set=RANKED_DEVICES)

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_ranked_chain_overlapped_dp_equals_brute_force(seed):
        _check_chain_overlapped(
            annotate_exchanges(make_chain(random.Random(seed), max_nodes=4),
                               random.Random(seed)),
            device_set=RANKED_DEVICES)
