"""Planner optimality properties (ISSUE-2 battery).

Nothing in the suite previously *proved* the planners optimal — these
tests pin it against brute-force enumeration over every placement:

  * chain DP == brute force on random chains (<=6 nodes x 3 devices);
  * the exact DAG planner (frontier DP) == brute force on random DAGs
    (<=8 nodes), and never worse than greedy;
  * branch-and-bound with an ample budget == brute force; with a starved
    budget it still returns its greedy-or-better incumbent;
  * greedy stays within an asserted bound of exact (the construction
    bounds per-node cost ratios, so the bound is structural, not luck).

The generators emit nodes with KV-residency annotations too — both the
read side (`kv_bytes`/`kv_home`, decode attention) and the write-back
side (`kv_write_bytes`/`kv_write_home`, prefill chunk attention) — so
the full migration term is exercised through every rung. A deterministic
seeded sweep always runs; when `hypothesis` is installed the same
properties are additionally fuzzed over its search space.
"""

from __future__ import annotations

import itertools
import random

import pytest

from repro.dispatch.graph import OpGraph, OpNode
from repro.dispatch.placement import (_plan_dag_bnb, _resolve, evaluate,
                                      greedy_plan, plan)

DEVICES = ("xeon", "titan_v", "upmem_2556")
#: structural bound for the greedy sweep on the sampled distribution —
#: generous against the observed worst case (~1.2x), tight enough that a
#: planner regression (e.g. dropping the transfer term) trips it
GREEDY_BOUND = 25.0
_REL = 1e-9


def _rand_node(rng: random.Random, name: str) -> OpNode:
    ops = {("add", "int32"): rng.uniform(0, 1e9)}
    if rng.random() < 0.5:
        ops[("mul", "float")] = rng.uniform(0, 1e8)
    node = OpNode(name, "x", flops=rng.uniform(1e6, 1e10),
                  hbm_bytes=rng.uniform(1e6, 1e9),
                  out_bytes=rng.uniform(0, 1e8), ops=ops,
                  exchange_bytes=rng.uniform(0, 1e7))
    if rng.random() < 0.3:
        node.meta.update(kv_bytes=rng.uniform(1e6, 1e8),
                         kv_home=rng.choice(DEVICES))
    if rng.random() < 0.3:
        node.meta.update(kv_write_bytes=rng.uniform(1e6, 1e8),
                         kv_write_home=rng.choice(DEVICES))
    return node


def make_chain(rng: random.Random, max_nodes: int = 6) -> OpGraph:
    g = OpGraph("chain", input_bytes=rng.uniform(0, 1e8))
    prev = None
    for i in range(rng.randint(1, max_nodes)):
        g.add(_rand_node(rng, f"n{i}"), *([prev] if prev else []))
        prev = f"n{i}"
    return g


def make_dag(rng: random.Random, max_nodes: int = 8) -> OpGraph:
    g = OpGraph("dag", input_bytes=rng.uniform(0, 1e8))
    names: list[str] = []
    for i in range(rng.randint(2, max_nodes)):
        preds = [p for p in names if rng.random() < 0.4]
        g.add(_rand_node(rng, f"n{i}"), *preds)
        names.append(f"n{i}")
    return g


def brute_force_cost(g: OpGraph) -> float:
    devices, dpu = _resolve(DEVICES)
    names = list(g.nodes)
    return min(
        evaluate(g, dict(zip(names, combo)), dpu).total_s
        for combo in itertools.product(devices, repeat=len(names)))


def _check_chain(g: OpGraph):
    best = brute_force_cost(g)
    p = plan(g, devices=DEVICES)
    assert p.method == "dp"
    assert p.total_s == pytest.approx(best, rel=_REL)


def _check_dag(g: OpGraph):
    best = brute_force_cost(g)
    exact = plan(g, devices=DEVICES)
    greedy = greedy_plan(g, devices=DEVICES)
    if not g.is_chain:
        assert exact.method == "dag-dp"
    assert exact.total_s == pytest.approx(best, rel=_REL)
    assert exact.total_s <= greedy.total_s * (1 + _REL)
    assert greedy.total_s <= GREEDY_BOUND * exact.total_s


def _check_bnb(g: OpGraph):
    devices, dpu = _resolve(DEVICES)
    best = brute_force_cost(g)
    ample = evaluate(g, _plan_dag_bnb(g, devices, dpu, "xeon", "xeon",
                                      10 ** 6), dpu)
    assert ample.total_s == pytest.approx(best, rel=_REL)
    starved = evaluate(g, _plan_dag_bnb(g, devices, dpu, "xeon", "xeon", 1),
                       dpu)
    assert starved.total_s <= greedy_plan(g, devices=DEVICES).total_s \
        * (1 + _REL)


# ------------------------------------------------------------------ #
# deterministic sweep (always runs, no optional deps)
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("seed", range(25))
def test_chain_dp_equals_brute_force(seed):
    _check_chain(make_chain(random.Random(1000 + seed)))


@pytest.mark.parametrize("seed", range(25))
def test_dag_exact_equals_brute_force_and_bounds_greedy(seed):
    _check_dag(make_dag(random.Random(2000 + seed)))


@pytest.mark.parametrize("seed", range(10))
def test_bnb_exact_when_budgeted_and_bounded_when_starved(seed):
    _check_bnb(make_dag(random.Random(3000 + seed, ), max_nodes=6))


# ------------------------------------------------------------------ #
# hypothesis fuzzing (when the dev extra is installed)
# ------------------------------------------------------------------ #

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _cases = settings(max_examples=25, deadline=None,
                      suppress_health_check=[hypothesis.HealthCheck.too_slow])

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_chain_dp_equals_brute_force(seed):
        _check_chain(make_chain(random.Random(seed)))

    @_cases
    @given(st.integers(min_value=0, max_value=10 ** 9))
    def test_hyp_dag_exact_equals_brute_force(seed):
        _check_dag(make_dag(random.Random(seed)))
