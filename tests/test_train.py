"""Training substrate: optimizer, checkpoint atomicity, fault-tolerant
restart exactness, straggler detection."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.configs.shapes import ShapeConfig
from repro.models import Shardings
from repro.train import (DataConfig, HParams, InjectedFailure, LoopConfig,
                         TrainLoop, adamw_init, adamw_update,
                         clip_by_global_norm, latest_step, restore, save,
                         schedule, valid_steps)

SHD = Shardings(None)
CFG = REDUCED["starcoder2-7b"]
SHAPE = ShapeConfig("t", 32, 4, "train")
HP = HParams(lr=1e-3, warmup_steps=5, total_steps=50)


def test_schedule_shape():
    assert float(schedule(0, HP)) == 0.0
    assert float(schedule(5, HP)) == pytest.approx(HP.lr)
    assert float(schedule(50, HP)) == pytest.approx(HP.lr * HP.min_lr_frac)
    # monotone decay after warmup
    vals = [float(schedule(s, HP)) for s in range(5, 51, 5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    from repro.train import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_descends_quadratic():
    import dataclasses
    hp = dataclasses.replace(HP, lr=0.1, weight_decay=0.0,
                             warmup_steps=0, total_steps=1000)
    cfg = CFG
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, hp, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)},
            "s": jnp.zeros((), jnp.int32)}
    save(str(tmp_path), 7, tree)
    assert valid_steps(str(tmp_path)) == [7]
    back = restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path):
    """A step dir without manifest.json is invisible to restore."""
    tree = {"a": jnp.ones((4,))}
    save(str(tmp_path), 1, tree)
    # fake a torn write at step 2
    os.makedirs(tmp_path / "step_2")
    with open(tmp_path / "step_2" / "leaf_0.bin", "wb") as f:
        f.write(b"partial")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_structure_mismatch(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"a": jnp.ones((4,)), "b": jnp.ones((2,))})


def test_restart_is_bitwise_exact(tmp_path):
    """Crash at step 8, resume from the step-5 checkpoint, end bitwise
    equal to an uninterrupted run (data pipeline is pure in step)."""
    def mk(ckpt, fail):
        return TrainLoop(CFG, SHAPE, SHD, HP,
                         LoopConfig(total_steps=12, ckpt_every=5,
                                    ckpt_dir=ckpt, log_every=100,
                                    fail_at_step=fail))
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    ref_loop = mk(d1, None)
    ref_state = ref_loop.run(ref_loop.resume_or_init())

    crash_loop = mk(d2, 8)
    with pytest.raises(InjectedFailure):
        crash_loop.run(crash_loop.resume_or_init())
    resume_loop = mk(d2, None)
    state = resume_loop.resume_or_init()
    assert state.step == 5                      # restored, not reinit
    state = resume_loop.run(state)

    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_loss_decreases():
    loop = TrainLoop(CFG, SHAPE, SHD,
                     HParams(lr=3e-3, warmup_steps=5, total_steps=60),
                     LoopConfig(total_steps=40, ckpt_every=1000,
                                ckpt_dir="/tmp/nock", log_every=1))
    state = loop.run(loop.init_state())
    losses = [m["loss"] for m in loop.metrics_log]
    assert np.mean(losses[-5:]) < losses[0] - 0.2, losses[:3] + losses[-3:]


def test_straggler_detection():
    import time
    loop = TrainLoop(CFG, SHAPE, SHD, HP,
                     LoopConfig(total_steps=1, ckpt_every=1000,
                                ckpt_dir="/tmp/nock2"))
    for i in range(20):
        loop._check_straggler(i, 0.1)
    loop._check_straggler(20, 1.0)              # 10x the median
    assert loop.straggler_steps == [20]
