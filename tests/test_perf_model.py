"""Paper-claims validation (EXPERIMENTS.md §Paper-claims): the calibrated
cross-system model must reproduce the four KT4 anchors of the paper."""

import pytest

from repro.core.perf_model import Figure4, compare
from repro.core.pim_model import DPU_OP_COST, UPMEM_2556, UPMEM_640
from repro.prim import all_ref_counts


@pytest.fixture(scope="module")
def fig4():
    return Figure4([compare(c) for c in all_ref_counts()])


def test_2556_vs_cpu_anchor(fig4):
    # paper: 23.2x average over all 16 PrIM benchmarks
    assert fig4.avg_speedup_2556_vs_cpu == pytest.approx(23.2, rel=0.20)


def test_640_vs_cpu_anchor(fig4):
    # paper: 10.1x
    assert fig4.avg_speedup_640_vs_cpu == pytest.approx(10.1, rel=0.20)


def test_2556_vs_gpu_suitable_anchor(fig4):
    # paper: 2.54x on the 10 PIM-suitable benchmarks
    assert fig4.avg_speedup_2556_vs_gpu_suitable == \
        pytest.approx(2.54, rel=0.15)


def test_energy_640_anchor(fig4):
    # paper: 1.64x more energy-efficient than the CPU
    assert fig4.avg_energy_eff_640_vs_cpu == pytest.approx(1.64, rel=0.15)


def test_suitable_group_beats_gpu_unsuitable_loses(fig4):
    for c in fig4.comparisons:
        if not c.pim_suitable:
            # group 2 loses to the GPU (paper Fig. 4's split)
            assert c.speedup_vs_gpu_2556 < 1.0, c.name


def test_fig3_op_throughput_ordering():
    """Paper Fig. 3: add/sub fast; mul/div order-of-magnitude slower;
    float slower than int; 64-bit slower than 32-bit."""
    d = UPMEM_2556
    add32 = d.op_throughput("add", "int32")
    mul32 = d.op_throughput("mul", "int32")
    div32 = d.op_throughput("div", "int32")
    addf = d.op_throughput("add", "float")
    addd = d.op_throughput("add", "double")
    add64 = d.op_throughput("add", "int64")
    assert add32 > 5 * mul32 > 0          # ~order of magnitude (Fig 3a)
    assert mul32 > div32
    assert add32 > addf > addd
    assert add32 > add64
    # absolute: paper measures ~58-70 MOPS for 32-bit add at 1 op/elem
    assert 50e6 < add32 < 80e6


def test_fig2_compute_bound_at_low_oi():
    """Paper KT1/Fig 2: int-add saturates the pipeline at OI as low as
    0.25 op/byte (1 add per int32): at k=1 the compute rate is already
    below the MRAM streaming rate — compute-bound."""
    d = UPMEM_2556
    elems_per_s_compute = d.freq_hz / (4 + 1)          # 1 add + bookkeeping
    elems_per_s_memory = d.mram_bw / 4                 # 4 B per int32
    assert elems_per_s_compute < elems_per_s_memory    # KT1 at OI=0.25
    # and the machine balance point sits below 1 op/byte (vs ~240 F/B on
    # the TPU — the inversion DESIGN.md §2 is built on)
    assert UPMEM_2556.as_machine().balance < 1.0


def test_launch_overhead_drives_sublinear_scaling():
    """10.1x -> 23.2x is only 2.3x for 4x the DPUs (paper KT4): the fixed
    launch overhead must make scaling sublinear in our model too."""
    from repro.prim import va
    c = va.counts(va.REF_N)
    from repro.core.perf_model import time_on_pim
    t640 = time_on_pim(c, UPMEM_640).total_s
    t2556 = time_on_pim(c, UPMEM_2556).total_s
    scaling = t640 / t2556
    assert 1.5 < scaling < 3.9            # << 4.0 (linear)
