"""Shared fixtures. NOTE: no xla_force_host_platform_device_count here —
smoke tests see the real (single) device; multi-bank behaviour is tested
in a subprocess (test_prim_multibank.py) per the dry-run isolation rule."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def bank_grid():
    """A BankGrid over whatever devices exist (1 on this container)."""
    from repro.core.bank_parallel import BankGrid, make_bank_mesh
    return BankGrid(make_bank_mesh())
