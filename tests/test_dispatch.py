"""repro.dispatch: planner, scheduler, and hybrid runtime.

Covers the ISSUE-1 acceptance gates: the suitability split matches the
Fig.-4 grouping, boundary transfer costs make flip-flop placements lose,
hybrid plans strictly beat both pure placements on the mixed PrIM pipeline
and the LM decode step, and executed plans match the single-device
reference."""

import jax
import jax.numpy as jnp
import pytest

from repro import prim
from repro.dispatch import workloads
from repro.dispatch.graph import (OpGraph, OpNode, annotate_kv_residency,
                                  chain_graph, ops_from_hlo)
from repro.dispatch.placement import (compare_plans, evaluate, greedy_plan,
                                      kv_migration_time, plan, pure_plan,
                                      transfer_hops, transfer_time)
from repro.dispatch.runtime import (Pipeline, Stage, check_phase_discipline,
                                    execute)
from repro.dispatch.schedule import make_schedule


@pytest.fixture(scope="module")
def mixed_graph():
    return workloads.mixed_pipeline(m=4096, concrete=False).graph()


@pytest.fixture(scope="module")
def decode_graph():
    return workloads.decode_pipeline(workloads.DecodeDims(),
                                     concrete=False).graph()


# ------------------------------------------------------------------ #
# graph building
# ------------------------------------------------------------------ #

def test_ops_from_hlo_counts_elements():
    n, k, m = 32, 16, 8
    x = jnp.ones((n, k), jnp.float32)
    w = jnp.ones((k, m), jnp.float32)
    text = jax.jit(lambda a, b: a @ b).lower(x, w).compile().as_text()
    ops = ops_from_hlo(text)
    assert ops[("mul", "float")] == pytest.approx(n * k * m)
    assert ops[("add", "float")] == pytest.approx(n * k * m)

    text = jax.jit(lambda a, b: a + b).lower(
        jnp.ones((64,), jnp.int32), jnp.ones((64,), jnp.int32)) \
        .compile().as_text()
    assert ops_from_hlo(text).get(("add", "int32")) == pytest.approx(64)


def test_from_hlo_instruction_graph():
    """Fine-grained graph from a compiled module: dot / fusion / reduce
    instructions become costed nodes wired by operand edges."""
    def f(x, w):
        h = jnp.maximum(x @ w, 0)
        return jnp.sum(h * h)

    text = jax.jit(f).lower(jnp.ones((64, 32), jnp.float32),
                            jnp.ones((32, 16), jnp.float32)) \
        .compile().as_text()
    g = OpGraph.from_hlo(text, "relu-gemv")
    kinds = {n.kind for n in g.nodes.values()}
    assert "dot" in kinds
    dot = next(n for n in g.nodes.values() if n.kind == "dot")
    assert dot.flops == pytest.approx(2 * 64 * 32 * 16)
    assert g.is_chain and plan(g).method == "dp"
    assert g.input_bytes == pytest.approx(4 * (64 * 32 + 32 * 16))


def test_node_takeaway_properties(mixed_graph):
    stream = mixed_graph.nodes["va.add"]
    assert stream.complex_frac == 0.0          # KT2: pure add
    assert stream.oi < 1.0                     # KT1: streaming
    assert stream.exchange_bytes == 0.0        # KT3: bank-local
    shuffle = mixed_graph.nodes["roll.rows"]
    assert shuffle.comm_ratio > 0.4            # KT3: exchange-heavy
    square = mixed_graph.nodes["ts.square"]
    assert square.complex_frac == 1.0          # all multiplies


def test_chain_detection(mixed_graph, decode_graph):
    assert mixed_graph.is_chain and decode_graph.is_chain
    dag = OpGraph("dag")
    a = dag.add(OpNode("a", "x", 1e6, 1e6, 1e3))
    dag.add(OpNode("b", "x", 1e6, 1e6, 1e3), "a")
    dag.add(OpNode("c", "x", 1e6, 1e6, 1e3), "a")
    dag.add(OpNode("d", "x", 1e6, 1e6, 1e3), "b", "c")
    assert not dag.is_chain
    assert dag.max_frontier() == 2          # diamond: b and c stay open
    # the ladder: DAGs get the exact frontier DP; a starved state budget
    # falls through to branch-and-bound; chains keep the chain DP
    assert plan(dag).method == "dag-dp"
    assert plan(dag, state_budget=0).method == "bnb"
    assert plan(dag).total_s <= plan(dag, state_budget=0).total_s + 1e-12
    assert plan(chain_graph("ch", [OpNode("e", "x", 1e6, 1e6, 1e3)])) \
        .method == "dp"


# ------------------------------------------------------------------ #
# placement: the paper's grouping, and DP optimality
# ------------------------------------------------------------------ #

def test_planner_matches_fig4_grouping():
    """Suitable (group-1) workloads plan onto PIM; unsuitable (group-2)
    workloads get a better device than PIM (the recovery)."""
    for counts in prim.all_ref_counts():
        g = workloads.prim_graph(counts)
        hyb = plan(g, devices=("xeon", "titan_v", "upmem_2556"))
        pick = hyb.assignment[counts.name]
        if counts.pim_suitable:
            assert pick != "xeon", counts.name       # PIM-wing of Fig. 4
        else:
            assert pick != "upmem_2556", counts.name
            assert hyb.total_s < pure_plan(g, "upmem_2556").total_s, \
                counts.name


def test_node_time_agrees_with_perf_model():
    """The planner's per-node costs intentionally use the same arithmetic
    as the Fig.-4 model; this pins the equivalence so a recalibration of
    one cannot silently drift from the other."""
    from repro.core.perf_model import time_on_host, time_on_pim
    from repro.core.pim_model import UPMEM_2556, XEON_E3_1240
    from repro.dispatch.placement import node_time
    for counts in prim.all_ref_counts():
        node = workloads.node_from_counts(counts)
        pim = time_on_pim(counts, UPMEM_2556)
        assert node_time(node, "upmem_2556") == pytest.approx(
            pim.total_s - UPMEM_2556.launch_overhead_s), counts.name
        host = time_on_host(counts, XEON_E3_1240, "xeon")
        assert node_time(node, "xeon") == pytest.approx(host.total_s), \
            counts.name


def test_suitable_workloads_prefer_pim_over_cpu():
    for counts in prim.all_ref_counts():
        if counts.pim_suitable:
            g = workloads.prim_graph(counts)
            assert pure_plan(g, "upmem_2556").total_s \
                < pure_plan(g, "xeon").total_s, counts.name


def test_boundary_costs_make_flipflop_lose(mixed_graph):
    """DP optimality spot-check: alternating devices every operator pays
    boundary transfers + launches and must lose to the planned hybrid."""
    best = plan(mixed_graph)
    names = list(mixed_graph.nodes)
    flip = {n: ("upmem_2556" if i % 2 else "xeon")
            for i, n in enumerate(names)}
    flipped = evaluate(mixed_graph, flip)
    assert best.total_s < flipped.total_s
    assert flipped.transfer_s > best.transfer_s
    # and against every pure plan in its device set (DP explores those)
    for dev in ("xeon", "upmem_2556"):
        assert best.total_s <= pure_plan(mixed_graph, dev).total_s + 1e-12


def test_mixed_hybrid_strictly_beats_both_pures(mixed_graph):
    plans = compare_plans(mixed_graph)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s
    assert plans["hybrid"].is_hybrid
    # the split is the paper's: streams bank-parallel, shuffles on host
    a = plans["hybrid"].assignment
    assert a["va.add"] == "upmem_2556" and a["ts.square"] == "upmem_2556"
    assert a["trns.fwd"] == "xeon" and a["roll.rows"] == "xeon"


def test_decode_hybrid_strictly_beats_both_pures(decode_graph):
    plans = compare_plans(decode_graph)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s
    a = plans["hybrid"].assignment
    # KV-cache attention bank-parallel; float-mul weight GEMVs on host (KT2)
    assert a["attn0"] == "upmem_2556"
    assert a["qkv0"] == "xeon" and a["up0"] == "xeon"


# ------------------------------------------------------------------ #
# decode DAG + KV residency
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def decode_dag():
    return workloads.decode_dag(workloads.DecodeDims())


def test_decode_dag_structure(decode_dag):
    d = workloads.DecodeDims()
    assert len(decode_dag.nodes) == 4 * d.n_layers + 2
    assert not decode_dag.is_chain
    # residual braid: the stream fans out to qkv and the o-residual, so
    # the frontier DP's width stays 2 — the exact class
    assert decode_dag.max_frontier() == 2
    preds = decode_dag.preds
    assert sorted(preds["o0"]) == ["attn0", "embed"]
    assert preds["qkv0"] == ["embed"] and preds["mlp0"] == ["o0"]
    assert plan(decode_dag).method == "dag-dp"


def test_decode_dag_kv_residency(decode_dag):
    attn = decode_dag.nodes["attn0"]
    assert attn.meta["kv_home"] == "upmem_2556"
    assert attn.meta["kv_bytes"] > 0
    # at home: free; elsewhere: the measured-channel charge
    assert kv_migration_time(attn, "upmem_2556") == 0.0
    off_home = kv_migration_time(attn, "xeon")
    assert off_home == pytest.approx(
        transfer_time("upmem_2556", "xeon", attn.meta["kv_bytes"]))
    # evaluate books the migration: all-CPU pays it once per attn node
    cpu = pure_plan(decode_dag, "xeon")
    n_attn = workloads.DecodeDims().n_layers
    assert cpu.migrate_s == pytest.approx(n_attn * off_home)
    assert pure_plan(decode_dag, "upmem_2556").migrate_s == 0.0


def test_decode_dag_planner_pins_attention_to_kv_home(decode_dag):
    hyb = plan(decode_dag)
    d = workloads.DecodeDims()
    for i in range(d.n_layers):
        assert hyb.assignment[f"attn{i}"] == "upmem_2556"
        assert hyb.assignment[f"qkv{i}"] == "xeon"     # f32 mul: host (KT2)
    # flipping the KV home flips where the planner keeps attention
    g_cpu_kv = workloads.decode_dag(d, kv_home="xeon")
    assert plan(g_cpu_kv).assignment["attn0"] == "xeon"


def test_decode_dag_hybrid_beats_pures_steelmanned():
    """Paper-scale acceptance, each baseline given its best-case KV
    residency: pure CPU with KV on the host, pure PIM and the hybrid with
    KV bank-resident."""
    d = workloads.DecodeDims()
    hybrid = plan(workloads.decode_dag(d))
    cpu = pure_plan(workloads.decode_dag(d, kv_home="xeon"), "xeon")
    pim = pure_plan(workloads.decode_dag(d), "upmem_2556")
    assert hybrid.total_s < cpu.total_s
    assert hybrid.total_s < pim.total_s
    assert hybrid.is_hybrid


def test_schedule_books_kv_migration(decode_dag):
    """Schedule and Plan must agree on KV-annotated graphs: a group whose
    device is not a member node's KV home pulls the migrated cache bytes
    as a boundary transfer in the timeline."""
    p = pure_plan(decode_dag, "xeon")
    assert p.migrate_s > 0
    sched = make_schedule(decode_dag, p)
    d = workloads.DecodeDims()
    kvb = decode_dag.nodes["attn0"].meta["kv_bytes"]
    # single host group: input never crosses (source==device), so the
    # incoming payload is exactly every layer's migrated KV
    assert len(sched.groups) == 1
    assert sched.groups[0].in_bytes == pytest.approx(d.n_layers * kvb)
    assert sched.groups[0].in_transfer_s >= p.migrate_s
    # at home (pure PIM) nothing migrates and nothing extra enters
    pim_sched = make_schedule(decode_dag, pure_plan(decode_dag,
                                                    "upmem_2556"))
    assert pim_sched.groups[0].in_bytes == pytest.approx(
        decode_dag.input_bytes)


def test_planner_never_worse_than_greedy(decode_dag, mixed_graph):
    for g in (decode_dag, mixed_graph):
        assert plan(g).total_s <= greedy_plan(g).total_s + 1e-12


# ------------------------------------------------------------------ #
# prefill DAG: chunked fan-out + KV write residency (ISSUE-3)
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def prefill_graph():
    """Reduced 2-chunk prefill DAG (prefill_len=8, chunk=4)."""
    return workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=8,
                                 chunk=4)


def test_prefill_dag_structure(prefill_graph):
    g = prefill_graph
    d = workloads.REDUCED_DIMS
    n_chunks = 2
    # per chunk: embed + 4 stages/layer; one head on the last chunk
    assert len(g.nodes) == n_chunks * (1 + 4 * d.n_layers) + 1
    assert not g.is_chain
    preds = g.preds
    # cross-chunk fan-in: chunk 1's attention reads chunk 0's written KV
    assert sorted(preds["attn0/c1"]) == ["qkv0/c0", "qkv0/c1"]
    assert sorted(preds["o0/c0"]) == ["attn0/c0", "embed/c0"]
    assert preds["head"] == [f"mlp{d.n_layers - 1}/c1"]
    # residual streams + open qkv fan-outs stay narrow at 2 chunks:
    # the exact frontier DP plans it
    assert g.max_frontier() <= 2 * n_chunks + 1
    assert plan(g).method == "dag-dp"


def test_prefill_dag_ragged_tail_and_validation():
    g = workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=11,
                              chunk=4)                 # chunks 4, 4, 3
    assert "embed/c2" in g.nodes and "embed/c3" not in g.nodes
    assert g.nodes["attn0/c2"].meta["kv_bytes"] > 0
    with pytest.raises(ValueError, match="chunk"):
        workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=8,
                              chunk=0)


def test_prefill_dag_kv_read_and_write_annotations(prefill_graph):
    d = workloads.REDUCED_DIMS
    row_bytes = 2.0 * d.kv_heads * d.head_dim * d.kv_itemsize
    first = prefill_graph.nodes["attn0/c0"]
    later = prefill_graph.nodes["attn0/c1"]
    # chunk 0 reads nothing resident (no prior rows), but writes its own
    assert "kv_bytes" not in first.meta
    assert first.meta["kv_write_bytes"] == pytest.approx(4 * row_bytes)
    assert first.meta["kv_write_home"] == "upmem_2556"
    # chunk 1 reads chunk 0's 4 rows and writes its own 4
    assert later.meta["kv_bytes"] == pytest.approx(4 * row_bytes)
    assert later.meta["kv_write_bytes"] == pytest.approx(4 * row_bytes)
    # kv_home=None disables both annotations
    bare = workloads.prefill_dag(workloads.REDUCED_DIMS, prefill_len=8,
                                 chunk=4, kv_home=None)
    assert "kv_write_bytes" not in bare.nodes["attn0/c0"].meta


def test_kv_writeback_charge(prefill_graph):
    """Placing a KV-writing node off the cache's home charges shipping the
    fresh rows back over the measured channel; at home it is free."""
    node = prefill_graph.nodes["attn0/c0"]
    wb = node.meta["kv_write_bytes"]
    assert kv_migration_time(node, "upmem_2556") == 0.0
    assert kv_migration_time(node, "xeon") == pytest.approx(
        transfer_time("xeon", "upmem_2556", wb))
    # a later chunk off-home pays read migration AND write-back
    later = prefill_graph.nodes["attn0/c1"]
    assert kv_migration_time(later, "xeon") == pytest.approx(
        transfer_time("upmem_2556", "xeon", later.meta["kv_bytes"])
        + transfer_time("xeon", "upmem_2556", later.meta["kv_write_bytes"]))


def test_schedule_books_kv_writeback(prefill_graph):
    """A host group whose members write bank-resident KV ships the rows
    back in one batched transfer, serialized after the group (Schedule and
    Plan must agree on the write-back term)."""
    p = pure_plan(prefill_graph, "xeon")
    assert p.migrate_s > 0
    sched = make_schedule(prefill_graph, p)
    assert len(sched.groups) == 1
    g = sched.groups[0]
    assert g.n_writebacks == 2 * workloads.REDUCED_DIMS.n_layers
    assert g.writeback_s > 0
    assert g.serial_s == pytest.approx(g.in_transfer_s + g.launch_s
                                       + g.compute_s + g.writeback_s)
    assert g.overlapped_s >= g.writeback_s    # never hidden under compute
    # at home nothing ships back
    home = make_schedule(prefill_graph, pure_plan(prefill_graph,
                                                  "upmem_2556"))
    assert all(grp.n_writebacks == 0 for grp in home.groups)


# ------------------------------------------------------------------ #
# MoE routing as an exchange phase (ISSUE-5)
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def moe_dag():
    """Reduced MoE decode DAG (4 experts top-2, routed ladder/layer)."""
    return workloads.moe_decode_dag(workloads.MOE_REDUCED_DIMS)


def test_moe_decode_dag_structure(moe_dag):
    d = workloads.MOE_REDUCED_DIMS
    # per layer: qkv, attn, o, router, expert, combine (+ embed, head)
    assert len(moe_dag.nodes) == 6 * d.n_layers + 2
    preds = moe_dag.preds
    assert preds["expert0"] == ["router0"]
    assert sorted(preds["combine0"]) == ["expert0", "o0", "router0"]
    assert preds["qkv1"] == ["combine0"]
    # the routed fan-out stays inside the exact frontier-DP class
    assert moe_dag.max_frontier() == 3
    assert plan(moe_dag).method == "dag-dp"
    # dense dims refuse the MoE entry point
    with pytest.raises(ValueError, match="MoE dims"):
        workloads.moe_decode_dag(workloads.REDUCED_DIMS)


def test_moe_exchange_edges_scale_with_tokens_not_experts(moe_dag):
    """The exchange annotation's volume contract: tokens x capacity, not
    expert count — doubling the expert count must not change the bytes,
    doubling the tokens must double them."""
    d = workloads.MOE_REDUCED_DIMS
    xb = moe_dag.exchange_edges[("router0", "expert0")]
    assert xb == pytest.approx(
        workloads.moe_exchange_bytes(d.batch, d.d_model, d.top_k))
    assert moe_dag.exchange_edges[("expert0", "combine0")] == xb
    import dataclasses as dc
    wide = workloads.moe_decode_dag(dc.replace(d, n_experts=2 * d.n_experts))
    assert wide.exchange_edges[("router0", "expert0")] == xb
    assert workloads.moe_exchange_bytes(2 * d.batch, d.d_model, d.top_k) \
        == pytest.approx(2 * xb)
    # annotating a non-edge fails loudly
    with pytest.raises(ValueError, match="no edge"):
        moe_dag.annotate_exchange("qkv0", "combine0", 1.0)


def test_exchange_time_charges_only_same_pim_device():
    """The exchange cost model: a bank re-distribution round-trips
    through host DRAM only when both endpoints share a PIM device
    (Takeaway 3); host-local shuffles are free and cross-device edges
    ride the ordinary boundary transfer."""
    from repro.core.pim_model import UPMEM_2556
    from repro.dispatch.placement import exchange_time
    nbytes = 1e8
    t = exchange_time("upmem_2556", "upmem_2556", nbytes)
    assert t == pytest.approx(nbytes / UPMEM_2556.dpu_to_host_bw
                              + nbytes / UPMEM_2556.host_to_dpu_bw)
    assert exchange_time("xeon", "xeon", nbytes) == 0.0
    assert exchange_time("titan_v", "titan_v", nbytes) == 0.0
    assert exchange_time("xeon", "upmem_2556", nbytes) == 0.0
    assert exchange_time("upmem_2556", "xeon", nbytes) == 0.0


def test_evaluate_books_exchange_on_pure_pim(moe_dag):
    """Plan totals: pure PIM pays both exchanges per layer; plans that
    split the exchange endpoints across devices pay none (the boundary
    transfer covers the relay)."""
    from repro.dispatch.placement import exchange_time
    d = workloads.MOE_REDUCED_DIMS
    pim = pure_plan(moe_dag, "upmem_2556")
    per_edge = exchange_time("upmem_2556", "upmem_2556",
                             moe_dag.exchange_edges[("router0", "expert0")])
    assert pim.exchange_s == pytest.approx(2 * d.n_layers * per_edge)
    assert pure_plan(moe_dag, "xeon").exchange_s == 0.0
    split = {n: "xeon" for n in moe_dag.nodes}
    for i in range(d.n_layers):
        split[f"expert{i}"] = "upmem_2556"
    assert evaluate(moe_dag, split).exchange_s == 0.0


def test_schedule_books_exchange_as_channel_occupancy(moe_dag):
    """Schedule/Plan agreement on exchange graphs: a pure-PIM timeline
    books every exchange into `LaunchGroup.exchange_s` (serialized into
    `overlapped_s` — an exchange can never hide under its own group's
    compute), and the pipelined sim treats it as shared-channel traffic,
    never beating the impossible exchange-free timeline by more than the
    exchanges it cannot remove."""
    from repro.dispatch.schedule import TRANSFER_SETUP_S
    d = workloads.MOE_REDUCED_DIMS
    pim = pure_plan(moe_dag, "upmem_2556")
    sched = make_schedule(moe_dag, pim, pipelined=True)
    assert len(sched.groups) == 1
    g = sched.groups[0]
    assert g.n_exchanges == 2 * d.n_layers
    assert g.exchange_s == pytest.approx(
        pim.exchange_s + g.n_exchanges * 2 * TRANSFER_SETUP_S)
    assert g.overlapped_s >= g.compute_s + g.exchange_s
    assert sched.pipelined_s <= sched.overlapped_s + 1e-15
    # host groups book nothing
    host = make_schedule(moe_dag, pure_plan(moe_dag, "xeon"))
    assert all(grp.n_exchanges == 0 for grp in host.groups)


def test_pipelined_transfer_bound_exchange_group_not_double_charged():
    """Review regression: a PIM group whose batched INPUT transfer
    dominates its compute and which also contains an exchange edge must
    not charge the input streaming twice — the exchange queues after the
    group's overlap window (the serial algebra), so `pipelined_s <=
    overlapped_s` holds on transfer-bound exchange groups too."""
    g = OpGraph("xbound", input_bytes=0.0)
    g.add(OpNode("a", "x", 1e6, 1e8, 5e8))         # huge boundary tensor
    g.add(OpNode("b", "x", 1e6, 1e6, 1e6,
                 ops={("add", "int32"): 1e6}), "a")
    g.add(OpNode("c", "x", 1e6, 1e6, 1e4,
                 ops={("add", "int32"): 1e6}), "b")
    g.annotate_exchange("b", "c", 1e6)
    p = evaluate(g, {"a": "xeon", "b": "upmem_2556", "c": "upmem_2556"})
    sched = make_schedule(g, p, pipelined=True)
    pim = sched.groups[-1]
    assert pim.n_exchanges == 1
    assert pim.in_transfer_s - pim.relay_s > pim.compute_s  # transfer-bound
    assert sched.pipelined_s <= sched.overlapped_s + 1e-15


def test_moe_paper_hybrid_beats_steelmanned_pures():
    """The ISSUE-5 acceptance at paper scale (mixtral-8x7b dims): the
    planner's hybrid strictly beats pure CPU (KV re-homed to the host)
    and pure PIM (KV at home, but float experts + two host-relayed
    exchanges per layer) — attention pinned to the bank-resident KV,
    router/experts/GEMVs on the host."""
    dims = workloads.MOE_PAPER_DIMS
    dag = workloads.moe_decode_dag(dims)
    hybrid = plan(dag)
    cpu = pure_plan(workloads.moe_decode_dag(dims, kv_home="xeon"), "xeon")
    pim = pure_plan(dag, "upmem_2556")
    assert hybrid.total_s < cpu.total_s
    assert hybrid.total_s < pim.total_s
    assert hybrid.method == "dag-dp"
    assert pim.exchange_s > 0 and hybrid.exchange_s == 0.0
    a = hybrid.assignment
    assert a["attn0"] == "upmem_2556"
    assert a["expert0"] == "xeon" and a["router0"] == "xeon"


def test_moe_prefill_dag_and_skeleton_parity():
    """MoE prefill DAGs carry the routed ladder per chunk with per-chunk
    exchange volumes, and the structural skeleton agrees on nodes, edges
    AND exchange annotations (the executor's host gather/scatter reads
    them from the skeleton)."""
    d = workloads.MOE_REDUCED_DIMS
    g = workloads.prefill_dag(d, prefill_len=8, chunk=4)
    assert sorted(g.preds["combine0/c1"]) == \
        ["expert0/c1", "o0/c1", "router0/c1"]
    assert g.preds["qkv1/c0"] == ["combine0/c0"]
    xb = workloads.moe_exchange_bytes(4, d.d_model, d.top_k)
    assert g.exchange_edges[("router0/c0", "expert0/c0")] == xb
    skel = workloads.prefill_dag(d, prefill_len=8, chunk=4, costed=False)
    assert set(skel.nodes) == set(g.nodes)
    assert skel.edges == g.edges
    assert skel.exchange_edges == g.exchange_edges


def test_facecache_moe_and_dense_kinds_share_without_recompiling(bank_grid):
    """ISSUE-5 satellite regression: MoE and dense stage kinds sharing
    one FaceCache must not collide (duplicate kinds fail loudly) and must
    not recompile per step — one compile per kind across repeated
    same-shape calls, asserted through the public `stats` counters
    (ISSUE-6: the cache accounts for itself; no monkeypatched bodies)."""
    from repro.dispatch.executor import FaceCache, StageDef

    kinds = ("mlp", "router", "expert", "combine")
    faces = FaceCache([StageDef(k, lambda x: x + 1, (0,), (0,))
                       for k in kinds], bank_grid)
    x = jnp.zeros((4,), jnp.float32)
    for _ in range(5):                 # five "steps", same shapes
        for k in kinds:
            faces.host(k)(x)
    st = faces.stats
    assert st["calls"] == 5 * len(kinds)
    assert st["compiles"] == len(kinds), st
    assert st["hits"] == 4 * len(kinds)
    assert all(st["by_kind"][k] == {"calls": 5, "compiles": 1}
               for k in kinds), st["by_kind"]
    assert st["host"]["compiles"] == len(kinds) and \
        st["pim"]["compiles"] == 0
    # a second executor sharing the cache adds hits, no compiles
    for k in kinds:
        faces.host(k)(x)
    st = faces.stats
    assert st["compiles"] == len(kinds) and st["hits"] == 5 * len(kinds)
    # a NEW shape per kind is a legitimate respecialization: one more
    # compile each, visible in the same counters
    y = jnp.zeros((8,), jnp.float32)
    for k in kinds:
        faces.host(k)(y)
    st = faces.stats
    assert st["compiles"] == 2 * len(kinds), st
    with pytest.raises(ValueError, match="duplicate"):
        FaceCache([StageDef("mlp", lambda x: x + 1, (0,), (0,)),
                   StageDef("mlp", lambda x: x + 2, (0,), (0,))], bank_grid)


# ------------------------------------------------------------------ #
# schedule-aware objective (objective="overlapped")
# ------------------------------------------------------------------ #

def test_overlapped_objective_never_worse(prefill_graph, decode_dag,
                                          mixed_graph):
    """The acceptance inequality, at unit scale: the overlapped-objective
    plan's Schedule.overlapped_s is never worse than scheduling the
    serial-objective plan (the serial plan seeds the candidate set).
    The full 20-graph sweep lives in tests/test_golden_plans.py."""
    for g in (prefill_graph, decode_dag, mixed_graph):
        serial = plan(g)
        over = plan(g, objective="overlapped")
        assert over.objective == "overlapped"
        # DAGs: coordinate descent ("...+overlap"); chains (mixed_graph):
        # the exact group-aggregate DP ("dp-overlap")
        assert over.method.endswith("overlap")
        assert over.overlapped_s is not None
        assert over.overlapped_s <= \
            make_schedule(g, serial).overlapped_s + 1e-15
        # and the returned score is the schedule's score for the plan
        assert over.overlapped_s == pytest.approx(
            make_schedule(g, evaluate(g, over.assignment)).overlapped_s)


def test_objective_validation(prefill_graph):
    with pytest.raises(ValueError, match="objective"):
        plan(prefill_graph, objective="nope")
    assert plan(prefill_graph).objective == "serial"


def test_chain_overlapped_planned_exactly(mixed_graph):
    """Chains hit the exact group-aggregate DP rung under the overlapped
    objective (ISSUE-4 satellite): method `dp-overlap`, score ==
    scheduler's score, never worse than the serial plan's schedule."""
    over = plan(mixed_graph, objective="overlapped")
    assert over.method == "dp-overlap"
    assert over.overlapped_s == pytest.approx(
        make_schedule(mixed_graph, over).overlapped_s)
    serial = plan(mixed_graph)
    assert over.overlapped_s <= \
        make_schedule(mixed_graph, serial).overlapped_s + 1e-15


# ------------------------------------------------------------------ #
# pipelined group timeline (ISSUE-4: what the executor runs)
# ------------------------------------------------------------------ #

def test_pipelined_never_worse_than_serial_groups(prefill_graph, decode_dag,
                                                  mixed_graph):
    """The pipelined event simulation can only remove serialization: the
    serial-group timeline is the same event system with every resource
    globally serialized, so `pipelined_s <= overlapped_s` on every graph
    and plan (both objectives)."""
    for g in (prefill_graph, decode_dag, mixed_graph):
        for objective in ("serial", "overlapped"):
            p = plan(g, objective=objective)
            sched = make_schedule(g, p, pipelined=True)
            assert sched.pipelined_s is not None
            assert sched.pipelined_s <= sched.overlapped_s + 1e-15


def test_pipelined_hides_writeback_under_later_chunks(prefill_graph):
    """The ISSUE-4 mechanism: on a pure-host prefill plan (KV stays
    bank-resident, every attention writes back), the pipelined timeline
    hides write-backs under later chunks' compute — strictly faster than
    the serialized groups — and the saving is bounded by the total
    write-back traffic it can hide."""
    p = pure_plan(prefill_graph, "xeon")
    sched = make_schedule(prefill_graph, p, pipelined=True)
    wb = sum(g.writeback_s for g in sched.groups)
    assert wb > 0
    assert sched.pipelined_s < sched.overlapped_s
    assert sched.overlapped_s - sched.pipelined_s <= wb + 1e-15


def test_pipelined_waits_for_kv_writers(prefill_graph):
    """`meta["kv_writers"]` is a real dependency: stripping it can only
    shorten the pipelined makespan (readers no longer wait for earlier
    chunks' write-backs to land at the home)."""
    import copy
    p = pure_plan(prefill_graph, "xeon")
    with_deps = make_schedule(prefill_graph, p, pipelined=True).pipelined_s
    stripped = copy.deepcopy(prefill_graph)
    for node in stripped.nodes.values():
        node.meta.pop("kv_writers", None)
    without = make_schedule(stripped, p, pipelined=True).pipelined_s
    assert without <= with_deps + 1e-15
    assert workloads.prefill_dag(
        workloads.REDUCED_DIMS, prefill_len=8,
        chunk=4).nodes["attn0/c1"].meta["kv_writers"] == ["attn0/c0"]


def test_schedule_order_parameter_prices_serial_chunk_loop(prefill_graph):
    """`make_schedule(order=...)` prices an alternative linearization —
    the old chunk-serial prefill loop. Groups cover the same nodes, and
    the pipelined default timeline never loses to the serialized loop
    (the dispatch_bench acceptance inequality)."""
    loop_order = workloads.prefill_serial_order(prefill_graph)
    assert sorted(loop_order) == sorted(prefill_graph.nodes)
    # chunk-major: chunk 0's whole ladder precedes chunk 1's first stage
    assert loop_order.index("mlp1/c0") < loop_order.index("embed/c1")
    # a non-topological linearization fails loudly, not silently
    with pytest.raises(ValueError, match="topological"):
        make_schedule(prefill_graph, plan(prefill_graph),
                      order=list(reversed(prefill_graph.topo_order())))
    for objective in ("serial", "overlapped"):
        p = plan(prefill_graph, objective=objective)
        loop = make_schedule(prefill_graph, p, order=loop_order)
        pipe = make_schedule(prefill_graph, p, pipelined=True)
        assert sorted(n for g in loop.groups for n in g.nodes) == \
            sorted(prefill_graph.nodes)
        assert pipe.pipelined_s <= loop.overlapped_s + 1e-15


def test_pipelined_rejects_reader_before_writer(prefill_graph):
    """A linearization that is topologically valid for the DAG's edges
    can still schedule a KV reader's group before its writer's (there is
    no attn->attn edge) — the pipelined simulation must refuse to price
    that physically impossible timeline rather than silently understate
    it."""
    order = list(prefill_graph.topo_order())
    i, j = order.index("attn0/c0"), order.index("attn0/c1")
    order[i], order[j] = order[j], order[i]     # reader before writer
    assignment = {n: "xeon" for n in prefill_graph.nodes}
    assignment["attn0/c0"] = "upmem_2556"       # writer in its own group
    p = evaluate(prefill_graph, assignment)
    make_schedule(prefill_graph, p, order=order)        # serial: fine
    with pytest.raises(ValueError, match="not executed yet"):
        make_schedule(prefill_graph, p, order=order, pipelined=True)


def test_executor_frees_dead_env_entries(bank_grid):
    """`PlanExecutor.run` drops a node's output once its last consumer
    group has dispatched (the serial loops' live-set footprint), keeping
    only what the caller names in `keep`."""
    from repro.dispatch.executor import FaceCache, PlanExecutor, StageDef
    g = OpGraph("tiny", input_bytes=4.0)
    for name, preds in (("a", ()), ("b", ("a",)), ("c", ("b",))):
        g.add(OpNode(name, "f", flops=1.0, hbm_bytes=4.0, out_bytes=4.0),
              *preds)
    faces = FaceCache([StageDef("f", lambda x: x + 1, (0,), (0,))],
                      bank_grid)
    ex = PlanExecutor(g, {"a": "xeon", "b": "xeon", "c": "xeon"}, faces,
                      kind_of=lambda n: "f")

    def bind(name, env):
        prev = {"b": "a", "c": "b"}.get(name)
        return (env[prev],) if prev else (jnp.zeros((2,)),)

    env = ex.run(bind, keep={"c"})
    assert set(env) == {"c"}                     # a, b freed when dead
    env = ex.run(bind, keep={"a", "c"})
    assert set(env) == {"a", "c"}                # keep pins survivors
    assert float(env["c"][0]) == 3.0


def test_prefill_skeleton_matches_costed_dag():
    """`prefill_dag(costed=False)` must agree with the costed DAG on node
    names, edges, and topological order — it is what the executor groups
    a ragged prompt's timeline from, so drift here would silently change
    the executed schedule."""
    d = workloads.REDUCED_DIMS
    costed = workloads.prefill_dag(d, prefill_len=11, chunk=4)
    skel = workloads.prefill_dag(d, prefill_len=11, chunk=4, costed=False)
    assert set(skel.nodes) == set(costed.nodes)
    assert skel.edges == costed.edges
    assert skel.topo_order() == costed.topo_order()
    assert all(n.flops == 0 and n.hbm_bytes == 0
               for n in skel.nodes.values())
    # same launch-group order under the same assignment
    p = plan(costed)
    a = {n: p.assignment[n] for n in costed.nodes}
    stub = evaluate(skel, a)
    got = [(g.device, g.nodes) for g in make_schedule(skel, stub).groups]
    want = [(g.device, g.nodes) for g in make_schedule(costed, p).groups]
    assert got == want


# ------------------------------------------------------------------ #
# scheduler
# ------------------------------------------------------------------ #

def test_schedule_coalesces_launches(mixed_graph):
    sched = make_schedule(mixed_graph, plan(mixed_graph))
    assert sched.n_launches == 3               # pim / host / pim
    assert sched.overlapped_s <= sched.total_s
    assert sched.total_s <= sched.unbatched_s


def test_schedule_batches_parallel_transfers():
    """Two producer tensors entering one PIM group: one batched transfer
    (one setup) must beat two serial ones."""
    g = OpGraph("fanin", input_bytes=0.0)
    g.add(OpNode("p1", "x", 1e6, 1e8, 1e8))
    g.add(OpNode("p2", "x", 1e6, 1e8, 1e8), "p1")
    g.add(OpNode("sink", "x", 1e6, 1e8, 1e4,
                 ops={("add", "int32"): 1e6}), "p1", "p2")
    assignment = {"p1": "xeon", "p2": "xeon", "sink": "upmem_2556"}
    sched = make_schedule(g, evaluate(g, assignment))
    pim_group = sched.groups[-1]
    assert pim_group.n_in_tensors == 2
    assert pim_group.in_transfer_s < pim_group.serial_transfer_s
    assert sched.total_s < sched.unbatched_s


def test_transfer_hops_split_matches_transfer_time():
    """GPU<->DPU splits into (relay, final); single-hop paths have no
    relay; the two components always sum to the planner's charge."""
    nbytes = 1e8
    for src, dst in (("titan_v", "upmem_2556"), ("upmem_2556", "titan_v"),
                     ("xeon", "upmem_2556"), ("upmem_2556", "xeon"),
                     ("xeon", "titan_v"), ("xeon", "xeon")):
        relay, last = transfer_hops(src, dst, nbytes)
        assert relay + last == pytest.approx(transfer_time(src, dst, nbytes))
        two_hop = "titan_v" in (src, dst) and "upmem" in src + dst
        assert (relay > 0) == two_hop, (src, dst)


def test_schedule_does_not_overlap_host_relay_with_dpu_compute():
    """placement charges both hops of the GPU->DPU boundary; the overlap
    model may hide only the final (host->DPU) hop under DPU compute — the
    PCIe relay into host DRAM happens before any bytes reach the DPUs, so
    it is serialized in front of the overlap window."""
    g = OpGraph("relay", input_bytes=0.0)
    g.add(OpNode("gpu_stage", "x", 1e9, 1e8, 2e8,
                 ops={("mul", "float"): 1e6}))
    g.add(OpNode("pim_stage", "x", 1e6, 2e8, 1e4,
                 ops={("add", "int32"): 1e10}), "gpu_stage")
    assignment = {"gpu_stage": "titan_v", "pim_stage": "upmem_2556"}
    sched = make_schedule(g, evaluate(g, assignment))
    pim_group = sched.groups[-1]
    relay, last = transfer_hops("titan_v", "upmem_2556", 2e8)
    assert pim_group.relay_s == pytest.approx(relay)
    # pinned formula: relay serialized, only the final hop double-buffers
    assert pim_group.overlapped_s == pytest.approx(
        relay + max(pim_group.compute_s,
                    pim_group.in_transfer_s - relay) + pim_group.launch_s)
    # the relay is NOT hidden: overlapped strictly exceeds the naive
    # max(compute, whole-transfer) model whenever compute dominates
    assert pim_group.compute_s > pim_group.in_transfer_s - relay
    naive = max(pim_group.compute_s, pim_group.in_transfer_s) \
        + pim_group.launch_s
    assert pim_group.overlapped_s > naive
    # host-sourced transfers still have no relay component
    host_g = OpGraph("noreplay", input_bytes=0.0)
    host_g.add(OpNode("h", "x", 1e6, 1e8, 2e8))
    host_g.add(OpNode("p", "x", 1e6, 2e8, 1e4,
                      ops={("add", "int32"): 5e7}), "h")
    sched2 = make_schedule(host_g, evaluate(
        host_g, {"h": "xeon", "p": "upmem_2556"}))
    assert sched2.groups[-1].relay_s == 0.0


# ------------------------------------------------------------------ #
# runtime: hybrid execution matches the single-device reference
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def small_mixed():
    return workloads.mixed_pipeline(m=256, concrete=True)


def test_runtime_matches_reference_planned(small_mixed, bank_grid):
    pipe = small_mixed
    rep = execute(pipe, plan(pipe.graph()), bank_grid)
    assert rep.matches and rep.max_abs_err == 0.0


def test_runtime_matches_reference_forced_hybrid(small_mixed, bank_grid):
    """Force both execution faces regardless of what the planner picks."""
    pipe = small_mixed
    g = pipe.graph()
    forced = evaluate(g, {n: ("upmem_2556" if i % 2 else "xeon")
                          for i, n in enumerate(g.nodes)})
    rep = execute(pipe, forced, bank_grid)
    assert rep.matches
    assert set(rep.stage_devices.values()) == {"xeon", "upmem_2556"}


def test_decode_runtime_matches_reference(bank_grid):
    pipe = workloads.decode_pipeline(concrete=True)
    g = pipe.graph()
    forced = evaluate(g, {n: ("upmem_2556" if i % 3 else "xeon")
                          for i, n in enumerate(g.nodes)})
    rep = execute(pipe, forced, bank_grid)
    assert rep.matches and rep.max_abs_err == 0.0
    assert jnp.asarray(rep.result).shape[-1] == workloads.REDUCED_DIMS.vocab


def test_phase_discipline_enforced(small_mixed, bank_grid):
    assert check_phase_discipline(small_mixed, bank_grid) == 4
    # a stage whose "local" body communicates must be rejected
    leaky = Pipeline("leaky", [
        Stage("bad", lambda x: x,
              local_fn=lambda x: jax.lax.psum(x, "banks"))],
        jnp.ones((8,), jnp.int32))
    with pytest.raises(Exception):
        check_phase_discipline(leaky, bank_grid)


@pytest.mark.slow
def test_hybrid_execution_on_two_banks():
    """Multi-bank execution in a subprocess (dry-run isolation rule):
    both pipelines must stay exact when shards are real."""
    import subprocess, sys, os, pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    code = (
        "import jax\n"
        "from repro.core.bank_parallel import BankGrid, make_bank_mesh\n"
        "from repro.dispatch import workloads\n"
        "from repro.dispatch.placement import evaluate\n"
        "from repro.dispatch.runtime import execute\n"
        "grid = BankGrid(make_bank_mesh())\n"
        "assert grid.n_banks == 2\n"
        "for pipe in (workloads.mixed_pipeline(m=256),\n"
        "             workloads.decode_pipeline()):\n"
        "    g = pipe.graph()\n"
        "    plan = evaluate(g, {n: 'upmem_2556' for n in g.nodes})\n"
        "    assert execute(pipe, plan, grid).matches\n"
        "print('OK')\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=f"{root / 'src'}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_divergence_detected(small_mixed, bank_grid):
    """A plan whose execution diverges from the reference must raise."""
    pipe = small_mixed
    broken = Pipeline(pipe.name, list(pipe.stages), pipe.x)
    s = broken.stages[1]
    broken.stages[1] = Stage(s.name, s.fn, s.params,
                             pim=lambda grid, x, b: x + b + 1)
    g = pipe.graph()
    forced = evaluate(g, {n: "upmem_2556" for n in g.nodes})
    with pytest.raises(AssertionError, match="diverged"):
        execute(broken, forced, bank_grid)
