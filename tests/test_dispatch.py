"""repro.dispatch: planner, scheduler, and hybrid runtime.

Covers the ISSUE-1 acceptance gates: the suitability split matches the
Fig.-4 grouping, boundary transfer costs make flip-flop placements lose,
hybrid plans strictly beat both pure placements on the mixed PrIM pipeline
and the LM decode step, and executed plans match the single-device
reference."""

import jax
import jax.numpy as jnp
import pytest

from repro import prim
from repro.dispatch import workloads
from repro.dispatch.graph import OpGraph, OpNode, chain_graph, ops_from_hlo
from repro.dispatch.placement import (compare_plans, evaluate, plan,
                                      pure_plan)
from repro.dispatch.runtime import (Pipeline, Stage, check_phase_discipline,
                                    execute)
from repro.dispatch.schedule import make_schedule


@pytest.fixture(scope="module")
def mixed_graph():
    return workloads.mixed_pipeline(m=4096, concrete=False).graph()


@pytest.fixture(scope="module")
def decode_graph():
    return workloads.decode_pipeline(workloads.DecodeDims(),
                                     concrete=False).graph()


# ------------------------------------------------------------------ #
# graph building
# ------------------------------------------------------------------ #

def test_ops_from_hlo_counts_elements():
    n, k, m = 32, 16, 8
    x = jnp.ones((n, k), jnp.float32)
    w = jnp.ones((k, m), jnp.float32)
    text = jax.jit(lambda a, b: a @ b).lower(x, w).compile().as_text()
    ops = ops_from_hlo(text)
    assert ops[("mul", "float")] == pytest.approx(n * k * m)
    assert ops[("add", "float")] == pytest.approx(n * k * m)

    text = jax.jit(lambda a, b: a + b).lower(
        jnp.ones((64,), jnp.int32), jnp.ones((64,), jnp.int32)) \
        .compile().as_text()
    assert ops_from_hlo(text).get(("add", "int32")) == pytest.approx(64)


def test_from_hlo_instruction_graph():
    """Fine-grained graph from a compiled module: dot / fusion / reduce
    instructions become costed nodes wired by operand edges."""
    def f(x, w):
        h = jnp.maximum(x @ w, 0)
        return jnp.sum(h * h)

    text = jax.jit(f).lower(jnp.ones((64, 32), jnp.float32),
                            jnp.ones((32, 16), jnp.float32)) \
        .compile().as_text()
    g = OpGraph.from_hlo(text, "relu-gemv")
    kinds = {n.kind for n in g.nodes.values()}
    assert "dot" in kinds
    dot = next(n for n in g.nodes.values() if n.kind == "dot")
    assert dot.flops == pytest.approx(2 * 64 * 32 * 16)
    assert g.is_chain and plan(g).method == "dp"
    assert g.input_bytes == pytest.approx(4 * (64 * 32 + 32 * 16))


def test_node_takeaway_properties(mixed_graph):
    stream = mixed_graph.nodes["va.add"]
    assert stream.complex_frac == 0.0          # KT2: pure add
    assert stream.oi < 1.0                     # KT1: streaming
    assert stream.exchange_bytes == 0.0        # KT3: bank-local
    shuffle = mixed_graph.nodes["roll.rows"]
    assert shuffle.comm_ratio > 0.4            # KT3: exchange-heavy
    square = mixed_graph.nodes["ts.square"]
    assert square.complex_frac == 1.0          # all multiplies


def test_chain_detection(mixed_graph, decode_graph):
    assert mixed_graph.is_chain and decode_graph.is_chain
    dag = OpGraph("dag")
    a = dag.add(OpNode("a", "x", 1e6, 1e6, 1e3))
    dag.add(OpNode("b", "x", 1e6, 1e6, 1e3), "a")
    dag.add(OpNode("c", "x", 1e6, 1e6, 1e3), "a")
    dag.add(OpNode("d", "x", 1e6, 1e6, 1e3), "b", "c")
    assert not dag.is_chain
    assert plan(dag).method == "greedy"
    assert plan(chain_graph("ch", [OpNode("e", "x", 1e6, 1e6, 1e3)])) \
        .method == "dp"


# ------------------------------------------------------------------ #
# placement: the paper's grouping, and DP optimality
# ------------------------------------------------------------------ #

def test_planner_matches_fig4_grouping():
    """Suitable (group-1) workloads plan onto PIM; unsuitable (group-2)
    workloads get a better device than PIM (the recovery)."""
    for counts in prim.all_ref_counts():
        g = workloads.prim_graph(counts)
        hyb = plan(g, devices=("xeon", "titan_v", "upmem_2556"))
        pick = hyb.assignment[counts.name]
        if counts.pim_suitable:
            assert pick != "xeon", counts.name       # PIM-wing of Fig. 4
        else:
            assert pick != "upmem_2556", counts.name
            assert hyb.total_s < pure_plan(g, "upmem_2556").total_s, \
                counts.name


def test_node_time_agrees_with_perf_model():
    """The planner's per-node costs intentionally use the same arithmetic
    as the Fig.-4 model; this pins the equivalence so a recalibration of
    one cannot silently drift from the other."""
    from repro.core.perf_model import time_on_host, time_on_pim
    from repro.core.pim_model import UPMEM_2556, XEON_E3_1240
    from repro.dispatch.placement import node_time
    for counts in prim.all_ref_counts():
        node = workloads.node_from_counts(counts)
        pim = time_on_pim(counts, UPMEM_2556)
        assert node_time(node, "upmem_2556") == pytest.approx(
            pim.total_s - UPMEM_2556.launch_overhead_s), counts.name
        host = time_on_host(counts, XEON_E3_1240, "xeon")
        assert node_time(node, "xeon") == pytest.approx(host.total_s), \
            counts.name


def test_suitable_workloads_prefer_pim_over_cpu():
    for counts in prim.all_ref_counts():
        if counts.pim_suitable:
            g = workloads.prim_graph(counts)
            assert pure_plan(g, "upmem_2556").total_s \
                < pure_plan(g, "xeon").total_s, counts.name


def test_boundary_costs_make_flipflop_lose(mixed_graph):
    """DP optimality spot-check: alternating devices every operator pays
    boundary transfers + launches and must lose to the planned hybrid."""
    best = plan(mixed_graph)
    names = list(mixed_graph.nodes)
    flip = {n: ("upmem_2556" if i % 2 else "xeon")
            for i, n in enumerate(names)}
    flipped = evaluate(mixed_graph, flip)
    assert best.total_s < flipped.total_s
    assert flipped.transfer_s > best.transfer_s
    # and against every pure plan in its device set (DP explores those)
    for dev in ("xeon", "upmem_2556"):
        assert best.total_s <= pure_plan(mixed_graph, dev).total_s + 1e-12


def test_mixed_hybrid_strictly_beats_both_pures(mixed_graph):
    plans = compare_plans(mixed_graph)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s
    assert plans["hybrid"].is_hybrid
    # the split is the paper's: streams bank-parallel, shuffles on host
    a = plans["hybrid"].assignment
    assert a["va.add"] == "upmem_2556" and a["ts.square"] == "upmem_2556"
    assert a["trns.fwd"] == "xeon" and a["roll.rows"] == "xeon"


def test_decode_hybrid_strictly_beats_both_pures(decode_graph):
    plans = compare_plans(decode_graph)
    assert plans["hybrid"].total_s < plans["pure_cpu"].total_s
    assert plans["hybrid"].total_s < plans["pure_pim"].total_s
    a = plans["hybrid"].assignment
    # KV-cache attention bank-parallel; float-mul weight GEMVs on host (KT2)
    assert a["attn0"] == "upmem_2556"
    assert a["qkv0"] == "xeon" and a["up0"] == "xeon"


# ------------------------------------------------------------------ #
# scheduler
# ------------------------------------------------------------------ #

def test_schedule_coalesces_launches(mixed_graph):
    sched = make_schedule(mixed_graph, plan(mixed_graph))
    assert sched.n_launches == 3               # pim / host / pim
    assert sched.overlapped_s <= sched.total_s
    assert sched.total_s <= sched.unbatched_s


def test_schedule_batches_parallel_transfers():
    """Two producer tensors entering one PIM group: one batched transfer
    (one setup) must beat two serial ones."""
    g = OpGraph("fanin", input_bytes=0.0)
    g.add(OpNode("p1", "x", 1e6, 1e8, 1e8))
    g.add(OpNode("p2", "x", 1e6, 1e8, 1e8), "p1")
    g.add(OpNode("sink", "x", 1e6, 1e8, 1e4,
                 ops={("add", "int32"): 1e6}), "p1", "p2")
    assignment = {"p1": "xeon", "p2": "xeon", "sink": "upmem_2556"}
    sched = make_schedule(g, evaluate(g, assignment))
    pim_group = sched.groups[-1]
    assert pim_group.n_in_tensors == 2
    assert pim_group.in_transfer_s < pim_group.serial_transfer_s
    assert sched.total_s < sched.unbatched_s


# ------------------------------------------------------------------ #
# runtime: hybrid execution matches the single-device reference
# ------------------------------------------------------------------ #

@pytest.fixture(scope="module")
def small_mixed():
    return workloads.mixed_pipeline(m=256, concrete=True)


def test_runtime_matches_reference_planned(small_mixed, bank_grid):
    pipe = small_mixed
    rep = execute(pipe, plan(pipe.graph()), bank_grid)
    assert rep.matches and rep.max_abs_err == 0.0


def test_runtime_matches_reference_forced_hybrid(small_mixed, bank_grid):
    """Force both execution faces regardless of what the planner picks."""
    pipe = small_mixed
    g = pipe.graph()
    forced = evaluate(g, {n: ("upmem_2556" if i % 2 else "xeon")
                          for i, n in enumerate(g.nodes)})
    rep = execute(pipe, forced, bank_grid)
    assert rep.matches
    assert set(rep.stage_devices.values()) == {"xeon", "upmem_2556"}


def test_decode_runtime_matches_reference(bank_grid):
    pipe = workloads.decode_pipeline(concrete=True)
    g = pipe.graph()
    forced = evaluate(g, {n: ("upmem_2556" if i % 3 else "xeon")
                          for i, n in enumerate(g.nodes)})
    rep = execute(pipe, forced, bank_grid)
    assert rep.matches and rep.max_abs_err == 0.0
    assert jnp.asarray(rep.result).shape[-1] == workloads.REDUCED_DIMS.vocab


def test_phase_discipline_enforced(small_mixed, bank_grid):
    assert check_phase_discipline(small_mixed, bank_grid) == 4
    # a stage whose "local" body communicates must be rejected
    leaky = Pipeline("leaky", [
        Stage("bad", lambda x: x,
              local_fn=lambda x: jax.lax.psum(x, "banks"))],
        jnp.ones((8,), jnp.int32))
    with pytest.raises(Exception):
        check_phase_discipline(leaky, bank_grid)


@pytest.mark.slow
def test_hybrid_execution_on_two_banks():
    """Multi-bank execution in a subprocess (dry-run isolation rule):
    both pipelines must stay exact when shards are real."""
    import subprocess, sys, os, pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    code = (
        "import jax\n"
        "from repro.core.bank_parallel import BankGrid, make_bank_mesh\n"
        "from repro.dispatch import workloads\n"
        "from repro.dispatch.placement import evaluate\n"
        "from repro.dispatch.runtime import execute\n"
        "grid = BankGrid(make_bank_mesh())\n"
        "assert grid.n_banks == 2\n"
        "for pipe in (workloads.mixed_pipeline(m=256),\n"
        "             workloads.decode_pipeline()):\n"
        "    g = pipe.graph()\n"
        "    plan = evaluate(g, {n: 'upmem_2556' for n in g.nodes})\n"
        "    assert execute(pipe, plan, grid).matches\n"
        "print('OK')\n")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=f"{root / 'src'}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_divergence_detected(small_mixed, bank_grid):
    """A plan whose execution diverges from the reference must raise."""
    pipe = small_mixed
    broken = Pipeline(pipe.name, list(pipe.stages), pipe.x)
    s = broken.stages[1]
    broken.stages[1] = Stage(s.name, s.fn, s.params,
                             pim=lambda grid, x, b: x + b + 1)
    g = pipe.graph()
    forced = evaluate(g, {n: "upmem_2556" for n in g.nodes})
    with pytest.raises(AssertionError, match="diverged"):
        execute(broken, forced, bank_grid)
