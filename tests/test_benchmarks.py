"""Smoke tests for the benchmarks/run.py modules, so the benches don't
rot: each module's `run(report)` must complete and its own embedded
assertions must hold. The compile-bound ones are `slow` (tier-1 skips
them; CI's full job and the bench invocation itself cover them)."""

import pytest

from benchmarks.run import Report


def test_scaling_bench_smoke(capsys):
    from benchmarks import scaling_bench
    scaling_bench.run(Report())
    out = capsys.readouterr().out
    assert "Strong scaling" in out and "VA" in out


@pytest.mark.slow
def test_suitability_bench_smoke(capsys):
    from benchmarks import suitability_bench
    suitability_bench.run(Report())
    out = capsys.readouterr().out
    assert "decode" in out


@pytest.mark.slow
def test_dispatch_bench_smoke(capsys):
    from benchmarks import dispatch_bench
    dispatch_bench.run(Report())
    out = capsys.readouterr().out
    assert "hybrid" in out and "allclose" in out.lower()
    assert "overlapped" in out          # sweep 5: the prefill DAG
    assert "MoE" in out                 # sweep 6: the exchange phase


def test_dispatch_bench_quick_smoke(capsys):
    """The CI coverage job's `benchmarks.run dispatch_bench --quick`
    path: the reduced prefill-DAG sweep plus the reduced MoE
    exchange-phase sweep, with their acceptance asserts."""
    from benchmarks import dispatch_bench
    dispatch_bench.run(Report(), quick=True)
    out = capsys.readouterr().out
    assert "prefill" in out.lower() and "objective=overlapped" in out
    assert "MoE" in out and "exchange" in out.lower()
