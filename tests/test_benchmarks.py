"""Smoke tests for the benchmarks/run.py modules, so the benches don't
rot: each module's `run(report)` must complete and its own embedded
assertions must hold. The compile-bound ones are `slow` (tier-1 skips
them; CI's full job and the bench invocation itself cover them)."""

import pytest

from benchmarks.run import Report


def test_scaling_bench_smoke(capsys):
    from benchmarks import scaling_bench
    scaling_bench.run(Report())
    out = capsys.readouterr().out
    assert "Strong scaling" in out and "VA" in out


@pytest.mark.slow
def test_suitability_bench_smoke(capsys):
    from benchmarks import suitability_bench
    suitability_bench.run(Report())
    out = capsys.readouterr().out
    assert "decode" in out


@pytest.mark.slow
def test_dispatch_bench_smoke(capsys):
    from benchmarks import dispatch_bench
    dispatch_bench.run(Report())
    out = capsys.readouterr().out
    assert "hybrid" in out and "allclose" in out.lower()
    assert "overlapped" in out          # sweep 5: the prefill DAG
    assert "MoE" in out                 # sweep 6: the exchange phase


def test_dispatch_bench_quick_smoke(capsys):
    """The CI coverage job's `benchmarks.run dispatch_bench --quick`
    path: the reduced prefill-DAG sweep plus the reduced MoE
    exchange-phase sweep, with their acceptance asserts."""
    from benchmarks import dispatch_bench
    dispatch_bench.run(Report(), quick=True)
    out = capsys.readouterr().out
    assert "prefill" in out.lower() and "objective=overlapped" in out
    assert "MoE" in out and "exchange" in out.lower()


@pytest.mark.slow
def test_gateway_bench_quick_smoke(capsys, tmp_path):
    """The CI tier-1 job's `benchmarks.run gateway_bench --quick --trace`
    path: churn sweep (plan-cache hit rate), overload goodput, budget-1
    gate on both engines, and the traced dispatch run feeding the
    planner-fidelity gate."""
    from benchmarks import gateway_bench
    out_json = tmp_path / "gw_trace.json"
    gateway_bench.run(Report(), quick=True, trace_out=str(out_json))
    out = capsys.readouterr().out
    assert "hit rate" in out and "goodput" in out
    assert "budget" in out.lower() and "fidelity" in out.lower()
    assert out_json.exists()


@pytest.mark.slow
def test_gateway_bench_smoke(capsys):
    """Full mode adds the jit steady-state sweep (SLO attainment under
    seeded Poisson) and the paper-scale fleet projection."""
    from benchmarks import gateway_bench
    gateway_bench.run(Report())
    out = capsys.readouterr().out
    assert "Steady-state" in out and "p99" in out
    assert "req/day" in out and "fleet" in out.lower()
