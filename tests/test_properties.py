"""Hypothesis property tests on the system's invariants: cache slot math,
compaction, scan/prefix structure, sharding-spec divisibility, optimizer
algebra."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev extra")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.cache import slot_positions, write_decode
from repro.models.sharding import Policy, Shardings
from repro.prim.common import assemble_compact, local_compact
from repro.train.optimizer import HParams, schedule

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


# --------------------------------------------------------------------- #
# ring-cache slot math
# --------------------------------------------------------------------- #

@given(st.integers(1, 200), st.integers(1, 64))
def test_slot_positions_invariants(count, width):
    pos = np.asarray(slot_positions(jnp.int32(count), width))
    # every reported position is either -1 or in [0, count)
    assert ((pos == -1) | ((pos >= 0) & (pos < count))).all()
    # the newest `min(count, width)` positions are all present
    want = set(range(max(0, count - width), count))
    assert set(pos[pos >= 0].tolist()) == want
    # slot s holds a position congruent to s mod width
    for s, p in enumerate(pos):
        if p >= 0:
            assert p % width == s


@given(st.integers(2, 16), st.integers(1, 40), st.integers(2, 8))
def test_write_decode_per_row_matches_scalar(width, index, batch):
    """Vector index with equal entries == scalar index write."""
    kvh, hd = 2, 4
    kv = {"k": jnp.zeros((batch, width, kvh, hd)),
          "v": jnp.zeros((batch, width, kvh, hd))}
    k_new = jnp.ones((batch, 1, kvh, hd))
    v_new = 2 * k_new
    a = write_decode(kv, k_new, v_new, jnp.int32(index), width)
    b = write_decode(kv, k_new, v_new,
                     jnp.full((batch,), index, jnp.int32), width)
    np.testing.assert_array_equal(np.asarray(a["k"]), np.asarray(b["k"]))
    np.testing.assert_array_equal(np.asarray(a["v"]), np.asarray(b["v"]))


# --------------------------------------------------------------------- #
# compaction (SEL/UNI building blocks)
# --------------------------------------------------------------------- #

@given(st.lists(st.integers(-100, 100), min_size=1, max_size=64),
       st.lists(st.booleans(), min_size=1, max_size=64))
def test_local_compact_is_stable_filter(vals, keeps):
    n = min(len(vals), len(keeps))
    v = jnp.asarray(vals[:n], jnp.int32)
    k = jnp.asarray(keeps[:n])
    comp, cnt = local_compact(v, k)
    want = [x for x, kk in zip(vals[:n], keeps[:n]) if kk]
    assert int(cnt) == len(want)
    assert np.asarray(comp)[:len(want)].tolist() == want


@given(st.integers(1, 6), st.integers(1, 10))
def test_assemble_compact_roundtrip(banks, per):
    rng = np.random.RandomState(banks * 100 + per)
    parts = rng.randint(0, 100, (banks, per)).astype(np.int32)
    counts = rng.randint(0, per + 1, (banks,)).astype(np.int32)
    total = int(counts.sum())
    out = np.asarray(assemble_compact(jnp.asarray(parts),
                                      jnp.asarray(counts), max(total, 1)))
    want = np.concatenate([parts[i, :counts[i]] for i in range(banks)]) \
        if total else np.zeros((1,), np.int32)
    np.testing.assert_array_equal(out[:total], want[:total])


# --------------------------------------------------------------------- #
# sharding spec algebra
# --------------------------------------------------------------------- #

@given(st.integers(1, 64), st.integers(1, 8))
def test_spec_never_breaks_divisibility(dim, axis_size):
    mesh = jax.make_mesh((1,), ("model",))

    class FakeShd(Shardings):
        def __init__(self):
            super().__init__(mesh)
            self._axis_size = {"model": axis_size}
    shd = FakeShd()
    spec = shd.spec((dim,), ("tp",), "t")
    entries = tuple(spec)
    if dim % axis_size != 0:
        assert entries == () or entries[0] is None
    # a sharded dim always divides
    if entries and entries[0] is not None:
        assert dim % axis_size == 0


# --------------------------------------------------------------------- #
# schedule / optimizer algebra
# --------------------------------------------------------------------- #

@given(st.integers(0, 10_000))
def test_schedule_bounded(step):
    hp = HParams(lr=1e-3, warmup_steps=100, total_steps=10_000)
    v = float(schedule(step, hp))
    assert 0.0 <= v <= hp.lr * (1 + 1e-6)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=16))
def test_clip_never_increases_norm(vals):
    from repro.train.optimizer import clip_by_global_norm, global_norm
    g = {"x": jnp.asarray(vals, jnp.float32)}
    clipped, pre = clip_by_global_norm(g, 1.0)
    post = float(global_norm(clipped))
    assert post <= max(float(pre), 1.0) + 1e-4
    assert post <= 1.0 + 1e-4


# --------------------------------------------------------------------- #
# prim phase structure: SSA == RSS == cumsum for any input
# --------------------------------------------------------------------- #

@given(st.lists(st.integers(-50, 50), min_size=1, max_size=128))
def test_scan_variants_agree(vals):
    from repro import prim
    from repro.core.bank_parallel import BankGrid, make_bank_mesh
    grid = BankGrid(make_bank_mesh())
    x = jnp.asarray(vals, jnp.int32)
    a = prim.WORKLOADS["SCAN-SSA"].run_pim(grid, x)
    b = prim.WORKLOADS["SCAN-RSS"].run_pim(grid, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.cumsum(vals))
