"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def k(i):
    return jax.random.fold_in(KEY, i)


@pytest.mark.parametrize("n", [128, 4096, 100_001, 262_144])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32, jnp.bfloat16])
def test_va(n, dtype):
    if dtype == jnp.bfloat16:
        a = jax.random.normal(k(0), (n,), jnp.float32).astype(dtype)
        b = jax.random.normal(k(1), (n,), jnp.float32).astype(dtype)
    else:
        a = jax.random.randint(k(0), (n,), -99, 99).astype(dtype)
        b = jax.random.randint(k(1), (n,), -99, 99).astype(dtype)
    np.testing.assert_allclose(np.asarray(ops.va(a, b), np.float32),
                               np.asarray(ref.va(a, b), np.float32))


@pytest.mark.parametrize("m,kk", [(256, 512), (300, 700), (1024, 1024),
                                  (8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemv(m, kk, dtype):
    A = (jax.random.normal(k(2), (m, kk), jnp.float32) / 8).astype(dtype)
    x = (jax.random.normal(k(3), (kk,), jnp.float32) / 8).astype(dtype)
    got = np.asarray(ops.gemv(A, x), np.float32)
    want = np.asarray(ref.gemv(A, x), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("n", [65_536, 70_000, 128])
def test_reduction(n):
    x = jax.random.normal(k(4), (n,), jnp.float32)
    np.testing.assert_allclose(float(ops.reduction(x)),
                               float(ref.reduction(x)), rtol=1e-5)


@pytest.mark.parametrize("n", [8192, 50_000, 128])
def test_scan(n):
    x = jax.random.randint(k(5), (n,), -10, 10).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.scan(x)),
                               np.asarray(jnp.cumsum(x)),
                               rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("n,bins", [(30_000, 256), (8192, 1024),
                                    (4096, 4096)])
def test_histogram(n, bins):
    x = jax.random.randint(k(6), (n,), 0, 1 << 12, jnp.uint32)
    got = np.asarray(ops.histogram(x, bins))
    want = np.asarray(ref.histogram(x, bins, 12))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == n


@pytest.mark.parametrize("n,m", [(5000, 8), (2048, 16), (512, 4)])
def test_ts(n, m):
    s = jax.random.randint(k(7), (n,), -100, 100, jnp.int32)
    q = jax.random.randint(k(8), (m,), -100, 100, jnp.int32)
    d, i = ops.ts_min(s, q)
    dr = ref.ts_dists(s, q)
    assert np.isclose(float(d), float(jnp.min(dr)))
    assert float(dr[int(i)]) == float(jnp.min(dr))


@pytest.mark.parametrize("m,n", [(128, 128), (200, 300), (512, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_transpose(m, n, dtype):
    A = jax.random.randint(k(9), (m, n), -99, 99).astype(dtype)
    np.testing.assert_array_equal(np.asarray(ops.transpose(A)),
                                  np.asarray(ref.trns(A)))


@pytest.mark.parametrize("b,h,kvh,hd,w,length", [
    (2, 8, 2, 64, 1000, 777),
    (1, 4, 4, 128, 512, 512),    # MHA, full cache
    (2, 16, 2, 64, 2048, 1),     # single valid slot
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kvh, hd, w, length, dtype):
    q = jax.random.normal(k(10), (b, h, hd), jnp.float32).astype(dtype)
    kc = jax.random.normal(k(11), (b, w, kvh, hd), jnp.float32).astype(dtype)
    vc = jax.random.normal(k(12), (b, w, kvh, hd), jnp.float32).astype(dtype)
    got = np.asarray(ops.decode_attention(q, kc, vc, jnp.int32(length)),
                     np.float32)
    want = np.asarray(ref.decode_attention(q, kc, vc, length), np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("ops_per_elem", [1, 4, 16])
def test_microbench_stream(ops_per_elem):
    x = jax.random.randint(k(13), (10_000,), 0, 100, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.stream_ops(x, ops_per_elem)),
        np.asarray(ref.microbench_stream(x, ops_per_elem)))


@pytest.mark.parametrize("sq,skv,h,kvh,hd,causal,window", [
    (300, 300, 4, 2, 64, True, 0),      # GQA, causal, padded seq
    (512, 512, 2, 2, 128, True, 64),    # sliding window
    (256, 700, 4, 1, 64, False, 0),     # cross-attention-like, padded kv
    (128, 512, 2, 2, 64, True, 32),     # window smaller than kv tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_fwd(sq, skv, h, kvh, hd, causal, window, dtype):
    q = jax.random.normal(k(20), (1, sq, h, hd), jnp.float32).astype(dtype)
    kk = jax.random.normal(k(21), (1, skv, kvh, hd),
                           jnp.float32).astype(dtype)
    v = jax.random.normal(k(22), (1, skv, kvh, hd),
                          jnp.float32).astype(dtype)
    got = np.asarray(ops.flash_attention(q, kk, v, causal=causal,
                                         window=window), np.float32)
    want = np.asarray(ref.flash_attention(q, kk, v, causal=causal,
                                          window=window), np.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
