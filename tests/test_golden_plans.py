"""Golden placement plans: planner regressions fail loudly.

The planner's output used to be asserted only through aggregate
inequalities (hybrid < pures), so a cost-model or planner change could
silently shift every placement while the inequalities kept passing. These
tests pin the exact plan — topo-ordered device sequence, stage boundaries,
method, and objective — for every `dispatch.workloads` pipeline, each of
the 16 PrIM one-operator graphs, the decode DAG, and the chunked prefill
DAGs, under BOTH planner objectives (`serial` and `overlapped`). Each
entry also pins the golden SCHEDULE: the launch-group order the unified
executor (`dispatch.executor.PlanExecutor`) actually walks, plus the
modeled `overlapped_s`/`pipelined_s` wall-clocks — so executor-timeline
drift is caught exactly like placement drift.

## The golden-plan workflow

`tests/golden_plans.json` is a reviewed artifact, not a cache. The test
fails whenever a planned placement differs from the pinned one; to accept
a change, regenerate and review:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_plans.py

then read the diff of tests/golden_plans.json like any other code change.

Regeneration is LEGITIMATE when the placement shift is the point of the
change you are making:

  * a cost-model recalibration (new measured bandwidths, DPU op costs,
    launch overheads) that deliberately moves operators;
  * a planner upgrade whose better optimum the old goldens predate (the
    new plan must cost <= the old one under the active objective);
  * adding cases: new graphs or planner knobs extend the file (existing
    entries must survive byte-identical).

It is a PLANNER REGRESSION — fix the code, do not regenerate — when
placements move although neither the cost model nor the planner was
intentionally changed; when the new plan's modeled total is *worse* than
the golden one; or when `method` falls off an exact rung (`dp`/`dag-dp`)
to a bounded one (`bnb`/`greedy`) for a graph that used to plan exactly.
(See also README.md §Golden plans.)
"""

from __future__ import annotations

import functools
import json
import os
import pathlib

import pytest

from repro.dispatch import workloads
from repro.dispatch.placement import plan
from repro.dispatch.schedule import make_schedule

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_plans.json"
REGEN = bool(os.environ.get("REGEN_GOLDEN"))


def _graph_builders():
    """name -> (graph builder, planner device set) — the shipped-graph
    registry (`workloads.shipped_graphs`), which is also what the
    planner-fidelity gate in tests/test_trace.py iterates; one entry per
    shipped graph, the objective variants below reuse these builds."""
    return workloads.shipped_graphs()


@functools.lru_cache(maxsize=None)
def _graph(name):
    build, _ = _graph_builders()[name]
    return build()


@functools.lru_cache(maxsize=None)
def _planned(name, objective):
    _, devices = _graph_builders()[name]
    return plan(_graph(name), devices=devices, objective=objective)


def _cases():
    """Golden case id -> (graph name, objective). Every shipped graph is
    pinned under the serial objective; the LM serving DAGs (where overlap
    has compute to hide transfers under) additionally pin the
    overlapped-objective plan."""
    cases = {}
    for name in _graph_builders():
        cases[name] = (name, "serial")
    for name in ("lm-decode-dag", "lm-prefill-dag",
                 "lm-prefill-dag-reduced", "lm-moe-decode-dag",
                 "lm-moe-decode-dag-reduced", "lm-moe-prefill-dag",
                 "lm-moe-prefill-dag-reduced", "lm-moe-decode-dag-int8",
                 "lm-moe-decode-dag-int8-reduced", "lm-moe-prefill-dag-int8",
                 "lm-moe-prefill-dag-int8-reduced",
                 # ISSUE-9: multi-rank device sets + cross-step DAGs
                 "lm-moe-decode-dag-reduced-ep2",
                 "lm-moe-decode-dag-int8-reduced-ep4",
                 "lm-decode-steps-dag-reduced",
                 "lm-moe-decode-steps-int8-reduced",
                 # ISSUE-10: sliding-window decode + banded prefill
                 "lm-decode-dag-swa4096", "lm-decode-dag-swa8-reduced",
                 "lm-moe-decode-dag-int8-swa4096",
                 "lm-moe-decode-dag-int8-swa8-reduced",
                 "lm-prefill-dag-swa4096-32k",
                 "lm-prefill-dag-swa8-reduced"):
        cases[f"{name}@overlapped"] = (name, "overlapped")
    return cases


def _snapshot(graph_name, objective):
    graph = _graph(graph_name)
    _, devices = _graph_builders()[graph_name]
    p = _planned(graph_name, objective)
    order = graph.topo_order()
    seq = [[n, p.assignment[n]] for n in order]
    boundaries = [i for i in range(1, len(order))
                  if p.assignment[order[i]] != p.assignment[order[i - 1]]]
    # the golden SCHEDULE: the executed launch-group order (device +
    # member count per group, exactly what PlanExecutor walks) plus the
    # modeled wall-clocks under both execution disciplines — executor
    # drift fails as loudly as placement drift
    sched = make_schedule(graph, p, pipelined=True)
    return {"method": p.method, "objective": p.objective,
            "devices": list(devices), "placement": seq,
            "stage_boundaries": boundaries,
            "schedule": {"groups": [[g.device, len(g.nodes)]
                                    for g in sched.groups],
                         "overlapped_s": sched.overlapped_s,
                         "pipelined_s": sched.pipelined_s}}


@pytest.fixture(scope="module")
def golden():
    if not GOLDEN_PATH.exists():
        if REGEN:               # bootstrapping: regenerate from scratch
            return {}
        pytest.skip("golden_plans.json missing — run with REGEN_GOLDEN=1")
    return json.loads(GOLDEN_PATH.read_text())


@pytest.mark.parametrize("name", sorted(_cases()))
def test_plan_matches_golden(name, golden, request):
    graph_name, objective = _cases()[name]
    snap = _snapshot(graph_name, objective)
    if REGEN:
        golden[name] = snap
        request.config._regen_golden = golden
        return
    assert name in golden, f"no golden entry for {name} (REGEN_GOLDEN=1)"
    want = golden[name]
    got_devs = dict(snap["placement"])
    want_devs = dict(want["placement"])
    moved = {n: (want_devs[n], got_devs[n]) for n in want_devs
             if got_devs.get(n) != want_devs[n]}
    assert not moved, (
        f"{name}: placements shifted (old -> new): {moved}; if intended, "
        "regenerate goldens and review the diff (see module docstring for "
        "when regeneration is legitimate vs a planner regression)")
    assert snap["method"] == want["method"]
    assert snap.get("objective", "serial") == want.get("objective", "serial")
    assert snap["stage_boundaries"] == want["stage_boundaries"]
    assert [n for n, _ in snap["placement"]] == \
        [n for n, _ in want["placement"]]
    got_s, want_s = snap["schedule"], want["schedule"]
    assert got_s["groups"] == want_s["groups"], (
        f"{name}: executed launch-group order drifted — the executor runs "
        "this timeline, so review like a placement change")
    assert got_s["overlapped_s"] == pytest.approx(want_s["overlapped_s"],
                                                  rel=1e-6)
    assert got_s["pipelined_s"] == pytest.approx(want_s["pipelined_s"],
                                                 rel=1e-6)


def test_goldens_cover_every_case(golden):
    missing = sorted(set(_cases()) - set(golden))
    assert not missing, f"stale golden file, missing: {missing}"


@pytest.mark.parametrize("graph_name", sorted(_graph_builders()))
def test_overlapped_never_worse_than_serial(graph_name):
    """The ISSUE-3 acceptance inequality over every shipped graph: the
    overlapped-objective plan never has a worse `Schedule.overlapped_s`
    than the serial-objective plan (the serial plan seeds the candidate
    set, so this is a structural guarantee — the assert keeps it from
    regressing)."""
    graph = _graph(graph_name)
    serial = _planned(graph_name, "serial")
    over = _planned(graph_name, "overlapped")
    serial_sched = make_schedule(graph, serial)
    assert over.overlapped_s <= serial_sched.overlapped_s + 1e-15


@pytest.fixture(scope="session", autouse=True)
def _write_regenerated(request):
    yield
    regen = getattr(request.config, "_regen_golden", None)
    if regen is not None:
        GOLDEN_PATH.write_text(json.dumps(regen, indent=1, sort_keys=True)
                               + "\n")
