"""Golden placement plans (ISSUE-2): planner regressions fail loudly.

The planner's output used to be asserted only through aggregate
inequalities (hybrid < pures), so a cost-model or planner change could
silently shift every placement while the inequalities kept passing. These
tests pin the exact plan — topo-ordered device sequence, stage boundaries,
and method — for every `dispatch.workloads` pipeline, each of the 16 PrIM
one-operator graphs, and the decode DAG.

When a placement shift is *intended* (recalibration, planner upgrade),
regenerate with:

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_plans.py

then review the diff of tests/golden_plans.json like any other code change.
"""

from __future__ import annotations

import json
import os
import pathlib

import pytest

from repro import prim
from repro.dispatch import workloads
from repro.dispatch.placement import plan

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden_plans.json"
REGEN = bool(os.environ.get("REGEN_GOLDEN"))

#: name -> (graph builder, planner device set)
TWO_DEV = ("xeon", "upmem_2556")
THREE_DEV = ("xeon", "titan_v", "upmem_2556")


def _cases():
    cases = {
        "prim-mixed": (
            lambda: workloads.mixed_pipeline(m=4096, concrete=False).graph(),
            TWO_DEV),
        "lm-decode-chain": (
            lambda: workloads.decode_pipeline(workloads.DecodeDims(),
                                              concrete=False).graph(),
            TWO_DEV),
        "lm-decode-dag": (
            lambda: workloads.decode_dag(workloads.DecodeDims()), TWO_DEV),
        "lm-decode-dag-kv-on-host": (
            lambda: workloads.decode_dag(workloads.DecodeDims(),
                                         kv_home="xeon"), TWO_DEV),
    }
    for counts in prim.all_ref_counts():
        cases[f"prim/{counts.name}"] = (
            (lambda c=counts: workloads.prim_graph(c)), THREE_DEV)
    return cases


def _snapshot(graph, devices):
    p = plan(graph, devices=devices)
    order = graph.topo_order()
    seq = [[n, p.assignment[n]] for n in order]
    boundaries = [i for i in range(1, len(order))
                  if p.assignment[order[i]] != p.assignment[order[i - 1]]]
    return {"method": p.method, "devices": list(devices),
            "placement": seq, "stage_boundaries": boundaries}


@pytest.fixture(scope="module")
def golden():
    if not GOLDEN_PATH.exists():
        if REGEN:               # bootstrapping: regenerate from scratch
            return {}
        pytest.skip("golden_plans.json missing — run with REGEN_GOLDEN=1")
    return json.loads(GOLDEN_PATH.read_text())


@pytest.mark.parametrize("name", sorted(_cases()))
def test_plan_matches_golden(name, golden, request):
    build, devices = _cases()[name]
    snap = _snapshot(build(), devices)
    if REGEN:
        golden[name] = snap
        request.config._regen_golden = golden
        return
    assert name in golden, f"no golden entry for {name} (REGEN_GOLDEN=1)"
    want = golden[name]
    got_devs = dict(snap["placement"])
    want_devs = dict(want["placement"])
    moved = {n: (want_devs[n], got_devs[n]) for n in want_devs
             if got_devs.get(n) != want_devs[n]}
    assert not moved, (
        f"{name}: placements shifted (old -> new): {moved}; if intended, "
        "regenerate goldens and review the diff")
    assert snap["method"] == want["method"]
    assert snap["stage_boundaries"] == want["stage_boundaries"]
    assert [n for n, _ in snap["placement"]] == \
        [n for n, _ in want["placement"]]


def test_goldens_cover_every_case(golden):
    missing = sorted(set(_cases()) - set(golden))
    assert not missing, f"stale golden file, missing: {missing}"


@pytest.fixture(scope="session", autouse=True)
def _write_regenerated(request):
    yield
    regen = getattr(request.config, "_regen_golden", None)
    if regen is not None:
        GOLDEN_PATH.write_text(json.dumps(regen, indent=1, sort_keys=True)
                               + "\n")
