"""Public-API docstring gate for `repro.dispatch` and `repro.serve`.

Every symbol those packages export through their `__init__.py` must carry
a docstring (the API contract states units — seconds, bytes — and the
device-name vocabulary), and so must the public methods/properties of
exported classes. CI additionally runs `interrogate` over the two
packages (see `[tool.interrogate]` in pyproject.toml and the coverage
job in .github/workflows/ci.yml); this test keeps the same gate
dependency-free for the tier-1 run.
"""

from __future__ import annotations

import importlib
import inspect

import pytest

PACKAGES = ("repro.dispatch", "repro.dispatch.trace", "repro.serve")


def _exports(pkg_name):
    pkg = importlib.import_module(pkg_name)
    for name, obj in sorted(vars(pkg).items()):
        if name.startswith("_") or inspect.ismodule(obj):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if not getattr(obj, "__module__", "").startswith("repro."):
            continue                     # re-exported third-party symbol
        yield name, obj


@pytest.mark.parametrize("pkg_name", PACKAGES)
def test_every_exported_symbol_documented(pkg_name):
    missing = [name for name, obj in _exports(pkg_name)
               if len((obj.__doc__ or "").strip()) < 20]
    assert not missing, (
        f"{pkg_name} exports without a (substantive) docstring: {missing} "
        "— state what it does, the units (seconds / bytes), and the "
        "device-name vocabulary where applicable")


@pytest.mark.parametrize("pkg_name", PACKAGES)
def test_every_public_method_documented(pkg_name):
    missing = []
    for cls_name, cls in _exports(pkg_name):
        if not inspect.isclass(cls):
            continue
        for mname, m in vars(cls).items():
            if mname.startswith("_"):
                continue
            fn = m.fget if isinstance(m, property) else m
            if not inspect.isfunction(fn):
                continue
            if not (fn.__doc__ or "").strip():
                missing.append(f"{cls_name}.{mname}")
    assert not missing, (
        f"{pkg_name} public methods without docstrings: {missing}")


def test_submodules_documented():
    """Every module in the two packages carries a module docstring."""
    import pkgutil
    missing = []
    for pkg_name in PACKAGES:
        pkg = importlib.import_module(pkg_name)
        assert (pkg.__doc__ or "").strip()
        for info in pkgutil.iter_modules(pkg.__path__):
            mod = importlib.import_module(f"{pkg_name}.{info.name}")
            if not (mod.__doc__ or "").strip():
                missing.append(mod.__name__)
    assert not missing, f"modules without docstrings: {missing}"


def test_cost_api_states_units():
    """The planner/scheduler cost API must state its units: the
    seconds-returning functions say 'seconds', byte-denominated arguments
    say 'bytes' — the unit vocabulary README/DESIGN promise."""
    from repro.dispatch import (kv_migration_time, node_time, placed_time,
                                transfer_hops, transfer_time)
    for fn in (node_time, placed_time, transfer_time, transfer_hops,
               kv_migration_time):
        doc = fn.__doc__.lower()
        assert "seconds" in doc, fn.__name__
    for fn in (transfer_time, transfer_hops):
        assert "nbytes" in inspect.signature(fn).parameters, fn.__name__
