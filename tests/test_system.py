"""End-to-end behaviour: the paper's full methodology pipeline on one
workload — run bank-parallel, characterize, score, compare — plus the
LM stack smoke path the framework wraps around it."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import prim
from repro.core.bank_parallel import BankGrid, make_bank_mesh
from repro.core.hlo_analysis import analyze_hlo
from repro.core.perf_model import compare
from repro.core.roofline import roofline_from_analysis
from repro.core.suitability import score


def test_methodology_pipeline_end_to_end(bank_grid):
    """PrIM workload -> bank-parallel run -> HLO census -> roofline ->
    KT1-3 suitability -> Fig-4 comparison, all consistent."""
    mod = prim.WORKLOADS["VA"]
    inputs = mod.make_inputs(1 << 16, jax.random.PRNGKey(0))

    # 1. bank-parallel execution matches the oracle
    got = mod.run_pim(bank_grid, **inputs)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(mod.ref(**inputs)))

    # 2. characterization: streaming add is memory-bound on the TPU...
    compiled = jax.jit(mod.ref).lower(inputs["a"], inputs["b"]).compile()
    an = analyze_hlo(compiled.as_text())
    rep = roofline_from_analysis(an, name="va", n_chips=1,
                                 model_flops=float(inputs["a"].size))
    assert rep.dominant == "memory"

    # 3. ...and PIM-suitable on the UPMEM machine (KT1-3)
    suit = score(an, name="va", machine="upmem_2556")
    assert suit.pim_suitable

    # 4. the Fig-4 model agrees: VA beats CPU and GPU on 2556 DPUs
    cmp = compare(mod.counts(mod.REF_N))
    assert cmp.speedup_vs_cpu_2556 > 10
    assert cmp.speedup_vs_gpu_2556 > 1

    # 5. and a compute-dense workload is correctly NOT suitable
    a = jnp.zeros((512, 512), jnp.float32)
    an2 = analyze_hlo(jax.jit(lambda x: x @ x).lower(a).compile().as_text())
    assert not score(an2, name="mm", machine="upmem_2556").pim_suitable


def test_train_then_serve_roundtrip(tmp_path):
    """Train a few steps, checkpoint, restore, serve greedily — the whole
    LM substrate in one flow."""
    from repro.configs import REDUCED
    from repro.configs.shapes import ShapeConfig
    from repro.models import Shardings
    from repro.serve import Request, ServeEngine
    from repro.train import (HParams, LoopConfig, TrainLoop, restore)

    cfg = REDUCED["starcoder2-7b"]
    shd = Shardings(None)
    loop = TrainLoop(cfg, ShapeConfig("t", 32, 2, "train"), shd,
                     HParams(warmup_steps=2, total_steps=20),
                     LoopConfig(total_steps=6, ckpt_every=3,
                                ckpt_dir=str(tmp_path), log_every=3))
    state = loop.run(loop.resume_or_init())
    assert state.step == 6

    tree = restore(str(tmp_path), 6, {"params": state.params,
                                      "opt": state.opt})
    engine = ServeEngine(cfg, tree["params"], batch_slots=2, max_len=48,
                         shd=shd)
    done = engine.serve([Request(0, jnp.arange(5, dtype=jnp.int32), 4),
                         Request(1, jnp.arange(7, dtype=jnp.int32), 4)])
    assert len(done) == 2
    assert all(len(r.out_tokens) == 4 for r in done)
