"""Model-layer equivalence tests — the numerics that make the zoo correct.

The decode-vs-full-forward equivalence is the strongest integration
invariant: prefill + N greedy decode steps must reproduce the logits of one
full forward over the same tokens (per-family: dense/SWA, SSM, enc-dec)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# compile-bound: the whole arch zoo retraces here; tier-1 skips by default
pytestmark = pytest.mark.slow

from repro.configs import REDUCED
from repro.models import Shardings, forward, init_cache, init_params
from repro.models import layers as L
from repro.models.config import ModelConfig

SHD = Shardings(None)


# --------------------------------------------------------------------- #
# attention building blocks
# --------------------------------------------------------------------- #

def test_flash_equals_plain():
    cfg = REDUCED["llama3-405b"]
    b, s, h, hd = 2, 64, 4, 16
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, hd))
    import dataclasses
    cfg8 = dataclasses.replace(cfg, q_chunk=8, kv_chunk=16)
    from repro.models.transformer import _plain_attention
    got = L.flash_attention(q, k, v, cfg8, SHD, causal=True)
    want = _plain_attention(q, k, v, cfg8, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_sliding_window():
    import dataclasses
    cfg = dataclasses.replace(REDUCED["mixtral-8x7b"], sliding_window=16,
                              q_chunk=8, kv_chunk=8)
    b, s, h, hd = 1, 64, 2, 8
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    from repro.models.transformer import _plain_attention
    got = L.flash_attention(q, k, v, cfg, SHD, causal=True)
    want = _plain_attention(q, k, v, cfg, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rope_rotation_preserves_norm():
    cfg = REDUCED["llama3-405b"]
    pos = jnp.arange(8)[None].repeat(2, 0)
    sin, cos = L.rope_sincos(pos, cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, cfg.hd))
    y = L.apply_rope(x, sin, cos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_mrope_reduces_to_rope_for_text():
    """When t==h==w (text tokens), M-RoPE must equal 1-D RoPE on the first
    2/3... actually on ALL sections (same positions per stream)."""
    cfg = REDUCED["qwen2-vl-72b"]
    b, s = 2, 8
    pos = jnp.arange(s)[None].repeat(b, 0)
    mpos = jnp.broadcast_to(pos[None], (3, b, s))
    sin_m, cos_m = L.rope_sincos(mpos, cfg)
    import dataclasses
    cfg1 = dataclasses.replace(cfg, rope="rope")
    sin_1, cos_1 = L.rope_sincos(pos, cfg1)
    np.testing.assert_allclose(np.asarray(sin_m), np.asarray(sin_1),
                               rtol=1e-6)


# --------------------------------------------------------------------- #
# decode == full forward (per family)
# --------------------------------------------------------------------- #

DECODE_EQUIV_ARCHS = ["llama3-405b", "starcoder2-7b", "granite-3-8b",
                      "rwkv6-3b", "deepseek-coder-33b"]


@pytest.mark.parametrize("name", DECODE_EQUIV_ARCHS)
def test_decode_matches_full_forward(name):
    cfg = REDUCED[name]
    b, s_pre, s_tot = 2, 8, 14
    key = jax.random.PRNGKey(11)
    toks = jax.random.randint(key, (b, s_tot), 0, cfg.vocab_size)
    params = init_params(key, cfg, SHD)

    full_logits, _, _ = forward(params, cfg, SHD, tokens=toks)

    cache = init_cache(cfg, b, 32, SHD)
    _, cache, _ = forward(params, cfg, SHD, tokens=toks[:, :s_pre],
                          cache=cache)
    dec = []
    for t in range(s_pre, s_tot):
        lg, cache, _ = forward(params, cfg, SHD, tokens=toks[:, t:t + 1],
                               cache=cache)
        dec.append(lg[:, 0])
    got = jnp.stack(dec, axis=1)                 # (b, s_tot-s_pre, V)
    want = full_logits[:, s_pre:s_tot]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_full_forward_jamba(monkeypatch):
    """Hybrid (mamba+attn+moe): states must carry across prefill/decode.
    Capacity-dropping legitimately differs between prefill widths (standard
    GShard semantics), so make capacity non-binding — then decode must be
    EXACT (it was 0.82-correlated before isolating the drops)."""
    monkeypatch.setattr(L, "CAPACITY_FACTOR", 8.0)
    cfg = REDUCED["jamba-1.5-large-398b"]
    b, s_pre, s_tot = 2, 8, 12
    key = jax.random.PRNGKey(12)
    toks = jax.random.randint(key, (b, s_tot), 0, cfg.vocab_size)
    params = init_params(key, cfg, SHD)
    full_logits, _, _ = forward(params, cfg, SHD, tokens=toks)
    cache = init_cache(cfg, b, 32, SHD)
    _, cache, _ = forward(params, cfg, SHD, tokens=toks[:, :s_pre],
                          cache=cache)
    dec = []
    for t in range(s_pre, s_tot):
        lg, cache, _ = forward(params, cfg, SHD, tokens=toks[:, t:t + 1],
                               cache=cache)
        dec.append(lg[:, 0])
    got = jnp.stack(dec, axis=1)
    want = full_logits[:, s_pre:s_tot]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_ring_cache_sliding_window_decode():
    """Mixtral-reduced (window 16): decoding past the window must match a
    full forward (which masks beyond the window) despite ring overwrite."""
    import dataclasses
    cfg = dataclasses.replace(REDUCED["mixtral-8x7b"], n_experts=0, top_k=0)
    b, s_tot = 1, 40   # window is 16 << 40
    key = jax.random.PRNGKey(13)
    toks = jax.random.randint(key, (b, s_tot), 0, cfg.vocab_size)
    params = init_params(key, cfg, SHD)
    full_logits, _, _ = forward(params, cfg, SHD, tokens=toks)
    cache = init_cache(cfg, b, s_tot, SHD)  # ring width = window = 16
    _, cache, _ = forward(params, cfg, SHD, tokens=toks[:, :8], cache=cache)
    dec = []
    for t in range(8, s_tot):
        lg, cache, _ = forward(params, cfg, SHD, tokens=toks[:, t:t + 1],
                               cache=cache)
        dec.append(lg[:, 0])
    got = jnp.stack(dec, axis=1)
    want = full_logits[:, 8:s_tot]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_whisper_cross_attention_cache():
    cfg = REDUCED["whisper-tiny"]
    b = 2
    key = jax.random.PRNGKey(14)
    enc = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                            jnp.float32)
    toks = jax.random.randint(key, (b, 10), 0, cfg.vocab_size)
    params = init_params(key, cfg, SHD)
    full_logits, _, _ = forward(params, cfg, SHD, tokens=toks,
                                encoder_embeds=enc)
    cache = init_cache(cfg, b, 16, SHD)
    _, cache, _ = forward(params, cfg, SHD, tokens=toks[:, :6],
                          encoder_embeds=enc, cache=cache)
    dec = []
    for t in range(6, 10):
        lg, cache, _ = forward(params, cfg, SHD, tokens=toks[:, t:t + 1],
                               cache=cache)   # no encoder: uses cached K/V
        dec.append(lg[:, 0])
    got = jnp.stack(dec, axis=1)
    want = full_logits[:, 6:10]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------- #
# vocab padding
# --------------------------------------------------------------------- #

def test_vocab_padding_masked():
    cfg = REDUCED["granite-3-8b"]
    assert cfg.padded_vocab > cfg.vocab_size        # 515 -> 640
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    toks = jnp.zeros((1, 4), jnp.int32)
    logits, _, _ = forward(params, cfg, SHD, tokens=toks)
    pads = np.asarray(logits, np.float32)[..., cfg.vocab_size:]
    assert (pads <= -1e29).all()


def test_moe_aux_loss_bounds():
    cfg = REDUCED["mixtral-8x7b"]
    params = init_params(jax.random.PRNGKey(0), cfg, SHD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    _, _, aux = forward(params, cfg, SHD, tokens=toks)
    # perfectly balanced -> 1.0 per moe layer; capacity blow-ups explode it
    n_moe = cfg.n_layers
    assert 0.5 * n_moe < float(aux) < 4.0 * n_moe


# --------------------------------------------------------------------- #
# §Perf optimizations: numerical-equivalence regressions
# --------------------------------------------------------------------- #

def test_remat_group_equivalence_f32():
    """remat_group is a pure memory/recompute trade: forward and grads
    must be EXACT in f32 (EXPERIMENTS.md §Perf llama3 iteration)."""
    import dataclasses
    from repro.models import lm_loss
    base = dataclasses.replace(REDUCED["granite-3-8b"], n_layers=4,
                               dtype="float32")
    g2 = dataclasses.replace(base, remat_group=2)
    key = jax.random.PRNGKey(0)
    params = init_params(key, base, SHD)
    toks = jax.random.randint(key, (2, 16), 0, base.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (2, 16), 0,
                                base.vocab_size)

    def loss(cfg):
        def f(p):
            lg, _, aux = forward(p, cfg, SHD, tokens=toks)
            return lm_loss(lg, labels, aux)
        return jax.value_and_grad(f)(params)

    l1, g1 = loss(base)
    l2, gg = loss(g2)
    assert float(l1) == float(l2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rwkv_chunked_equals_per_token():
    """The chunked-parallel wkv (MXU reformulation, §Perf rwkv iteration)
    must match the per-token recurrence (decode path) exactly."""
    import dataclasses
    from repro.models import init_cache
    cfg = dataclasses.replace(REDUCED["rwkv6-3b"], dtype="float32")
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg, SHD)
    b, s = 2, 24                       # 24 % WKV_CHUNK(8) == 0
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, SHD, tokens=toks)   # chunked path
    cache = init_cache(cfg, b, 32, SHD)
    _, cache, _ = forward(params, cfg, SHD, tokens=toks[:, :8],
                          cache=cache)
    dec = []
    for t in range(8, s):              # per-token recurrence path
        lg, cache, _ = forward(params, cfg, SHD, tokens=toks[:, t:t + 1],
                               cache=cache)
        dec.append(lg[:, 0])
    got = jnp.stack(dec, 1)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(full[:, 8:s]),
                               rtol=1e-4, atol=1e-4)
