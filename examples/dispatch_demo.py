"""Hybrid dispatch demo: plan -> schedule -> execute a mixed workload.

Builds the mixed PrIM pipeline (streaming int phases around a
transpose/rotate middle), lets `repro.dispatch` choose a per-operator
placement over the CPU and the 2556-DPU system, prints the plan and the
coalesced launch/transfer schedule, then actually executes the hybrid plan
in JAX (PIM stages as BankGrid phases, host stages under jit) and checks
the result against the single-device reference.

    PYTHONPATH=src python examples/dispatch_demo.py [--m 512] [--model-m 4096]
"""

import argparse

from repro.core.bank_parallel import BankGrid, make_bank_mesh
from repro.dispatch import workloads
from repro.dispatch.placement import compare_plans, plan
from repro.dispatch.runtime import check_phase_discipline, execute
from repro.dispatch.schedule import make_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=512,
                    help="matrix side for the executed pipeline")
    ap.add_argument("--model-m", type=int, default=4096,
                    help="matrix side for the paper-scale modeled plan")
    args = ap.parse_args()

    # --- model at paper scale: the planner's three-way comparison --------
    g = workloads.mixed_pipeline(m=args.model_m, concrete=False).graph()
    print(f"== modeled at {args.model_m}x{args.model_m} int32 ==")
    for name, p in compare_plans(g).items():
        print(f"  {name:10s} {p.total_s * 1e3:9.3f}ms  "
              f"devices={'+'.join(p.used_devices)}")
    hybrid = plan(g)
    print()
    print(hybrid.render())
    print()
    print(make_schedule(g, hybrid).render())

    # --- execute the paper-scale placement for real at a reduced size ----
    # (at small sizes the planner rightly keeps everything on the host —
    # launch overhead dominates — so we run the at-scale assignment to
    # exercise both execution faces)
    print(f"\n== executing hybrid plan at {args.m}x{args.m} ==")
    pipe = workloads.mixed_pipeline(m=args.m, concrete=True)
    grid = BankGrid(make_bank_mesh())
    checked = check_phase_discipline(pipe, grid)
    rep = execute(pipe, hybrid, grid)
    print(f"  {checked} bank-local phases verified collective-free")
    print(f"  stage placement: {rep.stage_devices}")
    print(f"  result matches single-device reference: {rep.matches} "
          f"(max |err| = {rep.max_abs_err:.3g})")


if __name__ == "__main__":
    main()
